"""Benchmark harness entry point: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows and writes the same rows
machine-readably to ``BENCH_<module>.json`` (the accumulating perf
trajectory).

  python -m benchmarks.run            # full suite
  python -m benchmarks.run frontier   # one module
Sizes scale with REPRO_BENCH_N (default 600 requests/cell; the paper's
cells are 3,534)."""
from __future__ import annotations

import sys
import time
import traceback

MODULES = ("predictors", "kernels_bench", "decision_core", "hotpath",
           "sweep", "replay", "frontier", "residual", "isolation",
           "batching", "budget", "tier_loss", "ladder", "tails",
           "roofline", "elastic", "chaos", "affinity", "hierarchy")


def main() -> None:
    only = sys.argv[1:] if len(sys.argv) > 1 else None
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"\n### {name}")
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            from benchmarks import common
            common.flush_json(getattr(mod, "FLUSH_AS", name))
            print(f"### {name} done in {time.time()-t0:.0f}s")
        except Exception:
            failures.append(name)
            from benchmarks import common
            common.discard_rows()
            print(f"### {name} FAILED:\n{traceback.format_exc()[-2000:]}")
    if failures:
        print("\nFAILED MODULES:", failures)
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
