"""Goodput under fault campaigns: the chaos harness benchmark.

Every campaign from `repro.serving.faults.CHAOS_SUITES` runs an arm
ladder on ONE built chaos world (same roster, same trained bundle, same
request stream), isolating what each layer of the recovery stack buys:

  * ``clean``       — recovery armed, NO faults: the fault-free ceiling
    (also the overhead reference for ``perf_guard``'s fault-free probe);
  * ``lost``        — the campaign fires with recovery DISARMED: every
    victim's in-flight work is terminally failed. The lost-work floor;
  * ``retry``       — bounded retry/requeue with seeded exponential
    backoff, hedging off;
  * ``retry_hedge`` — retry plus deadline-based hedged re-dispatch and
    the telemetry watchdog. The full stack.

Rows carry goodput/latency next to the lifecycle axes — ``retried``,
``gave_up``, ``hedges``, ``duplicate_tokens``, ``wasted_tokens``,
``quarantines``, ``degraded_decisions`` — plus the fused hot path's
``compiles`` pin: kill/revive/quarantine churn must ride the alive-mask
(one program per pow2 R bucket, never a recompile).

The headline acceptance gate (asserted here, pinned again in
``tests/test_bench_schema.py``): under ``crash_storm``, the full stack
recovers at least 90% of the goodput the lost-work arm gives up,

    g_retry_hedge >= g_lost + 0.9 * (g_clean - g_lost).

``controller_crash`` is the odd one out: the scheduler process itself
dies mid-trace (`simulate_controller_crash`), a fresh engine resumes
from the checkpoint tree, and the row reports whether the completion
set came back bitwise identical to an uninterrupted reference run.

Smoke mode for CI: REPRO_CHAOS_SMOKE=1 trims the cell size while
keeping every campaign and arm, so the artifact schema stays pinned.
"""
from __future__ import annotations

import dataclasses
import os

from .common import csv_row
from repro.core import RBConfig, RouteBalance
from repro.core.decision_jax import bucket_pow2
from repro.serving.cluster import ClusterSim
from repro.serving.faults import (CHAOS_SUITES, chaos_world,
                                  straggler_storm)
from repro.serving.recovery import (RecoveryConfig, arm_recovery,
                                    simulate_controller_crash)
from repro.serving.scenarios import apply_schedule

SMOKE = os.environ.get("REPRO_CHAOS_SMOKE", "") not in ("", "0")
N_CELL = 160 if SMOKE else 420
CAMPAIGNS = ("crash_storm", "correlated_failure", "telemetry_blackout",
             "straggler_storm")
RETRY = RecoveryConfig(hedge=False)
HEDGED = RecoveryConfig()
ARMS = (("lost", None), ("retry", RETRY), ("retry_hedge", HEDGED))


def _campaign(name, tiers):
    if name == "straggler_storm":
        # hedging is a TAIL tool: a few instances slow to a crawl while
        # the fast majority has headroom to absorb the re-dispatches.
        # (Sweeping most of the fleet instead just moves the crunch to
        # the survivors — hedges then add load, not cover.)
        return straggler_storm(tiers, frac=0.25, factor=8.0,
                               duration=10.0)
    return CHAOS_SUITES[name](tiers)


def _cell(run, schedule, recovery):
    run.recovery = recovery
    run.scenario = dataclasses.replace(run.scenario, schedule=schedule)
    reqs = run.requests(N_CELL, seed=0)
    rb = RouteBalance(RBConfig(charge_compute=False), run.bundle(),
                      run.tiers)
    m = run.run_cell(rb, reqs, seed=0)
    return m, rb


def _row(name, m, rb, seen_buckets, extra=""):
    buckets = {bucket_pow2(s) for s, _ in rb.compute_log}
    seen_buckets |= buckets
    compiles = (rb._fused.compile_count()
                if rb._fused is not None else 0)
    # fault churn must never reach XLA: the runner is cached on the
    # bundle, so its compile count is cumulative across arms and must
    # stay one program per pow2 R bucket ever seen
    assert compiles <= len(seen_buckets), (
        "fault churn must not add XLA compiles: "
        f"{compiles} programs for {len(seen_buckets)} R buckets")
    csv_row(
        name,
        m.get("measured_decide_ms_mean", 0.0) * 1e3,
        f"goodput={m['goodput']:.3f}"
        f";tput={m['throughput']:.2f}"
        f";p50_e2e={m['p50_e2e']:.3f}"
        f";p99_e2e={m['p99_e2e']:.3f}"
        f";served={m['n']}"
        f";failed={m['failed']}"
        f";retried={m['retried']}"
        f";gave_up={m.get('gave_up', 0)}"
        f";hedges={m.get('hedges', 0)}"
        f";duplicate_tokens={m.get('duplicate_tokens', 0)}"
        f";wasted_tokens={m['wasted_tokens']}"
        f";quarantines={m.get('quarantines', 0)}"
        f";degraded_decisions={m.get('degraded_decisions', 0)}"
        f";compiles={compiles}"
        f";r_buckets={len(seen_buckets)}"
        + extra)
    return m["goodput"]


def _controller_crash_row(run, seen_buckets):
    """Crash the scheduler mid-trace, resume a fresh engine from the
    checkpoint taken at the crash instant, and report whether the
    completion set is bitwise identical to an uninterrupted run."""
    sched = CHAOS_SUITES["crash_storm"](run.tiers)
    n = min(N_CELL, 160)

    def cell(crash_at=None):
        reqs = run.requests(n, seed=0)
        sim = ClusterSim(run.tiers, run.names, seed=0)
        arm_recovery(sim, HEDGED)
        eng = RouteBalance(RBConfig(charge_compute=False), run.bundle(),
                           run.tiers)
        eng.expected = len(reqs)
        eng.attach(sim)
        holder = {"eng": eng}
        for r in reqs:
            sim.push(r.arrival,
                     lambda t, rr=r: holder["eng"].enqueue(rr, t))
        apply_schedule(sim, sched, seed=run.scenario.seed)
        dropped = [0]
        if crash_at is not None:
            def crash(t):
                tree = holder["eng"].checkpoint_tree()
                dropped[0] = simulate_controller_crash(
                    sim, holder["eng"])
                arm_recovery(sim, HEDGED)
                eng2 = RouteBalance(RBConfig(charge_compute=False),
                                    run.bundle(), run.tiers)
                eng2.resume(sim, tree, reqs)
                holder["eng"] = eng2
            sim.push(crash_at, crash)
        sim.run()
        fp = [(r.rid, r.finish_time, r.tokens_out, r.instance,
               r.failed, r.attempt, r.hedges) for r in reqs]
        served = sum(1 for r in reqs
                     if r.finish_time is not None and not r.failed)
        return fp, served, dropped[0], holder["eng"]

    ref, served_ref, _, _ = cell()
    crash_at = 5.3                       # mid-storm, retries in flight
    got, served, dropped, eng = cell(crash_at=crash_at)
    identical = int(got == ref)
    assert identical, "crash/restore diverged from uninterrupted run"
    assert dropped > 0, "controller crash dropped no scheduler events"
    csv_row(
        "chaos/controller_crash_restore",
        0.0,
        f"identical={identical}"
        f";crash_at={crash_at:g}"
        f";dropped_events={dropped}"
        f";served={served}"
        f";served_ref={served_ref}"
        f";n={n}")


def main():
    sc = chaos_world()
    run = sc.build(dataset_n=300 if SMOKE else 600)
    bundle = run.bundle()
    base_scenario = run.scenario
    # deterministic warm-up outside the measured cells: compile the
    # pow2 R buckets the windowed cells reach (runner cached on bundle)
    warm = RouteBalance(RBConfig(charge_compute=False), bundle,
                        run.tiers)
    warm.sim = ClusterSim(run.tiers, run.names, seed=0)
    warm_reqs = run.requests(64, seed=99)
    seen_buckets = set()
    for R in (8, 16, 32, 64):
        warm._decide_core(warm_reqs[:R])
        seen_buckets.add(bucket_pow2(R))
    try:
        m, rb = _cell(run, (), RecoveryConfig())
        g_clean = _row("chaos/clean", m, rb, seen_buckets)
        goodput = {}
        for camp in CAMPAIGNS:
            sched = _campaign(camp, run.tiers)
            for arm, recovery in ARMS:
                m, rb = _cell(run, sched, recovery)
                goodput[arm] = _row(f"chaos/{camp}_{arm}", m, rb,
                                    seen_buckets)
            # recovered_frac is only meaningful when the campaign cost
            # the lost-work arm real goodput; below the noise floor the
            # stack has nothing to recover
            denom = g_clean - goodput["lost"]
            rec = ((goodput["retry_hedge"] - goodput["lost"]) / denom
                   if denom > 0.02 * g_clean else 1.0)
            csv_row(f"chaos/{camp}_recovery", 0.0,
                    f"recovered_frac={rec:.3f}"
                    f";g_clean={g_clean:.3f}"
                    f";g_lost={goodput['lost']:.3f}"
                    f";g_retry_hedge={goodput['retry_hedge']:.3f}")
            if camp == "crash_storm":
                # the headline acceptance gate: the full stack
                # recovers >= 90% of the goodput lost work costs
                assert goodput["retry_hedge"] >= (
                    goodput["lost"]
                    + 0.9 * (g_clean - goodput["lost"]) - 1e-9), (
                    "retry+hedge recovered too little goodput: "
                    f"{goodput['retry_hedge']:.3f} vs clean "
                    f"{g_clean:.3f} / lost {goodput['lost']:.3f}")
        _controller_crash_row(run, seen_buckets)
    finally:
        run.scenario = base_scenario
        run.recovery = base_scenario.recovery


if __name__ == "__main__":
    from .common import flush_json
    main()
    flush_json("chaos")
