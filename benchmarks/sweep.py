"""Cluster-scale frontier sweep on the fused backend: (weight vector x
arrival rate x scenario) — the repo's reproduction of the paper's
three-way quality-cost-throughput frontier and its high-load separation
plots (§6.2/§6.5), run on worlds from `repro.serving.scenarios`.

Each cell is a full `ClusterSim` run under
``RBConfig(decision_backend="fused")``: one scenario (roster + composite
workload + perturbation schedule), one weight preset, one load multiple
of the scenario's nominal rate. Rows carry p50/p99 end-to-end latency,
per-request cost, measured decision time with a host/stage/device/sync
breakdown (mean us per fired batch, from ``FusedHotPath.stats`` — see
``benchmarks.hotpath`` for the column semantics) plus delta-telemetry
counters, goodput (SLO-bounded throughput) and a per-weight-config
parity probe — ``parity`` is fused-vs-staged-jax agreement and
``parity_np`` fused-vs-numpy; both are exact-1.0 guarantees since the
epsilon-quantized tie-break (`repro.core.scoring`) and gated at 1.0 in
CI — landing in ``BENCH_sweep.json`` via benchmarks.run.

The ``sweep/hyperscale_*`` family promotes the 16-tier x 128-instance
scenario into the committed artifact on the decision-megakernel backend
(`RBConfig(decision_backend="megakernel")`): a smaller weights x loads
grid at CI-nightly sizing, carrying the same decide_ms_per_req +
per-stage breakdown columns, with the parity probes anchored on the
megakernel.

Smoke mode for CI: REPRO_SWEEP_SMOKE=1 trims the grid (small rosters,
low n) to under a couple of minutes while keeping the full
3-weights x 3-loads x 2-scenarios shape (plus the hyperscale family)
so the artifact schema stays pinned.
"""
from __future__ import annotations

import os

import numpy as np

from .common import N_REQ, csv_row, tenant_cols
from repro.core import PRESETS, RBConfig, RouteBalance
from repro.serving.cluster import ClusterSim
from repro.serving.scenarios import get_scenario, randomize_telemetry

SMOKE = os.environ.get("REPRO_SWEEP_SMOKE", "") not in ("", "0")
WEIGHTS = (("quality", PRESETS["quality"]),
           ("uniform", PRESETS["uniform"]),
           ("cost", PRESETS["cost"]))
LOADS = (0.5, 1.0, 2.0)            # multiples of the scenario's rate
SCENES = ("paper", "multitenant") if SMOKE else ("paper", "cluster")
N_CELL = 48 if SMOKE else N_REQ
DATASET_N = 300 if SMOKE else 1500
# the hyperscale family: the 16-tier x 128-instance scenario on the
# decision megakernel backend — a smaller (weights x loads) grid at
# CI-nightly sizing, since each cell runs the full 128-instance sim
HYPER_WEIGHTS = (("uniform", PRESETS["uniform"]),
                 ("quality", PRESETS["quality"]))
HYPER_LOADS = (0.5, 1.0)
HYPER_N_CELL = 48 if SMOKE else 192
HYPER_DATASET_N = 300 if SMOKE else 800


def _parity_probe(run, bundle, weights, R=16, seed=7,
                  cell_backend="fused"):
    """Probe batch under THIS cell's weight vector on a randomly-loaded
    roster. Returns (cell-backend-vs-staged-jax agreement,
    cell-backend-vs-numpy agreement); both are exact-parity guarantees
    under the epsilon-quantized tie-break and gate the artifact at
    1.0 (the hyperscale family anchors on the megakernel backend)."""
    reqs = run.requests(R, seed=seed)[:R]
    for r in reqs:
        r.arrival = 0.0
    picks = {}
    for be in ("numpy", "jax", cell_backend):
        rb = RouteBalance(
            RBConfig(weights=weights, decision_backend=be), bundle,
            run.tiers)
        rb.sim = randomize_telemetry(
            ClusterSim(run.tiers, run.names, seed=0), seed)
        instances, choice, _ = rb._decide_core(reqs)
        picks[be] = [instances[int(i)].iid for i in choice]
    agree = {be: float(np.mean([a == b for a, b in
                                zip(picks[be], picks[cell_backend])]))
             for be in ("jax", "numpy")}
    return agree["jax"], agree["numpy"]


def _cell_row(scene, run, sc, rb, m, wname, scale, parity, parity_np):
    lam = sc.lam * scale
    # per-fired-batch decision breakdown over the whole cell
    # (FusedHotPath.stats is a per-cell window: for_bundle resets it
    # when the cell's scheduler first decides)
    st = rb._fused.stats if rb._fused is not None else {}
    calls = max(st.get("calls", 0), 1)
    bd = {k: st.get(k, 0.0) / calls * 1e6
          for k in ("host_s", "stage_s", "dispatch_s", "device_s",
                    "sync_s")}
    csv_row(
        f"sweep/{scene}_{wname}_x{scale}",
        m.get("measured_decide_ms_mean", 0.0) * 1e3,
        f"lam={lam:.1f}"
        f";I={run.n_instances}"
        f";q={m['quality']:.3f}"
        f";p50_e2e={m['p50_e2e']:.3f}"
        f";p99_e2e={m['p99_e2e']:.3f}"
        f";cost={m['cost_per_req']:.3e}"
        f";tput={m['throughput']:.2f}"
        f";goodput={m['goodput']:.2f}"
        f";failed={m['failed']}"
        f";decide_ms_per_req="
        f"{m.get('measured_decide_ms_per_req', 0.0):.3f}"
        f";host_us={bd['host_s']:.1f}"
        f";stage_us={bd['stage_s']:.1f}"
        f";dispatch_us={bd['dispatch_s']:.1f}"
        f";device_us={bd['device_s']:.1f}"
        f";sync_us={bd['sync_s']:.1f}"
        f";full_reseeds={st.get('full_reseed', 0)}"
        f";delta_syncs={st.get('delta_sync', 0)}"
        f";carries={st.get('carry', 0)}"
        f";parity={parity:.3f}"
        f";parity_np={parity_np:.3f}"
        + tenant_cols(m))


def _hyperscale_cells():
    """The 16-tier x 128-instance scenario on the megakernel backend:
    the scale point where per-request decision cost must stay flat
    (amortized batched scoring) even with a 128-wide instance axis."""
    sc = get_scenario("hyperscale")
    run = sc.build(dataset_n=HYPER_DATASET_N)
    bundle = run.bundle()
    warm_reqs = run.requests(128, seed=99)
    for wname, w in HYPER_WEIGHTS:
        parity, parity_np = _parity_probe(
            run, bundle, w, cell_backend="megakernel")
        warm = RouteBalance(
            RBConfig(weights=w, decision_backend="megakernel"),
            bundle, run.tiers)
        warm.sim = ClusterSim(run.tiers, run.names, seed=0)
        for R in (8, 16, 32, 64, 128):
            warm._decide_core(warm_reqs[:R])
        for scale in HYPER_LOADS:
            reqs = run.requests(HYPER_N_CELL, lam_scale=scale, seed=0)
            rb = RouteBalance(
                RBConfig(weights=w, decision_backend="megakernel"),
                bundle, run.tiers)
            m = run.run_cell(rb, reqs, seed=0)
            _cell_row("hyperscale", run, sc, rb, m, wname, scale,
                      parity, parity_np)


def main():
    for scene in SCENES:
        sc = get_scenario(scene)
        run = sc.build(dataset_n=DATASET_N)
        bundle = run.bundle()
        warm_reqs = run.requests(128, seed=99)
        for wname, w in WEIGHTS:
            parity, parity_np = _parity_probe(run, bundle, w)
            # deterministic warm-up: compile every pow2 R bucket the
            # overloaded cells can reach (backlog pushes batch sizes up
            # through 128) so XLA compiles land outside the measured
            # cells — the fused runner is cached on the bundle per
            # weight config, so the grid below reuses these programs
            warm = RouteBalance(
                RBConfig(weights=w, decision_backend="fused"),
                bundle, run.tiers)
            warm.sim = ClusterSim(run.tiers, run.names, seed=0)
            for R in (8, 16, 32, 64, 128):
                warm._decide_core(warm_reqs[:R])
            for scale in LOADS:
                reqs = run.requests(N_CELL, lam_scale=scale, seed=0)
                rb = RouteBalance(
                    RBConfig(weights=w, decision_backend="fused"),
                    bundle, run.tiers)
                m = run.run_cell(rb, reqs, seed=0)
                _cell_row(scene, run, sc, rb, m, wname, scale,
                          parity, parity_np)
    _hyperscale_cells()


if __name__ == "__main__":
    from .common import flush_json
    main()
    flush_json("sweep")
