"""§Roofline: the three-term analysis per (arch x shape) from the
compiled dry-run artifacts (runs/dryrun/*.json).

  compute    = HLO_FLOPs_per_device / peak_FLOPs
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_link_bytes_per_device / link_bw

HLO terms come from the trip-count-aware walker (benchmarks/hlo_cost.py);
xla's own cost_analysis undercounts scan bodies (see EXPERIMENTS.md).
MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode), with N =
active params. The roofline fraction = ideal time (max of useful-compute
and irreducible-bytes terms) / bounded time (max of the three terms).
"""
from __future__ import annotations

import glob
import json
import os
import pathlib
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config                        # noqa: E402
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16  # noqa: E402
from repro.models.config import SHAPES                      # noqa: E402

RUNS = pathlib.Path(__file__).resolve().parents[1] / "runs" / "dryrun"


def model_flops(cfg, shape) -> float:
    n = cfg.param_counts()["active"]
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def ideal_bytes(cfg, shape, n_chips) -> float:
    """Irreducible per-device HBM traffic per step."""
    n_total = cfg.param_counts()["total"]
    pbytes = 2.0 * n_total
    if shape.kind == "train":
        # read params (fwd+bwd ~2x), write grads, touch opt moments (f32)
        return (3 * pbytes + 8.0 * n_total) / n_chips
    if shape.kind == "prefill":
        return pbytes / n_chips
    kv = 0.0
    for blk in cfg.layer_types:
        kv += blk.cache_len(shape.seq_len) * cfg.n_kv_heads * cfg.hd * 2 * 2
    kv *= shape.global_batch
    if cfg.ssm_state:
        kv += (cfg.n_layers * shape.global_batch * cfg.ssm_heads
               * cfg.ssm_head_dim * cfg.ssm_state * 4)
    return (pbytes + kv) / n_chips


def analyze_cell(path: str):
    d = json.load(open(path))
    if d["status"] != "ok":
        return dict(arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
                    status=d["status"], reason=d.get("reason", ""))
    cfg = get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    chips = d["n_chips"]
    t_comp = d["flops_per_device"] / PEAK_FLOPS_BF16
    t_mem = d["hbm_bytes_per_device"] / HBM_BW
    t_coll = d["collective_link_bytes_per_device"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) / chips
    t_ideal = max(mf / PEAK_FLOPS_BF16,
                  ideal_bytes(cfg, shape, chips) / HBM_BW)
    bound = max(terms.values())
    return dict(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"], status="ok",
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        dominant=dom, model_flops_per_dev=mf,
        flops_ratio=mf / max(d["flops_per_device"], 1.0),
        t_ideal=t_ideal, roofline_fraction=t_ideal / max(bound, 1e-12),
        peak_gb=d["peak_bytes_per_device"] / 1e9,
        microbatches=d.get("meta", {}).get("microbatches"),
    )


def main(mesh: str = "16x16", tag: str = ""):
    rows = []
    pat = f"*__{mesh}{('__' + tag) if tag else ''}.json"
    for f in sorted(glob.glob(str(RUNS / pat))):
        if tag == "" and "__ovr" in f:
            continue
        rows.append(analyze_cell(f))
    print("# roofline (%s): arch,shape,t_comp_s,t_mem_s,t_coll_s,"
          "dominant,MODEL/HLO_flops,roofline_frac" % mesh)
    out_csv = RUNS.parent / f"roofline_{mesh}.csv"
    with open(out_csv, "w") as fh:
        fh.write("arch,shape,status,t_compute,t_memory,t_collective,"
                 "dominant,flops_ratio,roofline_fraction,peak_gb\n")
        for r in rows:
            if r["status"] != "ok":
                fh.write(f"{r['arch']},{r['shape']},{r['status']},,,,,,,\n")
                print(f"roofline/{r['arch']}__{r['shape']},0.0,"
                      f"status={r['status']}")
                continue
            fh.write(f"{r['arch']},{r['shape']},ok,{r['t_compute']:.4f},"
                     f"{r['t_memory']:.4f},{r['t_collective']:.4f},"
                     f"{r['dominant']},{r['flops_ratio']:.3f},"
                     f"{r['roofline_fraction']:.3f},{r['peak_gb']:.2f}\n")
            print(f"roofline/{r['arch']}__{r['shape']},0.0,"
                  f"comp={r['t_compute']:.3f};mem={r['t_memory']:.3f};"
                  f"coll={r['t_collective']:.3f};dom={r['dominant']};"
                  f"ratio={r['flops_ratio']:.2f};"
                  f"frac={r['roofline_fraction']:.3f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    a = ap.parse_args()
    main(a.mesh, a.tag)
