"""Table 4: RouteBalance off-instance-residual decomposition vs load
(compute / batch wait / stats fetch; sub-linear growth, amortizing
decision compute)."""
from __future__ import annotations

from .common import context, csv_row, rb_cell
from repro.core import PRESETS


def main():
    ctx = context()
    rows = []
    for lam in (6.0, 12.0, 18.0, 24.0, 30.0):
        m = rb_cell(ctx, PRESETS["uniform"], lam)
        rows.append((lam, m))
        csv_row(f"residual/lam{lam:.0f}",
                m["measured_decide_ms_mean"] * 1e3,
                f"compute={m['residual_compute']*1e3:.1f}ms;"
                f"wait={m['residual_batch_wait']*1e3:.1f}ms;"
                f"stats={m['residual_stats_fetch']*1e3:.2f}ms;"
                f"total={m['mean_residual']*1e3:.1f}ms;"
                f"e2e={m['mean_e2e']:.2f}s;"
                f"batch={m.get('mean_batch_size', 0):.1f}")
    return rows


if __name__ == "__main__":
    main()
