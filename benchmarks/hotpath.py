"""Full hot-path latency: staged numpy vs staged jax vs the fused
single-dispatch program vs the Pallas decision megakernel
(`repro.kernels.decision_megakernel` — one kernel for KNN top-k ->
packed GBM -> admission -> LPT greedy scan; megakernel rows carry
`vs_fused` + `agree` so perf_guard can gate parity-or-better).

One "decision" here is everything between batch formation and dispatch —
token padding, the sentence encoder, the batched KNN lookup, the
per-tier TPOT heads, Eq. 2 admission, LPT ordering and the dead-reckoned
greedy pass — i.e. exactly what `RouteBalance._decide_core` runs per
fired batch (the paper's ~32 ms/batch headline, §6.3). The staged
backends pay one device dispatch + host round trip per stage; the fused
backend (`repro.core.hotpath`) pays one dispatch total with
device-resident constants and state.

Grid: (R, I) up to R=512, I=128 (instance pools are the paper's 4 tiers
proportionally scaled). Interleaved min-of-N timing so CPU drift doesn't
bias one backend. Rows land in BENCH_hotpath.json via the benchmarks.run
JSON emission (or the __main__ block when run directly). Smoke mode for
CI: REPRO_HOTPATH_SMOKE=1 trims the grid to seconds (a subset of the
full grid, so `benchmarks.perf_guard` can diff smoke rows against the
committed artifact).

Fused rows carry a host/stage/device/sync breakdown (mean us/call over
the timed reps, from `FusedHotPath.stats`): `stage_us` is the gather
into the preallocated staging buffers, `host_us` is all host-side work
up to dispatch (staging + telemetry delta assembly), `dispatch_us` is
the jitted-call dispatch, `device_us` is the wait on the device program
at fetch, and `sync_us` is the device->host result copy. Since the
host-path rebuild (SoA ingest + delta telemetry), `host_us` should be
microseconds — the paper's "router overhead" is all `device_us`.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from .common import context, csv_row, make_requests
from repro.core import RBConfig, RouteBalance
from repro.serving.cluster import ClusterSim

SMOKE = os.environ.get("REPRO_HOTPATH_SMOKE", "") not in ("", "0")
GRID = (((8, 13), (16, 13)) if SMOKE else
        ((8, 13), (16, 13), (64, 13), (256, 13), (256, 52), (256, 128),
         (512, 128)))
BACKENDS = ("numpy", "jax", "fused", "megakernel")


def scaled_pool(tiers, I):
    """The paper's 4-tier pool proportionally scaled to I instances."""
    counts = np.array([t.n_instances for t in tiers], float)
    n = np.maximum(np.round(counts * I / counts.sum()).astype(int), 1)
    while n.sum() > I:
        n[np.argmax(n)] -= 1
    while n.sum() < I:
        n[np.argmin(n)] += 1
    return [dataclasses.replace(t, n_instances=int(k))
            for t, k in zip(tiers, n)]


def _bench_cell(ctx, R, I, reps):
    tiers = (ctx["tiers"] if I == sum(t.n_instances for t in ctx["tiers"])
             else scaled_pool(ctx["tiers"], I))
    batch = make_requests(ctx["ds"], "test", np.zeros(R))
    rng = np.random.default_rng(0)
    budgets = np.where(rng.uniform(size=R) < 0.5,
                       rng.uniform(1e-5, 3e-4, R), np.nan)
    for r, b in zip(batch, budgets):
        r.budget = None if np.isnan(b) else float(b)
    rbs = {}
    picks = {}
    from repro.serving.scenarios import randomize_telemetry
    for be in BACKENDS:
        # same load per backend (seeded shared fixture)
        sim = randomize_telemetry(ClusterSim(tiers, ctx["names"], seed=0),
                                  seed=1)
        tel = sim.tel
        rb = RouteBalance(RBConfig(decision_backend=be), ctx["bundle"],
                          tiers)
        rb.sim = sim
        rb._decide_core(batch)                  # compile + warm
        # repeated calls are parity-safe by construction now: the fused
        # runner's carried mirror equals a fresh host read of `tel`
        # (reseed-per-batch semantics; telemetry hasn't moved between
        # calls, so the carry arm is exact)
        instances, choice, _ = rb._decide_core(batch)
        picks[be] = [instances[int(i)].iid for i in choice]
        rbs[be] = rb
    # fraction of requests on which every backend picked the same
    # instance as the numpy reference
    agree = float(np.mean([
        all(picks[be][r] == picks["numpy"][r] for be in BACKENDS)
        for r in range(R)]))
    ts = {be: [] for be in BACKENDS}
    s0 = dict(rbs["fused"]._fused.stats)        # breakdown window start
    for _ in range(reps):                       # interleaved timing
        for be, rb in rbs.items():
            t0 = time.perf_counter()
            rb._decide_core(batch)
            ts[be].append(time.perf_counter() - t0)
    s1 = rbs["fused"]._fused.stats
    breakdown = {k: (s1[k] - s0[k]) / reps * 1e6
                 for k in ("host_s", "stage_s", "dispatch_s", "device_s",
                           "sync_s")}           # mean us/call over reps
    best = {be: min(v) for be, v in ts.items()}
    # per-rep paired differences share ambient (CPU-frequency, co-tenant)
    # conditions, so their median is far more noise-robust than the
    # difference of the mins
    paired = {be: float(np.median(np.array(ts["jax"]) - np.array(v)))
              for be, v in ts.items()}
    return best, paired, agree, breakdown


def main():
    ctx = context()
    margins = {}
    for R, I in GRID:
        reps = 10 if R >= 256 else 16
        best, paired, agree, bd = _bench_cell(ctx, R, I, reps)
        margins[(R, I)] = paired["fused"] * 1e3
        for be in BACKENDS:
            extra = ""
            if be != "numpy":
                extra = f";speedup_vs_numpy={best['numpy']/best[be]:.2f}x"
            if be == "megakernel":
                # the one-kernel decision vs the fused-XLA pipeline:
                # the perf_guard parity-or-better gate's raw material
                extra += (f";speedup_vs_jax={best['jax']/best[be]:.2f}x"
                          f";vs_fused={best['fused']/best[be]:.2f}x"
                          f";agree={agree:.3f}")
            if be == "fused":
                extra += (f";speedup_vs_jax={best['jax']/best[be]:.2f}x"
                          f";margin_vs_jax_ms={paired['fused']*1e3:.2f}"
                          f";agree={agree:.3f}"
                          f";host_us={bd['host_s']:.1f}"
                          f";stage_us={bd['stage_s']:.1f}"
                          f";dispatch_us={bd['dispatch_s']:.1f}"
                          f";device_us={bd['device_s']:.1f}"
                          f";sync_us={bd['sync_s']:.1f}")
            csv_row(f"hotpath/{be}_R{R}_I{I}", best[be] * 1e6,
                    f"per_req_us={best[be]/R*1e6:.1f}{extra}")
    if not SMOKE:
        print(f"# fused margin over staged jax: "
              f"{margins.get((64, 13), 0):.1f} ms/batch at R=64,I=13 -> "
              f"{max(m for (R, _), m in margins.items() if R >= 256):.1f}"
              f" ms/batch at R>=256")


if __name__ == "__main__":
    from .common import flush_json
    main()
    flush_json("hotpath")
