"""Shared benchmark context: world, dataset, tiers, trained estimator
bundle — built once per process. Cell sizes scale with REPRO_BENCH_N
(requests per cell; default 600 — the paper's cells use 3,534, reachable
with REPRO_BENCH_N=3534 REPRO_BENCH_DATASET=18608)."""
from __future__ import annotations

import functools
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (EngineConfig, EstimatorBundle, PRESETS,          # noqa: E402
                        RBConfig, RouteBalance, ServingEngine,
                        make_policy, make_requests, run_cell)
from repro.core.dispatchers import RandomDispatch, RoundRobin, \
    ShortestQueue                                                        # noqa: E402
from repro.core.policies import RouterDispatchPolicy                    # noqa: E402
from repro.core.routers import AvengersProRouter, BestRouteRouter, \
    PassthroughRouter                                                    # noqa: E402
from repro.serving.tiers import paper_pool_tiers                        # noqa: E402
from repro.serving.workload import make_arrivals                        # noqa: E402
from repro.serving.world import build_dataset, paper_world              # noqa: E402

N_REQ = int(os.environ.get("REPRO_BENCH_N", "600"))
N_DATASET = int(os.environ.get("REPRO_BENCH_DATASET", "6000"))


@functools.lru_cache(maxsize=1)
def context():
    world, names = paper_world(seed=0)
    ds = build_dataset(world, n=N_DATASET)
    tiers = paper_pool_tiers()
    bundle = EstimatorBundle.train(ds, tiers, names)
    prompts, Q, L = ds.split("train")
    emb = _embed_all(bundle, prompts)
    prices = np.array([_price_of(names, tiers, m) for m in names])
    return dict(world=world, names=names, ds=ds, tiers=tiers,
                bundle=bundle, train_emb=emb, train_Q=Q, train_L=L,
                prices=prices)


def _price_of(names, tiers, model):
    for t in tiers:
        if t.model == model:
            return t.price_out
    return 0.1


def _embed_all(bundle, prompts, batch=512):
    from repro.estimators.embedding import pad_tokens
    toks = pad_tokens([p.tokens for p in prompts], bundle.encoder.max_len)
    lens = np.array([min(len(p.tokens), bundle.encoder.max_len)
                     for p in prompts])
    out = []
    for i in range(0, len(prompts), batch):
        out.append(bundle.encoder.encode(toks[i:i + batch],
                                         lens[i:i + batch]))
    return np.concatenate(out)


def rb_cell(ctx, weights, lam, *, seed=0, n=None, arrival="poisson",
            budgets=None, cfg_kw=None, fail_at=None):
    n = n or N_REQ
    arr = make_arrivals(arrival, lam, n, seed=seed)
    reqs = make_requests(ctx["ds"], "test", arr, budgets=budgets)
    cfg = RBConfig(weights=weights, **(cfg_kw or {}))
    rb = RouteBalance(cfg, ctx["bundle"], ctx["tiers"])
    m = run_cell(rb, ctx["tiers"], ctx["names"], reqs, seed=seed,
                 fail_at=fail_at)
    m["weights"] = weights
    m["lam"] = lam
    return m


def fit_router(ctx, router):
    return router.fit(ctx["train_emb"], ctx["train_Q"], ctx["train_L"],
                      ctx["prices"])


def pipeline_cell(ctx, router, dispatcher, lam, *, deployment="serial",
                  seed=0, n=None, arrival="poisson", budgets=None,
                  queue_capacity=None):
    """A baseline cell from pre-built router/dispatcher objects, run
    through the shared engine (the legacy pipeline path is a shim)."""
    n = n or N_REQ
    arr = make_arrivals(arrival, lam, n, seed=seed)
    reqs = make_requests(ctx["ds"], "test", arr, budgets=budgets)
    eng = ServingEngine(RouterDispatchPolicy(router, dispatcher),
                        ctx["bundle"], ctx["tiers"],
                        EngineConfig(deployment=deployment,
                                     queue_capacity=queue_capacity))
    m = run_cell(eng, ctx["tiers"], ctx["names"], reqs, seed=seed)
    m["lam"] = lam
    return m


def policy_cell(ctx, policy_name, lam, *, deployment="windowed", seed=0,
                n=None, arrival="poisson", budgets=None,
                queue_capacity=None, serial_scoring_s=None,
                policy_kw=None):
    """One cell of the (policy x deployment) plane: resolve
    `policy_name` through the POLICIES registry, fit it on the shared
    supervision, and run it through the one `ServingEngine`."""
    n = n or N_REQ
    arr = make_arrivals(arrival, lam, n, seed=seed)
    reqs = make_requests(ctx["ds"], "test", arr, budgets=budgets)
    policy = make_policy(policy_name, **(policy_kw or {}))
    policy.fit(ctx["train_emb"], ctx["train_Q"], ctx["train_L"],
               ctx["prices"])
    if serial_scoring_s is not None:    # e.g. the vLLM-SR classifier
        policy.router.serial_scoring_s = serial_scoring_s
    eng = ServingEngine(policy, ctx["bundle"], ctx["tiers"],
                        EngineConfig(deployment=deployment,
                                     queue_capacity=queue_capacity))
    m = run_cell(eng, ctx["tiers"], ctx["names"], reqs, seed=seed)
    m["lam"] = lam
    return m


def tenant_cols(m) -> str:
    """Per-tenant p50/p99/goodput `k=v` columns for a cell row (empty
    string when the stream carries no tenant stamps)."""
    parts = []
    for name, tm in sorted(m.get("tenants", {}).items()):
        parts.append(f"t_{name}_p50={tm['p50_e2e']:.3f}")
        parts.append(f"t_{name}_p99={tm['p99_e2e']:.3f}")
        parts.append(f"t_{name}_goodput={tm['goodput']:.2f}")
    return "".join(";" + p for p in parts)


_ROWS: list = []        # rows accumulated since the last flush_json()


def csv_row(name, us_per_call, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
    row = {"name": name, "us_per_call": float(us_per_call),
           "derived": str(derived)}
    # parse "k=v;k=v" derived strings into machine-readable fields
    for part in str(derived).split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                row[k.strip()] = float(v.rstrip("x"))
            except ValueError:
                row[k.strip()] = v
    _ROWS.append(row)


def flush_json(module: str, path: str = None) -> str:
    """Write the rows accumulated by `csv_row` to BENCH_<module>.json
    (machine-readable perf trajectory) and reset the buffer."""
    import json
    path = path or f"BENCH_{module}.json"
    rows, _ROWS[:] = list(_ROWS), []
    with open(path, "w") as f:
        json.dump({"module": module, "n_req_per_cell": N_REQ,
                   "n_dataset": N_DATASET, "rows": rows}, f, indent=1)
    print(f"# wrote {path} ({len(rows)} rows)")
    return path


def discard_rows():
    _ROWS[:] = []
