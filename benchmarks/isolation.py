"""Table 7: the four-arm isolation — where does the benefit come from?

arm1 full objective; arm2 w_lat=0 + reactive shortest-queue tiebreak;
arm3 w_lat=0 + predictive T̂ tiebreak; arm4 full objective with a static
per-tier prior (nominal TPOT x L̂, zero telemetry). The paper's finding:
arm2 ~ arm3 (within-tier prediction adds nothing over reactive), arm1
beats both via the cross-tier mix shift (72B share 14% -> 1%), and arm4
~ arm1 (the learned predictor is not load-bearing).

The arms are `latency_mode` variants of the registry's `routebalance`
policy, run through the shared `ServingEngine` like every other cell
(`benchmarks.common.policy_cell`)."""
from __future__ import annotations

from .common import context, csv_row, policy_cell
from repro.core import PRESETS

ARMS = (("arm1_full", dict(latency_mode="full")),
        ("arm2_reactive", dict(latency_mode="off_reactive")),
        ("arm3_predictive", dict(latency_mode="off_predictive")),
        ("arm4_static_prior", dict(latency_mode="static_prior")))


def main():
    ctx = context()
    rows = []
    for lam in (12.0, 24.0, 30.0):
        for name, kw in ARMS:
            m = policy_cell(ctx, "routebalance", lam,
                            policy_kw=dict(weights=PRESETS["uniform"],
                                           **kw))
            share72 = sum(v for k, v in m["mix"].items() if "72b" in k)
            rows.append((name, lam, m))
            csv_row(f"isolation/{name}@{lam:.0f}",
                    m.get("measured_decide_ms_per_req", 0.0) * 1e3,
                    f"e2e={m['mean_e2e']:.2f};q={m['quality']:.3f};"
                    f"share72={share72:.2f}")
    return rows


if __name__ == "__main__":
    main()
