"""Fig 2 / Table 3 / Fig 5: the quality-latency-cost frontier — one
RouteBalance stack sweeping the weight simplex vs decoupled baselines."""
from __future__ import annotations

from .common import (context, csv_row, fit_router, pipeline_cell, rb_cell)
from repro.core import PRESETS
from repro.core.dispatchers import RandomDispatch, RoundRobin, \
    ShortestQueue
from repro.core.routers import AvengersProRouter, BestRouteRouter, \
    PassthroughRouter

RB_SWEEP = [
    ("rb_cost", PRESETS["cost"]),
    ("rb_uniform", PRESETS["uniform"]),
    ("rb_mid", (0.55, 0.25, 0.20)),
    ("rb_quality", PRESETS["quality"]),
    ("rb_latency", PRESETS["latency"]),
    ("rb_q1", (1.0, 0.0, 0.0)),
]


def main(lam: float = 12.0):
    ctx = context()
    rows = []
    for name, w in RB_SWEEP:
        m = rb_cell(ctx, w, lam)
        rows.append((name, m))
    for t in (0.3, 0.5, 0.7):
        r = fit_router(ctx, BestRouteRouter(threshold=t))
        m = pipeline_cell(ctx, r, ShortestQueue(), lam,
                          deployment="concurrent")
        rows.append((f"bestroute_t{t}", m))
    for pw in (0.5, 0.8):
        r = fit_router(ctx, AvengersProRouter(p_w=pw))
        m = pipeline_cell(ctx, r, ShortestQueue(), lam,
                          deployment="concurrent")
        rows.append((f"avengers_pw{pw}", m))
    for dname, d in (("rr", RoundRobin()), ("sq", ShortestQueue()),
                     ("random", RandomDispatch())):
        m = pipeline_cell(ctx, PassthroughRouter(), d, lam,
                          deployment="concurrent")
        rows.append((f"passthrough_{dname}", m))
    print("# frontier (lam=%.0f): name, quality, mean_e2e_s, cost_usd, "
          "tput_rps, mix" % lam)
    for name, m in rows:
        csv_row(f"frontier/{name}",
                m.get("measured_decide_ms_per_req", 0.0) * 1e3,
                f"q={m['quality']:.3f};e2e={m['mean_e2e']:.2f};"
                f"cost={m['cost_per_req']:.2e};tput={m['throughput']:.1f}")
    return rows


if __name__ == "__main__":
    main()
