"""Fig 2 / Table 3 / Fig 5: the engineering-equalized
quality-latency-cost frontier — EVERY registered scheduling policy
swept through the one `ServingEngine` over a (policy x load x scenario)
grid, with per-tenant SLO columns.

The paper's frontier claim is comparative: RouteBalance's weight family
traces the frontier while the decoupled router -> dispatcher baselines
sit inside it, *once router engineering is equalized* (§5-§6.2). Here
that control is structural — the baselines run through the same engine,
the same SoA ingest, the same telemetry view as RouteBalance, under the
`deployment="concurrent"` equalized scoring arm; only the
`SchedulingPolicy` differs. Rows carry `policy=` / `deployment=`
columns plus `t_<tenant>_p50/p99/goodput` breakdowns and land in
``BENCH_frontier.json`` (schema pinned by
``tests/test_bench_schema.py``).

Smoke mode for CI: REPRO_FRONTIER_SMOKE=1 trims the grid (fewer
policies, small dataset, low n) while keeping the (policy x load x
scenario) shape and at least one RouteBalance + one baseline cell per
scenario so the artifact schema stays pinned.
"""
from __future__ import annotations

import os

from .common import N_REQ, csv_row, tenant_cols
from repro.core import PRESETS

SMOKE = os.environ.get("REPRO_FRONTIER_SMOKE", "") not in ("", "0")
SCENES = ("paper", "multitenant")
LOADS = (1.0, 2.0) if SMOKE else (0.5, 1.0, 2.0)   # x scenario rate
DATASET_N = 300 if SMOKE else 1500
N_CELL = 48 if SMOKE else N_REQ

# cell name, registry policy, policy kwargs, deployment
CELLS = [
    ("rb_cost", "routebalance", dict(weights=PRESETS["cost"]), "windowed"),
    ("rb_uniform", "routebalance", dict(weights=PRESETS["uniform"]),
     "windowed"),
    ("rb_mid", "routebalance", dict(weights=(0.55, 0.25, 0.20)),
     "windowed"),
    ("rb_quality", "routebalance", dict(weights=PRESETS["quality"]),
     "windowed"),
    ("rb_latency", "routebalance", dict(weights=PRESETS["latency"]),
     "windowed"),
    ("rb_q1", "routebalance", dict(weights=(1.0, 0.0, 0.0)), "windowed"),
    ("bestroute_t0.3_sq", "bestroute-sq", dict(threshold=0.3),
     "concurrent"),
    ("bestroute_t0.5_sq", "bestroute-sq", dict(threshold=0.5),
     "concurrent"),
    ("bestroute_t0.7_sq", "bestroute-sq", dict(threshold=0.7),
     "concurrent"),
    ("avengers_pw0.5_sq", "avengers-sq", dict(p_w=0.5), "concurrent"),
    ("avengers_pw0.8_sq", "avengers-sq", dict(p_w=0.8), "concurrent"),
    ("passthrough_rr", "passthrough-rr", {}, "concurrent"),
    ("passthrough_sq", "passthrough-sq", {}, "concurrent"),
    ("passthrough_random", "passthrough-random", {}, "concurrent"),
]
SMOKE_CELLS = ("rb_uniform", "rb_cost", "bestroute_t0.5_sq",
               "avengers_pw0.8_sq", "passthrough_sq")


def main():
    from repro.serving.scenarios import get_scenario
    cells = [c for c in CELLS if not SMOKE or c[0] in SMOKE_CELLS]
    rows = []
    for scene in SCENES:
        sc = get_scenario(scene)
        run = sc.build(dataset_n=DATASET_N)
        run.bundle()
        for cell_name, pname, pkw, deployment in cells:
            for scale in LOADS:
                reqs = run.requests(N_CELL, lam_scale=scale, seed=0)
                # fresh policy per cell: dispatcher state (rr counter,
                # random rng) must not leak across loads
                eng = run.engine(run.policy(pname, **pkw),
                                 deployment=deployment)
                m = run.run_cell(eng, reqs, seed=0)
                name = f"frontier/{scene}_{cell_name}_x{scale}"
                csv_row(
                    name,
                    m.get("measured_decide_ms_per_req", 0.0) * 1e3,
                    f"policy={m['policy']}"
                    f";deployment={m['deployment']}"
                    f";lam={sc.lam * scale:.1f}"
                    f";q={m['quality']:.3f}"
                    f";e2e={m['mean_e2e']:.2f}"
                    f";p99_e2e={m['p99_e2e']:.2f}"
                    f";cost={m['cost_per_req']:.3e}"
                    f";tput={m['throughput']:.2f}"
                    f";goodput={m['goodput']:.2f}"
                    f";failed={m['failed']}"
                    + tenant_cols(m))
                rows.append((name, m))
    return rows


if __name__ == "__main__":
    from .common import flush_json
    main()
    flush_json("frontier")
