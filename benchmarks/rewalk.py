"""Re-run the HLO cost walker over saved dry-run artifacts (no
recompilation) and update the cell JSONs in place — used after walker
refinements and by the §Perf loop."""
from __future__ import annotations

import glob
import gzip
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.hlo_cost import analyze

RUNS = pathlib.Path(__file__).resolve().parents[1] / "runs" / "dryrun"


def main(pattern: str = "*.json"):
    for jf in sorted(glob.glob(str(RUNS / pattern))):
        p = pathlib.Path(jf)
        hlo = p.with_suffix("").with_suffix(".hlo.gz") \
            if p.name.endswith(".json") else None
        hlo = pathlib.Path(str(p)[:-5] + ".hlo.gz")
        d = json.loads(p.read_text())
        if d.get("status") != "ok" or not hlo.exists():
            continue
        walk = analyze(gzip.open(hlo, "rt").read())
        d["walk"] = walk
        d["flops_per_device"] = walk["flops"]
        d["hbm_bytes_per_device"] = walk["hbm_bytes"]
        d["collectives"] = walk["by_kind"]
        d["collective_link_bytes_per_device"] = walk["coll_link_bytes"]
        p.write_text(json.dumps(d, indent=1))
        print("rewalked", p.name)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "*.json")
