"""Table 8: budget-aware execution at lam=16 over three budget-tightness
mixes — the Eq. 2 admission filter converts exhaustion into served
quality on top of the shared runtime cap (clamp + early stop)."""
from __future__ import annotations

import numpy as np

from .common import (N_REQ, context, csv_row, fit_router, pipeline_cell,
                     rb_cell)
from repro.core import PRESETS, RBConfig
from repro.core.dispatchers import ShortestQueue
from repro.core.routers import BestRouteRouter

MIXES = (("tight", 0.75, 1.6), ("medium", 0.45, 2.2), ("loose", 0.30, 3.0))


def _budgets(ctx, frac, scale, seed=0):
    """Budgets sampled as scale x the CHEAPEST-tier expected cost, so the
    tight mix forces real truncation on larger models."""
    rng = np.random.default_rng(seed)
    n = N_REQ
    b = np.full(n, np.nan)
    mask = rng.uniform(size=n) < frac
    base = 2.0e-5
    b[mask] = base * scale * rng.uniform(0.4, 1.2, mask.sum())
    return b


def main():
    ctx = context()
    rows = []
    lam = 16.0
    for name, frac, scale in MIXES:
        budgets = _budgets(ctx, frac, scale)
        m = rb_cell(ctx, PRESETS["uniform"], lam, budgets=budgets)
        rows.append((f"rb_filter_{name}", m))
        m = rb_cell(ctx, PRESETS["uniform"], lam, budgets=budgets,
                    cfg_kw=dict(budget_filter=False))
        rows.append((f"rb_nofilter_{name}", m))
        br = fit_router(ctx, BestRouteRouter(threshold=1.0))
        m = pipeline_cell(ctx, br, ShortestQueue(), lam,
                          deployment="concurrent", budgets=budgets)
        rows.append((f"bestroute_argmax_{name}", m))
    print("# budget: exhaustion fraction + served-text quality")
    for name, m in rows:
        csv_row(f"budget/{name}", 0.0,
                f"exh={m['exhausted_frac']:.3f};"
                f"served_q={m['served_quality']:.3f};"
                f"lookup_q={m['quality']:.3f}")
    return rows


if __name__ == "__main__":
    main()
