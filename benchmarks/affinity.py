"""Prefix-cache affinity benchmark: what the reuse term buys on a
multi-turn session workload.

One built ``session_chat`` world (multi-turn conversations sharing
growing prompt prefixes, `serving.scenarios.SessionSpec`) runs a 2x3
arm grid on one trained bundle and one request stream: affinity-on
(``RBConfig.affinity_weight > 0``) vs affinity-off, under each of the
three decision backends. The sim's prefill-cache physics is identical
in every arm — `Instance._admit` discounts prefill by the matched
prefix whether or not the router scored for it — so the arms isolate
exactly what affinity-aware ROUTING adds: follow-up turns landing on
the instance that already holds the conversation's KV prefix.

Rows carry ``cache_hit_rate`` (mean matched-prefix fraction at
dispatch), TTFT, goodput and the fused compile pin (the sig planes ride
the existing programs: session churn must never add an XLA compile
beyond one program per pow2 R bucket).

Headline acceptance (asserted here, pinned again in
``tests/test_bench_schema.py``): per backend, the affinity-on arm gets
``cache_hit_rate`` strictly above the off arm's incidental hits, a hit
rate > 0, and mean TTFT no worse than affinity-off at equal load.

Smoke mode for CI: REPRO_AFFINITY_SMOKE=1 trims the cell size while
keeping every arm, so the artifact schema stays pinned.
"""
from __future__ import annotations

import os

from .common import csv_row
from repro.core import RBConfig, RouteBalance
from repro.core.decision_jax import bucket_pow2
from repro.serving.scenarios import get_scenario

SMOKE = os.environ.get("REPRO_AFFINITY_SMOKE", "") not in ("", "0")
N_CELL = 140 if SMOKE else 420
BACKENDS = ("numpy", "jax", "fused")
W_AFF = 0.35


def _cell(run, reqs, backend, w_aff):
    rb = RouteBalance(RBConfig(decision_backend=backend,
                               affinity_weight=w_aff,
                               charge_compute=False),
                      run.bundle(), run.tiers)
    m = run.run_cell(rb, reqs, seed=0)
    return m, rb


def _row(name, m, rb):
    compiles = r_buckets = 0
    if rb._fused is not None:
        compiles = rb._fused.compile_count()
        r_buckets = len({bucket_pow2(s) for s, _ in rb.compute_log})
        # session/retry churn must never reach XLA: one program per
        # pow2 R bucket, with or without the affinity term
        assert compiles <= r_buckets, (compiles, r_buckets)
    csv_row(
        name,
        m.get("measured_decide_ms_mean", 0.0) * 1e3,
        f"cache_hit_rate={m['cache_hit_rate']:.4f}"
        f";mean_ttft={m['mean_ttft']:.5f}"
        f";p99_ttft={m['p99_ttft']:.5f}"
        f";goodput={m['goodput']:.3f}"
        f";mean_e2e={m['mean_e2e']:.4f}"
        f";served={m['n']}"
        f";compiles={compiles}"
        f";r_buckets={r_buckets}")
    return m


def main():
    run = get_scenario("session_chat").build(
        dataset_n=300 if SMOKE else 600)
    run.bundle()
    reqs_by_arm = {}
    for be in BACKENDS:
        out = {}
        for arm, w in (("off", 0.0), ("on", W_AFF)):
            # a fresh stream per cell: requests are mutated by the run
            reqs = run.requests(N_CELL, seed=0)
            m, rb = _cell(run, reqs, be, w)
            out[arm] = _row(f"affinity/{be}_{arm}", m, rb)
        # the headline: scoring reuse must actually ROUTE for reuse —
        # strictly more cache hits than the off arm's incidental ones,
        # and no TTFT regression at equal load
        assert out["on"]["cache_hit_rate"] > 0.0, be
        assert out["on"]["cache_hit_rate"] > out["off"]["cache_hit_rate"], \
            (be, out["on"]["cache_hit_rate"], out["off"]["cache_hit_rate"])
        assert out["on"]["mean_ttft"] <= out["off"]["mean_ttft"] + 1e-12, \
            (be, out["on"]["mean_ttft"], out["off"]["mean_ttft"])
        reqs_by_arm[be] = out
    # all three backends agree on what affinity buys (same decisions)
    for arm in ("off", "on"):
        hits = {be: reqs_by_arm[be][arm]["cache_hit_rate"]
                for be in BACKENDS}
        assert max(hits.values()) - min(hits.values()) < 1e-9, (arm, hits)


if __name__ == "__main__":
    from .common import flush_json
    main()
    flush_json("affinity")
