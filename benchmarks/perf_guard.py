"""CI perf-regression guard for the fused hot path.

Re-runs the hotpath smoke grid (REPRO_HOTPATH_SMOKE=1 — a subset of the
full grid, so every smoke row has a committed counterpart) and fails if
any backend's ``us_per_call`` regresses more than the tolerance against
the committed ``BENCH_hotpath.json`` baseline:

  python -m benchmarks.perf_guard

Since the `SchedulingPolicy` redesign the timed cells resolve through
the policy/engine API (`RouteBalance._decide_core` ->
`RouteBalancePolicy.assign` on the shared `ServingEngine`), so the
committed PR-4 baselines gate the refactor itself: the API seam must
not cost more than the tolerance. `_assert_engine_api` pins that
wiring — a future change that detaches the bench from the production
decision path fails the guard loudly instead of gating a dead code
path.

Only the **fused** rows gate (the production hot path this guard
protects); staged numpy/jax rows print informationally — their Python
loops are far noisier under co-tenant load, and a regression there
doesn't ship. A failing cell is re-timed once (min of the two runs)
before it counts, since even min-of-N timing jitters tens of percent on
a busy box.

The tolerance (default 1.25 = 25%) is multiplicative and env-tunable
via ``REPRO_PERF_GUARD_TOL`` — absolute wall-clock differs across
machines, so CI boxes that are systematically slower than the box that
produced the committed artifact should raise it rather than delete the
guard. Getting *faster* than baseline never fails; rows with no
committed counterpart are reported and skipped.

A second gate covers the fault-tolerant lifecycle (PR 7): a fault-free
cell is timed with the recovery manager armed and disarmed, and the
armed run must stay within the same tolerance — the retry/hedge/
watchdog hooks are only allowed to cost when faults actually fire.

A third gate covers the Pallas decision megakernel (PR 9): on every
smoke cell the megakernel row must land within the tolerance of the
fused-XLA row *from the same run* — a relative same-box comparison, so
machine speed cancels out. A megakernel more than 25% slower than
fused-XLA fails CI.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import tempfile

TOL = float(os.environ.get("REPRO_PERF_GUARD_TOL", "1.25"))
REPO = pathlib.Path(__file__).resolve().parent.parent


def _time_smoke_grid() -> dict:
    from benchmarks import common
    common.discard_rows()
    from benchmarks import hotpath
    hotpath.main()
    with tempfile.NamedTemporaryFile("r", suffix=".json") as tmp:
        common.flush_json("hotpath_guard", tmp.name)
        rows = json.load(open(tmp.name))["rows"]
    return {r["name"]: r["us_per_call"] for r in rows}


def _assert_engine_api():
    """The timed grid must exercise the policy/engine path the
    production scheduler serves through."""
    from benchmarks import common  # noqa: F401  (puts src on sys.path)
    from repro.core import (POLICIES, RouteBalance, RouteBalancePolicy,
                            ServingEngine, make_policy)
    assert issubclass(RouteBalance, ServingEngine), \
        "RouteBalance detached from ServingEngine — guard would gate a " \
        "dead path"
    assert "routebalance" in POLICIES
    assert isinstance(make_policy("routebalance"), RouteBalancePolicy)


def _recovery_overhead_guard() -> bool:
    """Fault-free cells must not pay for the recovery hooks: one small
    chaos-world cell, empty fault schedule, timed armed vs disarmed
    (min-of-3 each; the sim is a single-thread Python loop, so
    wall-clock is the honest cost of the extra per-dispatch bookkeeping
    and the watchdog's periodic scan)."""
    import dataclasses
    import time

    from repro.core import RBConfig, RouteBalance
    from repro.serving.faults import chaos_world
    from repro.serving.recovery import RecoveryConfig

    sc = chaos_world()
    run = sc.build(dataset_n=200)
    bundle = run.bundle()
    run.scenario = dataclasses.replace(run.scenario, schedule=())

    def cell(recovery):
        run.recovery = recovery
        reqs = run.requests(100, seed=0)
        rb = RouteBalance(RBConfig(charge_compute=False), bundle,
                          run.tiers)
        t0 = time.perf_counter()
        m = run.run_cell(rb, reqs, seed=0)
        assert m["failed"] == 0 and m.get("retries", 0) == 0
        return time.perf_counter() - t0

    cell(None)                          # warm-up: compiles and caches
    off = min(cell(None) for _ in range(3))
    on = min(cell(RecoveryConfig()) for _ in range(3))
    ratio = on / off
    verdict = "ok" if ratio <= TOL else "REGRESSED"
    print(f"recovery hooks (fault-free cell): armed {on * 1e3:.1f} ms "
          f"vs disarmed {off * 1e3:.1f} ms ({ratio:.2f}x, "
          f"tol {TOL:.2f}x) {verdict}")
    return ratio <= TOL


def _affinity_disabled_guard() -> bool:
    """The prefix-affinity term must be free when disabled (<5% of
    decide time). Structurally: ``affinity_weight=0`` compiles the term
    out of the fused program and stages NO signature data — the sig
    args are (1, 1) dummies and the per-bucket staging sets carry no
    ``psig`` buffer. By measurement: the disabled runner's decide time
    must not exceed the enabled runner's (which does strictly more
    work — sig gathers, plane upload, in-graph hit matching) by more
    than a 5% noise floor. Absolute regressions of the disabled path
    against history are the main BENCH_hotpath gate's job (those
    committed baselines predate the affinity term, so they gate it)."""
    import time

    from benchmarks import common  # noqa: F401  (puts src on sys.path)
    from repro.core import RBConfig, RouteBalance
    from repro.serving.cluster import ClusterSim
    from repro.serving.scenarios import (get_scenario,
                                         randomize_prefix_state)

    run = get_scenario("session_chat").build(dataset_n=200)
    bundle = run.bundle()
    reqs = run.requests(64, seed=0)
    for r in reqs:
        r.arrival = 0.0
    rbs = {}
    for w in (0.0, 0.35):
        rb = RouteBalance(RBConfig(decision_backend="fused",
                                   affinity_weight=w,
                                   charge_compute=False),
                          bundle, run.tiers)
        sim = ClusterSim(run.tiers, run.names, seed=0)
        if w:
            randomize_prefix_state(sim, reqs[0].cols, seed=0)
        rb.sim = sim
        rb._decide_core(reqs[:32])          # warm-up: compile the bucket
        rbs[w] = rb
    fused_off = rbs[0.0]._fused
    assert fused_off._w_aff == 0.0
    assert all("psig" not in bufset for pair in
               fused_off._stage.values() for bufset in pair), \
        "disabled affinity must stage no signature data"
    assert fused_off._dummy_psig.shape == (1, 1)

    def t_of(rb):
        best = float("inf")
        for _ in range(7):
            t0 = time.perf_counter()
            rb._decide_core(reqs[:32])
            best = min(best, time.perf_counter() - t0)
        return best

    ratio = t_of(rbs[0.0]) / t_of(rbs[0.35])
    if ratio > 1.05:                        # re-time once to shed noise
        ratio = min(ratio, t_of(rbs[0.0]) / t_of(rbs[0.35]))
    verdict = "ok" if ratio <= 1.05 else "REGRESSED"
    print(f"affinity term: disabled decide at {ratio:.2f}x the enabled "
          f"runner's (tol 1.05x) {verdict}")
    return ratio <= 1.05


def _hierarchy_guard() -> bool:
    """The hierarchical wrapper must be ~free at one cell: a 1-cell
    balanced `HierarchicalScheduler` (cell telemetry mirror, digest
    loop, recovery router — the whole control plane) runs the same
    trace as the plain fused controller and must stay within 1.10x of
    its wall-clock (min-of-3, same-box relative comparison; re-timed
    once before failing). This gates the PR-10 seam: the per-cell
    views may not tax the single-controller configuration everyone
    runs by default."""
    import time

    from repro.core import RBConfig, RouteBalance
    from repro.serving.hierarchy import HierarchyConfig, build_scheduler
    from repro.serving.scenarios import get_scenario

    tol = 1.10
    run = get_scenario("cluster").build(dataset_n=200)
    bundle = run.bundle()
    cfg = RBConfig(charge_compute=False)
    hcfg = HierarchyConfig(n_cells=1, routing="balanced")

    def cell(hier):
        reqs = run.requests(120, seed=0)
        sched = (build_scheduler(cfg, bundle, run.tiers, hcfg) if hier
                 else RouteBalance(cfg, bundle, run.tiers))
        t0 = time.perf_counter()
        m = run.run_cell(sched, reqs, seed=0)
        assert m["failed"] == 0
        return time.perf_counter() - t0

    cell(False), cell(True)             # warm-up: compiles and caches
    flat = min(cell(False) for _ in range(3))
    hier = min(cell(True) for _ in range(3))
    ratio = hier / flat
    if ratio > tol:                     # re-time once to shed noise
        ratio = min(ratio, min(cell(True) for _ in range(3))
                    / min(cell(False) for _ in range(3)))
    verdict = "ok" if ratio <= tol else "REGRESSED"
    print(f"hierarchy (1-cell balanced vs flat fused): "
          f"{hier * 1e3:.1f} ms vs {flat * 1e3:.1f} ms "
          f"({ratio:.2f}x, tol {tol:.2f}x) {verdict}")
    return ratio <= tol


def _megakernel_guard(fresh: dict) -> bool:
    """The one-kernel decision must hold parity-or-better against the
    fused-XLA pipeline: for every smoke cell, the megakernel row's
    us_per_call stays within TOL of the fused row's **from the same
    timed run** (both backends share ambient machine conditions, so the
    ratio is far more stable than any absolute baseline). A failing
    grid is re-timed once before it counts."""

    def ratios(rows):
        out = {}
        for name, us in rows.items():
            if name.startswith("hotpath/megakernel_"):
                cell = name.split("megakernel_", 1)[1]
                f = rows.get(f"hotpath/fused_{cell}")
                if f:
                    out[cell] = us / f
        return out

    r = ratios(fresh)
    assert r, "smoke grid lost its megakernel rows"
    if max(r.values()) > TOL:           # re-time once to shed noise
        print("# megakernel over tolerance: re-timing once")
        rerun = ratios(_time_smoke_grid())
        r = {c: min(v, rerun.get(c, v)) for c, v in r.items()}
    for cell, ratio in sorted(r.items()):
        verdict = "ok" if ratio <= TOL else "REGRESSED"
        print(f"megakernel vs fused @ {cell}: {ratio:.2f}x "
              f"(tol {TOL:.2f}x) {verdict}")
    return max(r.values()) <= TOL


def main() -> int:
    _assert_engine_api()
    os.environ["REPRO_HOTPATH_SMOKE"] = "1"
    baseline_doc = json.loads((REPO / "BENCH_hotpath.json").read_text())
    from benchmarks import common
    # the KNN index scales with the dataset, so timings are only
    # comparable at the baseline's dataset size — refuse a silent
    # apples-to-oranges gate (a paper-scale baseline would make every
    # default-scale run pass, a small-scale one would fail every run)
    base_n = baseline_doc.get("n_dataset")
    if base_n is not None and base_n != common.N_DATASET:
        print(f"perf guard: committed baseline was produced at "
              f"REPRO_BENCH_DATASET={base_n}, this run uses "
              f"{common.N_DATASET} — set REPRO_BENCH_DATASET={base_n} "
              f"(or regenerate the baseline) before gating")
        return 1
    baseline = {r["name"]: r["us_per_call"]
                for r in baseline_doc["rows"]}

    fresh = _time_smoke_grid()
    if any(name in baseline and us / baseline[name] > TOL
           and "fused" in name for name, us in fresh.items()):
        print("# possible regression: re-timing once to shed noise")
        rerun = _time_smoke_grid()
        fresh = {name: min(us, rerun.get(name, us))
                 for name, us in fresh.items()}

    failures, missing = [], []
    for name, us in fresh.items():
        base = baseline.get(name)
        if base is None:
            missing.append(name)
            continue
        ratio = us / base
        gates = "fused" in name
        verdict = ("ok" if ratio <= TOL else
                   "REGRESSED" if gates else "slow (informational)")
        print(f"{name}: {us:.0f} us vs baseline {base:.0f} us "
              f"({ratio:.2f}x, tol {TOL:.2f}x) {verdict}")
        if gates and ratio > TOL:
            failures.append((name, round(ratio, 2)))
    if missing:
        print(f"# no committed baseline for {missing} (new cells pass)")
    if not _megakernel_guard(fresh):
        failures.append(("megakernel_vs_fused", "regression"))
    if not _recovery_overhead_guard():
        failures.append(("recovery_hooks_fault_free", "overhead"))
    if not _affinity_disabled_guard():
        failures.append(("affinity_term_disabled", "overhead"))
    if not _hierarchy_guard():
        failures.append(("hierarchy_1cell_vs_flat", "overhead"))
    if failures:
        print(f"PERF REGRESSION: {failures}")
        return 1
    print(f"# perf guard ok: fused cells within {TOL:.2f}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
