"""Cost-vs-SLO frontier under overload control: the elastic worlds of
`repro.serving.scenarios` (diurnal square wave + flash crowd) swept over
admission/autoscaling arms on the fused backend.

Each scenario runs a ladder of arms on ONE built world (same roster,
same trained bundle, same request stream per load):

  * ``static``  — overload control disarmed: the base fleet takes the
    full trace (reserves stay cold, everything is admitted). The
    baseline the paper-style static rosters would produce;
  * ``shed``    — SLO-aware admission shedding only (no autoscaling):
    what priority classes buy when capacity cannot grow;
  * ``elastic_lag<L>`` — shedding + autoscaler with scale-up lag L
    seconds: the cost-vs-SLO frontier's elasticity axis. Slower
    provisioning means more of the burst is absorbed by shedding, so
    shed_rate rises with L while peak_alive stays the same.

Rows carry the new overload axes — ``shed_rate``, ``scale_ups`` /
``scale_downs`` / ``peak_alive``, ``scale_up_lag_s``, per-priority
goodput/shed/SLO-attainment columns (``prio<k>_*``) — next to the usual
latency/cost/goodput columns, landing in ``BENCH_elastic.json``.
``roster_reseeds`` counts the fused hot path's alive-mask resyncs from
scale events; ``compiles`` pins that roster churn added ZERO XLA
compiles (one program per pow2 R bucket, asserted against the observed
bucket count).

Smoke mode for CI: REPRO_ELASTIC_SMOKE=1 trims to one load and small
cells while keeping every arm, so the artifact schema stays pinned.
"""
from __future__ import annotations

import dataclasses
import os

from .common import csv_row
from repro.core import RBConfig, RouteBalance
from repro.core.decision_jax import bucket_pow2
from repro.serving.cluster import ClusterSim
from repro.serving.overload import OverloadConfig
from repro.serving.scenarios import ElasticSpec, get_scenario

SMOKE = os.environ.get("REPRO_ELASTIC_SMOKE", "") not in ("", "0")
SCENES = ("diurnal_elastic", "flashcrowd_elastic")
LOADS = (3.0,) if SMOKE else (2.0, 4.0)   # multiples of the nominal rate
LAGS = (0.5, 2.0, 4.0)                    # provisioning delay sweep (s)
# cells are sized by TIME, not request count: the trace must actually
# reach the flash burst (t=4s) / the diurnal high phase, and raising
# lam_scale compresses a fixed-n trace instead of lengthening the
# overload window
HORIZON_S = 14.0 if SMOKE else 24.0
DATASET_N = 300 if SMOKE else 1500


def _n_cell(lam: float, scale: float) -> int:
    return max(int(lam * scale * HORIZON_S), 200)


def _arms(base: ElasticSpec):
    """(name, ElasticSpec) ladder: static -> shed-only -> elastic at
    each scale-up lag. All arms share the same expanded roster (the
    reserves exist but stay cold when autoscale is off), so rows differ
    only in control policy."""
    cfg = base.overload
    yield "static", dataclasses.replace(
        base, overload=dataclasses.replace(cfg, autoscale=False,
                                           shed_enabled=False))
    yield "shed", dataclasses.replace(
        base, overload=dataclasses.replace(cfg, autoscale=False,
                                           shed_enabled=True))
    for lag in LAGS:
        yield f"elastic_lag{lag:g}", dataclasses.replace(
            base, overload=dataclasses.replace(cfg, autoscale=True,
                                               shed_enabled=True,
                                               scale_up_lag_s=lag))


def _prio_cols(m) -> str:
    parts = []
    for p, pm in sorted(m.get("priorities", {}).items()):
        parts.append(f"prio{p}_goodput={pm['goodput']:.2f}")
        parts.append(f"prio{p}_shed={pm['shed']}")
        parts.append(f"prio{p}_slo={pm['slo_attainment']:.3f}")
    return "".join(";" + p for p in parts)


def main():
    for scene in SCENES:
        sc = get_scenario(scene)
        run = sc.build(dataset_n=DATASET_N)
        bundle = run.bundle()
        base = sc.elastic
        i_base = run.n_instances - len(run.reserve_iids)
        # deterministic warm-up: compile the pow2 R buckets the
        # overloaded cells reach, outside the measured cells (the fused
        # runner is cached on the bundle, so every arm reuses these)
        warm_reqs = run.requests(128, seed=99)
        warm = RouteBalance(RBConfig(charge_compute=False), bundle,
                            run.tiers)
        warm.sim = ClusterSim(run.tiers, run.names, seed=0)
        seen_buckets = {8, 16, 32, 64, 128}
        for R in sorted(seen_buckets):
            warm._decide_core(warm_reqs[:R])
        for scale in LOADS:
            n_cell = _n_cell(sc.lam, scale)
            for arm, spec in _arms(base):
                run.elastic = spec
                # fresh request objects per arm: dispatch/finish state
                # is written in place by the sim
                reqs = run.requests(n_cell, lam_scale=scale, seed=0)
                rb = RouteBalance(RBConfig(charge_compute=False),
                                  bundle, run.tiers)
                m = run.run_cell(rb, reqs, seed=0)
                st = rb._fused.stats if rb._fused is not None else {}
                buckets = {bucket_pow2(s) for s, _ in rb.compute_log}
                seen_buckets |= buckets
                compiles = (rb._fused.compile_count()
                            if rb._fused is not None else 0)
                csv_row(
                    f"elastic/{scene}_{arm}_x{scale:g}",
                    m.get("measured_decide_ms_mean", 0.0) * 1e3,
                    f"lam={sc.lam * scale:.1f}"
                    f";I_base={i_base}"
                    f";I_max={run.n_instances}"
                    f";peak_alive={m.get('peak_alive', i_base)}"
                    f";shed_rate={m['shed_rate']:.4f}"
                    f";shed={m['shed']}"
                    f";scale_ups={m.get('scale_ups', 0)}"
                    f";scale_downs={m.get('scale_downs', 0)}"
                    f";scale_up_lag_s={m.get('scale_up_lag_s', 0.0):g}"
                    f";p50_e2e={m['p50_e2e']:.3f}"
                    f";p99_e2e={m['p99_e2e']:.3f}"
                    f";goodput={m['goodput']:.2f}"
                    f";tput={m['throughput']:.2f}"
                    f";cost={m['cost_per_req']:.3e}"
                    f";failed={m['failed']}"
                    f";roster_reseeds={st.get('roster_reseed', 0)}"
                    f";compiles={compiles}"
                    f";r_buckets={len(buckets)}"
                    + _prio_cols(m))
                # the no-recompile-on-scale gate: the runner is cached
                # on the bundle, so its compile count is cumulative
                # across arms and must never exceed one program per
                # pow2 R bucket ever seen — autoscaler roster churn
                # (scale_ups > 0 in the elastic arms) adds nothing
                assert compiles <= len(seen_buckets), (
                    "roster churn must not add XLA compiles: "
                    f"{compiles} programs for {len(seen_buckets)} "
                    "R buckets")
        run.elastic = base


if __name__ == "__main__":
    from .common import flush_json
    main()
    flush_json("elastic")
