"""Fig 4: batching ablation — LPT-off, adaptive-off, fixed batch sizes.
The paper: LPT-off within ±2.3% (dead reckoning already steers);
adaptive-off costs 0.4-6%; the batched-KNN keeps bs=1 from collapsing."""
from __future__ import annotations

from .common import context, csv_row, rb_cell
from repro.core import PRESETS


def main():
    ctx = context()
    rows = []
    for lam in (8.0, 16.0, 24.0):
        for name, kw in (("default", {}),
                         ("lpt_off", dict(lpt=False)),
                         ("adaptive_off", dict(adaptive=False)),
                         ("bs1", dict(fixed_batch=1)),
                         ("bs16", dict(fixed_batch=16)),
                         ("bs32", dict(fixed_batch=32))):
            m = rb_cell(ctx, PRESETS["uniform"], lam, cfg_kw=kw)
            rows.append((name, lam, m))
            csv_row(f"batching/{name}@{lam:.0f}",
                    m.get("measured_decide_ms_per_req", 0.0) * 1e3,
                    f"e2e={m['mean_e2e']:.2f};q={m['quality']:.3f}")
    return rows


if __name__ == "__main__":
    main()
