"""Table 12 + §6.8: deployed predictor accuracy and headroom.

TPOT-head MAE per tier on held-out sweeps; KNN best-model accuracy and
its insensitivity to k; oracle vs prompt-blind-mix headroom."""
from __future__ import annotations

import numpy as np

from .common import _embed_all, context, csv_row
from repro.core.scheduler import _tier_sweep
from repro.estimators.knn import KNNEstimator
from repro.estimators.latency import LatencyHead, mae, mape


def main():
    ctx = context()
    rows = []
    rng = np.random.default_rng(99)
    # --- latency heads (held-out tier sweeps)
    for t in ctx["tiers"]:
        X, y = _tier_sweep(t, rng)
        head = ctx["bundle"].heads[t.name]
        pred = head.model.predict(X)
        m_ae = mae(pred, y) * 1e3
        # end-to-end MAPE: T = tpot * (d/b + L)
        Lh = rng.uniform(50, 600, len(y))
        e2e_p = pred * (X[:, 1] / np.maximum(X[:, 0], 1) + Lh)
        e2e_t = y * (X[:, 1] / np.maximum(X[:, 0], 1) + Lh)
        m_ape = mape(e2e_p, e2e_t)
        rows.append((t.name, m_ae, m_ape))
        csv_row(f"predictors/tpot_{t.name.split('/')[0]}", 0.0,
                f"tpot_mae_ms={m_ae:.3f};e2e_mape={m_ape*100:.1f}%")
    # --- KNN accuracy + k sweep
    prompts, Q, L = ctx["ds"].split("test")
    emb = _embed_all(ctx["bundle"], prompts)
    for k in (5, 10, 20, 50):
        knn = KNNEstimator(k=k, backend="jax").fit(
            ctx["train_emb"], ctx["train_Q"], ctx["train_L"])
        acc = knn.best_model_accuracy(emb, Q)
        qh, lh = knn.query(emb)
        routed_q = float(np.take_along_axis(
            Q, qh.argmax(1)[:, None], 1).mean())
        csv_row(f"predictors/knn_k{k}", 0.0,
                f"best_model_acc={acc:.3f};routed_q={routed_q:.3f}")
    # --- headroom: oracle vs prompt-blind mix
    oracle = float(Q.max(1).mean())
    knn = ctx["bundle"].knn
    qh, _ = knn.query(emb)
    choice = qh.argmax(1)
    shares = np.bincount(choice, minlength=Q.shape[1]) / len(choice)
    rng2 = np.random.default_rng(3)
    blind = rng2.choice(Q.shape[1], len(choice), p=shares)
    blind_q = float(np.take_along_axis(Q, blind[:, None], 1).mean())
    routed_q = float(np.take_along_axis(Q, choice[:, None], 1).mean())
    csv_row("predictors/headroom", 0.0,
            f"oracle={oracle:.3f};routed={routed_q:.3f};"
            f"prompt_blind={blind_q:.3f}")
    # --- length prediction
    _, lh = knn.query(emb)
    csv_row("predictors/length", 0.0,
            f"len_mape={np.mean(np.abs(lh-L)/np.maximum(L,1)):.2f}")
    return rows


if __name__ == "__main__":
    main()
