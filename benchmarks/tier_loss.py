"""§6.8 graceful tier loss: remove the entire 72B tier mid-run; losing a
tier must be a capacity/quality-ceiling event, not an availability event
(zero failed requests, load redistributes, quality falls to the
best-remaining ceiling)."""
from __future__ import annotations

from .common import N_REQ, context, csv_row, rb_cell
from repro.core import PRESETS


def main():
    ctx = context()
    rows = []
    iids = [f"{t.name}#{j}" for t in ctx["tiers"] if "72b" in t.name
            for j in range(t.n_instances)]
    for name, w in (("quality", PRESETS["quality"]),
                    ("uniform", PRESETS["uniform"])):
        base = rb_cell(ctx, w, 12.0)
        lost = rb_cell(ctx, w, 12.0,
                       fail_at={"time": 0.0, "instances": iids})
        rows.append((name, base, lost))
        mix = "|".join(f"{k.split('/')[0].split('.')[-1]}:{v:.2f}"
                       for k, v in lost["mix"].items())
        csv_row(f"tier_loss/{name}", 0.0,
                f"q_base={base['quality']:.3f};q_lost={lost['quality']:.3f};"
                f"failed={lost['failed']};e2e={lost['mean_e2e']:.2f};"
                f"mix={mix}")
    # mid-run failure (availability event handling): kill after 20 s
    lost_mid = rb_cell(ctx, PRESETS["uniform"], 12.0,
                       fail_at={"time": 20.0, "instances": iids})
    csv_row("tier_loss/uniform_midrun", 0.0,
            f"failed={lost_mid['failed']};q={lost_mid['quality']:.3f};"
            f"e2e={lost_mid['mean_e2e']:.2f}")
    return rows


if __name__ == "__main__":
    main()
