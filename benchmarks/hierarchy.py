"""Hierarchical sharded scheduling (`repro.serving.hierarchy`): the
cells x load x digest-staleness grid plus the 10k-instance world the
two-level design exists for.

Three row families land in ``BENCH_hierarchy.json``:

  * ``parity_*`` — the exactness pins. ``parity_span_cells{C}``
    compares the sharded instance-column scan (``RBConfig.shard_cells``)
    against the plain fused controller on randomized mid-run telemetry:
    the per-cell max/argmax decomposition is exact, so ``agree`` must be
    1.0 at every cell count. ``parity_balanced_1cell`` runs the full
    balanced hierarchy at one cell against the single fused controller
    on an identical trace: cell telemetry mirrors are bitwise copies and
    the cell engine parks on the global expected count, so the entire
    per-request trajectory (instance, finish time, tokens, terminal
    state, attempt) must match — ``agree`` is the fraction of requests
    with identical trajectories and must be 1.0.
  * ``grid_*`` — balanced mode on the 128-instance ``hyperscale``
    world: cells x load x (digest interval, staleness bound, codec)
    with decide_ms_per_req, digest wire bytes/s, inter-cell imbalance
    (std/mean of assigned counts) and goodput. Each cell count is run
    warm (fresh schedulers share the bundle-cached compiled programs),
    so decide times exclude XLA compiles.
  * ``hyperfleet_10k_*`` — the 10k-instance, fleet-rate multi-tenant
    scenario. A single controller scans a 16384-row pow2 bucket per
    decision; partitioned into cells each engine rides a 1024-row
    bucket. The committed c16 row pins decide_ms_per_req <= 2.5 (the
    acceptance bar); the single-controller row rides along for the
    comparison story. Skipped in smoke mode — a 10k roster is not CI
    material.

Smoke mode for CI: REPRO_HIERARCHY_SMOKE=1 trims to cells (1, 2), one
load, and drops the 10k family while keeping both digest arms and every
parity row, so the artifact schema stays pinned.
"""
from __future__ import annotations

import os

import numpy as np

from .common import N_REQ, csv_row
from repro.core import RBConfig, RouteBalance
from repro.serving.cluster import ClusterSim
from repro.serving.hierarchy import HierarchyConfig, build_scheduler
from repro.serving.scenarios import get_scenario, randomize_telemetry

SMOKE = os.environ.get("REPRO_HIERARCHY_SMOKE", "") not in ("", "0")
CELLS = (1, 2) if SMOKE else (1, 2, 4)
LOADS = (1.0,) if SMOKE else (1.0, 2.0)
# (digest_interval_s, digest_stale_s, codec): a tight exact control
# plane vs a slow lossy one (4x staler digests, int8 wire)
DIGESTS = ((0.25, 1.0, "exact"), (1.0, 4.0, "int8"))
N_GRID = 200 if SMOKE else N_REQ
N_10K = 400
FLEET_CELLS = (16, 32)


def _traj(reqs):
    return [(r.rid, r.instance, r.finish_time, r.tokens_out,
             bool(r.failed), bool(r.shed), r.attempt) for r in reqs]


def _wall(reqs) -> float:
    ends = [r.finish_time if r.finish_time is not None else r.arrival
            for r in reqs]
    return max(ends) - min(r.arrival for r in reqs)


def _span_parity(run, bundle):
    """Sharded-scan agreement: plain fused vs shard_cells on randomized
    telemetry, over several (seed, batch-size) trials per cell count."""
    reqs = run.requests(128, seed=11)
    plain = RouteBalance(RBConfig(charge_compute=False), bundle,
                         run.tiers)
    for C in (2, 4):
        span = RouteBalance(RBConfig(charge_compute=False,
                                     shard_cells=C), bundle, run.tiers)
        agree = total = 0
        dt_sum = calls = 0
        for trial, R in enumerate((16, 48, 16, 48)):
            import time
            sim = ClusterSim(run.tiers, run.names, seed=trial)
            randomize_telemetry(sim, seed=trial,
                                kill_frac=0.1 if trial % 2 else 0.0)
            batch = reqs[trial * 8:trial * 8 + R]
            plain.sim = sim
            _, c0, _ = plain._decide_core(batch)
            span.sim = sim
            t0 = time.perf_counter()
            _, c1, _ = span._decide_core(batch)
            dt_sum += time.perf_counter() - t0
            calls += 1
            agree += int((c0 == c1).sum())
            total += R
        csv_row(f"hierarchy/parity_span_cells{C}",
                dt_sum / calls * 1e6,
                f"agree={agree / total:.4f};trials={calls}"
                f";I={run.n_instances}")
        assert agree == total, f"span cells={C} diverged from fused"


def _balanced_parity(run, bundle):
    """Full-trajectory equality: 1-cell balanced hierarchy vs the
    single fused controller on the same trace."""
    cfg = RBConfig(charge_compute=False)
    reqs_a = run.requests(N_GRID, seed=0)
    m = run.run_cell(RouteBalance(cfg, bundle, run.tiers), reqs_a,
                     seed=0)
    reqs_b = run.requests(N_GRID, seed=0)
    h1 = build_scheduler(cfg, bundle, run.tiers,
                         HierarchyConfig(n_cells=1, routing="balanced"))
    run.run_cell(h1, reqs_b, seed=0)
    ta, tb = _traj(reqs_a), _traj(reqs_b)
    agree = sum(a == b for a, b in zip(ta, tb)) / len(ta)
    csv_row("hierarchy/parity_balanced_1cell",
            m.get("measured_decide_ms_mean", 0.0) * 1e3,
            f"agree={agree:.4f};n={len(ta)};I={run.n_instances}")
    assert agree == 1.0, "1-cell hierarchy diverged from fused"


def _balanced_cell(run, bundle, n_cells, interval, stale, mode,
                   lam_scale, n, seed):
    sched = build_scheduler(
        RBConfig(charge_compute=False), bundle, run.tiers,
        HierarchyConfig(n_cells=n_cells, routing="balanced",
                        digest_interval_s=interval,
                        digest_stale_s=stale, digest_mode=mode))
    reqs = run.requests(n, lam_scale=lam_scale, seed=seed)
    m = run.run_cell(sched, reqs, seed=seed)
    m["_wall"] = _wall(reqs)
    m["_bal"] = sched.balancer
    return m


def _grid(run, bundle):
    sc = run.scenario
    for C in CELLS:
        # warm pass: compile this cell count's programs into the
        # bundle-level cache outside the measured cells (heaviest load
        # so the largest batch buckets are covered)
        _balanced_cell(run, bundle, C, 0.25, 1.0, "exact",
                       LOADS[-1], N_GRID, seed=0)
        for scale in LOADS:
            for interval, stale, mode in DIGESTS:
                m = _balanced_cell(run, bundle, C, interval, stale,
                                   mode, scale, N_GRID, seed=0)
                bal = m["_bal"]
                csv_row(
                    f"hierarchy/grid_{sc.name}_c{C}_x{scale:g}"
                    f"_d{interval:g}{mode}",
                    m.get("measured_decide_ms_mean", 0.0) * 1e3,
                    f"cells={C}"
                    f";lam={sc.lam * scale:.1f}"
                    f";I={run.n_instances}"
                    f";decide_ms_per_req="
                    f"{m.get('measured_decide_ms_per_req', 0.0):.4f}"
                    f";digest_interval_s={interval:g}"
                    f";digest_stale_s={stale:g}"
                    f";digest_mode={mode}"
                    f";digest_bytes_per_s="
                    f"{bal.bytes_sent / max(m['_wall'], 1e-9):.1f}"
                    f";digests={bal.digests_sent}"
                    f";imbalance={bal.imbalance():.4f}"
                    f";goodput={m['goodput']:.2f}"
                    f";p50_e2e={m['p50_e2e']:.3f}"
                    f";p99_e2e={m['p99_e2e']:.3f}"
                    f";shed={m['shed']}"
                    f";failed={m['failed']}"
                    f";n={m['n']}")


def _hyperfleet(run, bundle):
    from repro.core.decision_jax import bucket_pow2
    sc = run.scenario
    for C in FLEET_CELLS:
        i_cell = bucket_pow2(int(np.ceil(run.n_instances / C)))
        # warm run compiles the C per-cell programs on the SAME trace
        # the timed run replays — the deterministic trajectory visits
        # identical (cell, batch-bucket) shapes, so the timed run's
        # fresh schedulers hit the bundle cache on every decide
        _balanced_cell(run, bundle, C, 0.25, 1.0, "exact", 1.0,
                       N_10K, seed=0)
        m = _balanced_cell(run, bundle, C, 0.25, 1.0, "exact", 1.0,
                           N_10K, seed=0)
        bal = m["_bal"]
        csv_row(
            f"hierarchy/hyperfleet_10k_c{C}",
            m.get("measured_decide_ms_mean", 0.0) * 1e3,
            f"cells={C}"
            f";I={run.n_instances}"
            f";I_cell_bucket={i_cell}"
            f";decide_ms_per_req="
            f"{m.get('measured_decide_ms_per_req', 0.0):.4f}"
            f";digest_bytes_per_s="
            f"{bal.bytes_sent / max(m['_wall'], 1e-9):.1f}"
            f";imbalance={bal.imbalance():.4f}"
            f";goodput={m['goodput']:.2f}"
            f";p50_e2e={m['p50_e2e']:.3f}"
            f";p99_e2e={m['p99_e2e']:.3f}"
            f";failed={m['failed']}"
            f";n={m['n']}")
    # the single-controller comparison: one fused engine scanning the
    # whole roster's 16384-row bucket per decision (informational — the
    # acceptance pin rides the c16 row above)
    cfg = RBConfig(charge_compute=False)
    reqs = run.requests(N_10K, seed=0)
    run.run_cell(RouteBalance(cfg, bundle, run.tiers), reqs, seed=0)
    reqs = run.requests(N_10K, seed=0)
    m = run.run_cell(RouteBalance(cfg, bundle, run.tiers), reqs, seed=0)
    csv_row(
        "hierarchy/hyperfleet_10k_single",
        m.get("measured_decide_ms_mean", 0.0) * 1e3,
        f"cells=1"
        f";I={run.n_instances}"
        f";I_cell_bucket={bucket_pow2(run.n_instances)}"
        f";decide_ms_per_req="
        f"{m.get('measured_decide_ms_per_req', 0.0):.4f}"
        f";goodput={m['goodput']:.2f}"
        f";p50_e2e={m['p50_e2e']:.3f}"
        f";p99_e2e={m['p99_e2e']:.3f}"
        f";failed={m['failed']}"
        f";n={m['n']}")


def main():
    cluster = get_scenario("cluster").build(dataset_n=300)
    _span_parity(cluster, cluster.bundle())
    _balanced_parity(cluster, cluster.bundle())
    hyper = get_scenario("hyperscale").build(dataset_n=300 if SMOKE
                                             else 600)
    _grid(hyper, hyper.bundle())
    if not SMOKE:
        fleet = get_scenario("hyperfleet_10k").build(dataset_n=600)
        _hyperfleet(fleet, fleet.bundle())
    else:
        print("# smoke: hyperfleet_10k family skipped")


if __name__ == "__main__":
    from .common import flush_json
    main()
    flush_json("hierarchy")
