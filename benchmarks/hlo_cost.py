"""Trip-count-aware cost extraction from optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, which silently
drops ~n_layers x of the compute for layer-scanned models (verified in
EXPERIMENTS.md §Dry-run notes). This walker parses the optimized HLO,
recovers loop trip counts from the canonical scan/fori condition pattern
(a `s32[] constant(N)` feeding a compare), and accumulates per-device:

  * flops            — 2*out_elems*K for every dot/convolution, x trips
  * hbm_bytes        — post-fusion traffic model: every fusion/dot/conv/
                       collective reads its operands and writes its result
  * collectives      — count / payload / link-bytes per kind, x trips
                       (ring link model: all-gather ~1x output, all-reduce
                       ~2x, reduce-scatter / all-to-all /
                       collective-permute ~1x)

Shapes in post-SPMD HLO are per-partition, so results are per-device.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
                "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1,
                "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")

_OPS = ("dot|convolution|fusion|while|call|conditional|custom-call|"
        "all-gather-start|all-gather-done|all-gather|all-reduce-start|"
        "all-reduce-done|all-reduce|reduce-scatter|all-to-all|"
        "collective-permute-start|collective-permute-done|"
        "collective-permute")
_OP_RE = re.compile(r"\b(" + _OPS + r")\(")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")

_LINK_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_elems_bytes(tok: str) -> Tuple[int, int]:
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(tok):
        if dt not in _DTYPE_BYTES:
            continue
        n = math.prod([int(d) for d in dims.split(",") if d]) if dims else 1
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self.types: Dict[str, Dict[str, str]] = {}   # comp -> name -> type
        self.entry: Optional[str] = None
        cur = None
        for raw in text.splitlines():
            line = raw.strip()
            if not line or line.startswith(("//", "#")):
                continue
            if cur is None:
                if line.endswith("{") and "->" in line:
                    h = _HDR_RE.match(line)
                    if h:
                        cur = h.group(2)
                        self.computations[cur] = []
                        self.types[cur] = {}
                        if h.group(1):
                            self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            self.computations[cur].append(line)
            im = _INSTR_RE.match(line)
            if im:
                rest = im.group(2)
                om = _OP_RE.search(rest)
                if om:
                    self.types[cur][im.group(1)] = rest[:om.start()]
                else:
                    # non-tracked op: type is everything up to "opname("
                    om2 = re.search(r"\s([a-z][a-z0-9\-]*)\(", " " + rest)
                    self.types[cur][im.group(1)] = \
                        rest[:om2.start()] if om2 else rest
        if self.entry is None and self.computations:
            self.entry = list(self.computations)[-1]
        self._memo: Dict[str, Dict[str, float]] = {}
        self._kinds: Dict[str, Dict[str, Dict[str, float]]] = {}

    # ------------------------------------------------------------------
    _LAYOUT_ONLY = {"parameter", "convert", "copy", "bitcast", "tuple",
                    "get-tuple-element", "constant", "reshape",
                    "broadcast", "transpose", "iota"}

    def is_layout_fusion(self, comp: str) -> bool:
        """True if the fused computation only moves/converts data. On TPU
        these do not exist (native bf16 dots; layout changes fuse into
        consumers) — they are XLA:CPU artifacts (wholesale bf16->f32
        upconversion of loop-carried KV caches was measured at 32x the
        real traffic) and are excluded from the HBM model."""
        for line in self.computations.get(comp, ()):
            im = _INSTR_RE.match(line)
            if not im:
                continue
            om = re.search(r"\s([a-z][a-z0-9\-]*)\(", " " + im.group(2))
            if om and om.group(1) not in self._LAYOUT_ONLY:
                return False
        return True

    def trip_count(self, cond: str) -> int:
        best = 1
        for line in self.computations.get(cond, ()):
            for m in re.finditer(r"[su](?:32|64)\[\]\s+constant\((\d+)\)",
                                 line):
                best = max(best, int(m.group(1)))
        return best

    def _operand_bytes(self, comp: str, args: str) -> int:
        table = self.types.get(comp, {})
        total = 0
        for name in re.findall(r"%([\w.\-]+)", args):
            t = table.get(name)
            if t:
                total += _shape_elems_bytes(t)[1]
        return total

    def _operand_shapes(self, comp: str, args: str) -> List[str]:
        table = self.types.get(comp, {})
        out = []
        for name in re.findall(r"%([\w.\-]+)", args):
            if name in table:
                out.append(table[name])
        return out

    # ------------------------------------------------------------------
    def cost(self, comp: Optional[str] = None) -> Dict[str, float]:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        acc = {"flops": 0.0, "hbm_bytes": 0.0, "coll_bytes": 0.0,
               "coll_link_bytes": 0.0, "coll_count": 0.0}
        kinds: Dict[str, Dict[str, float]] = {}
        self._memo[comp] = acc
        self._kinds[comp] = kinds

        def add_kinds(sub: Dict, mult: float):
            for kname, d in sub.items():
                t = kinds.setdefault(kname, {"count": 0.0, "bytes": 0.0,
                                             "link_bytes": 0.0})
                for k2 in t:
                    t[k2] += mult * d[k2]

        for line in self.computations.get(comp, ()):
            im = _INSTR_RE.match(line)
            if not im:
                continue
            rest = im.group(2)
            om = _OP_RE.search(rest)
            if not om:
                continue
            op = om.group(1)
            result_tok = rest[:om.start()]
            args_and_attrs = rest[om.end():]
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", rest)
                cond = re.search(r"condition=%?([\w.\-]+)", rest)
                if body:
                    trips = self.trip_count(cond.group(1)) if cond else 1
                    sub = self.cost(body.group(1))
                    for k in acc:
                        acc[k] += trips * sub[k]
                    add_kinds(self._kinds.get(body.group(1), {}), trips)
                continue
            if op in ("fusion", "call", "conditional", "custom-call"):
                for cm in re.finditer(r"(?:calls=|to_apply=)%?([\w.\-]+)",
                                      rest):
                    sub = self.cost(cm.group(1))
                    for k in acc:
                        acc[k] += sub[k]
                    add_kinds(self._kinds.get(cm.group(1), {}), 1.0)
                bm = re.search(r"branch_computations=\{([^}]*)\}", rest)
                if bm:
                    subs = [self.cost(n.strip().lstrip("%"))
                            for n in bm.group(1).split(",") if n.strip()]
                    if subs:   # worst branch
                        worst = max(subs, key=lambda s: s["flops"])
                        for k in acc:
                            acc[k] += worst[k]
                if op == "fusion":
                    called = re.search(r"calls=%?([\w.\-]+)", rest)
                    if called and self.is_layout_fusion(called.group(1)):
                        continue   # CPU-only layout/convert artifact
                    _, out_b = _shape_elems_bytes(result_tok)
                    arg_names = args_and_attrs.split("),")[0]
                    # per-operand cap: a fusion that only *slices* a huge
                    # operand (dynamic-slice of a stacked cache) reads the
                    # slice, not the operand
                    traffic = out_b
                    for t in self._operand_shapes(comp, arg_names):
                        ob = _shape_elems_bytes(t)[1]
                        traffic += min(ob, max(16 * out_b, 4096))
                    acc["hbm_bytes"] += traffic
                continue
            if op in ("dot", "convolution"):
                out_elems, out_b = _shape_elems_bytes(result_tok)
                arg_names = args_and_attrs.split(")")[0]
                opers = self._operand_shapes(comp, arg_names)
                in_b = sum(_shape_elems_bytes(t)[1] for t in opers)
                k = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
                if cm and opers:
                    dims_m = _SHAPE_RE.search(opers[0])
                    if dims_m:
                        dims = [int(d) for d in dims_m.group(2).split(",")
                                if d]
                        for i in cm.group(1).split(","):
                            if i and int(i) < len(dims):
                                k *= dims[int(i)]
                if op == "convolution":
                    # window size from kernel operand
                    if len(opers) > 1:
                        km = _SHAPE_RE.search(opers[1])
                        if km:
                            kd = [int(d) for d in km.group(2).split(",")
                                  if d]
                            k = max(1, math.prod(kd) // max(kd[-1], 1))
                acc["flops"] += 2.0 * out_elems * max(k, 1)
                acc["hbm_bytes"] += out_b + in_b
                continue
            kind = op.replace("-start", "").replace("-done", "")
            if kind in _LINK_FACTOR and not op.endswith("-done"):
                _, out_b = _shape_elems_bytes(result_tok)
                f = _LINK_FACTOR[kind]
                acc["coll_bytes"] += out_b
                acc["coll_link_bytes"] += out_b * f
                acc["coll_count"] += 1
                acc["hbm_bytes"] += out_b
                t = kinds.setdefault(kind, {"count": 0.0, "bytes": 0.0,
                                            "link_bytes": 0.0})
                t["count"] += 1
                t["bytes"] += out_b
                t["link_bytes"] += out_b * f
        return acc

    def kinds(self) -> Dict[str, Dict[str, float]]:
        self.cost()
        return self._kinds.get(self.entry, {})


def analyze(hlo_text: str) -> Dict:
    mod = HloModule(hlo_text)
    c = mod.cost()
    return {"flops": c["flops"], "hbm_bytes": c["hbm_bytes"],
            "coll_bytes": c["coll_bytes"],
            "coll_link_bytes": c["coll_link_bytes"],
            "coll_count": c["coll_count"], "by_kind": mod.kinds()}
