"""§4.1 greedy-gap replay: re-solve logged score matrices with a
batch-level Hungarian matching; the paper finds 15.6% assignment
divergence but ~zero realized-quality change."""
from __future__ import annotations

import numpy as np

from .common import context, csv_row
from repro.core import PRESETS
from repro.core.assignment import greedy_assign, hungarian, lpt_order
from repro.core.scoring import score_matrix


def main(n_batches: int = 40, batch_size: int = 24, seed: int = 0):
    ctx = context()
    rng = np.random.default_rng(seed)
    names = ctx["names"]
    tiers = ctx["tiers"]
    inst_tiers = [t for t in tiers for _ in range(t.n_instances)]
    m_of_i = np.array([names.index(t.model) for t in inst_tiers])
    I = len(inst_tiers)
    prompts, Q, L = ctx["ds"].split("test")
    div, dq = [], []
    for _ in range(n_batches):
        idx = rng.choice(len(prompts), batch_size, replace=False)
        q_inst = Q[idx][:, m_of_i]
        l_inst = L[idx][:, m_of_i]
        price_out = np.array([t.price_out for t in inst_tiers])
        price_in = np.array([t.price_in for t in inst_tiers])
        len_in = np.array([prompts[i].len_in for i in idx], float)
        c_hat = (len_in[:, None] * price_in + l_inst * price_out) / 1e6
        tpot = np.array([t.tpot(8, 500) for t in inst_tiers])
        d = rng.uniform(0, 2000, I)
        b = rng.integers(1, 16, I).astype(float)
        free = rng.integers(0, 8, I).astype(float)
        maxb = np.array([t.max_batch for t in inst_tiers], float)
        order = lpt_order(l_inst.max(1))
        g_choice, _ = greedy_assign(order, q_inst, c_hat, l_inst, tpot,
                                    d, b, free, maxb, PRESETS["uniform"])
        # batch-level matching on the static score matrix (no within-batch
        # state updates) — what Hungarian would see
        T = tpot[None, :] * (np.where(free > 0, 0, d / np.maximum(b, 1))
                             + l_inst)
        S = score_matrix(q_inst, c_hat, T, PRESETS["uniform"])
        # replicate instances by free capacity to allow multi-assignment
        h_choice = hungarian(-S) if batch_size <= I else None
        if h_choice is None:
            cols = np.tile(np.arange(I), int(np.ceil(batch_size / I)))
            Sx = -S[:, cols % I]
            h = hungarian(Sx)
            h_choice = cols[h] % I
        div.append(float((g_choice != h_choice).mean()))
        qg = q_inst[np.arange(batch_size), g_choice].mean()
        qh = q_inst[np.arange(batch_size), h_choice].mean()
        dq.append(float(qh - qg))
    csv_row("replay/greedy_vs_hungarian", 0.0,
            f"divergence={np.mean(div):.3f};dq={np.mean(dq):+.4f}")
    return np.mean(div), np.mean(dq)


if __name__ == "__main__":
    main()
