"""Table 13 + §6.9: tail latency at headline operating points and
robustness under non-stationary (gamma-bursty / square-wave) arrivals."""
from __future__ import annotations

from .common import context, csv_row, fit_router, pipeline_cell, rb_cell
from repro.core import PRESETS
from repro.core.dispatchers import ShortestQueue
from repro.core.routers import AvengersProRouter, BestRouteRouter


def main():
    ctx = context()
    rows = []
    for lam in (12.0, 30.0):
        for name, w in (("uniform", PRESETS["uniform"]),
                        ("quality", PRESETS["quality"]),
                        ("cost", PRESETS["cost"])):
            m = rb_cell(ctx, w, lam)
            rows.append((f"rb_{name}@{lam:.0f}", m))
        br = fit_router(ctx, BestRouteRouter(threshold=0.5))
        m = pipeline_cell(ctx, br, ShortestQueue(), lam,
                          deployment="serial")
        rows.append((f"bestroute_serial@{lam:.0f}", m))
        ap = fit_router(ctx, AvengersProRouter(p_w=0.8))
        m = pipeline_cell(ctx, ap, ShortestQueue(), lam,
                          deployment="serial")
        rows.append((f"avengers_serial@{lam:.0f}", m))
    # non-stationary arrivals at matched mean lam=18
    for kind in ("poisson", "gamma", "square"):
        m = rb_cell(ctx, PRESETS["uniform"], 18.0, arrival=kind)
        rows.append((f"rb_uniform_{kind}@18", m))
        br = fit_router(ctx, BestRouteRouter(threshold=0.5))
        m = pipeline_cell(ctx, br, ShortestQueue(), 18.0,
                          deployment="serial", arrival=kind)
        rows.append((f"bestroute_serial_{kind}@18", m))
    print("# tails: p95/p99 e2e, p99 ttft")
    for name, m in rows:
        csv_row(f"tails/{name}", 0.0,
                f"p95={m['p95_e2e']:.1f};p99={m['p99_e2e']:.1f};"
                f"p99ttft={m['p99_ttft']:.2f};e2e={m['mean_e2e']:.2f}")
    return rows


if __name__ == "__main__":
    main()
