"""Batch-decision latency: numpy greedy loop vs the jitted decision core.

One "decision" = the whole per-batch hot-path tail after the estimator
feed: Eq. 2 admission, LPT ordering and the dead-reckoned greedy pass
(Eq. 1 per request). The paper's headline is that this stays cheap on
the request hot path (~32 ms/batch at 12 req/s, §6.3); the jitted core
is what keeps it cheap as R (batch) and I (instances) scale.

Rows: decision_core/<backend>_R<R>_I<I>, us per *batch* decision, with
per-request us derived. Run directly or via ``python -m benchmarks.run
decision_core``.
"""
from __future__ import annotations

import time

import numpy as np

from .common import csv_row
from repro.core import PRESETS
from repro.core.assignment import greedy_assign, lpt_order
from repro.core.budget import admission_mask
from repro.core import decision_jax


def _problem(rng, R, I):
    q = rng.uniform(0, 1, (R, I))
    ln = rng.uniform(20, 500, (R, I))
    plm = ln.max(1)
    tpot = rng.uniform(0.005, 0.05, I)
    nominal = tpot * 0.9
    d = rng.uniform(0, 3000, I)
    b = rng.integers(1, 12, I).astype(float)
    free = rng.integers(0, 6, I).astype(float)
    maxb = np.full(I, 48.0)
    price_in = rng.uniform(0.05, 0.5, I)
    price_out = rng.uniform(0.05, 0.5, I)
    budgets = np.where(rng.uniform(size=R) < 0.5,
                       rng.uniform(1e-5, 3e-4, R), np.nan)
    len_in = rng.uniform(10, 500, R)
    return (q, ln, plm, tpot, nominal, d, b, free, maxb, budgets,
            len_in, price_in, price_out)


def _time(fn, n=30, warmup=5):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def decide_numpy(p, weights):
    (q, ln, plm, tpot, nominal, d, b, free, maxb, budgets,
     len_in, price_in, price_out) = p
    allowed, c_hat = admission_mask(budgets, len_in, ln,
                                    price_in, price_out)
    order = lpt_order(plm)
    return greedy_assign(order, q, c_hat, ln, tpot, d, b, free, maxb,
                         weights, allowed, latency_mode="full",
                         nominal_tpot=nominal)[0]


def decide_jax(p, weights):
    (q, ln, plm, tpot, nominal, d, b, free, maxb, budgets,
     len_in, price_in, price_out) = p
    return decision_jax.decide(q, ln, plm, tpot, nominal, d, b, free,
                               maxb, budgets, len_in, price_in,
                               price_out, weights)[0]


def main():
    rng = np.random.default_rng(0)
    w = PRESETS["uniform"]
    speedups = {}
    for I in (13, 50, 200):
        for R in (8, 16, 64, 256):
            p = _problem(rng, R, I)
            ch_np = decide_numpy(p, w)
            ch_jx = decide_jax(p, w)
            agree = float((ch_np == ch_jx).mean())
            dt_np = _time(lambda: decide_numpy(p, w))
            dt_jx = _time(lambda: decide_jax(p, w))
            speedups[(R, I)] = dt_np / dt_jx
            csv_row(f"decision_core/numpy_R{R}_I{I}", dt_np * 1e6,
                    f"per_req_us={dt_np/R*1e6:.1f}")
            csv_row(f"decision_core/jax_R{R}_I{I}", dt_jx * 1e6,
                    f"per_req_us={dt_jx/R*1e6:.1f};"
                    f"speedup={dt_np/dt_jx:.2f}x;agree={agree:.3f}")
    key = (64, 13)
    print(f"# paper pool point R=64 I=13: jitted core "
          f"{speedups[key]:.2f}x the numpy loop")


if __name__ == "__main__":
    main()
