"""Table 5 / Table 6 / Fig 2(b): the deployment ladder as a
policy-orthogonal engine axis — the same `SchedulingPolicy` objects
served under every `deployment=` arm of the one `ServingEngine`:

  serial_published — one scoring call per request on one server,
                     charged at the policy's `serial_scoring_s` (the
                     baselines as published; collapses under load)
  microbatch       — co-located batch collector, pads to the longest
                     sequence (1.72 s per batch of 64), batches cannot
                     overlap
  concurrent       — our enhancement: scoring micro-batched off the
                     scheduling loop on a worker pool, routing
                     byte-identical
  windowed         — RouteBalance's amortized batch scoring (meets the
                     requirement by construction)

Includes the vLLM-SR-analogue external classifier (bounded queue =>
failed requests, Table 6) and the quality-only argmax router motivation
row. Rows carry `policy=` / `deployment=` columns and land in
``BENCH_ladder.json``; the schema test pins that the serial_published
arms degrade under load while the concurrent-scoring variants hold.
"""
from __future__ import annotations

from .common import context, csv_row, policy_cell
from repro.core import PRESETS

LAMBDAS = (12.0, 24.0, 30.0)

# cell name, registry policy, policy kwargs, deployment, extra cell kw
CELLS = [
    ("rb_uniform", "routebalance", dict(weights=PRESETS["uniform"]),
     "windowed", {}),
    # (i) serial as-published vs (ii) microbatch vs (iv) concurrent —
    # the SAME fitted policy class, only the engine knob moves
    ("bestroute_serial", "bestroute-rr", dict(threshold=0.5),
     "serial_published", {}),
    ("bestroute_microbatch", "bestroute-rr", dict(threshold=0.5),
     "microbatch", {}),
    ("bestroute_concurrent", "bestroute-sq", dict(threshold=0.5),
     "concurrent", {}),
    ("avengers_serial", "avengers-sq", dict(p_w=0.8),
     "serial_published", {}),
    ("avengers_concurrent", "avengers-sq", dict(p_w=0.8),
     "concurrent", {}),
    # (iii) vLLM-SR analogue: external classifier, bounded queue
    ("vllm_sr", "bestroute-rr", dict(threshold=0.6), "serial_published",
     dict(serial_scoring_s=0.120, queue_capacity=256)),
    # motivation: quality-only argmax router (always nominally best)
    ("argmax_quality", "bestroute-sq", dict(threshold=1.0),
     "concurrent", {}),
]


def main():
    ctx = context()
    rows = []
    for lam in LAMBDAS:
        for cell_name, pname, pkw, deployment, cell_kw in CELLS:
            m = policy_cell(ctx, pname, lam, deployment=deployment,
                            policy_kw=pkw, **cell_kw)
            rows.append((f"{cell_name}@{lam:.0f}", m))
    print("# ladder: name -> e2e_s, residual_s, failed")
    for name, m in rows:
        csv_row(f"ladder/{name}",
                m.get("measured_decide_ms_per_req", 0.0) * 1e3,
                f"policy={m['policy']}"
                f";deployment={m['deployment']}"
                f";lam={m['lam']:.0f}"
                f";e2e={m['mean_e2e']:.2f}"
                f";resid={m['mean_residual']:.3f}"
                f";fail={m['failed']}"
                f";q={m['quality']:.3f}"
                f";goodput={m['goodput']:.2f}")
    return rows


if __name__ == "__main__":
    from .common import flush_json
    main()
    flush_json("ladder")
