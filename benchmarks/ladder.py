"""Table 5 / Table 6 / Fig 2(b): the deployment ladder — serial scoring
collapses under load; engineering-equalized (concurrent) baselines
survive; RouteBalance's amortized batch scoring meets the requirement by
construction. Includes the vLLM-SR-analogue bounded-queue external
service (failures) and the quality-only argmax router motivation row."""
from __future__ import annotations

from .common import context, csv_row, fit_router, pipeline_cell, rb_cell
from repro.core import PRESETS
from repro.core.dispatchers import RoundRobin, ShortestQueue
from repro.core.routers import AvengersProRouter, BestRouteRouter

LAMBDAS = (12.0, 24.0, 30.0)


def main():
    ctx = context()
    rows = []
    for lam in LAMBDAS:
        m = rb_cell(ctx, PRESETS["uniform"], lam)
        rows.append((f"rb_uniform@{lam:.0f}", m))
        # (i) serial as-published
        br = fit_router(ctx, BestRouteRouter(threshold=0.5))
        m = pipeline_cell(ctx, br, RoundRobin(), lam, deployment="serial")
        rows.append((f"bestroute_serial@{lam:.0f}", m))
        # (ii) co-located microbatch
        m = pipeline_cell(ctx, br, RoundRobin(), lam,
                          deployment="microbatch")
        rows.append((f"bestroute_microbatch@{lam:.0f}", m))
        # (iv) enhanced concurrent (ours)
        m = pipeline_cell(ctx, br, ShortestQueue(), lam,
                          deployment="concurrent")
        rows.append((f"bestroute_concurrent@{lam:.0f}", m))
        # Avengers-Pro serial vs concurrent
        ap = fit_router(ctx, AvengersProRouter(p_w=0.8))
        m = pipeline_cell(ctx, ap, ShortestQueue(), lam,
                          deployment="serial")
        rows.append((f"avengers_serial@{lam:.0f}", m))
        m = pipeline_cell(ctx, ap, ShortestQueue(), lam,
                          deployment="concurrent")
        rows.append((f"avengers_concurrent@{lam:.0f}", m))
        # (iii) vLLM-SR analogue: external classifier, bounded queue
        sr = fit_router(ctx, BestRouteRouter(threshold=0.6))
        sr.serial_scoring_s = 0.120
        m = pipeline_cell(ctx, sr, RoundRobin(), lam, deployment="serial",
                          queue_capacity=256)
        rows.append((f"vllm_sr@{lam:.0f}", m))
        # motivation: quality-only argmax router (always nominally best)
        qr = fit_router(ctx, BestRouteRouter(threshold=1.0))
        m = pipeline_cell(ctx, qr, ShortestQueue(), lam,
                          deployment="concurrent")
        rows.append((f"argmax_quality@{lam:.0f}", m))
    print("# ladder: name -> e2e_s, residual_s, failed")
    for name, m in rows:
        csv_row(f"ladder/{name}",
                m.get("measured_decide_ms_per_req", 0.0) * 1e3,
                f"e2e={m['mean_e2e']:.2f};resid={m['mean_residual']:.3f};"
                f"fail={m['failed']};q={m['quality']:.3f}")
    return rows


if __name__ == "__main__":
    main()
