"""Hot-path microbenchmarks: the batched KNN lookup (the paper's ~27 ms
term), the greedy scoring loop scaling (|I| = 13/100/500; paper:
12.8/14.3/22.5 us), and kernel-vs-oracle parity timings.

Pallas kernels run interpret=True here (CPU container) — their timing is
NOT the TPU number; the jitted jnp backend is the measured hot path, and
the kernels are validated for correctness + lowered-structure only."""
from __future__ import annotations

import time

import numpy as np

from .common import context, csv_row
from repro.core import PRESETS
from repro.core.assignment import greedy_assign, lpt_order


def _time(fn, n=20, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def main():
    ctx = context()
    bundle = ctx["bundle"]
    rng = np.random.default_rng(0)
    # batched embed+KNN (the per-batch amortized decision compute)
    prompts, Q, L = ctx["ds"].split("test")
    for B in (1, 16, 64):
        reqs = [prompts[i] for i in range(B)]
        from repro.estimators.embedding import pad_tokens
        toks = pad_tokens([p.tokens for p in reqs], bundle.encoder.max_len)
        lens = np.array([min(len(p.tokens), 128) for p in reqs])
        dt_e = _time(lambda: bundle.encoder.encode(toks, lens))
        emb = bundle.encoder.encode(toks, lens)
        dt_k = _time(lambda: bundle.knn.query(emb))
        csv_row(f"kernels/embed_knn_B{B}", (dt_e + dt_k) * 1e6,
                f"embed_us={dt_e*1e6:.0f};knn_us={dt_k*1e6:.0f};"
                f"per_req_us={(dt_e+dt_k)/B*1e6:.0f}")
    # scoring-loop scaling with instance count (paper §4.2)
    for I in (13, 100, 500):
        R = 16
        q_inst = rng.uniform(0, 1, (R, I))
        c_hat = rng.uniform(1e-6, 1e-4, (R, I))
        l_inst = rng.uniform(50, 500, (R, I))
        tpot = rng.uniform(0.01, 0.05, I)
        d = rng.uniform(0, 2000, I)
        b = rng.integers(1, 16, I).astype(float)
        free = rng.integers(0, 8, I).astype(float)
        maxb = np.full(I, 48.0)
        order = lpt_order(l_inst.max(1))
        dt = _time(lambda: greedy_assign(
            order, q_inst, c_hat, l_inst, tpot, d, b, free, maxb,
            PRESETS["uniform"]), n=10)
        csv_row(f"kernels/scoring_loop_I{I}", dt / R * 1e6,
                f"per_req_us={dt/R*1e6:.1f}")
    # pallas kernels vs oracles (correctness timing, interpret mode)
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels import ref as kref
    q = jnp.asarray(rng.normal(size=(32, 128)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4096, 128)), jnp.float32)
    dv, di = ops.knn_topk(q, x, k=10)
    rv, ri = kref.knn_topk_ref(q, x, k=10)
    err = float(jnp.abs(dv - rv).max())
    dt_ref = _time(lambda: jax.block_until_ready(
        kref.knn_topk_ref(q, x, k=10)), n=10)
    csv_row("kernels/knn_topk_pallas", dt_ref * 1e6,
            f"allclose_err={err:.1e};jnp_oracle_us={dt_ref*1e6:.0f}")
    return None


if __name__ == "__main__":
    main()
