"""Kernel-level microbenchmarks -> BENCH_kernels.json.

Three families:

  * the historical hot-spot rows — batched embed+KNN (the paper's
    ~27 ms term), greedy scoring-loop scaling (|I| = 13/100/500;
    paper: 12.8/14.3/22.5 us), knn_topk-vs-oracle;
  * the **decision megakernel grid**: per-batch decision µs over
    (R, I) cells with megakernel / fused-XLA / staged-jax columns —
    the same `RouteBalance._decide_core` probe `benchmarks.hotpath`
    times, here centered on the kernel comparison (interleaved
    min-of-N so ambient CPU drift doesn't bias one backend). On this
    CPU container the megakernel runs interpret mode
    (``REPRO_PALLAS_INTERPRET``), which executes as XLA — the
    parity-or-better gate against fused-XLA
    (`benchmarks.perf_guard._megakernel_guard`) is meaningful here,
    and the TPU compiled path reuses the identical kernel body;
  * **multi-window batching**: K coalesced windows through one
    megakernel dispatch (`FusedHotPath.decide_cols_multi`) vs K
    separate dispatches — the launch/sync amortization rows.

Smoke mode for CI: REPRO_KERNELS_SMOKE=1 trims the decision grid to the
small cells (a subset of the full grid, so perf_guard can gate smoke
rows against the committed artifact's shape).
"""
from __future__ import annotations

import os
import time

import numpy as np

from .common import context, csv_row, make_requests
from repro.core import PRESETS, RBConfig, RouteBalance
from repro.core.assignment import greedy_assign, lpt_order

FLUSH_AS = "kernels"     # artifact name: BENCH_kernels.json

SMOKE = os.environ.get("REPRO_KERNELS_SMOKE", "") not in ("", "0")
DECISION_GRID = (((8, 13), (16, 13)) if SMOKE else
                 ((8, 13), (16, 13), (64, 13), (64, 52), (256, 128)))
MULTIWIN_GRID = (((4, 16, 13),) if SMOKE else
                 ((4, 16, 13), (8, 16, 13), (4, 64, 52)))


def _time(fn, n=20, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def _decision_cells(ctx):
    """The megakernel-vs-fused-vs-staged (R, I) grid."""
    from .hotpath import scaled_pool
    from repro.serving.cluster import ClusterSim
    from repro.serving.scenarios import randomize_telemetry
    backends = ("jax", "fused", "megakernel")
    for R, I in DECISION_GRID:
        tiers = (ctx["tiers"]
                 if I == sum(t.n_instances for t in ctx["tiers"])
                 else scaled_pool(ctx["tiers"], I))
        batch = make_requests(ctx["ds"], "test", np.zeros(R))
        rbs, picks = {}, {}
        for be in backends:
            sim = randomize_telemetry(
                ClusterSim(tiers, ctx["names"], seed=0), seed=1)
            rb = RouteBalance(RBConfig(decision_backend=be),
                              ctx["bundle"], tiers)
            rb.sim = sim
            rb._decide_core(batch)              # compile + warm
            instances, choice, _ = rb._decide_core(batch)
            picks[be] = [instances[int(i)].iid for i in choice]
            rbs[be] = rb
        agree = float(np.mean([
            all(picks[be][r] == picks["megakernel"][r]
                for be in backends) for r in range(R)]))
        reps = 10 if R >= 256 else 16
        ts = {be: [] for be in backends}
        for _ in range(reps):                   # interleaved timing
            for be, rb in rbs.items():
                t0 = time.perf_counter()
                rb._decide_core(batch)
                ts[be].append(time.perf_counter() - t0)
        best = {be: min(v) * 1e6 for be, v in ts.items()}
        csv_row(
            f"kernels/decision_R{R}_I{I}", best["megakernel"],
            f"megakernel_us={best['megakernel']:.1f}"
            f";fused_us={best['fused']:.1f}"
            f";staged_us={best['jax']:.1f}"
            f";per_req_us={best['megakernel']/R:.1f}"
            f";vs_fused={best['fused']/best['megakernel']:.2f}x"
            f";vs_staged={best['jax']/best['megakernel']:.2f}x"
            f";agree={agree:.3f}")


def _multiwin_cells(ctx):
    """K windows, one dispatch vs K dispatches."""
    from .hotpath import scaled_pool
    from repro.core.engine import BatchView
    from repro.core.scheduler import RouteBalancePolicy
    from repro.serving.cluster import ClusterSim
    from repro.serving.scenarios import randomize_telemetry
    for K, R, I in MULTIWIN_GRID:
        tiers = (ctx["tiers"]
                 if I == sum(t.n_instances for t in ctx["tiers"])
                 else scaled_pool(ctx["tiers"], I))
        sim = randomize_telemetry(
            ClusterSim(tiers, ctx["names"], seed=0), seed=1)
        reqs = make_requests(ctx["ds"], "test", np.zeros(K * R))
        views = [BatchView(reqs[i * R:(i + 1) * R]) for i in range(K)]
        pol = RouteBalancePolicy(RBConfig(decision_backend="megakernel",
                                          window_coalesce=K))
        pol.prepare(ctx["bundle"], tiers)
        pol.on_attach(sim)

        def coalesced():
            for res in pol.assign_windows(views, sim):
                res.fetch()

        def separate():
            for v in views:
                pol.assign(v, sim).fetch()

        coalesced(), separate()                 # compile both shapes
        dt_c = _time(coalesced, n=12) / K
        dt_s = _time(separate, n=12) / K
        csv_row(
            f"kernels/decision_multiwin_K{K}_R{R}_I{I}", dt_c * 1e6,
            f"per_window_us={dt_c*1e6:.1f}"
            f";separate_per_window_us={dt_s*1e6:.1f}"
            f";amortization={dt_s/dt_c:.2f}x")


def main():
    ctx = context()
    bundle = ctx["bundle"]
    rng = np.random.default_rng(0)
    # batched embed+KNN (the per-batch amortized decision compute)
    prompts, Q, L = ctx["ds"].split("test")
    for B in (1, 16, 64):
        reqs = [prompts[i] for i in range(B)]
        from repro.estimators.embedding import pad_tokens
        toks = pad_tokens([p.tokens for p in reqs], bundle.encoder.max_len)
        lens = np.array([min(len(p.tokens), 128) for p in reqs])
        dt_e = _time(lambda: bundle.encoder.encode(toks, lens))
        emb = bundle.encoder.encode(toks, lens)
        dt_k = _time(lambda: bundle.knn.query(emb))
        csv_row(f"kernels/embed_knn_B{B}", (dt_e + dt_k) * 1e6,
                f"embed_us={dt_e*1e6:.0f};knn_us={dt_k*1e6:.0f};"
                f"per_req_us={(dt_e+dt_k)/B*1e6:.0f}")
    # scoring-loop scaling with instance count (paper §4.2)
    for I in (13, 100, 500):
        R = 16
        q_inst = rng.uniform(0, 1, (R, I))
        c_hat = rng.uniform(1e-6, 1e-4, (R, I))
        l_inst = rng.uniform(50, 500, (R, I))
        tpot = rng.uniform(0.01, 0.05, I)
        d = rng.uniform(0, 2000, I)
        b = rng.integers(1, 16, I).astype(float)
        free = rng.integers(0, 8, I).astype(float)
        maxb = np.full(I, 48.0)
        order = lpt_order(l_inst.max(1))
        dt = _time(lambda: greedy_assign(
            order, q_inst, c_hat, l_inst, tpot, d, b, free, maxb,
            PRESETS["uniform"]), n=10)
        csv_row(f"kernels/scoring_loop_I{I}", dt / R * 1e6,
                f"per_req_us={dt/R*1e6:.1f}")
    # pallas kernels vs oracles (correctness timing, interpret mode)
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels import ref as kref
    q = jnp.asarray(rng.normal(size=(32, 128)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4096, 128)), jnp.float32)
    dv, di = ops.knn_topk(q, x, k=10)
    rv, ri = kref.knn_topk_ref(q, x, k=10)
    err = float(jnp.abs(dv - rv).max())
    dt_ref = _time(lambda: jax.block_until_ready(
        kref.knn_topk_ref(q, x, k=10)), n=10)
    csv_row("kernels/knn_topk_pallas", dt_ref * 1e6,
            f"allclose_err={err:.1e};jnp_oracle_us={dt_ref*1e6:.0f}")
    # the decision megakernel grid + multi-window amortization
    _decision_cells(ctx)
    _multiwin_cells(ctx)
    return None


if __name__ == "__main__":
    from .common import flush_json
    main()
    flush_json(FLUSH_AS)
