# Pallas TPU kernels for the perf-critical hot spots:
#   knn_topk         — the paper's batched estimator lookup (§4.2/§6.3)
#   decode_attention — flash-decoding GQA step (serving substrate)
#   ssd_scan         — mamba2 SSD chunked scan (assigned arch)
# ops.py = jit'd wrappers; ref.py = pure-jnp oracles.
from . import ops as knn_ops  # noqa: F401  (KNNEstimator pallas backend)
from .ops import decode_attention, knn_topk, ssd_scan  # noqa: F401
