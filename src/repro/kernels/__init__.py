# Pallas TPU kernels for the perf-critical hot spots:
#   knn_topk            — the paper's batched estimator lookup (§4.2/§6.3)
#   decode_attention    — flash-decoding GQA step (serving substrate)
#   ssd_scan            — mamba2 SSD chunked scan (assigned arch)
#   decision_megakernel — the whole fused routing decision (KNN top-k →
#                         packed GBM → Eq. 2 admission → LPT greedy
#                         scan) as one kernel, K windows per dispatch
# ops.py = jit'd wrappers (REPRO_PALLAS_INTERPRET selects interpret vs
# compiled TPU mode); ref.py = pure oracles.
from . import ops as knn_ops  # noqa: F401  (KNNEstimator pallas backend)
# import the decision_megakernel SUBMODULE before binding the same-named
# wrapper function: a later `import repro.kernels.decision_megakernel`
# would otherwise silently rebind the package attribute to the module,
# shadowing the function for everyone after it
from . import decision_megakernel as _decision_megakernel_module  # noqa: F401,E501
from .ops import (decision_megakernel, decode_attention,  # noqa: F401
                  knn_topk, ssd_scan)
