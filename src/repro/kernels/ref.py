"""Pure-jnp/numpy oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def knn_topk_ref(q, x, k: int = 10):
    """Exact top-k smallest squared L2 distances. -> (d2 (B,k), idx)."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    d2 = (jnp.sum(q * q, 1, keepdims=True)
          + jnp.sum(x * x, 1)[None, :]
          - 2.0 * q @ x.T)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx.astype(jnp.int32)


def decision_ref(emb, row_valid, budgets, len_in, psig,
                 d, b, free, ctx, alive,
                 x, xsq, qual, leng,
                 m_of_i, tier_of_i, maxb, price_in, price_out, nominal,
                 sig_plane, gbm=None, *, k: int, eps: float, weights,
                 latency_mode: str = "full", lpt: bool = True,
                 budget_filter: bool = True, w_aff: float = 0.0):
    """Pure-numpy oracle for the decision megakernel
    (`repro.kernels.decision_megakernel.decision_call`): the same
    KNN -> GBM -> admission -> affinity -> greedy-scan pipeline, one
    Python loop per request, float32 throughout like the device
    backends. Args mirror `decision_call` (leading K window axis on
    the per-window inputs; `gbm` is the `pack_ensemble` dict or None
    for nominal-TPOT mode). Returns the same six outputs.

    This is a *logical* oracle (assignment-exact on the tested worlds,
    latencies to float tolerance), not the bitwise contract — that is
    the fused backend, asserted in ``tests/test_megakernel.py``."""
    from repro.core.budget import admission_math, cost_matrix
    from repro.core.scoring import affinity_discount, masked_score
    from repro.estimators.gbm import _accumulate
    from repro.estimators.knn import distance_weights
    from repro.serving.affinity import hit_fraction

    f32 = np.float32
    emb = np.asarray(emb, f32)
    K, R, E = emb.shape
    d0, b_tel, free0, ctx0 = (np.asarray(a, f32)
                              for a in (d, b, free, ctx))
    alive = np.asarray(alive, bool)
    x = np.asarray(x, f32)
    xsq = np.asarray(xsq, f32)
    qual_lbl = np.asarray(qual, f32)
    leng_lbl = np.asarray(leng, f32)
    m_of_i = np.asarray(m_of_i)
    maxb = np.asarray(maxb, f32)
    price_in = np.asarray(price_in, f32)
    price_out = np.asarray(price_out, f32)
    nominal = np.asarray(nominal, f32)
    I = d0.shape[0]
    wq, wl, wc = (f32(w) for w in weights)

    # state-dependent TPOT is window-invariant (every window scans from
    # the same telemetry snapshot), so evaluate it once
    b_eff = np.maximum(b_tel, f32(1.0))
    ctx_eff = np.maximum(ctx0, f32(64.0))
    if gbm is not None:
        feats = np.stack([b_eff, d0, ctx_eff, b_eff * ctx_eff],
                         axis=1).astype(f32)
        feat_m = np.asarray(gbm["feature"])[tier_of_i]   # (I, T, n_int)
        thr_m = np.asarray(gbm["threshold"], f32)[tier_of_i]
        leaf_m = np.asarray(gbm["leaf"], f32)[tier_of_i]
        idx = np.zeros((I, feat_m.shape[1]), np.int32)
        for _ in range(gbm["depth"]):
            fsel = np.take_along_axis(feat_m, idx[:, :, None],
                                      axis=2)[..., 0]
            tsel = np.take_along_axis(thr_m, idx[:, :, None],
                                      axis=2)[..., 0]
            xv = np.take_along_axis(feats, fsel, axis=1)
            idx = 2 * idx + 1 + (xv > tsel).astype(np.int32)
        leaf_idx = idx - (2 ** gbm["depth"] - 1)
        vals = np.take_along_axis(leaf_m, leaf_idx[:, :, None],
                                  axis=2)[..., 0]        # (I, T)
        base = np.asarray(gbm["base"], f32)[tier_of_i]
        tpot = np.maximum(
            _accumulate(base, gbm["lr"], vals.T, np), f32(1e-4))
    else:
        tpot = nominal

    outs = [np.zeros((K, R), np.int32), np.zeros((K, R), f32),
            np.zeros((K, R), f32), np.zeros((K, I), f32),
            np.zeros((K, I), f32), np.zeros((K, I), f32)]
    for wi in range(K):
        q = emb[wi]
        rv = np.asarray(row_valid[wi], bool)
        bud = np.asarray(budgets[wi], f32)
        lin = np.asarray(len_in[wi], f32)
        # stage 1: exact KNN (sorted ascending by (distance, index))
        d2 = (xsq[None, :] - 2.0 * q @ x.T
              + (q * q).sum(-1, keepdims=True)).astype(f32)
        nidx = np.argsort(d2, axis=1, kind="stable")[:, :k]
        d2k = np.take_along_axis(d2, nidx, axis=1)
        w = distance_weights(d2k, eps, np).astype(f32)
        qmix = (qual_lbl[nidx] * w[..., None]).sum(1)    # (R, M)
        lmix = (leng_lbl[nidx] * w[..., None]).sum(1)
        q_inst = qmix[:, m_of_i]
        l_inst = lmix[:, m_of_i]
        pred_len_max = np.where(rv, lmix.max(axis=1), -1e30)
        # stage 3: admission + affinity
        if budget_filter:
            allowed, c_hat = admission_math(
                bud, lin, l_inst, price_in, price_out, np, valid=alive)
        else:
            c_hat = cost_matrix(lin, l_inst, price_in, price_out, np)
            allowed = np.broadcast_to(alive[None, :], c_hat.shape)
        if w_aff > 0.0:
            hit = hit_fraction(np.asarray(psig[wi]), lin,
                               np.asarray(sig_plane), np)
            aff = f32(w_aff) * np.where(alive[None, :], hit, f32(0.0))
        else:
            aff = None
        # stage 4: LPT order + greedy scan (mirrors greedy_step)
        order = (np.argsort(-pred_len_max, kind="stable") if lpt
                 else np.arange(R))
        dc, bc, fc = d0.copy(), b_eff.copy(), free0.copy()
        b0 = np.maximum(b_eff, f32(1.0))
        for r in order:
            wait = np.where(fc > 0, f32(0.0),
                            dc / np.maximum(bc, f32(1.0)))
            tpot_eff = tpot * np.maximum(bc / b0, f32(1.0))
            if latency_mode == "static_prior":
                T = nominal * l_inst[r]
            else:
                T = tpot_eff * (wait + l_inst[r])
            if aff is not None:
                T = affinity_discount(T, aff[r], np)
            if latency_mode in ("off_reactive", "off_predictive"):
                s = masked_score(q_inst[r], c_hat[r], T, (wq, 0.0, wc),
                                 allowed[r], np)
                tie = (dc + bc) if latency_mode == "off_reactive" else T
                tn = tie / np.maximum(tie.max(), f32(1e-9))
                i = int(np.argmin(np.where(s >= s.max(), tn, np.inf)))
            else:
                s = masked_score(q_inst[r], c_hat[r], T, (wq, wl, wc),
                                 allowed[r], np)
                i = int(np.argmax(s))
            outs[0][wi, r] = i
            outs[1][wi, r] = T[i]
            outs[2][wi, r] = l_inst[r, i]
            if rv[r]:
                dc[i] += l_inst[r, i]
                if fc[i] > 0:
                    fc[i] -= 1.0
                    bc[i] = min(bc[i] + 1.0, maxb[i])
        outs[3][wi], outs[4][wi], outs[5][wi] = dc, bc, fc
    return tuple(outs)


def decode_attention_ref(q, k_cache, v_cache, cache_positions, pos,
                         window: int = 0):
    """GQA decode attention; mirrors models.attention.decode_attention
    but takes q (B, H, d) and returns (B, H, d)."""
    from repro.models.attention import decode_attention
    o = decode_attention(q[:, None], k_cache, v_cache, cache_positions,
                         pos, window=window)
    return o[:, 0]


def ssd_scan_ref(xh, Bm, Cm, dt, A, chunk: int):
    """Chunked SSD (mamba2) oracle; mirrors models.blocks._ssd_chunked
    with heads already expanded. Returns (y, final_state)."""
    from repro.models.blocks import _ssd_chunked
    B, S, nh, P = xh.shape
    init = jnp.zeros((B, nh, P, Bm.shape[-1]), jnp.float32)
    # _ssd_chunked expects group dim; here Bm/Cm are (B, S, G, N)
    return _ssd_chunked(xh, Bm, Cm, dt, A, chunk, init)


def ssd_recurrent_ref(xh, Bm, Cm, dt, A):
    """Token-by-token linear recurrence (the SSD ground truth):
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t . h_t.
    xh: (B,S,nh,P); Bm/Cm: (B,S,nh,N); dt: (B,S,nh); A: (nh,)."""
    B, S, nh, P = xh.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, B_t, C_t, dt_t = inp
        dA = jnp.exp(dt_t * A)[..., None, None]          # (B,nh,1,1)
        h = h * dA + jnp.einsum("bhp,bhn,bh->bhpn",
                                x_t.astype(jnp.float32), B_t, dt_t)
        y = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y

    xs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(Bm, 1, 0),
          jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(dt, 1, 0))
    h0 = jnp.zeros((B, nh, P, N), jnp.float32)
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT
