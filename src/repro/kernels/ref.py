"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def knn_topk_ref(q, x, k: int = 10):
    """Exact top-k smallest squared L2 distances. -> (d2 (B,k), idx)."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    d2 = (jnp.sum(q * q, 1, keepdims=True)
          + jnp.sum(x * x, 1)[None, :]
          - 2.0 * q @ x.T)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx.astype(jnp.int32)


def decode_attention_ref(q, k_cache, v_cache, cache_positions, pos,
                         window: int = 0):
    """GQA decode attention; mirrors models.attention.decode_attention
    but takes q (B, H, d) and returns (B, H, d)."""
    from repro.models.attention import decode_attention
    o = decode_attention(q[:, None], k_cache, v_cache, cache_positions,
                         pos, window=window)
    return o[:, 0]


def ssd_scan_ref(xh, Bm, Cm, dt, A, chunk: int):
    """Chunked SSD (mamba2) oracle; mirrors models.blocks._ssd_chunked
    with heads already expanded. Returns (y, final_state)."""
    from repro.models.blocks import _ssd_chunked
    B, S, nh, P = xh.shape
    init = jnp.zeros((B, nh, P, Bm.shape[-1]), jnp.float32)
    # _ssd_chunked expects group dim; here Bm/Cm are (B, S, G, N)
    return _ssd_chunked(xh, Bm, Cm, dt, A, chunk, init)


def ssd_recurrent_ref(xh, Bm, Cm, dt, A):
    """Token-by-token linear recurrence (the SSD ground truth):
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t . h_t.
    xh: (B,S,nh,P); Bm/Cm: (B,S,nh,N); dt: (B,S,nh); A: (nh,)."""
    B, S, nh, P = xh.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, B_t, C_t, dt_t = inp
        dA = jnp.exp(dt_t * A)[..., None, None]          # (B,nh,1,1)
        h = h * dA + jnp.einsum("bhp,bhn,bh->bhpn",
                                x_t.astype(jnp.float32), B_t, dt_t)
        y = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y

    xs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(Bm, 1, 0),
          jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(dt, 1, 0))
    h0 = jnp.zeros((B, nh, P, N), jnp.float32)
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT
