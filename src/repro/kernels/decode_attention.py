"""Pallas TPU kernel: GQA single-token decode attention, KV-blocked.

Decode is KV-bandwidth-bound: per step the cache is read once and q is a
single token. The kernel streams the (B, C, K, d) cache through VMEM
tiles along C (flash-decoding), maintaining the online-softmax
(m, l, acc) state in VMEM scratch across the sequential grid axis; GQA is
native (no head repetition — repeating would multiply HBM reads by
H/K). One kv-head per grid row keeps every dot 2D-ish for the MXU.

Grid: (K, C/tile). Scratch m,l: (B, g); acc: (B, g, d).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, cpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, window: int, tile: int, scale: float):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...][:, 0]                  # (B, g, d)
    kt = k_ref[...][:, :, 0]              # (B, T, d)
    vt = v_ref[...][:, :, 0]              # (B, T, d)
    cpos = cpos_ref[...]                  # (1, T)
    pos = pos_ref[0]

    s = jax.lax.dot_general(
        q, kt, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale        # (B, g, T)
    valid = (cpos >= 0) & (cpos <= pos)
    if window > 0:
        valid &= cpos > pos - window
    s = jnp.where(valid[:, None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])                      # (B, g, T)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p.astype(vt.dtype), vt, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                # (B, g, d)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new

    @pl.when(c == nc - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[..., None])[:, None].astype(
            o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "tile", "interpret"))
def decode_attention(q, k_cache, v_cache, cache_positions, pos, *,
                     window: int = 0, tile: int = 512,
                     interpret: bool = True):
    """q: (B, H, d); caches: (B, C, K, d); cache_positions: (C,) int32;
    pos: scalar int32. Returns (B, H, d)."""
    B, H, d = q.shape
    _, C, K, _ = k_cache.shape
    g = H // K
    tile = min(tile, C)
    pad = (-C) % tile
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, padw)
        v_cache = jnp.pad(v_cache, padw)
        cache_positions = jnp.pad(cache_positions, (0, pad),
                                  constant_values=-1)
    Cp = k_cache.shape[1]
    grid = (K, Cp // tile)
    out = pl.pallas_call(
        functools.partial(_kernel, window=window, tile=tile,
                          scale=d ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda h, c: (0,)),
            pl.BlockSpec((B, 1, g, d), lambda h, c: (0, h, 0, 0)),
            pl.BlockSpec((B, tile, 1, d), lambda h, c: (0, c, h, 0)),
            pl.BlockSpec((B, tile, 1, d), lambda h, c: (0, c, h, 0)),
            pl.BlockSpec((1, tile), lambda h, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((B, 1, g, d), lambda h, c: (0, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((B, g), jnp.float32),
            pltpu.VMEM((B, g), jnp.float32),
            pltpu.VMEM((B, g, d), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32)[None],
      q.reshape(B, K, g, d), k_cache, v_cache, cache_positions[None, :])
    return out.reshape(B, H, d)
