"""Jitted public wrappers over the Pallas kernels.

``interpret=True`` everywhere in this container (CPU): the kernel bodies
execute in Python for correctness validation; on TPU set interpret=False
(the BlockSpecs are written for VMEM/MXU tiling).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .decode_attention import decode_attention as decode_attention_kernel
from .knn_topk import knn_topk as knn_topk_kernel
from .ssd_scan import ssd_scan as ssd_scan_kernel

INTERPRET = True   # flip on real TPU


def knn_topk(q, x, k: int = 10, tile: int = 512):
    return knn_topk_kernel(q, x, k=k, tile=tile, interpret=INTERPRET)


def decode_attention(q, k_cache, v_cache, cache_positions, pos,
                     window: int = 0, tile: int = 512):
    return decode_attention_kernel(q, k_cache, v_cache, cache_positions,
                                   pos, window=window, tile=tile,
                                   interpret=INTERPRET)


def ssd_scan(xh, Bm, Cm, dt, A, chunk: int = 128, head_tile: int = 8):
    return ssd_scan_kernel(xh, Bm, Cm, dt, A, chunk=chunk,
                           head_tile=head_tile, interpret=INTERPRET)


# -- KNN estimator backend ---------------------------------------------------

def build_query(x: np.ndarray, quality: np.ndarray, lengths: np.ndarray,
                k: int, eps: float):
    """Returns a callable (B, E) -> (quality (B, M), length (B, M)) using
    the fused Pallas distance+top-k kernel."""
    xj = jnp.asarray(x, jnp.float32)
    qualj = jnp.asarray(quality, jnp.float32)
    lenj = jnp.asarray(lengths, jnp.float32)

    from repro.estimators.knn import distance_weights

    @jax.jit
    def run(q):
        d2, idx = knn_topk_kernel(q, xj, k=k, interpret=INTERPRET)
        w = distance_weights(d2, eps, jnp)
        return ((qualj[idx] * w[..., None]).sum(1),
                (lenj[idx] * w[..., None]).sum(1))
    return run
