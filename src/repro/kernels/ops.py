"""Jitted public wrappers over the Pallas kernels.

Execution mode is env-driven: ``REPRO_PALLAS_INTERPRET`` (default on)
runs every kernel body through the Pallas interpreter — correct on the
CPU containers this repo develops in. On a real TPU export
``REPRO_PALLAS_INTERPRET=0`` and the same call sites compile with
Mosaic (the BlockSpecs are written for VMEM/MXU tiling); no source
edit required.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from .decode_attention import decode_attention as decode_attention_kernel
from .knn_topk import knn_topk as knn_topk_kernel
from .ssd_scan import ssd_scan as ssd_scan_kernel


def env_interpret(default: bool = True) -> bool:
    """The process-wide interpret switch: REPRO_PALLAS_INTERPRET unset
    -> `default` (on: CPU container); "0"/"false"/"off"/"" -> compiled
    TPU mode; anything else -> interpret."""
    v = os.environ.get("REPRO_PALLAS_INTERPRET")
    if v is None:
        return default
    return v.strip().lower() not in ("0", "false", "off", "")


INTERPRET = env_interpret()   # resolved once at import; flip via env


def knn_topk(q, x, k: int = 10, tile: int = 512):
    return knn_topk_kernel(q, x, k=k, tile=tile, interpret=INTERPRET)


def decode_attention(q, k_cache, v_cache, cache_positions, pos,
                     window: int = 0, tile: int = 512):
    return decode_attention_kernel(q, k_cache, v_cache, cache_positions,
                                   pos, window=window, tile=tile,
                                   interpret=INTERPRET)


def ssd_scan(xh, Bm, Cm, dt, A, chunk: int = 128, head_tile: int = 8):
    return ssd_scan_kernel(xh, Bm, Cm, dt, A, chunk=chunk,
                           head_tile=head_tile, interpret=INTERPRET)


def decision_megakernel(*args, **kwargs):
    """The fused-decision megakernel at the env-selected interpret
    mode (see `repro.kernels.decision_megakernel` for the signature).
    Production reaches the kernel through `FusedHotPath`; this wrapper
    is the direct kernel-level entry for tests and benches."""
    from .decision_megakernel import decision_megakernel as _mk
    kwargs.setdefault("interpret", INTERPRET)
    return _mk(*args, **kwargs)


# -- KNN estimator backend ---------------------------------------------------

def build_query(x: np.ndarray, quality: np.ndarray, lengths: np.ndarray,
                k: int, eps: float):
    """Returns a callable (B, E) -> (quality (B, M), length (B, M)) using
    the fused Pallas distance+top-k kernel."""
    xj = jnp.asarray(x, jnp.float32)
    qualj = jnp.asarray(quality, jnp.float32)
    lenj = jnp.asarray(lengths, jnp.float32)

    from repro.estimators.knn import distance_weights

    @jax.jit
    def run(q):
        d2, idx = knn_topk_kernel(q, xj, k=k, interpret=INTERPRET)
        w = distance_weights(d2, eps, jnp)
        return ((qualj[idx] * w[..., None]).sum(1),
                (lenj[idx] * w[..., None]).sum(1))
    return run
