"""Pallas TPU kernel: Mamba-2 SSD chunked scan (forward).

State-space duality: within a chunk of Q tokens the recurrence is a
masked (B,h,Q,Q) attention-like product (MXU work); across chunks a
(B,h,P,N) state is carried. The chunk axis is the sequential grid axis;
the carried state lives in VMEM scratch. Heads are tiled on their own
grid axis so the working set (xq, Bq, Cq, L, state) stays within VMEM:
per (head-tile, chunk) step the VMEM footprint is
  hb*(Q*P + 2*Q*N + Q + Q*Q + P*N) floats — hardware-aligned for
Q=P=64..128, N=128.

Inputs are per-head expanded: xh (B,S,nh,P), Bm/Cm (B,S,nh,N),
dt (B,S,nh); A (nh,). Output y (B,S,nh,P) + final state (B,nh,P,N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xh_ref, b_ref, c_ref, dt_ref, a_ref, y_ref, state_out_ref,
            state_ref, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xq = xh_ref[...]        # (B, Q, hb, P)
    Bq = b_ref[...]         # (B, Q, hb, N)
    Cq = c_ref[...]         # (B, Q, hb, N)
    dtq = dt_ref[...].astype(jnp.float32)      # (B, Q, hb)
    A = a_ref[...].astype(jnp.float32)         # (1, hb)

    dA = dtq * A[None]                          # (B, Q, hb)
    dA_t = jnp.moveaxis(dA, 1, 2)               # (B, hb, Q)
    cum = jnp.cumsum(dA_t, axis=-1)
    Q = xq.shape[1]
    seg = cum[..., :, None] - cum[..., None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(mask[None, None], jnp.exp(seg), 0.0)    # (B,hb,Q,Q)

    scores = jnp.einsum("bqhn,bkhn->bhqk", Cq.astype(jnp.float32),
                        Bq.astype(jnp.float32))
    M = scores * L * jnp.moveaxis(dtq, 1, 2)[:, :, None, :]
    y_intra = jnp.einsum("bhqk,bkhp->bqhp", M, xq.astype(jnp.float32))

    state = state_ref[...]                      # (B, hb, P, N)
    decay_in = jnp.exp(cum)                     # (B, hb, Q)
    y_inter = jnp.einsum(
        "bqhn,bhpn->bqhp",
        Cq.astype(jnp.float32) * jnp.moveaxis(decay_in, 1, 2)[..., None],
        state)
    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_out = jnp.exp(cum[..., -1:] - cum)    # (B, hb, Q)
    contrib = dtq * jnp.moveaxis(decay_out, 1, 2)
    st = jnp.einsum("bqhn,bqhp,bqh->bhpn", Bq.astype(jnp.float32),
                    xq.astype(jnp.float32), contrib)
    state = state * jnp.exp(cum[..., -1])[..., None, None] + st
    state_ref[...] = state

    @pl.when(ci == nc - 1)
    def _fin():
        state_out_ref[...] = state_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "head_tile", "interpret"))
def ssd_scan(xh, Bm, Cm, dt, A, *, chunk: int = 128,
             head_tile: int = 8, interpret: bool = True):
    """xh: (B,S,nh,P); Bm/Cm: (B,S,nh,N); dt: (B,S,nh); A: (nh,).
    Returns (y (B,S,nh,P) f32->xh.dtype, final_state (B,nh,P,N) f32)."""
    B, S, nh, P = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to the chunk size"
    hb = min(head_tile, nh)
    assert nh % hb == 0
    grid = (nh // hb, S // chunk)
    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, chunk, hb, P), lambda h, c: (0, c, h, 0)),
            pl.BlockSpec((B, chunk, hb, N), lambda h, c: (0, c, h, 0)),
            pl.BlockSpec((B, chunk, hb, N), lambda h, c: (0, c, h, 0)),
            pl.BlockSpec((B, chunk, hb), lambda h, c: (0, c, h)),
            pl.BlockSpec((1, hb), lambda h, c: (0, h)),
        ],
        out_specs=[
            pl.BlockSpec((B, chunk, hb, P), lambda h, c: (0, c, h, 0)),
            pl.BlockSpec((B, hb, P, N), lambda h, c: (0, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, nh, P), xh.dtype),
            jax.ShapeDtypeStruct((B, nh, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((B, hb, P, N), jnp.float32)],
        interpret=interpret,
    )(xh, Bm, Cm, dt.astype(jnp.float32), A[None].astype(jnp.float32))
    return y, state
