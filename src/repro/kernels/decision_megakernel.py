"""Pallas decision megakernel: the whole RouteBalance per-batch
decision — KNN top-k, packed-GBM TPOT heads, Eq. 2 admission,
prefix-affinity and the LPT greedy scan — as ONE kernel dispatch
(ROADMAP item 4).

The fused XLA backend (`repro.core.hotpath`) already runs the decision
as a single jitted program, but XLA still materializes every stage
boundary (the (R, N) distance matrix, the (R, M) label mixes, the
(R, I) admission/affinity planes) as separate HBM buffers between
fusions, and the greedy scan lowers to a `lax.scan` whose per-step
carry round-trips through loop state XLA owns. This kernel hand-places
the whole pipeline instead:

  * **stage 1 — KNN top-k**, the `knn_topk` running-top-k idiom: the
    (R, N) distance plane never leaves the kernel; per index tile, k
    rounds of (min, argmin, replace-worst) maintain a (R, k) running
    buffer, and the survivors are ordered by (distance, index) — the
    exact `lax.top_k` tie order the staged backends see — before the
    distance-weighted label mix. That form exists because `lax.top_k`
    has no Mosaic/TPU-kernel lowering; under the interpreter (where the
    body executes as plain XLA anyway) ``topk_mode="auto"`` routes the
    selection through `lax.top_k` itself — bitwise the same survivors
    and order (pinned by ``test_topk_running_matches_lax_topk_order``
    and the forced-``"running"`` parity arm), ~20x cheaper than
    emulating the k-round scan op by op;
  * **stage 2 — packed GBM**: the per-tier TPOT heads walk their trees
    via the shared `predict_packed_gathered` body, so the tree-by-tree
    float32 accumulation keeps the numpy ensemble's bitwise rounding
    order (`_accumulate` is the one definition);
  * **stage 3 — Eq. 2 admission + affinity**: `admission_math` and
    `hit_fraction` traced in-kernel over the same alive mask the fused
    program uses;
  * **stage 4 — LPT greedy scan**: a fori_loop over the R rows whose
    per-step body IS `repro.core.decision_jax.greedy_step` (the one
    definition shared with the staged/fused lax.scan), with the
    dead-reckoned (d, b, free) carry held in loop registers/VMEM for
    the whole R-loop — no per-stage HBM intermediates.

**Multi-window batching**: the grid is (K,) over scheduler windows.
Per-window inputs (embeddings, row masks, budgets, signatures) carry a
leading K axis and block per program instance; the telemetry mirror
and every estimator constant are shared blocks with constant index
maps. K windows decided from one telemetry snapshot are independent by
construction — the fused path reseeds the mirror from telemetry every
batch, so K back-to-back `decide` calls on unmoved telemetry all scan
from the same state — which is exactly what lets them share one
dispatch bitwise-safely (`FusedHotPath.decide_cols_multi`).

Execution modes: ``interpret=True`` (the default in this container,
via ``REPRO_PALLAS_INTERPRET``) runs the kernel body on CPU for
correctness/parity work; ``interpret=False`` compiles it with Mosaic
on a real TPU (BlockSpecs are written for whole-block VMEM residency —
at paper scale the operands total ~1.5 MB, well under a core's 16 MB).
Parity against the fused/staged/numpy backends is asserted exactly in
``tests/test_megakernel.py`` and the randomized soak.

The numpy oracle is `repro.kernels.ref.decision_ref`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG = 3.4e38  # +inf stand-in for f32 distance masking (knn_topk.NEG)


def _topk_running(d2, k: int, tile: int):
    """The `knn_topk` running-top-k merge over column tiles of an
    in-register distance plane: k rounds of (min, argmin,
    replace-worst) per tile against a persistent (R, k) buffer.

    The survivors are re-ordered by (distance, index) — `lax.top_k` is
    a stable sort, so this is bit-for-bit the neighbor ORDER the
    staged `topk_soft_lookup` feeds its label mix, which the weighted
    sums need for exact parity (slot order in the running buffer is
    insertion order, not tie order)."""
    R, Np = d2.shape
    vals = jnp.full((R, k), NEG, jnp.float32)
    idx = jnp.full((R, k), -1, jnp.int32)
    for t in range(0, Np, tile):
        dt = d2[:, t:t + tile]                           # static slice
        for _ in range(k):
            m = jnp.min(dt, axis=1, keepdims=True)       # (R, 1)
            am = jnp.argmin(dt, axis=1)                  # (R,)
            gidx = am.astype(jnp.int32) + t
            worst = jnp.max(vals, axis=1, keepdims=True)
            wslot = jnp.argmax(vals, axis=1)
            better = m < worst
            onehot_w = (jax.lax.broadcasted_iota(
                jnp.int32, vals.shape, 1) == wslot[:, None])
            take = onehot_w & better
            vals = jnp.where(take, m, vals)
            idx = jnp.where(take, gidx[:, None], idx)
            onehot_d = (jax.lax.broadcasted_iota(
                jnp.int32, dt.shape, 1) == am[:, None])
            dt = jnp.where(onehot_d, NEG, dt)
    order = jnp.lexsort((idx, vals), axis=-1)            # (value, index)
    return (jnp.take_along_axis(vals, order, axis=1),
            jnp.take_along_axis(idx, order, axis=1))


def _kernel(emb_ref, rv_ref, budgets_ref, len_in_ref, psig_ref,
            d_ref, b_ref, free_ref, ctx_ref, alive_ref,
            x_ref, xsq_ref, qual_ref, leng_ref,
            m_of_i_ref, tier_of_i_ref, maxb_ref, price_in_ref,
            price_out_ref, nominal_ref, sig_plane_ref,
            gfeat_ref, gthr_ref, gleaf_ref, gbase_ref,
            choice_ref, est_ref, lchosen_ref, d1_ref, b1_ref, f1_ref,
            *, k: int, eps: float, weights, latency_mode: str,
            lpt: bool, budget_filter: bool, w_aff: float,
            use_gbm: bool, depth: int, lr: float, knn_tile: int,
            topk_mode: str):
    # deferred: repro.core imports repro.kernels-adjacent modules at
    # package-init time; the kernel body only traces after everything
    # is importable, so the shared one-definition math can be pulled in
    # here without a cycle.
    from repro.core.budget import admission_math, cost_matrix
    from repro.core.decision_jax import greedy_step
    from repro.estimators.gbm import predict_packed_gathered
    from repro.estimators.knn import distance_weights
    from repro.serving.affinity import hit_fraction

    emb = emb_ref[0]                                     # (R, E)
    rv = rv_ref[0]                                       # (R,)
    budgets = budgets_ref[0].astype(jnp.float32)
    len_in = len_in_ref[0].astype(jnp.float32)
    d = d_ref[...]                                       # (I,) shared
    b = b_ref[...]
    free = free_ref[...]
    ctx = ctx_ref[...]
    alive = alive_ref[...]
    m_of_i = m_of_i_ref[...]
    nominal = nominal_ref[...]
    R = emb.shape[0]

    # -- stage 1: KNN top-k + distance-weighted label mix ------------------
    # the distance expansion is spelled exactly as topk_soft_lookup's —
    # same shapes, same op order — so the survivors' d2 values (and
    # therefore the inverse-distance weights) are bitwise the staged
    # backends'
    x = x_ref[...]                                       # (N, E)
    d2 = (xsq_ref[...][None, :] - 2.0 * emb @ x.T
          + jnp.sum(emb * emb, -1, keepdims=True))       # (R, N)
    if topk_mode == "running":
        # Mosaic-lowerable selection (the compiled-TPU path): proven
        # order-identical to lax.top_k (tests/test_megakernel.py)
        d2k, nidx = _topk_running(d2, k, knn_tile)
    else:
        # interpret mode executes as XLA anyway, where lax.top_k IS the
        # staged/fused selection — bitwise identical and ~20x cheaper
        # than emulating the k-round running scan op by op
        neg, nidx = jax.lax.top_k(-d2, k)
        d2k = -neg
    w = distance_weights(d2k, eps, jnp)
    qual = (qual_ref[...][nidx] * w[..., None]).sum(1)   # (R, M)
    leng = (leng_ref[...][nidx] * w[..., None]).sum(1)
    q_inst = qual[:, m_of_i]                             # (R, I)
    l_inst = leng[:, m_of_i]
    pred_len_max = jnp.where(rv, leng.max(axis=1), -1e30)

    # -- stage 2: packed-GBM TPOT heads ------------------------------------
    b_eff = jnp.maximum(b, 1.0)
    ctx_eff = jnp.maximum(ctx, 64.0)
    if use_gbm:
        feats = jnp.stack([b_eff, d, ctx_eff, b_eff * ctx_eff],
                          axis=1).astype(jnp.float32)
        stacked = {"feature": gfeat_ref[...],
                   "threshold": gthr_ref[...],
                   "leaf": gleaf_ref[...],
                   "base": gbase_ref[...],
                   "lr": lr, "depth": depth}
        tpot = jnp.maximum(
            predict_packed_gathered(stacked, tier_of_i_ref[...], feats),
            1e-4)
    else:
        tpot = nominal

    # -- stage 3: Eq. 2 admission + prefix affinity ------------------------
    if budget_filter:
        allowed, c_hat = admission_math(
            budgets, len_in, l_inst, price_in_ref[...],
            price_out_ref[...], jnp, valid=alive)
    else:
        c_hat = cost_matrix(len_in, l_inst, price_in_ref[...],
                            price_out_ref[...], jnp)
        allowed = jnp.broadcast_to(alive[None, :], c_hat.shape)
    if w_aff > 0.0:
        hit = hit_fraction(psig_ref[0], len_in, sig_plane_ref[...], jnp)
        hit = jnp.where(alive[None, :], hit, jnp.float32(0.0))
        aff = jnp.float32(w_aff) * hit
    else:
        aff = None

    # -- stage 4: LPT order + dead-reckoned greedy scan --------------------
    # the (d, b, free) carry lives in the fori_loop state for the whole
    # R-loop; every step body is the shared `greedy_step` definition
    if lpt:
        order = jnp.argsort(-pred_len_max, stable=True)
    else:
        order = jnp.arange(R)
    b0 = jnp.maximum(b_eff, 1.0)

    def body(t, carry):
        dc, bc, fc, picks, ests = carry
        r = order[t]
        dc, bc, fc, i, est = greedy_step(
            r, dc, bc, fc, q_inst=q_inst, c_hat=c_hat, l_inst=l_inst,
            tpot=tpot, nominal_tpot=nominal, b0=b0,
            max_batch=maxb_ref[...], weights=weights,
            latency_mode=latency_mode, allowed=allowed,
            row_valid=rv, affinity=aff)
        return (dc, bc, fc, picks.at[r].set(i), ests.at[r].set(est))

    d1, b1, f1, choice, est_T = jax.lax.fori_loop(
        0, R, body, (d, b_eff, free,
                     jnp.zeros(R, jnp.int32), jnp.zeros(R, jnp.float32)))
    l_chosen = jnp.take_along_axis(l_inst, choice[:, None], axis=1)[:, 0]

    choice_ref[0] = choice
    est_ref[0] = est_T
    lchosen_ref[0] = l_chosen
    d1_ref[0] = d1
    b1_ref[0] = b1
    f1_ref[0] = f1


def decision_call(emb, row_valid, budgets, len_in, psig,
                  d, b, free, ctx, alive,
                  x, xsq, qual, leng,
                  m_of_i, tier_of_i, maxb, price_in, price_out, nominal,
                  sig_plane, gfeat, gthr, gleaf, gbase, *,
                  k: int, eps: float, weights, latency_mode: str,
                  lpt: bool, budget_filter: bool, w_aff: float,
                  use_gbm: bool, depth: int, lr: float,
                  knn_tile: int = 2048,
                  topk_mode: str = "auto",
                  interpret: Optional[bool] = None):
    """The megakernel dispatch (traceable; jit at the call site).

    Per-window args carry a leading K axis — emb (K, R, E), row_valid
    (K, R) bool, budgets/len_in (K, R), psig (K, R, SIG_WIDTH) int32
    (any (K, 1, 1) dummy when ``w_aff == 0``). Telemetry mirror
    d/b/free/ctx (I,) f32 + alive (I,) bool and every estimator
    constant are shared across windows. GBM args may be 1-element
    dummies when ``use_gbm`` is False. Returns
    (choice (K, R) i32, est_T (K, R) f32, l_chosen (K, R) f32,
    d1/b1/f1 (K, I) f32 post-scan dead-reckoned views).
    """
    if interpret is None:
        from .ops import INTERPRET
        interpret = INTERPRET
    if topk_mode == "auto":
        # stage-1 selection: the running-top-k idiom is the
        # Mosaic-lowerable form (lax.top_k has no TPU-kernel lowering);
        # under the interpreter both execute as XLA and top_k is the
        # bitwise-identical, much cheaper staged-backend op. "running" /
        # "topk" force either (the parity tests pin their equivalence).
        topk_mode = "topk" if interpret else "running"
    assert topk_mode in ("topk", "running"), topk_mode
    K, R, E = emb.shape
    I = d.shape[0]
    N, M = qual.shape
    S_req = psig.shape[1:]
    S_pl = sig_plane.shape

    def win(*block):
        return pl.BlockSpec((1,) + block,
                            lambda wi: (wi,) + (0,) * len(block))

    def shared(*block):
        return pl.BlockSpec(block, lambda wi: (0,) * len(block))

    kern = functools.partial(
        _kernel, k=k, eps=eps, weights=tuple(weights),
        latency_mode=latency_mode, lpt=lpt, budget_filter=budget_filter,
        w_aff=w_aff, use_gbm=use_gbm, depth=depth, lr=lr,
        knn_tile=knn_tile, topk_mode=topk_mode)
    return pl.pallas_call(
        kern,
        grid=(K,),
        in_specs=[
            win(R, E),                 # emb
            win(R),                    # row_valid
            win(R),                    # budgets
            win(R),                    # len_in
            win(*S_req),               # psig
            shared(I), shared(I), shared(I), shared(I),   # d b free ctx
            shared(I),                 # alive
            shared(N, E),              # x
            shared(N),                 # xsq
            shared(N, M),              # qual
            shared(N, M),              # leng
            shared(I),                 # m_of_i
            shared(I),                 # tier_of_i
            shared(I),                 # maxb
            shared(I),                 # price_in
            shared(I),                 # price_out
            shared(I),                 # nominal
            shared(*S_pl),             # sig_plane
            shared(*gfeat.shape),      # gbm feature
            shared(*gthr.shape),       # gbm threshold
            shared(*gleaf.shape),      # gbm leaf
            shared(*gbase.shape),      # gbm base
        ],
        out_specs=[
            win(R), win(R), win(R),    # choice, est_T, l_chosen
            win(I), win(I), win(I),    # d1, b1, f1
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, R), jnp.int32),
            jax.ShapeDtypeStruct((K, R), jnp.float32),
            jax.ShapeDtypeStruct((K, R), jnp.float32),
            jax.ShapeDtypeStruct((K, I), jnp.float32),
            jax.ShapeDtypeStruct((K, I), jnp.float32),
            jax.ShapeDtypeStruct((K, I), jnp.float32),
        ],
        interpret=interpret,
    )(emb, row_valid, budgets, len_in, psig,
      d, b, free, ctx, alive, x, xsq, qual, leng,
      m_of_i, tier_of_i, maxb, price_in, price_out, nominal,
      sig_plane, gfeat, gthr, gleaf, gbase)


@functools.partial(
    jax.jit,
    static_argnames=("k", "eps", "weights", "latency_mode", "lpt",
                     "budget_filter", "w_aff", "use_gbm", "depth", "lr",
                     "knn_tile", "topk_mode", "interpret"))
def decision_megakernel(emb, row_valid, budgets, len_in, psig,
                        d, b, free, ctx, alive,
                        x, xsq, qual, leng,
                        m_of_i, tier_of_i, maxb, price_in, price_out,
                        nominal, sig_plane, gfeat, gthr, gleaf, gbase,
                        *, k, eps, weights, latency_mode, lpt,
                        budget_filter, w_aff, use_gbm, depth, lr,
                        knn_tile: int = 2048, topk_mode: str = "auto",
                        interpret: bool = True):
    """Jitted standalone entry for tests/benches; production goes
    through `FusedHotPath` (decision_backend="megakernel"), which
    traces `decision_call` inside its own donated-buffer step."""
    return decision_call(
        emb, row_valid, budgets, len_in, psig, d, b, free, ctx, alive,
        x, xsq, qual, leng, m_of_i, tier_of_i, maxb, price_in,
        price_out, nominal, sig_plane, gfeat, gthr, gleaf, gbase,
        k=k, eps=eps, weights=weights, latency_mode=latency_mode,
        lpt=lpt, budget_filter=budget_filter, w_aff=w_aff,
        use_gbm=use_gbm, depth=depth, lr=lr, knn_tile=knn_tile,
        topk_mode=topk_mode, interpret=interpret)


def dummy_gbm() -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """1-element placeholder GBM operands for ``use_gbm=False`` calls
    (the static flag keeps the kernel from ever reading them)."""
    return (np.zeros((1, 1, 1), np.int32),
            np.zeros((1, 1, 1), np.float32),
            np.zeros((1, 1, 1), np.float32),
            np.zeros(1, np.float32))
