"""Pallas TPU kernel: fused L2-distance + running top-k over a KNN index.

The paper's dominant hot-path term is the batched MiniLM+KNN estimator
(~27 ms/batch on their CPU; §6.3). TPU-native re-think (DESIGN.md §3):
the index lives in HBM and is streamed through VMEM tiles; per tile the
(B, E) x (E, T) distance cross-term runs on the MXU via the
||q - x||^2 = ||q||^2 - 2 q.x + ||x||^2 expansion, and a running top-k
(k ~ 10) is maintained in the output VMEM buffers across the sequential
grid (the index-tile axis is a reduction axis: output index_map is
constant along it, so the buffers persist).

Top-k merge per tile: k rounds of (min, argmin, mask) over the (B, T)
tile distances — O(k*T) vector ops, no sort.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = 3.4e38  # +inf stand-in for f32 distance masking


def _kernel(q_ref, qsq_ref, x_ref, xsq_ref, vals_ref, idx_ref, *,
            k: int, tile: int, n_total: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    q = q_ref[...]                     # (B, E)
    x = x_ref[...]                     # (T, E)
    xsq = xsq_ref[...]                 # (1, T)
    qsq = qsq_ref[...]                 # (B, 1)
    # (B, T) squared distances on the MXU
    d = qsq + xsq - 2.0 * jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    base = t * tile
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1) + base
    d = jnp.where(col < n_total, d, NEG)

    vals = vals_ref[...]               # (B, k) current best (distances)
    idx = idx_ref[...]                 # (B, k)
    # merge: k rounds of extract-min from the tile
    for j in range(k):
        m = jnp.min(d, axis=1, keepdims=True)            # (B, 1)
        am = jnp.argmin(d, axis=1)                       # (B,)
        gidx = am.astype(jnp.int32) + base
        worst = jnp.max(vals, axis=1, keepdims=True)     # (B, 1)
        wslot = jnp.argmax(vals, axis=1)                 # (B,)
        better = m < worst                               # (B, 1)
        onehot_w = (jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
                    == wslot[:, None])
        take = onehot_w & better
        vals = jnp.where(take, m, vals)
        idx = jnp.where(take, gidx[:, None], idx)
        onehot_d = (jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
                    == am[:, None])
        d = jnp.where(onehot_d, NEG, d)
    vals_ref[...] = vals
    idx_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("k", "tile", "interpret"))
def knn_topk(q, x, *, k: int = 10, tile: int = 512,
             interpret: bool = True):
    """q: (B, E) queries; x: (N, E) index. Returns (d2 (B,k), idx (B,k)),
    sorted ascending by distance."""
    B, E = q.shape
    N = x.shape[0]
    n_pad = (-N) % tile
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    Np = x.shape[0]
    qsq = jnp.sum(q * q, axis=1, keepdims=True)          # (B, 1)
    xsq = jnp.sum(x * x, axis=1)[None, :]                # (1, Np)
    grid = (Np // tile,)
    vals, idx = pl.pallas_call(
        functools.partial(_kernel, k=k, tile=tile, n_total=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, E), lambda t: (0, 0)),
            pl.BlockSpec((B, 1), lambda t: (0, 0)),
            pl.BlockSpec((tile, E), lambda t: (t, 0)),
            pl.BlockSpec((1, tile), lambda t: (0, t)),
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda t: (0, 0)),
            pl.BlockSpec((B, k), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), qsq.astype(jnp.float32),
      x.astype(jnp.float32), xsq.astype(jnp.float32))
    # final ascending sort of the k survivors
    order = jnp.argsort(vals, axis=1)
    return (jnp.take_along_axis(vals, order, axis=1),
            jnp.take_along_axis(idx, order, axis=1))
