"""Unified Model facade: one object per architecture config.

Dispatches decoder-only vs encoder-decoder families and exposes the four
entry points the launcher/serving layers lower:
  init(key), loss(params, batch), prefill(params, batch), decode(params,
  cache, tokens), init_cache(batch, ctx).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import encdec, model
from .config import ModelConfig


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters ---------------------------------------------------------
    def init(self, key) -> Dict:
        if self.cfg.is_encdec:
            return encdec.init_params(self.cfg, key)
        return model.init_params(self.cfg, key)

    def param_specs(self) -> Dict:
        """Abstract parameter tree (ShapeDtypeStructs; no allocation)."""
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    # -- training -----------------------------------------------------------
    def loss(self, params, batch: Dict[str, Any]):
        if self.cfg.is_encdec:
            return encdec.loss_fn(params, self.cfg, batch)
        return model.loss_fn(params, self.cfg, batch)

    # -- serving ------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, Any], pad_to: int = 0):
        if self.cfg.is_encdec:
            return encdec.prefill(params, self.cfg, batch["frames"],
                                  batch["tokens"])
        return model.prefill(params, self.cfg, batch["tokens"],
                             batch.get("frontend_embeds"), pad_to=pad_to)

    def decode(self, params, cache, tokens):
        if self.cfg.is_encdec:
            return encdec.decode_step(params, self.cfg, cache, tokens)
        return model.decode_step(params, self.cfg, cache, tokens)

    def init_cache(self, batch: int, ctx: int):
        if self.cfg.is_encdec:
            return encdec.init_cache(self.cfg, batch, ctx)
        return model.init_cache(self.cfg, batch, ctx)

    def cache_specs(self, batch: int, ctx: int):
        return jax.eval_shape(lambda: self.init_cache(batch, ctx))


def greedy_sample(logits) -> jax.Array:
    """Temperature-0 decoding (the paper's determinism contract, §4.2)."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
