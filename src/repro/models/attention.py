"""Flash-style chunked attention in pure jnp, with a hand-written VJP.

Why hand-written: differentiating through an online-softmax ``lax.scan``
makes JAX save every per-block probability matrix, which is exactly the
(S x S) memory wall flash attention exists to avoid. With a custom VJP the
forward saves only (o, lse) and the backward recomputes block scores —
the standard flash backward — so 4k-token training steps and 32k prefills
lower within HBM budgets.

Layout: q, k, v are (B, S, H, hd) with K/V heads already repeated to H for
GQA in training/prefill (cheap broadcast; keeps head sharding trivially
divisible under GSPMD). The decode path is GQA-native (no repeat) because
decode is KV-bandwidth-bound and the repeat would multiply HBM reads.

``skip_masked_blocks`` skips fully-masked KV blocks (causal upper triangle
and out-of-window bands) via dynamic loop bounds — legal here because the
custom VJP means reverse-mode AD never traces through the loops. It is OFF
by default (baseline) and enabled during the §Perf hillclimb.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, kv_len, causal: bool, window: int):
    """(bq, bkv) bool mask of *allowed* positions."""
    m = (k_pos[None, :] < kv_len)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, window: int, block_q: int, block_kv: int,
                skip: bool):
    """Build a custom-VJP flash attention for a static mask configuration."""

    def _ranges(nq, nkv, q_offset):
        """Per-q-block [lo, hi) kv-block ranges (traced; used when skip)."""
        def lo(i):
            if window <= 0:
                return jnp.int32(0)
            first_q = q_offset + i * block_q
            return jnp.maximum(0, (first_q - window + 1) // block_kv)

        def hi(i):
            if not causal:
                return jnp.int32(nkv)
            last_q = q_offset + (i + 1) * block_q - 1
            return jnp.minimum(nkv, last_q // block_kv + 1)

        return lo, hi

    def fwd(q, k, v, q_offset, kv_len):
        B, Sq, H, d = q.shape
        Sk = k.shape[1]
        nq, nkv = Sq // block_q, Sk // block_kv
        scale = d ** -0.5
        qb = jnp.moveaxis(q.reshape(B, nq, block_q, H, d), 1, 0)
        kb = jnp.moveaxis(k.reshape(B, nkv, block_kv, H, d), 1, 0)
        vb = jnp.moveaxis(v.reshape(B, nkv, block_kv, H, d), 1, 0)
        lo_f, hi_f = _ranges(nq, nkv, q_offset)

        def q_block(i, q_i):
            q_pos = q_offset + i * block_q + jnp.arange(block_q)

            def kv_step(j, carry):
                m, l, acc = carry
                k_j = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
                v_j = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
                k_pos = j * block_kv + jnp.arange(block_kv)
                s = jnp.einsum("bqhd,bchd->bhqc", q_i, k_j,
                               preferred_element_type=jnp.float32) * scale
                mask = _block_mask(q_pos, k_pos, kv_len, causal, window)
                s = jnp.where(mask[None, None], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                alpha = jnp.exp(m - m_new)
                p = jnp.exp(s - m_new[..., None])
                l = l * alpha + p.sum(axis=-1)
                pv = jnp.einsum("bhqc,bchd->bqhd", p.astype(v_j.dtype), v_j,
                                preferred_element_type=jnp.float32)
                acc = acc * jnp.moveaxis(alpha, 1, 2)[..., None] + pv
                return m_new, l, acc

            init = (jnp.full((B, H, block_q), NEG_INF, jnp.float32),
                    jnp.zeros((B, H, block_q), jnp.float32),
                    jnp.zeros((B, block_q, H, d), jnp.float32))
            if skip:
                m, l, acc = jax.lax.fori_loop(lo_f(i), hi_f(i), kv_step, init)
            else:
                m, l, acc = jax.lax.fori_loop(0, nkv, kv_step, init)
            l_safe = jnp.maximum(l, 1e-30)
            o = acc / jnp.moveaxis(l_safe, 1, 2)[..., None]
            lse = m + jnp.log(l_safe)
            return o.astype(q.dtype), lse

        def scan_body(_, xs):
            i, q_i = xs
            return None, q_block(i, q_i)

        _, (ob, lseb) = jax.lax.scan(scan_body, None,
                                     (jnp.arange(nq), qb))
        o = jnp.moveaxis(ob, 0, 1).reshape(B, Sq, H, d)
        lse = jnp.moveaxis(lseb, 0, 1)  # (B, nq, H, bq) -> keep blocked
        return o, lse

    def bwd_impl(q, k, v, q_offset, kv_len, o, lse, g):
        B, Sq, H, d = q.shape
        Sk = k.shape[1]
        nq, nkv = Sq // block_q, Sk // block_kv
        scale = d ** -0.5
        qb = jnp.moveaxis(q.reshape(B, nq, block_q, H, d), 1, 0)
        gb = jnp.moveaxis(g.reshape(B, nq, block_q, H, d), 1, 0)
        ob = jnp.moveaxis(o.reshape(B, nq, block_q, H, d), 1, 0)
        kb = jnp.moveaxis(k.reshape(B, nkv, block_kv, H, d), 1, 0)
        vb = jnp.moveaxis(v.reshape(B, nkv, block_kv, H, d), 1, 0)
        # D_i = rowsum(dO * O): (nq, B, H, bq)
        Db = jnp.einsum("nbqhd,nbqhd->nbhq", gb.astype(jnp.float32),
                        ob.astype(jnp.float32))

        def kv_block(j, dq_acc):
            k_j = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
            k_pos = j * block_kv + jnp.arange(block_kv)
            if skip and causal:
                q_lo = jnp.maximum(0, (j * block_kv - q_offset) // block_q)
            else:
                q_lo = jnp.int32(0)
            if skip and window > 0:
                last_k = (j + 1) * block_kv - 1
                q_hi = jnp.minimum(
                    nq, (last_k + window - q_offset) // block_q + 1)
            else:
                q_hi = jnp.int32(nq)

            def q_step(i, carry):
                dk_j, dv_j, dq_acc = carry
                q_i = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
                g_i = jax.lax.dynamic_index_in_dim(gb, i, 0, keepdims=False)
                lse_i = jax.lax.dynamic_index_in_dim(lse, i, 1, keepdims=False)
                D_i = jax.lax.dynamic_index_in_dim(Db, i, 0, keepdims=False)
                q_pos = q_offset + i * block_q + jnp.arange(block_q)
                s = jnp.einsum("bqhd,bchd->bhqc", q_i, k_j,
                               preferred_element_type=jnp.float32) * scale
                mask = _block_mask(q_pos, k_pos, kv_len, causal, window)
                s = jnp.where(mask[None, None], s, NEG_INF)
                p = jnp.exp(s - lse_i[..., None])                 # (B,H,bq,bkv)
                dv_j = dv_j + jnp.einsum("bhqc,bqhd->bchd",
                                         p, g_i.astype(jnp.float32))
                dp = jnp.einsum("bqhd,bchd->bhqc", g_i, v_j,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - D_i[..., None]) * scale
                dq_i = jnp.einsum("bhqc,bchd->bqhd", ds,
                                  k_j.astype(jnp.float32))
                dq_acc = jax.lax.dynamic_update_index_in_dim(
                    dq_acc,
                    jax.lax.dynamic_index_in_dim(dq_acc, i, 0, keepdims=False)
                    + dq_i, i, 0)
                dk_j = dk_j + jnp.einsum("bhqc,bqhd->bchd", ds,
                                         q_i.astype(jnp.float32))
                return dk_j, dv_j, dq_acc

            dk0 = jnp.zeros((B, block_kv, H, d), jnp.float32)
            dv0 = jnp.zeros((B, block_kv, H, d), jnp.float32)
            dk_j, dv_j, dq_acc = jax.lax.fori_loop(
                q_lo, q_hi, q_step, (dk0, dv0, dq_acc))
            return dk_j, dv_j, dq_acc

        def scan_body(dq_acc, j):
            dk_j, dv_j, dq_acc = kv_block(j, dq_acc)
            return dq_acc, (dk_j, dv_j)

        dq0 = jnp.zeros((nq, B, block_q, H, d), jnp.float32)
        dq_acc, (dkb, dvb) = jax.lax.scan(scan_body, dq0, jnp.arange(nkv))
        dq = jnp.moveaxis(dq_acc, 0, 1).reshape(B, Sq, H, d).astype(q.dtype)
        dk = jnp.moveaxis(dkb, 0, 1).reshape(B, Sk, H, d).astype(k.dtype)
        dv = jnp.moveaxis(dvb, 0, 1).reshape(B, Sk, H, d).astype(v.dtype)
        return dq, dk, dv

    @jax.custom_vjp
    def flash(q, k, v, q_offset, kv_len):
        o, _ = fwd(q, k, v, q_offset, kv_len)
        return o

    def flash_fwd(q, k, v, q_offset, kv_len):
        o, lse = fwd(q, k, v, q_offset, kv_len)
        return o, (q, k, v, q_offset, kv_len, o, lse)

    def flash_bwd(res, g):
        q, k, v, q_offset, kv_len, o, lse = res
        dq, dk, dv = bwd_impl(q, k, v, q_offset, kv_len, o, lse, g)
        return dq, dk, dv, None, None

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset=0, kv_len: Optional[jax.Array] = None,
                    block_q: int = 512, block_kv: int = 512,
                    skip_masked_blocks: bool = False):
    """Chunked attention. q,k,v: (B,S,H,hd) with KV repeated to H heads."""
    B, Sq, H, d = q.shape
    Sk = k.shape[1]
    bq = block_q if Sq % block_q == 0 else Sq
    bkv = block_kv if Sk % block_kv == 0 else Sk
    fn = _make_flash(causal, int(window), int(bq), int(bkv),
                     bool(skip_masked_blocks))
    kv_len = jnp.int32(Sk) if kv_len is None else jnp.int32(kv_len)
    return fn(q, k, v, jnp.int32(q_offset), kv_len)


def repeat_kv(x, n_rep: int):
    """(B,S,K,d) -> (B,S,K*n_rep,d) by head repetition (GQA)."""
    if n_rep == 1:
        return x
    B, S, K, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (B, S, K, n_rep, d)) \
             .reshape(B, S, K * n_rep, d)


def decode_attention(q, k_cache, v_cache, cache_positions, pos, *,
                     window: int = 0):
    """Single-token GQA decode attention (no head repetition).

    q: (B, 1, H, d); caches: (B, C, K, d); cache_positions: (C,) global
    position of each cache slot (-1 = empty); pos: current position.

    Scores are (B, H, 1, C) — small because q is one token — so a plain
    masked softmax is used. With the cache sharded over its C (sequence)
    axis this lowers to a local einsum + small logits all-gather, the
    flash-decoding pattern.
    """
    B, _, H, d = q.shape
    K = k_cache.shape[2]
    g = H // K
    qg = q.reshape(B, 1, K, g, d)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k_cache,
                   preferred_element_type=jnp.float32) * (d ** -0.5)
    valid = (cache_positions >= 0) & (cache_positions <= pos)
    if window > 0:
        valid &= cache_positions > pos - window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, d).astype(q.dtype)
