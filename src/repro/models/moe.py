"""Mixture-of-Experts layer with scatter-based (sort-free) dispatch.

Capacity-based token dispatch via cumsum positions + scatter-add into
per-expert buffers, batched expert matmuls, and gather-combine. This avoids
the (T, E, C) one-hot dispatch einsum of GShard-style MoE, whose memory is
prohibitive at train_4k token counts. Expert weights are TP-shardable on
the d_ff axis (works for any expert count, incl. E=8 and E=40 which do not
divide a 16-wide model axis); the dispatch itself stays data-local, so no
cross-data-shard token routing is required at lowering time. True EP with
all-to-all is an optimization explored in §Perf.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .layers import apply_act, dense_init


def moe_params(key, d: int, f: int, n_experts: int, glu: bool,
               dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, n_experts), dtype=jnp.float32),
        "up": dense_init(ks[1], (n_experts, d, f), dtype=dtype),
        "down": dense_init(ks[2], (n_experts, f, d), dtype=dtype),
    }
    if glu:
        p["gate"] = dense_init(ks[3], (n_experts, d, f), dtype=dtype)
    return p


def moe_layer(x, p: Dict, *, top_k: int, capacity_factor: float,
              act: str = "silu", glu: bool = True, no_drop: bool = False):
    """x: (..., D) -> (out (..., D), aux load-balance loss).

    no_drop=True sets capacity C=T (each token fits every expert it picks —
    used at decode where per-shard token counts are tiny and capacity drops
    would perturb served quality).
    """
    orig_shape = x.shape
    D = orig_shape[-1]
    x2 = x.reshape(-1, D)
    T = x2.shape[0]
    E = p["router"].shape[1]
    k = top_k

    logits = (x2.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                           # (T, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * P_e.
    me = probs.mean(axis=0)                                    # (T,E)->(E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        jnp.ones((T * k,), jnp.float32)) / (T * k)
    aux = E * jnp.sum(me * ce)

    C = T if no_drop else max(1, int(capacity_factor * k * T / E))
    flat_e = idx.reshape(-1)                                   # (T*k,)
    flat_w = w.reshape(-1)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (T*k, E)
    pos = jnp.cumsum(oh, axis=0) - 1
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]
    keep = (pos_in_e < C).astype(x2.dtype)
    slot = jnp.clip(pos_in_e, 0, C - 1)

    x_rep = jnp.repeat(x2, k, axis=0)                          # (T*k, D)
    buf = jnp.zeros((E, C, D), x2.dtype).at[flat_e, slot].add(
        x_rep * keep[:, None])

    up = jnp.einsum("ecd,edf->ecf", buf, p["up"])
    if glu:
        h = apply_act(jnp.einsum("ecd,edf->ecf", buf, p["gate"]), act) * up
    else:
        h = apply_act(up, act)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down"])          # (E, C, D)

    y = out_buf[flat_e, slot] * (keep * flat_w.astype(x2.dtype))[:, None]
    out = y.reshape(T, k, D).sum(axis=1)
    return out.reshape(orig_shape), aux


def moe_layer_sharded(x, p: Dict, *, top_k: int, capacity_factor: float,
                      act: str = "silu", glu: bool = True,
                      no_drop: bool = False):
    """Data-local MoE under an active sharding context.

    shard_map keeps the dispatch (cumsum/scatter/gather) entirely within
    each data shard — no cross-shard token routing at lowering time — while
    expert FFN weights stay TP-sharded on d_ff over "model". This is what
    prevents GSPMD from materializing replicated (E, C, D) buffers with
    cross-data psums. The capacity C is computed from the LOCAL token count
    (shapes inside shard_map are per-shard).
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.distributed.shardctx import batch_axes, current
    mesh, _ = current()
    if mesh is None:
        return moe_layer(x, p, top_k=top_k, capacity_factor=capacity_factor,
                         act=act, glu=glu, no_drop=no_drop)
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    if not ba or x.shape[0] % nb != 0:
        return moe_layer(x, p, top_k=top_k, capacity_factor=capacity_factor,
                         act=act, glu=glu, no_drop=no_drop)

    def local(xl, pl):
        out, aux = moe_layer(xl, pl, top_k=top_k,
                             capacity_factor=capacity_factor, act=act,
                             glu=glu, no_drop=no_drop)
        # expert down-proj contracted over the TP-sharded d_ff: finish it
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, ba)
        return out, aux

    p_specs = {
        "router": P(),
        "up": P(None, None, "model"),
        "down": P(None, "model", None),
    }
    if glu:
        p_specs["gate"] = P(None, None, "model")
    x_spec = P(ba, *([None] * (x.ndim - 1)))
    fn = shard_map(local, mesh=mesh,
                   in_specs=(x_spec, p_specs),
                   out_specs=(x_spec, P()),
                   check_rep=False)
    return fn(x, p)
