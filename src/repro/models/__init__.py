from .api import Model, greedy_sample
from .config import BlockCfg, ModelConfig, SHAPES, ShapeSpec, smoke_shape

__all__ = ["Model", "greedy_sample", "BlockCfg", "ModelConfig", "SHAPES",
           "ShapeSpec", "smoke_shape"]
