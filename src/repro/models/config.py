"""Model & shape configuration for the repro model zoo.

One ``ModelConfig`` describes any architecture in the assigned pool. Layers
are described by a repeating ``pattern`` of ``BlockCfg`` entries (mixer +
mlp), which lets a single scanned implementation host dense GQA, 5:1
local:global (gemma3), RG-LRU hybrids (recurrentgemma), SSD (mamba2) and
MoE (mixtral / granite-moe) bodies.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Block-level configuration


@dataclass(frozen=True)
class BlockCfg:
    """One layer 'slot' in the repeating layer pattern."""

    mixer: str = "attn"          # attn | rglru | ssd
    window: int = 0              # 0 = full attention; >0 = sliding window
    mlp: str = "dense"           # dense | moe | none
    rope_theta: float = 10_000.0

    def cache_len(self, seq_len: int) -> int:
        """KV-cache length this slot needs for a context of ``seq_len``."""
        if self.mixer != "attn":
            return 0
        if self.window > 0:
            return min(self.window, seq_len)
        return seq_len


# ---------------------------------------------------------------------------
# Model-level configuration


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qk_norm: bool = False
    pattern: Tuple[BlockCfg, ...] = (BlockCfg(),)
    norm: str = "rms"             # rms | layer
    act: str = "silu"             # silu (SwiGLU) | gelu (GeGLU / plain)
    glu: bool = True              # gated MLP (SwiGLU/GeGLU) vs plain 2-layer
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_groups: int = 1
    conv_width: int = 4
    # --- hybrid (RG-LRU / griffin) ---
    lru_width: int = 0
    # --- enc-dec ---
    n_enc_layers: int = 0         # >0 => encoder-decoder; n_layers = decoder
    dec_max_len: int = 448        # whisper-style decoder design length
    # --- vlm / audio stub frontends ---
    frontend: str = "none"        # none | vision | audio
    n_frontend_tokens: int = 0    # patch/frame embeddings prepended (vision)
    frontend_dim: int = 0         # raw patch/frame feature dim (stub proj)
    embed_scale: float = 1.0      # gemma-style sqrt(d_model) embed scaling
    # --- numerics / lowering ---
    vocab_pad_to: int = 1         # pad embedding rows to a multiple (TP)
    dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunk: int = 8192        # global tokens per CE chunk: large
    #   enough that the per-chunk embed-grad psum amortizes (§Perf iter 2),
    #   small enough that per-chip chunk logits stay ~tens of MB
    attn_chunk: int = 512         # flash-attention KV block
    scan_layers: bool = True

    # -- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab + m - 1) // m) * m

    @property
    def layer_types(self) -> Tuple[BlockCfg, ...]:
        """Per-layer BlockCfg, the pattern cycled over n_layers."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def n_cycles(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_rem(self) -> int:
        return self.n_layers % len(self.pattern)

    @property
    def d_inner(self) -> int:     # ssd inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (for 6ND roofline math). MoE: total & active.
    def param_counts(self) -> dict:
        D, F, V, hd = self.d_model, self.d_ff, self.vocab, self.hd
        H, K = self.n_heads, self.n_kv_heads
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D
        active = total
        for blk in self.layer_types:
            n = 2 * D  # norms (approx)
            if blk.mixer == "attn":
                n += D * H * hd + 2 * D * K * hd + H * hd * D
                if self.qk_norm:
                    n += 2 * hd
            elif blk.mixer == "ssd":
                di, N, G, nh = (self.d_inner, self.ssm_state,
                                self.ssm_groups, self.ssm_heads)
                n += D * (2 * di + 2 * G * N + nh)       # in_proj
                n += self.conv_width * (di + 2 * G * N)  # conv
                n += di * D + di + 2 * nh                # out_proj, norm, A/dt
            elif blk.mixer == "rglru":
                W = self.lru_width or D
                n += 2 * D * W + W * D + 2 * W * W + 3 * W \
                    + self.conv_width * W
            n_active = n
            if blk.mlp == "dense":
                n += (3 if self.glu else 2) * D * F
                n_active = n
            elif blk.mlp == "moe":
                e = (3 if self.glu else 2) * D * F
                n += self.n_experts * e + D * self.n_experts
                n_active += self.top_k * e + D * self.n_experts
            total += n
            active += n_active
        # encoder tower (enc-dec): encoder layers + cross-attn in decoder
        if self.is_encdec:
            enc = self.n_enc_layers * (
                D * H * hd + 2 * D * K * hd + H * hd * D + 2 * D * F + 4 * D)
            cross = self.n_layers * (D * H * hd + 2 * D * K * hd + H * hd * D + 2 * D)
            total += enc + cross
            active += enc + cross
        return {"total": int(total), "active": int(active)}


# ---------------------------------------------------------------------------
# Input shapes (assigned to every architecture)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def smoke_shape(kind: str = "train") -> ShapeSpec:
    return ShapeSpec(f"smoke_{kind}", 128, 2, kind)
