"""Unified decoder-only model over repeating block patterns.

The layer stack is ``n_cycles`` repetitions of ``cfg.pattern`` (scanned, with
per-cycle stacked parameters — one traced layer body per *slot* regardless of
depth, which keeps 62-layer lowering cheap) plus ``n_rem`` unrolled remainder
layers. Hosts every decoder-only architecture in the pool: dense GQA
(granite/qwen3/phi3), 5:1 local:global (gemma3), MoE (mixtral/granite-moe),
SSD (mamba2), RG-LRU hybrid (recurrentgemma) and the VLM variant (phi3-vision,
patch embeddings prepended via a stub projection).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.shardctx import constrain

from .blocks import block_cache_spec, block_forward, block_params
from .config import ModelConfig
from .layers import apply_norm, chunked_ce_loss, dense_init, embed_lookup, \
    norm_params

Params = Dict[str, Any]

_ONE_HOT_VOCAB_MIN = 8192  # above this, lookup via chunked one-hot matmul


def _embed(tokens, table, scale: float):
    if table.shape[0] >= _ONE_HOT_VOCAB_MIN:
        x = embed_lookup(tokens, table)
    else:
        x = jnp.take(table, tokens, axis=0)
    return x * jnp.asarray(scale, x.dtype)


def _unembed_table(params: Params, cfg: ModelConfig):
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def _logits(h_last, params: Params, cfg: ModelConfig):
    """(B, D) -> (B, V) f32 with padded-vocab columns masked."""
    table = _unembed_table(params, cfg)
    logits = h_last.astype(jnp.float32) @ table.T.astype(jnp.float32)
    if cfg.padded_vocab > cfg.vocab:
        logits = jnp.where(jnp.arange(cfg.padded_vocab)[None, :] < cfg.vocab,
                           logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# init

def init_params(cfg: ModelConfig, key) -> Params:
    assert not cfg.is_encdec, "use encdec.init_params"
    ks = jax.random.split(key, 8)
    params: Params = {
        "embed": dense_init(ks[0], (cfg.padded_vocab, cfg.d_model),
                            scale=0.02, dtype=cfg.dtype),
        "final_norm": norm_params(ks[1], cfg.d_model, cfg.norm, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (cfg.padded_vocab,
                                              cfg.d_model),
                                       scale=0.02, dtype=cfg.dtype)
    if cfg.frontend != "none":
        params["frontend_proj"] = dense_init(
            ks[3], (cfg.frontend_dim, cfg.d_model), dtype=cfg.dtype)
    for s, blk in enumerate(cfg.pattern):
        if cfg.n_cycles > 0:
            keys = jax.random.split(jax.random.fold_in(ks[4], s),
                                    cfg.n_cycles)
            params[f"slot{s}"] = jax.vmap(
                lambda k, _blk=blk: block_params(k, cfg, _blk))(keys)
    for r in range(cfg.n_rem):
        params[f"rem{r}"] = block_params(
            jax.random.fold_in(ks[5], r), cfg, cfg.pattern[r])
    return params


def init_cache(cfg: ModelConfig, batch: int, ctx: int) -> Params:
    cache: Params = {"pos": jnp.int32(0)}
    for s, blk in enumerate(cfg.pattern):
        if cfg.n_cycles > 0:
            one = block_cache_spec(cfg, blk, batch, ctx)
            cache[f"slot{s}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (cfg.n_cycles,) + a.shape).copy(), one)
    for r in range(cfg.n_rem):
        cache[f"rem{r}"] = block_cache_spec(cfg, cfg.pattern[r], batch, ctx)
    return cache


# ---------------------------------------------------------------------------
# forward

def _run_layers(params: Params, cfg: ModelConfig, x, mode: str,
                cache: Optional[Params], pos, pad_to: int = 0):
    period = len(cfg.pattern)
    aux0 = jnp.float32(0.0)
    new_cache: Params = None if mode == "train" else {}

    if cfg.n_cycles > 0:
        slot_params = tuple(params[f"slot{s}"] for s in range(period))

        if mode in ("train", "prefill"):
            def body(carry, xs):
                h, aux = carry
                h = constrain(h, "residual")
                outs = []
                for s, blk in enumerate(cfg.pattern):
                    h, nc, a = block_forward(h, xs[s], cfg, blk, mode,
                                             None, pos, pad_to)
                    outs.append(nc)
                    aux = aux + a
                ys = tuple(outs) if mode == "prefill" else None
                return (h, aux), ys

            body = jax.checkpoint(body) if cfg.remat else body
            (x, aux0), ys = jax.lax.scan(body, (x, aux0), slot_params)
            if mode == "prefill":
                for s in range(period):
                    new_cache[f"slot{s}"] = ys[s]
        else:  # decode
            slot_caches = tuple(cache[f"slot{s}"] for s in range(period))

            def body(carry, xs):
                h, aux = carry
                ps, cs = xs
                outs = []
                for s, blk in enumerate(cfg.pattern):
                    h, nc, a = block_forward(h, ps[s], cfg, blk, "decode",
                                             cs[s], pos)
                    outs.append(nc)
                    aux = aux + a
                return (h, aux), tuple(outs)

            (x, aux0), new_slot_caches = jax.lax.scan(
                body, (x, aux0), (slot_params, slot_caches))
            for s in range(period):
                new_cache[f"slot{s}"] = new_slot_caches[s]

    for r in range(cfg.n_rem):
        blk = cfg.pattern[r]
        c = cache.get(f"rem{r}") if mode == "decode" else None
        x, nc, a = block_forward(x, params[f"rem{r}"], cfg, blk, mode, c,
                                 pos, pad_to)
        aux0 = aux0 + a
        if new_cache is not None:
            new_cache[f"rem{r}"] = nc
    return x, new_cache, aux0


def forward(params: Params, cfg: ModelConfig, tokens, *, mode: str,
            cache: Optional[Params] = None, frontend_embeds=None,
            pad_to: int = 0):
    """tokens: (B, S) int32. Returns (hidden (B,S',D), new_cache, aux)."""
    pos = cache["pos"] if mode == "decode" else jnp.int32(0)
    x = _embed(tokens, params["embed"], cfg.embed_scale)
    if cfg.frontend != "none" and mode != "decode" \
            and frontend_embeds is not None:
        fe = frontend_embeds.astype(cfg.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([fe, x], axis=1)
    x, new_cache, aux = _run_layers(params, cfg, x, mode, cache, pos, pad_to)
    x = apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# entry points

def loss_fn(params: Params, cfg: ModelConfig, batch: Dict):
    """batch: tokens (B,S), labels (B,S), optional loss_mask,
    frontend_embeds (B,F,frontend_dim)."""
    h, _, aux = forward(params, cfg, batch["tokens"], mode="train",
                        frontend_embeds=batch.get("frontend_embeds"))
    n_front = 0
    if cfg.frontend != "none" and "frontend_embeds" in batch:
        n_front = batch["frontend_embeds"].shape[1]
        h = h[:, n_front:]
    table = _unembed_table(params, cfg)
    ce = chunked_ce_loss(h, table, batch["labels"],
                         batch.get("loss_mask"), cfg.loss_chunk,
                         valid_vocab=cfg.vocab)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def prefill(params: Params, cfg: ModelConfig, tokens, frontend_embeds=None,
            pad_to: int = 0) -> Tuple[jax.Array, Params]:
    """pad_to: total context the caches should be sized for (>= prompt)."""
    ctx = _ctx_len(cfg, tokens, frontend_embeds)
    h, cache, _ = forward(params, cfg, tokens, mode="prefill",
                          frontend_embeds=frontend_embeds,
                          pad_to=max(pad_to, ctx))
    cache["pos"] = jnp.int32(ctx)
    logits = _logits(h[:, -1], params, cfg)
    return logits, cache


def _ctx_len(cfg, tokens, frontend_embeds):
    n = tokens.shape[1]
    if cfg.frontend != "none" and frontend_embeds is not None:
        n += frontend_embeds.shape[1]
    return n


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens) -> Tuple[jax.Array, Params]:
    """tokens: (B, 1). Returns (logits (B, V) f32, new cache)."""
    h, new_cache, _ = forward(params, cfg, tokens, mode="decode", cache=cache)
    new_cache["pos"] = cache["pos"] + 1
    logits = _logits(h[:, 0], params, cfg)
    return logits, new_cache
