"""Layer blocks: attention / RG-LRU / SSD mixers + dense|moe MLP.

A block is (pre-norm -> mixer -> residual -> pre-norm -> mlp -> residual);
mamba2-style ssd blocks have no separate MLP (mlp="none"). Every forward
supports three modes:
  train   — full-sequence causal, no cache
  prefill — full-sequence causal, returns populated cache
  decode  — single token, consumes + updates cache

Caches are plain dict pytrees so they stack over scan cycles.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import decode_attention, flash_attention, repeat_kv
from .config import BlockCfg, ModelConfig
from .layers import apply_act, apply_norm, apply_rope, dense_init, mlp, \
    mlp_params, norm_params
from .moe import moe_layer, moe_layer_sharded, moe_params

Params = Dict


# ---------------------------------------------------------------------------
# causal depthwise conv (width w)

def conv_params(key, width: int, channels: int, dtype):
    return {"w": dense_init(key, (width, channels), scale=0.5, dtype=dtype)}


def causal_conv(x, p, width: int):
    """x: (B, S, C) full-sequence causal depthwise conv."""
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    S = x.shape[1]
    out = sum(pad[:, i:i + S] * p["w"][i] for i in range(width))
    return out


def conv_step(x_t, state, p, width: int):
    """x_t: (B, C) one step; state: (B, width-1, C) past inputs."""
    full = jnp.concatenate([state, x_t[:, None]], axis=1)   # (B, w, C)
    out = jnp.einsum("bwc,wc->bc", full, p["w"])
    return out, full[:, 1:]


# ---------------------------------------------------------------------------
# Attention block

def attn_params(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (D, K * hd), dtype=dtype),
        "wv": dense_init(ks[2], (D, K * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, D), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _qk_norm(x, scale, eps):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * (1.0 + scale.astype(jnp.float32))).astype(dt)


def attn_forward(x, p: Params, cfg: ModelConfig, blk: BlockCfg, mode: str,
                 cache: Optional[Params], pos,
                 pad_to: int = 0) -> Tuple[jax.Array, Params]:
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, K, hd)
    v = (x @ p["wv"]).reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)

    if mode == "decode":
        q = apply_rope(q, jnp.full((B, 1), pos), blk.rope_theta)
        k = apply_rope(k, jnp.full((B, 1), pos), blk.rope_theta)
        C = cache["k"].shape[1]
        slot = pos % C
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cache["positions"], pos[None].astype(jnp.int32), (slot,))
        o = decode_attention(q, kc, vc, cpos, pos, window=blk.window)
        new_cache = {"k": kc, "v": vc, "positions": cpos}
    else:
        positions = jnp.arange(S)[None, :]
        q = apply_rope(q, positions, blk.rope_theta)
        k = apply_rope(k, positions, blk.rope_theta)
        o = flash_attention(
            q, repeat_kv(k, H // K), repeat_kv(v, H // K),
            causal=True, window=blk.window,
            block_q=min(cfg.attn_chunk, S), block_kv=min(cfg.attn_chunk, S),
            skip_masked_blocks=getattr(cfg, "_skip_blocks", False))
        if mode == "prefill":
            C = blk.cache_len(max(pad_to, S))
            if S <= C:
                padw = ((0, 0), (0, C - S), (0, 0), (0, 0))
                new_cache = {
                    "k": jnp.pad(k, padw),
                    "v": jnp.pad(v, padw),
                    "positions": jnp.concatenate(
                        [jnp.arange(S, dtype=jnp.int32),
                         jnp.full((C - S,), -1, jnp.int32)]),
                }
            else:
                # windowed: slot j holds the latest pos p with p % C == j
                j = jnp.arange(C)
                p_j = (S - 1) - ((S - 1 - j) % C)
                new_cache = {
                    "k": jnp.take(k, p_j, axis=1),
                    "v": jnp.take(v, p_j, axis=1),
                    "positions": p_j.astype(jnp.int32),
                }
        else:
            new_cache = cache
    out = o.reshape(B, S, H * hd) @ p["wo"]
    return out, new_cache


def attn_cache_spec(cfg: ModelConfig, blk: BlockCfg, B: int, ctx: int):
    C = blk.cache_len(ctx)
    return {
        "k": jnp.zeros((B, C, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "v": jnp.zeros((B, C, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "positions": jnp.full((C,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# RG-LRU (griffin / recurrentgemma) recurrent block

_LRU_C = 8.0


def rglru_params(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    D, W = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], (D, W), dtype=dtype),
        "w_gate_branch": dense_init(ks[1], (D, W), dtype=dtype),
        "w_out": dense_init(ks[2], (W, D), dtype=dtype),
        "w_i": dense_init(ks[3], (W, W), dtype=dtype),
        "w_r": dense_init(ks[4], (W, W), dtype=dtype),
        "lam": jax.random.uniform(ks[5], (W,), jnp.float32, 0.9, 0.999),
        "conv": conv_params(ks[6], cfg.conv_width, W, dtype),
    }


def _lru_gates(u, p):
    uf = u.astype(jnp.float32)
    i_t = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32))
    r_t = jax.nn.sigmoid(uf @ p["w_r"].astype(jnp.float32))
    log_a = _LRU_C * jax.nn.log_sigmoid(
        jnp.log(p["lam"] / (1 - p["lam"]))) * r_t     # (..., W) in (-inf, 0)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i_t * uf)
    return a, b


def rglru_forward(x, p: Params, cfg: ModelConfig, blk: BlockCfg, mode: str,
                  cache: Optional[Params], pos,
                  pad_to: int = 0) -> Tuple[jax.Array, Params]:
    B, S, D = x.shape
    u_in = x @ p["w_in"]
    gate = apply_act(x @ p["w_gate_branch"], "gelu")
    if mode == "decode":
        u, conv_state = conv_step(u_in[:, 0], cache["conv"], p["conv"],
                                  cfg.conv_width)
        a, b = _lru_gates(u, p)
        h = a * cache["h"] + b
        y = h[:, None].astype(x.dtype)
        new_cache = {"h": h, "conv": conv_state}
    else:
        u = causal_conv(u_in, p["conv"], cfg.conv_width)
        a, b = _lru_gates(u, p)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        y = h.astype(x.dtype)
        if mode == "prefill":
            new_cache = {"h": h[:, -1],
                         "conv": u_in[:, -(cfg.conv_width - 1):]}
        else:
            new_cache = cache
    return (y * gate) @ p["w_out"], new_cache


def rglru_cache_spec(cfg: ModelConfig, blk: BlockCfg, B: int, ctx: int):
    W = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((B, W), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_width - 1, W), cfg.dtype)}


# ---------------------------------------------------------------------------
# SSD (mamba2) block

def ssd_params(key, cfg: ModelConfig, dtype=None) -> Params:
    """Projections are stored per logical segment (z/x/B/C/dt) rather than
    as one fused in_proj so each can be TP-sharded on its own output dim
    without splits crossing shard boundaries."""
    dtype = dtype or cfg.dtype
    D, di, N, G, nh = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.ssm_groups, cfg.ssm_heads)
    ks = jax.random.split(key, 9)
    return {
        "in_z": dense_init(ks[0], (D, di), dtype=dtype),
        "in_x": dense_init(ks[1], (D, di), dtype=dtype),
        "in_B": dense_init(ks[2], (D, G * N), dtype=dtype),
        "in_C": dense_init(ks[3], (D, G * N), dtype=dtype),
        "in_dt": dense_init(ks[4], (D, nh), dtype=dtype),
        "conv_x": conv_params(ks[5], cfg.conv_width, di, dtype),
        "conv_B": conv_params(ks[6], cfg.conv_width, G * N, dtype),
        "conv_C": conv_params(ks[7], cfg.conv_width, G * N, dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "out_norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[8], (di, D), dtype=dtype),
    }


def _segsum(dA):
    """dA: (..., Q) -> (..., Q, Q) cumulative sums over segments k<q."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    # L[q, k] = exp(sum_{j=k+1..q} dA_j) = exp(cs_q - cs_k), k <= q
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_chunked(xh, Bm, Cm, dt, A, chunk: int, init_state):
    """Chunked SSD scan (Mamba-2 'state space duality' algorithm).

    xh: (B,S,nh,P); Bm/Cm: (B,S,G,N) (G broadcast over heads); dt: (B,S,nh);
    A: (nh,) negative. Returns (y (B,S,nh,P), final_state (B,nh,P,N)).
    """
    Bsz, S, nh, P = xh.shape
    G = Bm.shape[2]
    N = Bm.shape[3]
    rep = nh // G
    S_orig = S
    if S % chunk:
        # zero-pad: dt=0 makes padded steps exact no-ops (decay 1, input 0)
        pad = chunk - S % chunk
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // chunk
    xc = xh.reshape(Bsz, nc, chunk, nh, P)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, chunk, G, N), rep, axis=3)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, chunk, G, N), rep, axis=3)
    dtc = dt.reshape(Bsz, nc, chunk, nh)
    dAc = dtc * A[None, None, None, :]                      # (B,nc,Q,nh)

    def chunk_body(state, inp):
        xq, Bq, Cq, dtq, dAq = inp                          # per-chunk
        dAq_t = jnp.moveaxis(dAq, -1, 1)                    # (B,nh,Q)
        cum = jnp.cumsum(dAq_t, axis=-1)                    # (B,nh,Q)
        # intra-chunk: L[q,k] = exp(cum_q - cum_k + dA_k)?  standard segsum
        L = jnp.exp(_segsum(dAq_t))                         # (B,nh,Q,Q)
        scores = jnp.einsum("bqhn,bkhn->bhqk", Cq, Bq,
                            preferred_element_type=jnp.float32)
        M = scores * L * jnp.moveaxis(dtq, -1, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", M.astype(xq.dtype), xq,
                             preferred_element_type=jnp.float32)
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(cum)                             # (B,nh,Q)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp",
                             Cq * jnp.moveaxis(decay_in, 1, -1)[..., None],
                             state, preferred_element_type=jnp.float32)
        # chunk's new state
        decay_out = jnp.exp(cum[..., -1:] - cum)            # (B,nh,Q)
        contrib = dtq * jnp.moveaxis(decay_out, 1, -1)      # (B,Q,nh)
        st = jnp.einsum("bqhn,bqhp,bqh->bhpn", Bq, xq, contrib,
                        preferred_element_type=jnp.float32)
        chunk_decay = jnp.exp(cum[..., -1])                 # (B,nh)
        state = state * chunk_decay[..., None, None] + st
        return state, (y_intra + y_inter).astype(xq.dtype)

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(Bc, 1, 0),
          jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(dAc, 1, 0))
    final_state, ys = jax.lax.scan(jax.checkpoint(chunk_body), init_state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, nh, P)[:, :S_orig]
    return y, final_state


def ssd_forward(x, p: Params, cfg: ModelConfig, blk: BlockCfg, mode: str,
                cache: Optional[Params], pos,
                pad_to: int = 0) -> Tuple[jax.Array, Params]:
    B, S, D = x.shape
    di, N, G, nh, P = (cfg.d_inner, cfg.ssm_state, cfg.ssm_groups,
                       cfg.ssm_heads, cfg.ssm_head_dim)
    z = x @ p["in_z"]
    xr = x @ p["in_x"]
    Br = x @ p["in_B"]
    Cr = x @ p["in_C"]
    dt_raw = x @ p["in_dt"]
    A = -jnp.exp(p["A_log"])                                 # (nh,)

    if mode == "decode":
        xt, cs_x = conv_step(xr[:, 0], cache["conv_x"], p["conv_x"],
                             cfg.conv_width)
        Bt, cs_B = conv_step(Br[:, 0], cache["conv_B"], p["conv_B"],
                             cfg.conv_width)
        Ct, cs_C = conv_step(Cr[:, 0], cache["conv_C"], p["conv_C"],
                             cfg.conv_width)
        xh = apply_act(xt, "silu").reshape(B, nh, P)
        Bm = jnp.repeat(apply_act(Bt, "silu").reshape(B, G, N).astype(
            jnp.float32), nh // G, axis=1)
        Cm = jnp.repeat(apply_act(Ct, "silu").reshape(B, G, N).astype(
            jnp.float32), nh // G, axis=1)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
        dA = jnp.exp(dt * A)                                 # (B,nh)
        state = cache["state"] * dA[..., None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", xh.astype(jnp.float32), Bm, dt)
        y = jnp.einsum("bhpn,bhn->bhp", state, Cm)
        y = y + p["D_skip"][None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(B, 1, di).astype(x.dtype)
        new_cache = {"state": state, "conv_x": cs_x, "conv_B": cs_B,
                     "conv_C": cs_C}
    else:
        xh = apply_act(causal_conv(xr, p["conv_x"], cfg.conv_width), "silu")
        Bm = apply_act(causal_conv(Br, p["conv_B"], cfg.conv_width), "silu")
        Cm = apply_act(causal_conv(Cr, p["conv_C"], cfg.conv_width), "silu")
        xh = xh.reshape(B, S, nh, P)
        Bm = Bm.reshape(B, S, G, N).astype(jnp.float32)
        Cm = Cm.reshape(B, S, G, N).astype(jnp.float32)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        init = jnp.zeros((B, nh, P, N), jnp.float32)
        y, final_state = _ssd_chunked(xh, Bm, Cm, dt, A,
                                      min(cfg.ssm_chunk, S), init)
        y = y + p["D_skip"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(B, S, di).astype(x.dtype)
        if mode == "prefill":
            new_cache = {"state": final_state,
                         "conv_x": xr[:, -(cfg.conv_width - 1):],
                         "conv_B": Br[:, -(cfg.conv_width - 1):],
                         "conv_C": Cr[:, -(cfg.conv_width - 1):]}
        else:
            new_cache = cache
    # gated RMSNorm then out projection (mamba2)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + cfg.norm_eps) \
        * (1.0 + p["out_norm"].astype(jnp.float32))
    return yf.astype(x.dtype) @ p["out_proj"], new_cache


def ssd_cache_spec(cfg: ModelConfig, blk: BlockCfg, B: int, ctx: int):
    GN = cfg.ssm_groups * cfg.ssm_state
    w = cfg.conv_width - 1
    return {
        "state": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
        "conv_x": jnp.zeros((B, w, cfg.d_inner), cfg.dtype),
        "conv_B": jnp.zeros((B, w, GN), cfg.dtype),
        "conv_C": jnp.zeros((B, w, GN), cfg.dtype),
    }


# ---------------------------------------------------------------------------
# Block = norm -> mixer -> residual [-> norm -> mlp -> residual]

_MIXERS = {"attn": (attn_params, attn_forward, attn_cache_spec),
           "rglru": (rglru_params, rglru_forward, rglru_cache_spec),
           "ssd": (ssd_params, ssd_forward, ssd_cache_spec)}


def block_params(key, cfg: ModelConfig, blk: BlockCfg) -> Params:
    ks = jax.random.split(key, 4)
    mixer_init = _MIXERS[blk.mixer][0]
    p = {"norm1": norm_params(ks[0], cfg.d_model, cfg.norm, cfg.dtype),
         "mixer": mixer_init(ks[1], cfg)}
    if blk.mlp != "none":
        p["norm2"] = norm_params(ks[2], cfg.d_model, cfg.norm, cfg.dtype)
        if blk.mlp == "moe":
            p["mlp"] = moe_params(ks[3], cfg.d_model, cfg.d_ff,
                                  cfg.n_experts, cfg.glu, cfg.dtype)
        else:
            p["mlp"] = mlp_params(ks[3], cfg.d_model, cfg.d_ff, cfg.glu,
                                  cfg.dtype)
    return p


def block_forward(x, p: Params, cfg: ModelConfig, blk: BlockCfg, mode: str,
                  cache: Optional[Params], pos, pad_to: int = 0):
    """Returns (x, new_cache, aux_loss)."""
    mixer_fwd = _MIXERS[blk.mixer][1]
    h = apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    mix, new_cache = mixer_fwd(h, p["mixer"], cfg, blk, mode, cache, pos,
                               pad_to)
    x = x + mix
    aux = jnp.float32(0.0)
    if blk.mlp != "none":
        h2 = apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
        if blk.mlp == "moe":
            out, aux = moe_layer_sharded(
                h2, p["mlp"], top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
                act=cfg.act, glu=cfg.glu, no_drop=(mode == "decode"))
        else:
            out = mlp(h2, p["mlp"], cfg.act, cfg.glu)
        x = x + out
    return x, new_cache, aux


def block_cache_spec(cfg: ModelConfig, blk: BlockCfg, B: int, ctx: int):
    return _MIXERS[blk.mixer][2](cfg, blk, B, ctx)
