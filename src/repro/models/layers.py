"""Shared layers: norms, RoPE, (gated) MLPs, embeddings, chunked CE loss.

All parameters are plain dict pytrees; all functions are pure. Compute dtype
follows the config (bf16 by default) with f32 reductions where it matters
(norm statistics, softmax/logsumexp, loss).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init helpers

def dense_init(key, shape, scale: float | None = None, dtype=jnp.bfloat16):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = (1.0 / fan_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


def norm_params(key, d: int, kind: str, dtype=jnp.bfloat16) -> Params:
    if kind == "layer":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.zeros((d,), dtype)}  # rms: stored as (1 + scale)


def apply_norm(x, p: Params, kind: str, eps: float = 1e-6):
    if kind == "layer":
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p["scale"], eps)


# ---------------------------------------------------------------------------
# Rotary position embedding

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    ang = ang[..., None, :]                             # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq_len: int, d: int, dtype=jnp.bfloat16):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10_000.0) / d))
    pe = jnp.zeros((seq_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


# ---------------------------------------------------------------------------
# MLPs

def mlp_params(key, d: int, f: int, glu: bool, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], (d, f), dtype=dtype),
         "down": dense_init(ks[1], (f, d), dtype=dtype)}
    if glu:
        p["gate"] = dense_init(ks[2], (d, f), dtype=dtype)
    return p


def apply_act(x, act: str):
    return jax.nn.silu(x) if act == "silu" else jax.nn.gelu(x)


def mlp(x, p: Params, act: str = "silu", glu: bool = True):
    up = x @ p["up"]
    h = apply_act(x @ p["gate"], act) * up if glu else apply_act(up, act)
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding.
#
# Embedding tables are vocab-sharded at scale. The input lookup is expressed
# as one-hot @ table (chunked over tokens) so GSPMD resolves it with a psum
# over the model axis instead of an all-gather of the (V, D) table; XLA fuses
# the one-hot into a masked gather per shard.

def embed_lookup(tokens, table, chunk: int = 4096):
    V, _ = table.shape
    B, S = tokens.shape
    flat = tokens.reshape(-1)

    def one(chunk_tokens):
        oh = jax.nn.one_hot(chunk_tokens, V, dtype=table.dtype)
        return oh @ table

    if flat.shape[0] <= chunk or flat.shape[0] % chunk != 0:
        out = one(flat)
    else:
        out = jax.lax.map(jax.checkpoint(one), flat.reshape(-1, chunk))
        out = out.reshape(flat.shape[0], -1)
    return out.reshape(B, S, -1)


def _chunk_ce(h, table, labels, mask, valid_vocab):
    """CE over one token chunk; logits never leave the chunk. f32 math."""
    logits = (h @ table.T).astype(jnp.float32)           # (T, Vp)
    if valid_vocab and valid_vocab < table.shape[0]:
        pad_mask = jnp.arange(table.shape[0]) < valid_vocab
        logits = jnp.where(pad_mask[None, :], logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.sum((lse - gold) * mask), jnp.sum(mask)


def chunked_ce_loss(h, table, labels, mask=None, chunk: int = 1024,
                    valid_vocab: int = 0):
    """Sequence-chunked cross-entropy.

    h: (B, S, D) final hidden; table: (V, D) unembedding; labels: (B, S).
    Chunking + inner remat keeps the (B, S, V) logits from ever being
    resident — each chunk's logits are recomputed in the backward pass.

    Chunks are taken along the SEQUENCE dim with the batch dim intact:
    the scan's xs leading dim stays unsharded, so a data-sharded batch is
    never gathered (scanning over token-chunks of a flattened (B*S, D)
    stream made GSPMD all-gather the whole hidden stream — measured at
    2.9 TB/step on gemma3-27b train_4k; EXPERIMENTS.md §Perf iter 1).
    """
    B, S, D = h.shape
    fn = jax.checkpoint(functools.partial(_chunk_ce,
                                          valid_vocab=valid_vocab))
    mask_f = (jnp.ones((B, S), jnp.float32) if mask is None
              else mask.astype(jnp.float32))
    cs = max(chunk // B, 1)
    if S % cs != 0 or S <= cs:
        loss, cnt = fn(h.reshape(B * S, D), table,
                       labels.reshape(B * S), mask_f.reshape(B * S))
    else:
        n = S // cs

        def body(c, xs):
            hc, lc, mc = xs          # (B, cs, D), (B, cs), (B, cs)
            l, k = fn(hc.reshape(B * cs, D), table, lc.reshape(B * cs),
                      mc.reshape(B * cs))
            return (c[0] + l, c[1] + k), None

        (loss, cnt), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.float32(0.0)),
            (jnp.moveaxis(h.reshape(B, n, cs, D), 1, 0),
             jnp.moveaxis(labels.reshape(B, n, cs), 1, 0),
             jnp.moveaxis(mask_f.reshape(B, n, cs), 1, 0)))
    return loss / jnp.maximum(cnt, 1.0)
