"""Whisper-style encoder-decoder backbone.

The audio frontend is a STUB per the brief: ``input_specs()`` provides
precomputed mel-frame features (B, S_enc, frontend_dim) which a single
linear projection (standing in for whisper's conv stack) maps to d_model.
The encoder is bidirectional; the decoder has causal self-attention +
cross-attention with a whisper-design max decoder length (448).

Shape mapping for the assigned LM shapes (noted in DESIGN.md): ``seq_len``
parameterizes the ENCODER frame count; the decoder runs at
min(dec_max_len, seq_len). Decode steps carry a self-attn KV cache plus a
precomputed cross-attention KV over the encoded frames.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import decode_attention, flash_attention, repeat_kv
from .config import ModelConfig
from .layers import apply_norm, chunked_ce_loss, dense_init, mlp, \
    mlp_params, norm_params, sinusoidal_pos

Params = Dict[str, Any]


def _masked_logits(h_last, params, cfg):
    logits = h_last.astype(jnp.float32) @ params["embed"].T.astype(
        jnp.float32)
    if cfg.padded_vocab > cfg.vocab:
        logits = jnp.where(jnp.arange(cfg.padded_vocab)[None, :] < cfg.vocab,
                           logits, -1e30)
    return logits


def _attn_p(key, cfg, dtype):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], (D, H * hd), dtype=dtype),
            "wk": dense_init(ks[1], (D, K * hd), dtype=dtype),
            "wv": dense_init(ks[2], (D, K * hd), dtype=dtype),
            "wo": dense_init(ks[3], (H * hd, D), dtype=dtype)}


def _enc_layer_p(key, cfg):
    ks = jax.random.split(key, 4)
    return {"norm1": norm_params(ks[0], cfg.d_model, cfg.norm, cfg.dtype),
            "attn": _attn_p(ks[1], cfg, cfg.dtype),
            "norm2": norm_params(ks[2], cfg.d_model, cfg.norm, cfg.dtype),
            "mlp": mlp_params(ks[3], cfg.d_model, cfg.d_ff, cfg.glu,
                              cfg.dtype)}


def _dec_layer_p(key, cfg):
    ks = jax.random.split(key, 6)
    p = _enc_layer_p(key, cfg)
    p["norm_x"] = norm_params(ks[4], cfg.d_model, cfg.norm, cfg.dtype)
    p["xattn"] = _attn_p(ks[5], cfg, cfg.dtype)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "frontend_proj": dense_init(ks[2], (cfg.frontend_dim, cfg.d_model),
                                    dtype=cfg.dtype),
        "embed": dense_init(ks[3], (cfg.padded_vocab, cfg.d_model),
                            scale=0.02, dtype=cfg.dtype),
        "pos_dec": dense_init(ks[4], (cfg.dec_max_len, cfg.d_model),
                              scale=0.02, dtype=cfg.dtype),
        "enc": jax.vmap(lambda k: _enc_layer_p(k, cfg))(enc_keys),
        "dec": jax.vmap(lambda k: _dec_layer_p(k, cfg))(dec_keys),
        "enc_norm": norm_params(ks[5], cfg.d_model, cfg.norm, cfg.dtype),
        "dec_norm": norm_params(ks[6], cfg.d_model, cfg.norm, cfg.dtype),
    }


def _mha(x_q, x_kv, p, cfg, *, causal):
    B, Sq, D = x_q.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x_q @ p["wq"]).reshape(B, Sq, H, hd)
    k = (x_kv @ p["wk"]).reshape(B, x_kv.shape[1], K, hd)
    v = (x_kv @ p["wv"]).reshape(B, x_kv.shape[1], K, hd)
    o = flash_attention(q, repeat_kv(k, H // K), repeat_kv(v, H // K),
                        causal=causal,
                        block_q=min(cfg.attn_chunk, Sq),
                        block_kv=min(cfg.attn_chunk, x_kv.shape[1]))
    return o.reshape(B, Sq, H * hd) @ p["wo"]


def encode(params: Params, cfg: ModelConfig, frames) -> jax.Array:
    """frames: (B, S_enc, frontend_dim) -> (B, S_enc, D)."""
    x = frames.astype(cfg.dtype) @ params["frontend_proj"]
    x = x + sinusoidal_pos(x.shape[1], cfg.d_model, cfg.dtype)[None]

    def body(h, lp):
        s = apply_norm(h, lp["norm1"], cfg.norm, cfg.norm_eps)
        h = h + _mha(s, s, lp["attn"], cfg, causal=False)
        m = mlp(apply_norm(h, lp["norm2"], cfg.norm, cfg.norm_eps),
                lp["mlp"], cfg.act, cfg.glu)
        return h + m, None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["enc"])
    return apply_norm(x, params["enc_norm"], cfg.norm, cfg.norm_eps)


def _dec_body_train(cfg, enc_out):
    def body(h, lp):
        s = apply_norm(h, lp["norm1"], cfg.norm, cfg.norm_eps)
        h = h + _mha(s, s, lp["attn"], cfg, causal=True)
        c = apply_norm(h, lp["norm_x"], cfg.norm, cfg.norm_eps)
        h = h + _mha(c, enc_out, lp["xattn"], cfg, causal=False)
        m = mlp(apply_norm(h, lp["norm2"], cfg.norm, cfg.norm_eps),
                lp["mlp"], cfg.act, cfg.glu)
        return h + m, None
    return body


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict):
    """batch: frames (B,S_enc,Fd), tokens (B,S_dec), labels (B,S_dec)."""
    enc_out = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0) \
        + params["pos_dec"][None, :tokens.shape[1]]
    body = _dec_body_train(cfg, enc_out)
    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = apply_norm(x, params["dec_norm"], cfg.norm, cfg.norm_eps)
    ce = chunked_ce_loss(x, params["embed"], batch["labels"],
                         batch.get("loss_mask"), cfg.loss_chunk,
                         valid_vocab=cfg.vocab)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# serving path

def init_cache(cfg: ModelConfig, batch: int, enc_len: int) -> Params:
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    C = cfg.dec_max_len
    return {
        "pos": jnp.int32(0),
        "self_k": jnp.zeros((L, batch, C, K, hd), cfg.dtype),
        "self_v": jnp.zeros((L, batch, C, K, hd), cfg.dtype),
        "positions": jnp.full((C,), -1, jnp.int32),
        "cross_k": jnp.zeros((L, batch, enc_len, K, hd), cfg.dtype),
        "cross_v": jnp.zeros((L, batch, enc_len, K, hd), cfg.dtype),
        "enc_len": jnp.int32(enc_len),
    }


def prefill(params: Params, cfg: ModelConfig, frames,
            tokens) -> Tuple[jax.Array, Params]:
    """Encode frames, precompute cross KV, run decoder prefix (B, S0)."""
    B = frames.shape[0]
    enc_out = encode(params, cfg, frames)
    K, hd = cfg.n_kv_heads, cfg.hd

    def cross_kv(lp):
        k = (enc_out @ lp["xattn"]["wk"]).reshape(B, -1, K, hd)
        v = (enc_out @ lp["xattn"]["wv"]).reshape(B, -1, K, hd)
        return k, v

    ck, cv = jax.vmap(cross_kv)(params["dec"])
    cache = init_cache(cfg, B, enc_out.shape[1])
    cache["cross_k"], cache["cross_v"] = ck, cv

    # run the decoder prefix through decode steps' math in one pass
    S0 = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0) \
        + params["pos_dec"][None, :S0]

    def body(carry, xs):
        h = carry
        lp, = xs
        s = apply_norm(h, lp["norm1"], cfg.norm, cfg.norm_eps)
        q = (s @ lp["attn"]["wq"]).reshape(B, S0, cfg.n_heads, hd)
        k = (s @ lp["attn"]["wk"]).reshape(B, S0, K, hd)
        v = (s @ lp["attn"]["wv"]).reshape(B, S0, K, hd)
        o = flash_attention(q, repeat_kv(k, cfg.n_heads // K),
                            repeat_kv(v, cfg.n_heads // K), causal=True,
                            block_q=S0, block_kv=S0)
        h = h + o.reshape(B, S0, -1) @ lp["attn"]["wo"]
        c = apply_norm(h, lp["norm_x"], cfg.norm, cfg.norm_eps)
        h = h + _mha(c, enc_out, lp["xattn"], cfg, causal=False)
        m = mlp(apply_norm(h, lp["norm2"], cfg.norm, cfg.norm_eps),
                lp["mlp"], cfg.act, cfg.glu)
        padw = ((0, 0), (0, cfg.dec_max_len - S0), (0, 0), (0, 0))
        return h + m, (jnp.pad(k, padw), jnp.pad(v, padw))

    x, (sk, sv) = jax.lax.scan(body, x, (params["dec"],))
    cache["self_k"], cache["self_v"] = sk, sv
    cache["positions"] = jnp.concatenate(
        [jnp.arange(S0, dtype=jnp.int32),
         jnp.full((cfg.dec_max_len - S0,), -1, jnp.int32)])
    cache["pos"] = jnp.int32(S0)
    x = apply_norm(x, params["dec_norm"], cfg.norm, cfg.norm_eps)
    logits = _masked_logits(x[:, -1], params, cfg)
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens) -> Tuple[jax.Array, Params]:
    """tokens: (B, 1) decoder token. Returns (logits (B,V), cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    K, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    dec_pos = jnp.clip(pos, 0, cfg.dec_max_len - 1)
    x = jnp.take(params["embed"], tokens, axis=0) \
        + jax.lax.dynamic_slice_in_dim(params["pos_dec"], dec_pos, 1)[None]
    C = cache["self_k"].shape[2]
    slot = pos % C
    cpos = jax.lax.dynamic_update_slice(
        cache["positions"], pos[None].astype(jnp.int32), (slot,))
    enc_positions = jnp.arange(cache["cross_k"].shape[2], dtype=jnp.int32)

    def body(carry, xs):
        h = carry
        lp, skc, svc, ckc, cvc = xs
        s = apply_norm(h, lp["norm1"], cfg.norm, cfg.norm_eps)
        q = (s @ lp["attn"]["wq"]).reshape(B, 1, H, hd)
        k = (s @ lp["attn"]["wk"]).reshape(B, 1, K, hd)
        v = (s @ lp["attn"]["wv"]).reshape(B, 1, K, hd)
        skc = jax.lax.dynamic_update_slice(skc, k, (0, slot, 0, 0))
        svc = jax.lax.dynamic_update_slice(svc, v, (0, slot, 0, 0))
        o = decode_attention(q, skc, svc, cpos, pos)
        h = h + o.reshape(B, 1, -1) @ lp["attn"]["wo"]
        c = apply_norm(h, lp["norm_x"], cfg.norm, cfg.norm_eps)
        qx = (c @ lp["xattn"]["wq"]).reshape(B, 1, H, hd)
        ox = decode_attention(qx, ckc, cvc, enc_positions,
                              cache["cross_k"].shape[2])
        h = h + ox.reshape(B, 1, -1) @ lp["xattn"]["wo"]
        m = mlp(apply_norm(h, lp["norm2"], cfg.norm, cfg.norm_eps),
                lp["mlp"], cfg.act, cfg.glu)
        return h + m, (skc, svc)

    x, (sk, sv) = jax.lax.scan(
        body, x, (params["dec"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache)
    new_cache.update(self_k=sk, self_v=sv, positions=cpos, pos=pos + 1)
    x = apply_norm(x, params["dec_norm"], cfg.norm, cfg.norm_eps)
    logits = _masked_logits(x[:, 0], params, cfg)
    return logits, new_cache
