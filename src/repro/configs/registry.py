"""Architecture registry: the 10 assigned configs + the paper's Qwen2.5
routing pool, smoke-reduced variants, and ``input_specs()`` abstract inputs.

Exact assigned configs (source tags in each entry's docstring line).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import BlockCfg, ModelConfig, SHAPES, ShapeSpec

# ---------------------------------------------------------------------------
# Assigned architectures (exact configs from the brief)

ARCHS: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# [hf:ibm-granite/granite-3.0-2b-base; hf] — dense GQA
GRANITE_3_2B = _register(ModelConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155,
    pattern=(BlockCfg(mixer="attn"),)))

# [hf:Qwen/Qwen3-8B; hf] — qk_norm, GQA
QWEN3_0_6B = _register(ModelConfig(
    name="qwen3-0.6b", family="dense", n_layers=28, d_model=1024,
    n_heads=16, n_kv_heads=8, d_ff=3072, vocab=151936, qk_norm=True,
    pattern=(BlockCfg(mixer="attn"),)))

# [arXiv:2404.14219; unverified] — RoPE SwiGLU GQA (kv=32 => MHA)
PHI3_MINI = _register(ModelConfig(
    name="phi3-mini-3.8b", family="dense", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064,
    pattern=(BlockCfg(mixer="attn"),)))

# [hf:google/gemma-3-1b-pt; unverified] — 5:1 local:global, 128k
GEMMA3_27B = _register(ModelConfig(
    name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
    n_heads=32, n_kv_heads=16, d_ff=21504, vocab=262144,
    embed_scale=math.sqrt(5376.0),
    pattern=tuple([BlockCfg(mixer="attn", window=1024,
                            rope_theta=10_000.0)] * 5
                  + [BlockCfg(mixer="attn", rope_theta=1_000_000.0)])))

# [arXiv:2402.19427; hf] — RG-LRU + local attn, 1:2 (2 recurrent : 1 attn)
RECURRENTGEMMA_2B = _register(ModelConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000, lru_width=2560,
    act="gelu", embed_scale=math.sqrt(2560.0),
    pattern=(BlockCfg(mixer="rglru"), BlockCfg(mixer="rglru"),
             BlockCfg(mixer="attn", window=2048))))

# [hf:microsoft/Phi-3-vision-128k-instruct; hf] — phi3-mini + CLIP stub
PHI3_VISION = _register(ModelConfig(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064,
    frontend="vision", frontend_dim=1024, n_frontend_tokens=576,
    pattern=(BlockCfg(mixer="attn"),)))

# [arXiv:2212.04356; unverified] — enc-dec, conv frontend (stub)
WHISPER_TINY = _register(ModelConfig(
    name="whisper-tiny", family="encdec", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865, n_enc_layers=4,
    norm="layer", act="gelu", glu=False, frontend="audio", frontend_dim=80,
    dec_max_len=448, pattern=(BlockCfg(mixer="attn"),)))

# [arXiv:2405.21060; unverified] — SSD (state-space duality), attn-free
MAMBA2_1_3B = _register(ModelConfig(
    name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab=50280, ssm_state=128,
    ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    pattern=(BlockCfg(mixer="ssd", mlp="none"),)))

# [arXiv:2401.04088; hf] — 8 experts top-2, SWA (window 4096)
MIXTRAL_8X7B = _register(ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
    n_experts=8, top_k=2,
    pattern=(BlockCfg(mixer="attn", window=4096, mlp="moe"),)))

# [hf:ibm-granite/granite-3.0-1b-a400m-base; hf] — config line: 40e top-8
# (prose in the pool card says 32e; the config line is binding — DESIGN.md)
GRANITE_MOE = _register(ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155,
    n_experts=40, top_k=8,
    pattern=(BlockCfg(mixer="attn", mlp="moe"),)))


# ---------------------------------------------------------------------------
# Paper routing pool: Qwen2.5 3B/7B/14B/72B [Qwen2.5 technical report]
# Used by the serving substrate's tier roofline (TPOT) model.

QWEN25_POOL: Dict[str, ModelConfig] = {}


def _pool(cfg: ModelConfig) -> ModelConfig:
    QWEN25_POOL[cfg.name] = cfg
    return cfg


_pool(ModelConfig(name="qwen2.5-3b", family="dense", n_layers=36,
                  d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
                  vocab=151936, pattern=(BlockCfg(mixer="attn"),)))
_pool(ModelConfig(name="qwen2.5-7b", family="dense", n_layers=28,
                  d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
                  vocab=152064, pattern=(BlockCfg(mixer="attn"),)))
_pool(ModelConfig(name="qwen2.5-14b", family="dense", n_layers=48,
                  d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
                  vocab=152064, pattern=(BlockCfg(mixer="attn"),)))
_pool(ModelConfig(name="qwen2.5-72b", family="dense", n_layers=80,
                  d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
                  vocab=152064, pattern=(BlockCfg(mixer="attn"),)))


# ---------------------------------------------------------------------------
# long_500k applicability (DESIGN.md §Arch-applicability)

LONG_CONTEXT_OK = {"mamba2-1.3b", "recurrentgemma-2b", "mixtral-8x7b"}


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True


def skip_reason(arch: str, shape: str) -> Optional[str]:
    if shape_applicable(arch, shape):
        return None
    return ("long_500k requires sub-quadratic attention; "
            f"{arch} is a full-attention family (see DESIGN.md)")


# ---------------------------------------------------------------------------
# Smoke variants: same family, tiny dims, CPU-runnable.

def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    pat = tuple(dataclasses.replace(b, window=(16 if b.window else 0))
                for b in cfg.pattern)
    return cfg.replace(
        n_layers=len(cfg.pattern) + 1, d_model=64,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512, pattern=pat, embed_scale=1.0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        lru_width=64 if cfg.lru_width else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=8, ssm_chunk=16,
        n_enc_layers=2 if cfg.n_enc_layers else 0, dec_max_len=16,
        frontend_dim=12 if cfg.frontend_dim else 0,
        n_frontend_tokens=4 if cfg.n_frontend_tokens else 0,
        attn_chunk=16, loss_chunk=64, remat=False)


# ---------------------------------------------------------------------------
# Abstract inputs for every (arch x shape) cell.

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """ShapeDtypeStruct stand-ins for the cell's batch (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    if cfg.is_encdec:
        dec = min(cfg.dec_max_len, S)
        if shape.kind == "train":
            return {"frames": _sds((B, S, cfg.frontend_dim), bf16),
                    "tokens": _sds((B, dec), i32),
                    "labels": _sds((B, dec), i32)}
        if shape.kind == "prefill":
            return {"frames": _sds((B, S, cfg.frontend_dim), bf16),
                    "tokens": _sds((B, dec), i32)}
        return {"tokens": _sds((B, 1), i32)}
    if cfg.frontend == "vision":
        nf = cfg.n_frontend_tokens
        st = max(S - nf, 1)
        if shape.kind == "train":
            return {"tokens": _sds((B, st), i32),
                    "labels": _sds((B, st), i32),
                    "frontend_embeds": _sds((B, nf, cfg.frontend_dim), bf16)}
        if shape.kind == "prefill":
            return {"tokens": _sds((B, st), i32),
                    "frontend_embeds": _sds((B, nf, cfg.frontend_dim), bf16)}
        return {"tokens": _sds((B, 1), i32)}
    if shape.kind == "train":
        return {"tokens": _sds((B, S), i32), "labels": _sds((B, S), i32)}
    if shape.kind == "prefill":
        return {"tokens": _sds((B, S), i32)}
    return {"tokens": _sds((B, 1), i32)}


def get_config(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in QWEN25_POOL:
        return QWEN25_POOL[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def list_archs():
    return sorted(ARCHS)
