"""Config module for --arch granite-moe-3b-a800m. Binding definition in registry.py."""
from .registry import ARCHS, smoke_variant

CONFIG = ARCHS["granite-moe-3b-a800m"]
SMOKE = smoke_variant(CONFIG)
