"""Config module for --arch granite-3-2b. Binding definition in registry.py."""
from .registry import ARCHS, smoke_variant

CONFIG = ARCHS["granite-3-2b"]
SMOKE = smoke_variant(CONFIG)
