"""Config module for --arch mamba2-1.3b. Binding definition in registry.py."""
from .registry import ARCHS, smoke_variant

CONFIG = ARCHS["mamba2-1.3b"]
SMOKE = smoke_variant(CONFIG)
