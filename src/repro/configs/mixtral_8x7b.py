"""Config module for --arch mixtral-8x7b. Binding definition in registry.py."""
from .registry import ARCHS, smoke_variant

CONFIG = ARCHS["mixtral-8x7b"]
SMOKE = smoke_variant(CONFIG)
