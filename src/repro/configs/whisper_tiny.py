"""Config module for --arch whisper-tiny. Binding definition in registry.py."""
from .registry import ARCHS, smoke_variant

CONFIG = ARCHS["whisper-tiny"]
SMOKE = smoke_variant(CONFIG)
