"""Config module for --arch qwen3-0.6b. Binding definition in registry.py."""
from .registry import ARCHS, smoke_variant

CONFIG = ARCHS["qwen3-0.6b"]
SMOKE = smoke_variant(CONFIG)
