from .registry import ARCHS, LONG_CONTEXT_OK, QWEN25_POOL, get_config, \
    input_specs, list_archs, shape_applicable, skip_reason, smoke_variant
from repro.models.config import SHAPES, ShapeSpec

__all__ = ["ARCHS", "LONG_CONTEXT_OK", "QWEN25_POOL", "get_config",
           "input_specs", "list_archs", "shape_applicable", "skip_reason",
           "smoke_variant", "SHAPES", "ShapeSpec"]
