"""Config module for --arch recurrentgemma-2b. Binding definition in registry.py."""
from .registry import ARCHS, smoke_variant

CONFIG = ARCHS["recurrentgemma-2b"]
SMOKE = smoke_variant(CONFIG)
