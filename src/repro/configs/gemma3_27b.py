"""Config module for --arch gemma3-27b. Binding definition in registry.py."""
from .registry import ARCHS, smoke_variant

CONFIG = ARCHS["gemma3-27b"]
SMOKE = smoke_variant(CONFIG)
