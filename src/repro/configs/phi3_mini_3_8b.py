"""Config module for --arch phi3-mini-3.8b. Binding definition in registry.py."""
from .registry import ARCHS, smoke_variant

CONFIG = ARCHS["phi3-mini-3.8b"]
SMOKE = smoke_variant(CONFIG)
