"""Config module for --arch phi-3-vision-4.2b. Binding definition in registry.py."""
from .registry import ARCHS, smoke_variant

CONFIG = ARCHS["phi-3-vision-4.2b"]
SMOKE = smoke_variant(CONFIG)
