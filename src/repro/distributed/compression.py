"""Gradient compression for the DP all-reduce: int8 quantization with
error feedback (EF-SGD style).

``compress_decompress`` is the pure single-program form: under GSPMD the
data-axis psum of the quantized tensor is what crosses the network
(8-bit payload instead of 16/32), and the local quantization error is
carried to the next step, preserving convergence. ``shardmap_allreduce``
is the explicit-collective variant (int8 payload, int32 accumulation)
for meshes where the launcher wants the collective pinned.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def _quantize(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def compress_decompress(grads, error_state=None
                        ) -> Tuple[Any, Any, Dict[str, jax.Array]]:
    """Per-tensor int8 quantize(+error feedback) -> dequantize.

    Returns (grads_hat, new_error_state, metrics). grads_hat replaces the
    raw grads in the optimizer update; the psum over data happens on the
    int8-scaled values downstream (GSPMD)."""
    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)) / 127.0, 1e-12)
        q = _quantize(gf, scale)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g = jax.tree.leaves(grads)
    flat_e = jax.tree.leaves(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    treedef = jax.tree.structure(grads)
    ghat = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    err_norm = sum(jnp.sum(jnp.square(o[1])) for o in outs)
    return ghat, new_e, {"compression_err_sq": err_norm}


def shardmap_allreduce(x, mesh, axes=("data",)):
    """Explicit int8-payload all-reduce over the data axes: quantize
    locally, psum int32 accumulators, dequantize with the max scale."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local(xl):
        scale = jnp.maximum(jnp.max(jnp.abs(xl)) / 127.0, 1e-12)
        scale = jax.lax.pmax(scale, axes)          # shared scale
        q = _quantize(xl, scale).astype(jnp.int32)
        s = jax.lax.psum(q, axes)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return (s.astype(jnp.float32) * scale / n).astype(xl.dtype)

    spec = P(*([None] * x.ndim))
    return shard_map(local, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)(x)
