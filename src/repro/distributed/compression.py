"""Compression for everything that crosses the (simulated) network:
gradients and telemetry digests.

``compress_decompress`` is the pure single-program gradient form (int8
quantization with EF-SGD error feedback): under GSPMD the data-axis
psum of the quantized tensor is what crosses the network (8-bit payload
instead of 16/32), and the local quantization error is carried to the
next step, preserving convergence. ``shardmap_allreduce`` is the
explicit-collective variant (int8 payload, int32 accumulation) for
meshes where the launcher wants the collective pinned.

``TelemetryDigest`` + ``encode_digest``/``decode_digest`` are the
hierarchical scheduler's control plane (`repro.serving.hierarchy`):
each cell summarizes its dead-reckoned telemetry into per-tier
occupancy/depth/free vectors, the digest is serialized to wire bytes
(exact float32, or the same int8 scale-quantization the gradient path
uses), and the `GlobalBalancer` routes ONLY from what survived the
round trip — so the lossy mode's routing error is exactly the codec's
quantization error, nothing hidden. Digests carry the sending cell's
sim-clock timestamp; `digest_fresh` is the staleness contract: a
balancer may use a digest only while ``now - digest.t <= stale_s``,
otherwise the cell must be treated as dark (the same discipline the
telemetry watchdog applies to instance rows).
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _quantize(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def compress_decompress(grads, error_state=None
                        ) -> Tuple[Any, Any, Dict[str, jax.Array]]:
    """Per-tensor int8 quantize(+error feedback) -> dequantize.

    Returns (grads_hat, new_error_state, metrics). grads_hat replaces the
    raw grads in the optimizer update; the psum over data happens on the
    int8-scaled values downstream (GSPMD)."""
    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)) / 127.0, 1e-12)
        q = _quantize(gf, scale)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g = jax.tree.leaves(grads)
    flat_e = jax.tree.leaves(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    treedef = jax.tree.structure(grads)
    ghat = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    err_norm = sum(jnp.sum(jnp.square(o[1])) for o in outs)
    return ghat, new_e, {"compression_err_sq": err_norm}


def shardmap_allreduce(x, mesh, axes=("data",)):
    """Explicit int8-payload all-reduce over the data axes: quantize
    locally, psum int32 accumulators, dequantize with the max scale."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local(xl):
        scale = jnp.maximum(jnp.max(jnp.abs(xl)) / 127.0, 1e-12)
        scale = jax.lax.pmax(scale, axes)          # shared scale
        q = _quantize(xl, scale).astype(jnp.int32)
        s = jax.lax.psum(q, axes)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return (s.astype(jnp.float32) * scale / n).astype(xl.dtype)

    spec = P(*([None] * x.ndim))
    return shard_map(local, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)(x)


# ---------------------------------------------------------------------------
# Telemetry digests (hierarchical scheduling control plane)
# ---------------------------------------------------------------------------

_DIGEST_MAGIC = b"RBTD"
_DIGEST_VERSION = 1
_DIGEST_MODES = ("exact", "int8")
# magic, version, mode, cell, seq, t, n_alive, n_total, n_tiers
_HEADER = struct.Struct("<4sBBiidiii")


@dataclasses.dataclass
class TelemetryDigest:
    """One cell's compressed telemetry summary: per-tier occupancy
    (batch fill fraction of the alive capacity), queue depth
    (pending + queued work) and free decode slots, plus the alive
    roster count and the cell's sim-clock send time."""
    cell: int
    seq: int
    t: float
    n_alive: int
    n_total: int
    tier_occupancy: np.ndarray          # (T,) float32
    tier_depth: np.ndarray              # (T,) float32
    tier_free: np.ndarray               # (T,) float32

    @property
    def depth_total(self) -> float:
        return float(self.tier_depth.sum())

    @property
    def free_total(self) -> float:
        return float(self.tier_free.sum())

    def age(self, now: float) -> float:
        return now - self.t


def digest_fresh(d: TelemetryDigest, now: float, stale_s: float) -> bool:
    """The staleness-bound contract: a digest is usable while its age
    is within ``stale_s`` of the observer's clock; past that the cell
    is dark and a balancer must route around it (or fall back to blind
    round-robin when every cell is dark)."""
    return d.age(now) <= stale_s


def digest_from_telemetry(tel, tier_of_slot: np.ndarray, n_tiers: int,
                          cell: int, seq: int, t: float
                          ) -> TelemetryDigest:
    """Summarize a TelemetryArrays view (a cell mirror or the full
    array) into per-tier vectors. ``tier_of_slot`` (n,) int maps each
    telemetry row to its tier index; quarantined/dead rows contribute
    nothing (the balancer must not route toward capacity the watchdog
    masked)."""
    alive = np.asarray(tel.alive, bool)
    tos = np.asarray(tier_of_slot)
    wsum = lambda w: np.bincount(  # noqa: E731 - tiny local reducer
        tos[alive], weights=np.asarray(w, np.float64)[alive],
        minlength=n_tiers).astype(np.float32)
    cap = wsum(tel.max_batch)
    occ = wsum(tel.batch) / np.maximum(cap, 1.0)
    depth = wsum(np.asarray(tel.pending) + np.asarray(tel.queue))
    free = wsum(tel.free)
    return TelemetryDigest(cell=int(cell), seq=int(seq), t=float(t),
                           n_alive=int(alive.sum()), n_total=len(alive),
                           tier_occupancy=occ, tier_depth=depth,
                           tier_free=free)


def _encode_plane(x: np.ndarray, mode: str) -> bytes:
    x = np.asarray(x, np.float32)
    if mode == "exact":
        return x.tobytes()
    # int8: the gradient codec's scale-quantization, one scale per plane
    scale = np.float32(max(float(np.abs(x).max()) / 127.0, 1e-12))
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return struct.pack("<f", scale) + q.tobytes()


def _decode_plane(buf: bytes, off: int, n: int, mode: str
                  ) -> Tuple[np.ndarray, int]:
    if mode == "exact":
        end = off + 4 * n
        return np.frombuffer(buf[off:end], np.float32).copy(), end
    (scale,) = struct.unpack_from("<f", buf, off)
    off += 4
    end = off + n
    q = np.frombuffer(buf[off:end], np.int8)
    return q.astype(np.float32) * np.float32(scale), end


def encode_digest(d: TelemetryDigest, mode: str = "exact") -> bytes:
    """Serialize a digest to wire bytes. ``exact`` ships raw float32
    planes (bitwise round trip); ``int8`` ships one float32 scale + an
    int8 payload per plane (the `_quantize` semantics), cutting the
    plane payload 4x at <= scale/2 absolute error per entry."""
    assert mode in _DIGEST_MODES, mode
    head = _HEADER.pack(_DIGEST_MAGIC, _DIGEST_VERSION,
                        _DIGEST_MODES.index(mode), d.cell, d.seq, d.t,
                        d.n_alive, d.n_total, len(d.tier_depth))
    return head + b"".join(
        _encode_plane(p, mode)
        for p in (d.tier_occupancy, d.tier_depth, d.tier_free))


def decode_digest(buf: bytes) -> TelemetryDigest:
    magic, ver, mode_i, cell, seq, t, n_alive, n_total, T = \
        _HEADER.unpack_from(buf, 0)
    assert magic == _DIGEST_MAGIC and ver == _DIGEST_VERSION, \
        (magic, ver)
    mode = _DIGEST_MODES[mode_i]
    off = _HEADER.size
    occ, off = _decode_plane(buf, off, T, mode)
    depth, off = _decode_plane(buf, off, T, mode)
    free, off = _decode_plane(buf, off, T, mode)
    return TelemetryDigest(cell=cell, seq=seq, t=t, n_alive=n_alive,
                           n_total=n_total, tier_occupancy=occ,
                           tier_depth=depth, tier_free=free)
