"""Elastic membership + staleness for the hierarchical control plane.

A registry of peers with heartbeat timestamps: peers that miss
heartbeats are quarantined (stop receiving traffic) and re-admitted
when they return. The hierarchical scheduler
(`repro.serving.hierarchy.GlobalBalancer`) registers each CELL as a
member — a digest arrival is the heartbeat — so cell-level liveness
rides the same quarantine/re-admit discipline the telemetry watchdog
applies to instance rows. `staleness_penalty` is the soft arm:
digest age inflates a cell's apparent load, so a cell whose control
plane lags organically sheds traffic before the hard timeout darkens
it entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass
class MemberState:
    iid: str
    tier: str
    last_heartbeat: float
    quarantined: bool = False


class ElasticMembership:
    def __init__(self, heartbeat_timeout: float = 5.0,
                 staleness_decay: float = 2.0):
        self.timeout = heartbeat_timeout
        self.decay = staleness_decay
        self.members: Dict[str, MemberState] = {}

    def register(self, iid: str, tier: str, now: float):
        self.members[iid] = MemberState(iid, tier, now)

    def heartbeat(self, iid: str, now: float):
        m = self.members.get(iid)
        if m:
            m.last_heartbeat = now
            m.quarantined = False

    def active(self, now: float) -> List[str]:
        out = []
        for m in self.members.values():
            if now - m.last_heartbeat > self.timeout:
                m.quarantined = True
            if not m.quarantined:
                out.append(m.iid)
        return out

    def staleness_penalty(self, iid: str, now: float) -> float:
        """Multiplier (>= 1) applied to dead-reckoned pending work: a
        straggling instance looks increasingly loaded as its telemetry
        ages, shedding traffic before the quarantine timeout."""
        m = self.members.get(iid)
        if m is None:
            return float("inf")
        age = max(now - m.last_heartbeat, 0.0)
        return 1.0 + self.decay * age / max(self.timeout, 1e-9)
