"""Elastic serving-cluster membership + straggler handling.

The scheduler's view of the cluster is a registry of instances with
heartbeat timestamps. Instances that miss heartbeats are quarantined
(stop receiving traffic) and re-admitted when they return — scale-up is
just registration (the KNN estimator and per-tier heads are tier-local,
so no retraining; §6.8's tier-loss result is the degenerate case).
Straggler mitigation: telemetry staleness inflates an instance's
dead-reckoned pending work, so slow/unresponsive instances organically
stop attracting traffic before the hard timeout trips.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class MemberState:
    iid: str
    tier: str
    last_heartbeat: float
    quarantined: bool = False
    dispatches: int = 0


class ElasticMembership:
    def __init__(self, heartbeat_timeout: float = 5.0,
                 staleness_decay: float = 2.0):
        self.timeout = heartbeat_timeout
        self.decay = staleness_decay
        self.members: Dict[str, MemberState] = {}

    def register(self, iid: str, tier: str, now: float):
        self.members[iid] = MemberState(iid, tier, now)

    def deregister(self, iid: str):
        self.members.pop(iid, None)

    def heartbeat(self, iid: str, now: float):
        m = self.members.get(iid)
        if m:
            m.last_heartbeat = now
            m.quarantined = False

    def active(self, now: float) -> List[str]:
        out = []
        for m in self.members.values():
            if now - m.last_heartbeat > self.timeout:
                m.quarantined = True
            if not m.quarantined:
                out.append(m.iid)
        return out

    def staleness_penalty(self, iid: str, now: float) -> float:
        """Multiplier (>= 1) applied to dead-reckoned pending work: a
        straggling instance looks increasingly loaded as its telemetry
        ages, shedding traffic before the quarantine timeout."""
        m = self.members.get(iid)
        if m is None:
            return float("inf")
        age = max(now - m.last_heartbeat, 0.0)
        return 1.0 + self.decay * age / max(self.timeout, 1e-9)

    # -- scheduler-state persistence (restart-safe scheduling layer) -----
    def save(self, path: str):
        data = {iid: dataclasses.asdict(m)
                for iid, m in self.members.items()}
        p = pathlib.Path(path)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps(data))
        tmp.rename(p)

    @classmethod
    def load(cls, path: str, **kw) -> "ElasticMembership":
        em = cls(**kw)
        data = json.loads(pathlib.Path(path).read_text())
        for iid, m in data.items():
            em.members[iid] = MemberState(**m)
        return em
