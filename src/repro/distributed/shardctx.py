"""Sharding context: lets distribution-agnostic model code pick up
mesh-aware sharding constraints when lowered by the launcher.

Model code calls ``constrain(x, "residual")`` etc. — a no-op unless a
``sharding_rules(mesh, residual=P(...))`` context is active (so CPU unit
tests and the serving engine run the exact same code with zero overhead).
``current()`` exposes (mesh, rules) so layers that need ``shard_map``
(e.g. the data-local MoE dispatch) can build it — and since the
hierarchical scheduler it is also the fused hot path's mesh source: a
launcher that pins a ``("cell",)`` mesh here gets the decision scan
sharded across cells (`repro.core.hotpath`).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE: Dict[str, Any] = {"mesh": None, "rules": {}}


def current() -> Tuple[Optional[jax.sharding.Mesh], Dict[str, P]]:
    return _STATE["mesh"], _STATE["rules"]


@contextlib.contextmanager
def sharding_rules(mesh, **rules):
    old = (_STATE["mesh"], _STATE["rules"])
    _STATE["mesh"], _STATE["rules"] = mesh, dict(rules)
    try:
        yield
    finally:
        _STATE["mesh"], _STATE["rules"] = old


def constrain(x, name: str):
    mesh, rules = current()
    if mesh is None or name not in rules or rules[name] is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, rules[name]))


def batch_axes(mesh=None) -> Tuple[str, ...]:
    mesh = mesh if mesh is not None else _STATE["mesh"]
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
