"""Fault-tolerant training checkpoints: atomic, sharded-tree save/restore.

Trees are flattened by keystr path and written as .npz plus a JSON
manifest; writes go to a temp name and are renamed atomically so a crash
mid-save never corrupts the latest checkpoint. ``keep`` bounds disk use.
On a multi-host cluster each process saves its addressable shards under
its process index (the manifest records the mesh + PartitionSpecs so
restore can re-shard on a different topology — elastic restart); in this
single-process container that degenerates to one shard file.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        a = np.asarray(leaf)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            # npz cannot round-trip ml_dtypes (saved as raw void):
            # store widened; restore casts back to the tree's dtype
            a = np.asarray(jax.numpy.asarray(leaf).astype(
                jax.numpy.float32))
        out[jax.tree_util.keystr(path)] = a
    return out


def _unflatten(tree_like, flat: Dict[str, np.ndarray]):
    leaves = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree_like)[0]:
        k = jax.tree_util.keystr(path)
        arr = flat[k]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = np.asarray(jax.numpy.asarray(arr).astype(leaf.dtype))
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 process_index: int = 0):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.proc = process_index

    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:08d}"

    def save(self, step: int, tree, metadata: Optional[Dict] = None):
        tmp = self.dir / f".tmp_step_{step:08d}_{time.time_ns()}"
        tmp.mkdir(parents=True)
        flat = _flatten(jax.device_get(tree))
        np.savez(tmp / f"shard_{self.proc}.npz",
                 **{k: v for k, v in flat.items()})
        manifest = {"step": step, "time": time.time(),
                    "keys": sorted(flat.keys()),
                    "metadata": metadata or {}}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)             # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None
                ) -> Tuple[Any, int]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        flat = dict(np.load(d / f"shard_{self.proc}.npz"))
        return _unflatten(tree_like, flat), step
