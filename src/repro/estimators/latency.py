"""Per-tier TPOT heads + the analytical end-to-end latency combine (§4.2).

T̂(r, i) = TPOT̂_i * (d_i / b_i + L̂_{r, m(i)})

where d_i is the instance's (dead-reckoned) pending decode tokens and b_i
its decode batch size: d_i/b_i is the number of decode iterations the
request waits through before its own L̂ steps. If the instance has a free
decode slot only the second term applies (the request joins immediately).

TPOT heads are per-(model, hardware) tier GradientBoostedRegressors
trained on a tier-local QPS sweep (features: decode batch size, pending
tokens, mean context). One head query per TIER per scheduler batch — not
per instance (§4.2 cost model). A static analytic prior (nominal roofline
TPOT) is available as the paper's arm-4 variant.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from .gbm import GradientBoostedRegressor


def tpot_features(batch_size: float, pending_tokens: float,
                  mean_ctx: float) -> np.ndarray:
    return np.array([batch_size, pending_tokens, mean_ctx,
                     batch_size * mean_ctx], np.float32)


@dataclasses.dataclass
class LatencyHead:
    tier: str
    model: Optional[GradientBoostedRegressor] = None
    nominal_tpot: float = 0.02      # seconds/token — static prior

    def fit(self, X: np.ndarray, y: np.ndarray):
        self.model = GradientBoostedRegressor(n_trees=60, depth=3).fit(X, y)
        return self

    def tpot(self, batch_size, pending_tokens, mean_ctx,
             learned: bool = True) -> float:
        if learned and self.model is not None:
            x = tpot_features(batch_size, pending_tokens, mean_ctx)[None]
            return float(np.maximum(self.model.predict(x)[0], 1e-4))
        return self.nominal_tpot

    def tpot_batch(self, feats: np.ndarray, learned: bool = True
                   ) -> np.ndarray:
        if learned and self.model is not None:
            return np.maximum(self.model.predict(feats), 1e-4)
        return np.full(feats.shape[0], self.nominal_tpot, np.float32)


def analytic_latency(tpot: np.ndarray, pending_tokens: np.ndarray,
                     batch_size: np.ndarray, pred_len: np.ndarray,
                     has_free_slot: np.ndarray) -> np.ndarray:
    """Vectorized T̂ over (R, I): all args broadcastable to (R, I)."""
    wait_iters = np.where(has_free_slot, 0.0,
                          pending_tokens / np.maximum(batch_size, 1.0))
    return tpot * (wait_iters + pred_len)


def mae(pred, true) -> float:
    return float(np.mean(np.abs(np.asarray(pred) - np.asarray(true))))


def mape(pred, true) -> float:
    t = np.asarray(true, np.float64)
    return float(np.mean(np.abs(np.asarray(pred) - t)
                         / np.maximum(np.abs(t), 1e-9)))
