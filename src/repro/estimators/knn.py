"""Distance-weighted KNN quality + output-length estimator (FAISS stand-in).

One lookup over the training split returns, for every candidate model, a
predicted quality in [0,1] and an expected output length (§4.2). The
interface is metric-agnostic: labels are whatever per-(prompt, model)
scores the operator supplies.

Backends:
  * numpy  — exact brute force (default off the hot path)
  * jax    — jitted matmul + lax.top_k (the batched hot path)
  * pallas — fused distance+top-k kernel (repro.kernels.knn_topk), used
             when available; validated against the jnp oracle in tests.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import numpy as np


def distance_weights(d2, eps: float, xp=np):
    """Inverse-distance weights over the k neighbors, normalized to sum
    to 1 along the trailing axis. The one definition shared by the
    numpy / jax / pallas backends and the fused hot path
    (`repro.core.hotpath`)."""
    w = 1.0 / (xp.sqrt(xp.maximum(d2, 0.0)) + eps)
    return w / w.sum(-1, keepdims=True)


def topk_soft_lookup(q, x, xsq, quality, length, k: int, eps: float):
    """The jnp KNN query body: squared distances via the
    ||q-x||² = ||q||² - 2 q·x + ||x||² expansion, `lax.top_k`, then the
    distance-weighted label mix. One definition traced by both the
    staged jax backend and the fused hot path (exact-parity tests
    compare their outputs bitwise). All args are jnp arrays; returns
    (quality (B, M), length (B, M))."""
    import jax
    import jax.numpy as jnp
    d2 = xsq[None, :] - 2.0 * q @ x.T + jnp.sum(q * q, -1, keepdims=True)
    neg, idx = jax.lax.top_k(-d2, k)
    w = distance_weights(-neg, eps, jnp)
    return ((quality[idx] * w[..., None]).sum(1),
            (length[idx] * w[..., None]).sum(1))


class KNNEstimator:
    def __init__(self, k: int = 10, backend: str = "jax",
                 eps: float = 1e-6):
        self.k = k
        self.backend = backend
        self.eps = eps
        self._x: Optional[np.ndarray] = None          # (N, E)
        self._quality: Optional[np.ndarray] = None    # (N, M)
        self._length: Optional[np.ndarray] = None     # (N, M)
        self._jq = None

    # -- index build ---------------------------------------------------------
    def fit(self, embeddings: np.ndarray, quality: np.ndarray,
            lengths: np.ndarray):
        self._x = np.ascontiguousarray(embeddings, np.float32)
        self._quality = np.asarray(quality, np.float32)
        self._length = np.asarray(lengths, np.float32)
        self._sq = (self._x ** 2).sum(-1)
        self._jq = None
        return self

    @property
    def n_models(self) -> int:
        return self._quality.shape[1]

    def with_backend(self, backend: str) -> "KNNEstimator":
        """Copy sharing the fitted index but querying via `backend`
        (the compiled-query cache is backend-specific, so it resets)."""
        import copy
        knn = copy.copy(self)
        knn.backend = backend
        knn._jq = None
        return knn

    # -- query ----------------------------------------------------------------
    def query(self, q: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """q: (B, E) -> (quality (B, M), length (B, M))."""
        if self.backend == "jax":
            return self._query_jax(q)
        if self.backend == "pallas":
            return self._query_pallas(q)
        return self._query_np(q)

    def _query_np(self, q):
        q = np.asarray(q, np.float32)
        d2 = self._sq[None, :] - 2.0 * q @ self._x.T \
            + (q ** 2).sum(-1, keepdims=True)
        idx = np.argpartition(d2, self.k, axis=1)[:, :self.k]
        d2k = np.take_along_axis(d2, idx, axis=1)
        order = np.argsort(d2k, axis=1)
        idx = np.take_along_axis(idx, order, axis=1)
        d2k = np.take_along_axis(d2k, order, axis=1)
        w = distance_weights(d2k, self.eps)
        qual = (self._quality[idx] * w[..., None]).sum(1)
        leng = (self._length[idx] * w[..., None]).sum(1)
        return qual, leng

    def _build_jax(self):
        import jax
        import jax.numpy as jnp
        x = jnp.asarray(self._x)
        sq = jnp.asarray(self._sq)
        qual = jnp.asarray(self._quality)
        leng = jnp.asarray(self._length)
        k, eps = self.k, self.eps

        @jax.jit
        def run(q):
            return topk_soft_lookup(q, x, sq, qual, leng, k, eps)
        return run

    def _query_jax(self, q):
        import jax.numpy as jnp
        if self._jq is None:
            self._jq = self._build_jax()
        # pow2-pad the batch to the same buckets the fused hot path
        # compiles at: XLA picks its dot kernel by shape (B=1 lowers to
        # a gemv whose f32 accumulation order differs from the gemm a
        # padded batch gets), so querying at the raw B would leave
        # staged-vs-fused bitwise parity to rounding luck on exactly
        # the batches retries produce. Bucketing makes it structural —
        # and caps the jit cache at O(log B) entries instead of one
        # per distinct batch size.
        q = np.asarray(q, np.float32)
        B = q.shape[0]
        Bb = max(1 << (B - 1).bit_length(), 8) if B else 8
        if Bb != B:
            q = np.concatenate(
                [q, np.zeros((Bb - B, q.shape[1]), np.float32)])
        qa, la = self._jq(jnp.asarray(q))
        return np.asarray(qa)[:B], np.asarray(la)[:B]

    def _query_pallas(self, q):
        from repro.kernels import knn_ops
        if self._jq is None:
            self._jq = knn_ops.build_query(
                self._x, self._quality, self._length, self.k, self.eps)
        qa, la = self._jq(np.asarray(q, np.float32))
        return np.asarray(qa), np.asarray(la)

    # -- diagnostics ----------------------------------------------------------
    def best_model_accuracy(self, q_emb, true_quality) -> float:
        qual, _ = self.query(q_emb)
        return float((qual.argmax(1)
                      == np.asarray(true_quality).argmax(1)).mean())
