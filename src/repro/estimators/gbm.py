"""Gradient-boosted regression trees — the XGBoost stand-in for the
per-(model, GPU)-tier TPOT heads (§4.2).

Training: numpy, histogram-based exact greedy on 256 bins, squared loss,
level-wise full binary trees. Inference: vectorized numpy (and a jnp
variant for in-graph use) walking the full tree arrays — one gather per
depth level, so a TPOT query stays O(depth) per row (the paper's ≈3 ms
booster contract is trivially met: ours measures in the tens of µs).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class _Tree:
    feature: np.ndarray      # (n_internal,) int32
    threshold: np.ndarray    # (n_internal,) float32
    leaf: np.ndarray         # (n_leaves,)  float32
    depth: int

    def leaves(self, X: np.ndarray) -> np.ndarray:
        """(n,) leaf index per row — the exact traversal result, used by
        the packed-parity tests."""
        idx = np.zeros(X.shape[0], np.int64)
        for _ in range(self.depth):
            f = self.feature[idx]
            t = self.threshold[idx]
            go_right = X[np.arange(X.shape[0]), f] > t
            idx = 2 * idx + 1 + go_right
        return idx - (2 ** self.depth - 1)

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.leaf[self.leaves(X)]


def _fit_tree(X, g, depth: int, n_bins: int, min_child: int,
              lam: float) -> _Tree:
    n, f = X.shape
    n_internal = 2 ** depth - 1
    n_leaves = 2 ** depth
    feature = np.zeros(n_internal, np.int32)
    threshold = np.full(n_internal, np.inf, np.float32)
    node = np.zeros(n, np.int64)           # current node per row

    # global quantile bins per feature
    qs = np.linspace(0, 100, n_bins + 1)[1:-1]
    bins = np.percentile(X, qs, axis=0)    # (n_bins-1, f)
    Xb = np.empty((n, f), np.int16)
    for j in range(f):
        Xb[:, j] = np.searchsorted(bins[:, j], X[:, j], side="right")

    for d in range(depth):
        level = range(2 ** d - 1, 2 ** (d + 1) - 1)
        for nd in level:
            rows = node == nd
            cnt = int(rows.sum())
            if cnt < 2 * min_child:
                feature[nd] = 0
                threshold[nd] = np.inf   # all go left
                continue
            gs = g[rows]
            xb = Xb[rows]
            best = (0.0, -1, -1)
            total = gs.sum()
            for j in range(f):
                sums = np.bincount(xb[:, j], weights=gs, minlength=n_bins)
                cnts = np.bincount(xb[:, j], minlength=n_bins)
                csum = np.cumsum(sums)[:-1]
                ccnt = np.cumsum(cnts)[:-1]
                ok = (ccnt >= min_child) & ((cnt - ccnt) >= min_child)
                if not ok.any():
                    continue
                gain = (csum ** 2 / (ccnt + lam)
                        + (total - csum) ** 2 / (cnt - ccnt + lam)
                        - total ** 2 / (cnt + lam))
                gain = np.where(ok, gain, -np.inf)
                b = int(np.argmax(gain))
                if gain[b] > best[0]:
                    best = (float(gain[b]), j, b)
            if best[1] >= 0:
                feature[nd] = best[1]
                threshold[nd] = (bins[best[2], best[1]]
                                 if best[2] < bins.shape[0]
                                 else np.inf)
        # route rows one level down
        f_nd = feature[node]
        t_nd = threshold[node]
        go_right = X[np.arange(n), f_nd] > t_nd
        node = 2 * node + 1 + go_right

    leaf_idx = node - n_internal
    leaf = np.zeros(n_leaves, np.float32)
    cnts = np.bincount(leaf_idx, minlength=n_leaves)
    sums = np.bincount(leaf_idx, weights=g, minlength=n_leaves)
    nzero = cnts > 0
    leaf[nzero] = (sums[nzero] / (cnts[nzero] + lam)).astype(np.float32)
    return _Tree(feature, threshold, leaf, depth)


class GradientBoostedRegressor:
    def __init__(self, n_trees: int = 80, depth: int = 4,
                 learning_rate: float = 0.15, n_bins: int = 64,
                 min_child: int = 8, lam: float = 1.0):
        self.n_trees = n_trees
        self.depth = depth
        self.lr = learning_rate
        self.n_bins = n_bins
        self.min_child = min_child
        self.lam = lam
        self.base = 0.0
        self.trees: List[_Tree] = []

    def fit(self, X: np.ndarray, y: np.ndarray,
            sample_weight: Optional[np.ndarray] = None):
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32)
        self.base = float(y.mean())
        pred = np.full(y.shape, self.base, np.float32)
        self.trees = []
        for _ in range(self.n_trees):
            resid = y - pred
            tree = _fit_tree(X, resid, self.depth, self.n_bins,
                             self.min_child, self.lam)
            upd = tree.predict(X)
            pred += self.lr * upd
            self.trees.append(tree)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float32)
        out = np.full(X.shape[0], self.base, np.float32)
        for t in self.trees:
            out += self.lr * t.predict(X)
        return out

    def leaf_indices(self, X: np.ndarray) -> np.ndarray:
        """(T, n) leaf index per (tree, row) — numpy reference for the
        packed traversal."""
        X = np.asarray(X, np.float32)
        return np.stack([t.leaves(X) for t in self.trees])

    # -- packed arrays for in-graph (jnp) inference -------------------------
    def pack(self):
        feat = np.stack([t.feature for t in self.trees])
        thr = np.stack([t.threshold for t in self.trees])
        leaf = np.stack([t.leaf for t in self.trees])
        return {"feature": feat, "threshold": thr, "leaf": leaf,
                "base": self.base, "lr": self.lr, "depth": self.depth}


def _packed_leaves(feat, thr, X, depth):
    """Shared packed traversal: one gather per depth level over all trees
    at once. feat/thr: (..., T, n_internal); X: matching (..., n, f);
    returns leaf idx (..., T, n)."""
    import jax.numpy as jnp
    idx = jnp.zeros(feat.shape[:-1] + (X.shape[-2],), jnp.int32)
    for _ in range(depth):
        f = jnp.take_along_axis(feat, idx, axis=-1)     # (..., T, n)
        t = jnp.take_along_axis(thr, idx, axis=-1)      # (..., T, n)
        # gather each row's split feature value: X[..., row, f]
        xv = jnp.take_along_axis(
            jnp.swapaxes(X, -1, -2)[..., None, :, :],   # (..., 1, f, n)
            f[..., None, :], axis=-2)[..., 0, :]        # (..., T, n)
        idx = 2 * idx + 1 + (xv > t).astype(jnp.int32)
    return idx - (2 ** depth - 1)


def _accumulate(base, lr, vals, xp):
    """base + sum_j lr * vals[..., j, :] accumulated tree-by-tree in
    float32 — the same rounding order as the numpy ensemble loop in
    `GradientBoostedRegressor.predict`, so packed inference is exactly
    (bitwise) the numpy prediction. The ONE definition of that rounding
    order: both packed entry points route through here. base may be a
    scalar or an array broadcastable to the output."""
    out = (xp.zeros(vals.shape[:-2] + vals.shape[-1:], np.float32)
           + xp.asarray(base, np.float32))
    for j in range(vals.shape[-2]):
        out = out + lr * vals[..., j, :]
    return out


def predict_packed(packed, X, return_leaves: bool = False):
    """jnp inference over packed trees, vectorized across trees.

    X: (n, f) -> (n,). One gather per depth level over all T trees at
    once; the per-tree accumulation mirrors the numpy loop bitwise.
    """
    import jax.numpy as jnp
    feat, thr, leaf = (jnp.asarray(packed["feature"]),
                       jnp.asarray(packed["threshold"]),
                       jnp.asarray(packed["leaf"]))
    X = jnp.asarray(X, jnp.float32)
    leaf_idx = _packed_leaves(feat, thr, X, packed["depth"])     # (T, n)
    vals = jnp.take_along_axis(leaf, leaf_idx, axis=1)           # (T, n)
    out = _accumulate(packed["base"], packed["lr"], vals, jnp)
    if return_leaves:
        return out, leaf_idx
    return out


def pack_ensemble(models: List["GradientBoostedRegressor"]):
    """Stack several same-shape boosters into one packed dict with a
    leading member axis — e.g. the per-tier TPOT heads fused into one
    device-resident gather for the single-dispatch hot path."""
    packs = [m.pack() for m in models]
    assert len({p["depth"] for p in packs}) == 1, "depth mismatch"
    assert len({p["lr"] for p in packs}) == 1, "learning-rate mismatch"
    assert len({p["feature"].shape for p in packs}) == 1, "tree-count mismatch"
    return {"feature": np.stack([p["feature"] for p in packs]),
            "threshold": np.stack([p["threshold"] for p in packs]),
            "leaf": np.stack([p["leaf"] for p in packs]),
            "base": np.array([p["base"] for p in packs], np.float32),
            "lr": packs[0]["lr"], "depth": packs[0]["depth"]}


def predict_packed_gathered(stacked, member, X):
    """Per-row member selection over a `pack_ensemble` stack (in-graph).

    member: (n,) int — which booster scores each row; X: (n, f).
    Returns (n,). Each row walks its own member's trees; used by the
    fused hot path to run all per-tier TPOT heads in one dispatch.
    The traversal gather is diagonal (row r vs row r's trees), unlike
    `_packed_leaves`' cross product (every row vs every tree), but the
    parity-critical accumulation shares `_accumulate`.
    """
    import jax.numpy as jnp
    feat = jnp.asarray(stacked["feature"])[member]      # (n, T, n_int)
    thr = jnp.asarray(stacked["threshold"])[member]
    leaf = jnp.asarray(stacked["leaf"])[member]
    X = jnp.asarray(X, jnp.float32)
    T = feat.shape[1]
    idx = jnp.zeros((X.shape[0], T), jnp.int32)
    for _ in range(stacked["depth"]):
        f = jnp.take_along_axis(feat, idx[:, :, None], axis=2)[..., 0]
        t = jnp.take_along_axis(thr, idx[:, :, None], axis=2)[..., 0]
        xv = jnp.take_along_axis(X, f, axis=1)          # (n, T)
        idx = 2 * idx + 1 + (xv > t).astype(jnp.int32)
    leaf_idx = idx - (2 ** stacked["depth"] - 1)
    vals = jnp.take_along_axis(leaf, leaf_idx[:, :, None],
                               axis=2)[..., 0]          # (n, T)
    base = jnp.asarray(stacked["base"])[member]
    return _accumulate(base, stacked["lr"], vals.T, jnp)
