"""Gradient-boosted regression trees — the XGBoost stand-in for the
per-(model, GPU)-tier TPOT heads (§4.2).

Training: numpy, histogram-based exact greedy on 256 bins, squared loss,
level-wise full binary trees. Inference: vectorized numpy (and a jnp
variant for in-graph use) walking the full tree arrays — one gather per
depth level, so a TPOT query stays O(depth) per row (the paper's ≈3 ms
booster contract is trivially met: ours measures in the tens of µs).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class _Tree:
    feature: np.ndarray      # (n_internal,) int32
    threshold: np.ndarray    # (n_internal,) float32
    leaf: np.ndarray         # (n_leaves,)  float32
    depth: int

    def predict(self, X: np.ndarray) -> np.ndarray:
        idx = np.zeros(X.shape[0], np.int64)
        for _ in range(self.depth):
            f = self.feature[idx]
            t = self.threshold[idx]
            go_right = X[np.arange(X.shape[0]), f] > t
            idx = 2 * idx + 1 + go_right
        return self.leaf[idx - (2 ** self.depth - 1)]


def _fit_tree(X, g, depth: int, n_bins: int, min_child: int,
              lam: float) -> _Tree:
    n, f = X.shape
    n_internal = 2 ** depth - 1
    n_leaves = 2 ** depth
    feature = np.zeros(n_internal, np.int32)
    threshold = np.full(n_internal, np.inf, np.float32)
    node = np.zeros(n, np.int64)           # current node per row

    # global quantile bins per feature
    qs = np.linspace(0, 100, n_bins + 1)[1:-1]
    bins = np.percentile(X, qs, axis=0)    # (n_bins-1, f)
    Xb = np.empty((n, f), np.int16)
    for j in range(f):
        Xb[:, j] = np.searchsorted(bins[:, j], X[:, j], side="right")

    for d in range(depth):
        level = range(2 ** d - 1, 2 ** (d + 1) - 1)
        for nd in level:
            rows = node == nd
            cnt = int(rows.sum())
            if cnt < 2 * min_child:
                feature[nd] = 0
                threshold[nd] = np.inf   # all go left
                continue
            gs = g[rows]
            xb = Xb[rows]
            best = (0.0, -1, -1)
            total = gs.sum()
            for j in range(f):
                sums = np.bincount(xb[:, j], weights=gs, minlength=n_bins)
                cnts = np.bincount(xb[:, j], minlength=n_bins)
                csum = np.cumsum(sums)[:-1]
                ccnt = np.cumsum(cnts)[:-1]
                ok = (ccnt >= min_child) & ((cnt - ccnt) >= min_child)
                if not ok.any():
                    continue
                gain = (csum ** 2 / (ccnt + lam)
                        + (total - csum) ** 2 / (cnt - ccnt + lam)
                        - total ** 2 / (cnt + lam))
                gain = np.where(ok, gain, -np.inf)
                b = int(np.argmax(gain))
                if gain[b] > best[0]:
                    best = (float(gain[b]), j, b)
            if best[1] >= 0:
                feature[nd] = best[1]
                threshold[nd] = (bins[best[2], best[1]]
                                 if best[2] < bins.shape[0]
                                 else np.inf)
        # route rows one level down
        f_nd = feature[node]
        t_nd = threshold[node]
        go_right = X[np.arange(n), f_nd] > t_nd
        node = 2 * node + 1 + go_right

    leaf_idx = node - n_internal
    leaf = np.zeros(n_leaves, np.float32)
    cnts = np.bincount(leaf_idx, minlength=n_leaves)
    sums = np.bincount(leaf_idx, weights=g, minlength=n_leaves)
    nzero = cnts > 0
    leaf[nzero] = (sums[nzero] / (cnts[nzero] + lam)).astype(np.float32)
    return _Tree(feature, threshold, leaf, depth)


class GradientBoostedRegressor:
    def __init__(self, n_trees: int = 80, depth: int = 4,
                 learning_rate: float = 0.15, n_bins: int = 64,
                 min_child: int = 8, lam: float = 1.0):
        self.n_trees = n_trees
        self.depth = depth
        self.lr = learning_rate
        self.n_bins = n_bins
        self.min_child = min_child
        self.lam = lam
        self.base = 0.0
        self.trees: List[_Tree] = []

    def fit(self, X: np.ndarray, y: np.ndarray,
            sample_weight: Optional[np.ndarray] = None):
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32)
        self.base = float(y.mean())
        pred = np.full(y.shape, self.base, np.float32)
        self.trees = []
        for _ in range(self.n_trees):
            resid = y - pred
            tree = _fit_tree(X, resid, self.depth, self.n_bins,
                             self.min_child, self.lam)
            upd = tree.predict(X)
            pred += self.lr * upd
            self.trees.append(tree)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float32)
        out = np.full(X.shape[0], self.base, np.float32)
        for t in self.trees:
            out += self.lr * t.predict(X)
        return out

    # -- packed arrays for in-graph (jnp) inference -------------------------
    def pack(self):
        feat = np.stack([t.feature for t in self.trees])
        thr = np.stack([t.threshold for t in self.trees])
        leaf = np.stack([t.leaf for t in self.trees])
        return {"feature": feat, "threshold": thr, "leaf": leaf,
                "base": self.base, "lr": self.lr, "depth": self.depth}


def predict_packed(packed, X):
    """jnp inference over packed trees, vectorized across trees.

    X: (n, f) -> (n,). One gather per depth level over all T trees at once.
    """
    import jax.numpy as jnp
    feat, thr, leaf = (jnp.asarray(packed["feature"]),
                       jnp.asarray(packed["threshold"]),
                       jnp.asarray(packed["leaf"]))
    n = X.shape[0]
    T = feat.shape[0]
    idx = jnp.zeros((T, n), jnp.int32)
    for _ in range(packed["depth"]):
        f = jnp.take_along_axis(feat, idx, axis=1)      # (T, n)
        t = jnp.take_along_axis(thr, idx, axis=1)       # (T, n)
        xv = jnp.take_along_axis(X[None, :, :].repeat(T, axis=0),
                                 f[:, :, None].astype(jnp.int32),
                                 axis=2)[:, :, 0]       # (T, n)
        idx = 2 * idx + 1 + (xv > t).astype(jnp.int32)
    leaf_idx = idx - (2 ** packed["depth"] - 1)
    vals = jnp.take_along_axis(leaf, leaf_idx, axis=1)  # (T, n)
    return packed["base"] + packed["lr"] * vals.sum(axis=0)
