"""CPU-resident sentence encoder — the all-MiniLM-L6-v2 stand-in.

A small frozen transformer encoder (random features): hashed token
embeddings -> 2 encoder layers -> masked mean-pool -> L2 normalize. Frozen
random transformers preserve input similarity structure (random-features
kernel), which is all the KNN estimator needs; the interface matches the
paper's contract — one batched call per scheduler batch, embeddings
reused across every candidate model (§4.2).

The scoring hot path ``encode()`` is jitted once; the Pallas knn_topk
kernel consumes its output.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def pad_tokens(token_lists, max_len: int) -> np.ndarray:
    """Ragged token lists -> (B, max_len) int32, zero-padded.

    Runs on the scheduler hot path once per batch, so the per-token work
    is one boolean-mask scatter (row-major mask order matches the
    concatenation order) instead of a Python loop over rows.
    """
    n = len(token_lists)
    out = np.zeros((n, max_len), np.int32)
    if n == 0:
        return out
    lens = np.minimum(
        np.fromiter((len(t) for t in token_lists), np.int64, count=n),
        max_len)
    if lens.sum() == 0:
        return out
    mask = np.arange(max_len)[None, :] < lens[:, None]
    out[mask] = np.concatenate(
        [np.asarray(t[:l], np.int32) for t, l in zip(token_lists, lens)])
    return out


class SentenceEncoder:
    def __init__(self, dim: int = 128, hidden: int = 128, n_layers: int = 2,
                 n_heads: int = 4, hash_vocab: int = 4096, seed: int = 7,
                 max_len: int = 128):
        self.dim = dim
        self.hidden = hidden
        self.max_len = max_len
        self.hash_vocab = hash_vocab
        key = jax.random.key(seed)
        ks = jax.random.split(key, 4 + 4 * n_layers)
        s = hidden ** -0.5
        self.params = {
            "embed": jax.random.normal(ks[0], (hash_vocab, hidden)) * s,
            "pos": jax.random.normal(ks[1], (max_len, hidden)) * s * 0.1,
            "out": jax.random.normal(ks[2], (hidden, dim)) * s,
            "layers": [],
        }
        self.n_heads = n_heads
        for i in range(n_layers):
            k = ks[4 + i]
            sub = jax.random.split(k, 6)
            self.params["layers"].append({
                "wq": jax.random.normal(sub[0], (hidden, hidden)) * s,
                "wk": jax.random.normal(sub[1], (hidden, hidden)) * s,
                "wv": jax.random.normal(sub[2], (hidden, hidden)) * s,
                "wo": jax.random.normal(sub[3], (hidden, hidden)) * s,
                "w1": jax.random.normal(sub[4], (hidden, 2 * hidden)) * s,
                "w2": jax.random.normal(sub[5], (2 * hidden, hidden))
                      * (2 * hidden) ** -0.5,
            })
        self._encode = jax.jit(self._encode_impl)

    def _encode_impl(self, tokens, mask):
        """tokens: (B, L) int32 (already hashed); mask: (B, L) bool."""
        p = self.params
        h = p["embed"][tokens % self.hash_vocab] + p["pos"][None,
                                                            :tokens.shape[1]]
        mf = mask[..., None].astype(h.dtype)
        B, L, D = h.shape
        nh = self.n_heads
        hd = D // nh
        for lp in p["layers"]:
            q = (h @ lp["wq"]).reshape(B, L, nh, hd)
            k = (h @ lp["wk"]).reshape(B, L, nh, hd)
            v = (h @ lp["wv"]).reshape(B, L, nh, hd)
            s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * hd ** -0.5
            s = jnp.where(mask[:, None, None, :], s, -1e30)
            a = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, L, D)
            h = h + o @ lp["wo"]
            h = h + jax.nn.gelu(h @ lp["w1"]) @ lp["w2"]
            h = h * jax.lax.rsqrt(
                jnp.mean(jnp.square(h), -1, keepdims=True) + 1e-6)
        pooled = (h * mf).sum(1) / jnp.maximum(mf.sum(1), 1.0)
        e = pooled @ p["out"]
        return e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True),
                               1e-6)

    def encode(self, tokens: np.ndarray,
               lengths: Optional[np.ndarray] = None) -> np.ndarray:
        """tokens: (B, L) int; lengths: (B,). One batched call (§4.2)."""
        tokens = np.asarray(tokens)
        if tokens.ndim == 1:
            tokens = tokens[None]
        L = min(tokens.shape[1], self.max_len)
        tokens = tokens[:, :L]
        if lengths is None:
            mask = np.ones(tokens.shape, bool)
        else:
            mask = np.arange(L)[None, :] < np.asarray(lengths)[:, None]
        out = self._encode(jnp.asarray(tokens, jnp.int32),
                           jnp.asarray(mask))
        return np.asarray(out)
