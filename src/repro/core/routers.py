"""Decoupled model routers (baseline policies, §5-§6) — composed with
a dispatcher into a `RouterDispatchPolicy` (`repro.core.policies`) and
served through the shared `ServingEngine`.

All consume the SAME supervision as RouteBalance's KNN estimator (the
paper's fairness control: identical DeepEval labels, identical train
split) and are instance-blind — they pick a model name; the dispatcher
picks a replica.

  * AvengersProRouter — embedding k-means clusters with per-cluster
    model ranking; score = p_w * quality_rank + (1-p_w) * efficiency.
  * BestRouteRouter  — quality-scorer cascade with threshold t: cheapest
    model whose predicted quality is within (1-t) of the best.
  * PassthroughRouter — no model preference (dispatcher sees the whole
    pool).

Each returns a model index per request plus its serial per-request
scoring time (`serial_scoring_s` — what the engine's
``deployment="serial_published"`` arm charges per request, §6.3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


class Router:
    name = "router"
    serial_scoring_s = 0.0         # per-request serial scoring service time

    def fit(self, emb: np.ndarray, quality: np.ndarray,
            lengths: np.ndarray, prices: np.ndarray):
        return self

    def route(self, emb: np.ndarray) -> np.ndarray:
        raise NotImplementedError


class PassthroughRouter(Router):
    """No model selection; candidates = whole pool (dispatcher decides)."""
    name = "passthrough"
    serial_scoring_s = 0.0

    def route(self, emb: np.ndarray) -> np.ndarray:
        return np.full(emb.shape[0], -1, np.int64)


class AvengersProRouter(Router):
    """k-means over embeddings + per-cluster quality/efficiency mix.

    As published, scoring is one request at a time (embedding + cluster
    assign); its measured residual climbs 258 ms -> 2.79 s under load
    (§6.3). serial_scoring_s models the embedding forward on the
    baseline's own stack.
    """
    name = "avengers-pro"
    serial_scoring_s = 0.080

    def __init__(self, p_w: float = 0.8, n_clusters: int = 64,
                 seed: int = 0, iters: int = 25):
        self.p_w = p_w
        self.k = n_clusters
        self.seed = seed
        self.iters = iters
        self.centroids: Optional[np.ndarray] = None
        self.cluster_quality: Optional[np.ndarray] = None
        self.efficiency: Optional[np.ndarray] = None

    def fit(self, emb, quality, lengths, prices):
        rng = np.random.default_rng(self.seed)
        n = emb.shape[0]
        c = emb[rng.choice(n, self.k, replace=False)].copy()
        for _ in range(self.iters):
            d = ((emb[:, None, :] - c[None]) ** 2).sum(-1) \
                if n * self.k * emb.shape[1] < 5e7 else None
            if d is None:
                d = (emb ** 2).sum(1)[:, None] - 2 * emb @ c.T \
                    + (c ** 2).sum(1)[None]
            a = d.argmin(1)
            for j in range(self.k):
                m = a == j
                if m.any():
                    c[j] = emb[m].mean(0)
        self.centroids = c
        M = quality.shape[1]
        cq = np.zeros((self.k, M))
        for j in range(self.k):
            m = a == j
            cq[j] = quality[m].mean(0) if m.any() else quality.mean(0)
        self.cluster_quality = cq
        # efficiency: inverse expected cost (per-model mean length x price)
        mean_cost = lengths.mean(0) * prices
        eff = 1.0 / np.maximum(mean_cost, 1e-9)
        self.efficiency = (eff - eff.min()) / max(eff.max() - eff.min(),
                                                  1e-9)
        return self

    def route(self, emb):
        d = (emb ** 2).sum(1)[:, None] - 2 * emb @ self.centroids.T \
            + (self.centroids ** 2).sum(1)[None]
        cl = d.argmin(1)
        q = self.cluster_quality[cl]                       # (R, M)
        qn = (q - q.min(1, keepdims=True)) / np.maximum(
            q.max(1, keepdims=True) - q.min(1, keepdims=True), 1e-9)
        s = self.p_w * qn + (1 - self.p_w) * self.efficiency[None]
        return s.argmax(1)


class BestRouteRouter(Router):
    """Quality-scorer + threshold cascade (BEST-Route analogue).

    Routes to the CHEAPEST model whose predicted quality >= best - (1-t) *
    spread; t=1 -> always best model, t=0 -> always cheapest. The scorer
    is a KNN head on the shared supervision (the paper refits BEST-Route's
    DeBERTa on the same labels; ours matches that control). As published
    the scorer runs one generative-classifier forward per request:
    431 ms single-threaded (§6.3).
    """
    name = "best-route"
    serial_scoring_s = 0.431

    def __init__(self, threshold: float = 0.5, k: int = 10):
        self.t = threshold
        self.k = k
        self._knn = None
        self.price_order: Optional[np.ndarray] = None

    def fit(self, emb, quality, lengths, prices):
        from repro.estimators.knn import KNNEstimator
        self._knn = KNNEstimator(k=self.k, backend="jax").fit(
            emb, quality, lengths)
        self.price_order = np.argsort(prices)     # cheapest first
        return self

    def route(self, emb):
        q, _ = self._knn.query(emb)               # (R, M)
        best = q.max(1, keepdims=True)
        spread = best - q.min(1, keepdims=True)
        ok = q >= best - (1.0 - self.t) * spread - 1e-12
        # cheapest acceptable: reorder the mask cheapest-first, argmax
        # picks the first acceptable column (every row has one — the
        # best model always passes its own threshold)
        return self.price_order[ok[:, self.price_order].argmax(1)]
