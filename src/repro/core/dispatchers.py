"""Replica dispatchers for the decoupled baseline policies:
round-robin, shortest-queue, random (§5).

`pick_slots(slots, tel)` is the dispatch interface: `slots` is the
candidate set as roster-slot indices (ascending — the router's
candidate filter over the alive mask), `tel` the scheduler-side
columnar `TelemetryArrays` view. State-dependent dispatchers read
telemetry as vectorized column gathers — the legacy per-request
`telemetry.get(inst.iid, ...)` dict scan is gone (it marshaled one
dict per instance per request, the baselines' host-path hot spot)."""
from __future__ import annotations

import numpy as np

from repro.serving.cluster import TelemetryArrays


class Dispatcher:
    name = "dispatcher"

    def pick_slots(self, slots: np.ndarray, tel: TelemetryArrays) -> int:
        """Index into `slots` of the chosen replica."""
        raise NotImplementedError


class RoundRobin(Dispatcher):
    name = "rr"

    def __init__(self):
        self._n = 0

    def pick_slots(self, slots, tel):
        i = self._n % len(slots)
        self._n += 1
        return i


class ShortestQueue(Dispatcher):
    name = "sq"

    def pick_slots(self, slots, tel):
        # queue depth dominates, pending decode tokens break ties —
        # one vectorized argmin over the telemetry columns
        return int(np.argmin(tel.queue[slots] * 1000.0
                             + tel.pending[slots]))


class RandomDispatch(Dispatcher):
    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def pick_slots(self, slots, tel):
        return int(self.rng.integers(0, len(slots)))


DISPATCHERS = {"rr": RoundRobin, "sq": ShortestQueue,
               "random": RandomDispatch}
