"""Replica dispatchers for pipeline mode: round-robin, shortest-queue,
random (§5)."""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


class Dispatcher:
    name = "dispatcher"

    def pick(self, candidates: Sequence, telemetry: Dict[str, Dict]) -> int:
        raise NotImplementedError


class RoundRobin(Dispatcher):
    name = "rr"

    def __init__(self):
        self._n = 0

    def pick(self, candidates, telemetry):
        i = self._n % len(candidates)
        self._n += 1
        return i


class ShortestQueue(Dispatcher):
    name = "sq"

    def pick(self, candidates, telemetry):
        loads = []
        for inst in candidates:
            s = telemetry.get(inst.iid, inst.telemetry())
            loads.append(s["queue_depth"] * 1000 + s["pending_decode"])
        return int(np.argmin(loads))


class RandomDispatch(Dispatcher):
    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def pick(self, candidates, telemetry):
        return int(self.rng.integers(0, len(candidates)))


DISPATCHERS = {"rr": RoundRobin, "sq": ShortestQueue,
               "random": RandomDispatch}
