"""Equation 1: the fused quality-latency-cost score over request-instance
pairs, with per-request normalization of cost and latency by candidate
maxima (the batch supplies the reference set a point-at-a-time router
lacks; §4.1)."""
from __future__ import annotations

from typing import Optional

import numpy as np


def score_matrix(q_hat: np.ndarray, c_hat: np.ndarray, t_hat: np.ndarray,
                 weights, allowed: Optional[np.ndarray] = None
                 ) -> np.ndarray:
    """q_hat: (R, I) quality of instance's model per request in [0,1];
    c_hat, t_hat: (R, I) positive; weights = (w_qual, w_lat, w_cost).
    Returns (R, I) scores with disallowed pairs at -inf."""
    wq, wl, wc = weights
    mask = np.ones(c_hat.shape, bool) if allowed is None else allowed
    c = np.where(mask, c_hat, -np.inf)
    t = np.where(mask, t_hat, -np.inf)
    cmax = np.maximum(c.max(axis=1, keepdims=True), 1e-12)
    tmax = np.maximum(t.max(axis=1, keepdims=True), 1e-12)
    s = (wq * q_hat
         + wc * (1.0 - c_hat / cmax)
         + wl * (1.0 - t_hat / tmax))
    return np.where(mask, s, -np.inf)


def score_row(q: np.ndarray, c: np.ndarray, t: np.ndarray, weights,
              allowed: Optional[np.ndarray] = None) -> np.ndarray:
    """Single-request variant used inside the greedy loop (t is
    state-dependent so it is recomputed per dispatch)."""
    wq, wl, wc = weights
    mask = np.ones(c.shape, bool) if allowed is None else allowed
    cmax = max(float(np.max(np.where(mask, c, -np.inf))), 1e-12)
    tmax = max(float(np.max(np.where(mask, t, -np.inf))), 1e-12)
    s = wq * q + wc * (1.0 - c / cmax) + wl * (1.0 - t / tmax)
    return np.where(mask, s, -np.inf)
