"""Equation 1: the fused quality-latency-cost score over request-instance
pairs, with per-request normalization of cost and latency by candidate
maxima (the batch supplies the reference set a point-at-a-time router
lacks; §4.1).

The math lives in one backend-agnostic function (`masked_score`) shared
by the numpy production loop and the jitted JAX decision core
(`repro.core.decision_jax`) — exact-parity differential tests depend on
both backends evaluating the identical expression in the identical
operation order.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def masked_score(q, c, t, weights, mask, xp=np):
    """Eq. 1 over the trailing candidate axis, any leading batch shape.

    q/c/t/mask broadcastable arrays whose last axis enumerates the
    candidate instances; weights = (w_qual, w_lat, w_cost); xp is the
    array namespace (numpy or jax.numpy). Cost and latency are
    normalized per request by the max over *allowed* candidates;
    disallowed pairs come back -inf.
    """
    wq, wl, wc = weights
    neg = -xp.inf
    cmax = xp.maximum(
        xp.max(xp.where(mask, c, neg), axis=-1, keepdims=True), 1e-12)
    tmax = xp.maximum(
        xp.max(xp.where(mask, t, neg), axis=-1, keepdims=True), 1e-12)
    s = wq * q + wc * (1.0 - c / cmax) + wl * (1.0 - t / tmax)
    return xp.where(mask, s, neg)


def score_matrix(q_hat: np.ndarray, c_hat: np.ndarray, t_hat: np.ndarray,
                 weights, allowed: Optional[np.ndarray] = None
                 ) -> np.ndarray:
    """q_hat: (R, I) quality of instance's model per request in [0,1];
    c_hat, t_hat: (R, I) positive; weights = (w_qual, w_lat, w_cost).
    Returns (R, I) scores with disallowed pairs at -inf."""
    mask = np.ones(c_hat.shape, bool) if allowed is None else allowed
    return masked_score(q_hat, c_hat, t_hat, weights, mask, np)


def score_row(q: np.ndarray, c: np.ndarray, t: np.ndarray, weights,
              allowed: Optional[np.ndarray] = None) -> np.ndarray:
    """Single-request variant used inside the greedy loop (t is
    state-dependent so it is recomputed per dispatch)."""
    mask = np.ones(c.shape, bool) if allowed is None else allowed
    return masked_score(q, c, t, weights, mask, np)
