"""Equation 1: the fused quality-latency-cost score over request-instance
pairs, with per-request normalization of cost and latency by candidate
maxima (the batch supplies the reference set a point-at-a-time router
lacks; §4.1).

The math lives in one backend-agnostic function (`masked_score`) shared
by the numpy production loop and the jitted JAX decision cores
(`repro.core.decision_jax`, `repro.core.hotpath`) — exact-parity
differential tests depend on every backend evaluating the identical
expression in the identical operation order.

Scores are **epsilon-quantized** before they are returned: snapped to a
2^-13 grid (~1.2e-4 of the O(1) score scale). Two candidates whose
scores are equal in real arithmetic — same-tier replicas in identical
dead-reckoned state, the common case on a live cluster — used to come
back with a sub-1e-7 noise gap that the numpy loop's float64 resolved
and the jitted cores' float32 collapsed (or vice versa), flipping the
argmax between backends on unlucky worlds. Three coordinated choices
make the backends agree instead: the scheduler's numpy reference now
evaluates the decision arithmetic in float32 (`greedy_assign` follows
its input dtype, so the T/score chains are bitwise the jitted cores'),
the cost scale is a reciprocal multiply rather than a division
(matching XLA's rewrite), and quantization absorbs the one residual
cross-backend difference — XLA's FMA contraction of the cost mul-add,
~1 ulp — by collapsing every sub-quantum gap to an exact tie in both
precisions (the pow2 scale makes the snap itself exact in either float
width). Ties break deterministically by candidate index in all
backends, so the three-way randomized soak holds on every seed with no
pinned exclusions (`tests/test_soak.py`). Gaps that matter — actual
quality/cost/latency differences, O(1e-3) and up — sit a thousand
quanta apart and are untouched.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

# pow2 quantum: s * 2^13 only shifts the exponent, so the snap is exact
# in both float32 and float64 and the two precisions land on the same
# grid point for any sub-quantum disagreement. 2^-13 ~ 1.2e-4 sits far
# below meaningful score signal (KNN quality noise is 0.14, TPOT heads
# carry ~3% error, weighted differences that matter are O(1e-2)) and
# ~1000x above float32 evaluation noise, so near-tie straddles of a
# grid boundary — the residual cross-precision flip mode — are rare
# enough that the randomized soak holds on every seed.
SCORE_QUANTUM = 2.0 ** -13
_INV_QUANTUM = 2.0 ** 13


def quantize_scores(s, xp=np):
    """Snap Eq. 1 scores to the shared epsilon grid (round half to even
    in both numpy and jax). -inf (masked candidates) passes through."""
    return xp.round(s * _INV_QUANTUM) * SCORE_QUANTUM


def masked_score(q, c, t, weights, mask, xp=np):
    """Eq. 1 over the trailing candidate axis, any leading batch shape.

    q/c/t/mask broadcastable arrays whose last axis enumerates the
    candidate instances; weights = (w_qual, w_lat, w_cost); xp is the
    array namespace (numpy or jax.numpy). Cost and latency are
    normalized per request by the max over *allowed* candidates;
    disallowed pairs come back -inf. Scores are epsilon-quantized (see
    module docstring) so float32 and float64 evaluations agree exactly.
    """
    wq, wl, wc = weights
    neg = -xp.inf
    cmax = xp.maximum(
        xp.max(xp.where(mask, c, neg), axis=-1, keepdims=True), 1e-12)
    tmax = xp.maximum(
        xp.max(xp.where(mask, t, neg), axis=-1, keepdims=True), 1e-12)
    s = wq * q + wc * (1.0 - c / cmax) + wl * (1.0 - t / tmax)
    return xp.where(mask, quantize_scores(s, xp), neg)


def affinity_discount(t, affinity, xp=np):
    """The prefix-affinity term (ROADMAP item 2): discount the
    predicted latency by the matched-prefix reuse score before Eq. 1
    normalizes it. `affinity` is `affinity_weight * hit_fraction`
    broadcastable to `t`'s shape (float32 in every backend); the
    multiplicative form keeps `affinity == 0` EXACTLY the legacy
    arithmetic (t * 1.0 is an IEEE identity), which is what lets the
    disabled path stay bitwise-identical across all three decision
    backends. Every backend must apply this before scoring AND use the
    discounted value for its est-latency/tie-break bookkeeping."""
    return t * (xp.float32(1.0) - affinity)


def score_matrix(q_hat: np.ndarray, c_hat: np.ndarray, t_hat: np.ndarray,
                 weights, allowed: Optional[np.ndarray] = None,
                 affinity: Optional[np.ndarray] = None) -> np.ndarray:
    """q_hat: (R, I) quality of instance's model per request in [0,1];
    c_hat, t_hat: (R, I) positive; weights = (w_qual, w_lat, w_cost);
    affinity: optional (R, I) prefix-reuse discount (weight x matched
    fraction) applied to t_hat. Returns (R, I) scores with disallowed
    pairs at -inf."""
    mask = np.ones(c_hat.shape, bool) if allowed is None else allowed
    if affinity is not None:
        t_hat = affinity_discount(t_hat, affinity, np)
    return masked_score(q_hat, c_hat, t_hat, weights, mask, np)


def score_row(q: np.ndarray, c: np.ndarray, t: np.ndarray, weights,
              allowed: Optional[np.ndarray] = None,
              affinity: Optional[np.ndarray] = None) -> np.ndarray:
    """Single-request variant used inside the greedy loop (t is
    state-dependent so it is recomputed per dispatch)."""
    mask = np.ones(c.shape, bool) if allowed is None else allowed
    if affinity is not None:
        t = affinity_discount(t, affinity, np)
    return masked_score(q, c, t, weights, mask, np)
