"""The policy registry: every scheduler the repo can serve, as
`SchedulingPolicy` implementations over the one `ServingEngine`.

`RouterDispatchPolicy` adapts the decoupled router → dispatcher
baselines (§5): the router picks a model per request from the batch's
memoized ingest embeddings (batched — the per-group encoder forward of
the legacy pipeline collapses into one gather), the dispatcher picks a
replica among that model's alive instances off the columnar
`TelemetryArrays` view, and the predicted output length comes from the
shared KNN supervision — the paper's fairness control. Deployment
(serial_published / microbatch / concurrent / windowed) is the
engine's axis, not the policy's.

`POLICIES` names every registered policy — RouteBalance plus the full
router × dispatcher grid — resolvable by `make_policy(name, **kw)`;
`repro.launch.serve --policy` and the frontier/ladder benches sweep it.
Register your own with `register_policy` (see README "Policies on one
engine")."""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.cluster import ClusterSim
from repro.serving.tiers import Tier

from .dispatchers import Dispatcher, RandomDispatch, RoundRobin, \
    ShortestQueue
from .engine import AssignmentResult, BatchView, Ready, SchedulingPolicy
from .routers import AvengersProRouter, BestRouteRouter, \
    PassthroughRouter, Router
from .scheduler import RBConfig, RouteBalancePolicy


class RouterDispatchPolicy(SchedulingPolicy):
    """Decoupled model router + replica dispatcher as one policy.

    `assign` is batched: one embedding gather + one `router.route` +
    one KNN length lookup for the whole fired group, then a per-request
    dispatcher pick over the router's candidate set. Candidate
    filtering and dispatcher state reads are vectorized over the
    columnar telemetry view (`TelemetryArrays`) — no per-instance dict
    marshaling (the legacy `core/pipeline.py` hot spot)."""

    def __init__(self, router: Router, dispatcher: Dispatcher,
                 budget_clamp: bool = True, shed: bool = True):
        self.router = router
        self.dispatcher = dispatcher
        self.budget_clamp = budget_clamp
        self.shed = shed            # honor overload admission control
        self.bundle = None
        self._model_of_slot: Optional[np.ndarray] = None

    @property
    def name(self) -> str:
        return f"{self.router.name}-{self.dispatcher.name}"

    @property
    def serial_scoring_s(self) -> float:
        # the router's measured one-request scoring forward — what the
        # serial_published deployment charges per request (§6.3)
        return self.router.serial_scoring_s

    def fit(self, emb, quality, lengths, prices):
        self.router.fit(emb, quality, lengths, prices)
        return self

    def on_attach(self, sim: ClusterSim):
        self._model_of_slot = np.array(
            [i.model_idx for i in sim.instances], np.int64)

    def shed_verdict(self, req, controller) -> bool:
        # shedding is policy-visible: a baseline built with shed=False
        # admits everything even on an elastic sim (the "no admission
        # control" arm of the overload benches)
        if not self.shed:
            return False
        return controller.wants_shed(req.priority)

    def assign(self, batch: BatchView, cluster: ClusterSim
               ) -> AssignmentResult:
        cols, rows = batch.columns(self.bundle.encoder)
        emb = cols.emb[cols.prompt_row[rows]]
        models = self.router.route(emb)                   # (R,) model idx
        _, L = self.bundle.knn.query(emb)                 # (R, M) lengths
        tel = cluster.tel
        model_of = self._model_of_slot
        if model_of is None or len(model_of) != len(tel.alive):
            # direct callers that skipped attach(): derive lazily
            model_of = np.array([i.model_idx for i in cluster.instances],
                                np.int64)
            self._model_of_slot = model_of
        alive_slots = np.flatnonzero(tel.alive)
        alive_models = model_of[alive_slots]
        R = len(batch)
        choice = np.empty(R, np.int64)
        l_chosen = np.empty(R, np.float64)
        for j in range(R):
            m = int(models[j])
            cand = (alive_slots if m < 0
                    else alive_slots[alive_models == m])
            if not len(cand):                 # model has no alive replica
                cand = alive_slots
            slot = int(cand[self.dispatcher.pick_slots(cand, tel)])
            choice[j] = slot
            l_chosen[j] = L[j, model_of[slot]]
        return AssignmentResult(cluster.instances,
                                Ready(choice, l_chosen))


# -- registry -----------------------------------------------------------------

_ROUTERS: Dict[str, Callable[..., Router]] = {
    "avengers": AvengersProRouter,
    "bestroute": BestRouteRouter,
    "passthrough": PassthroughRouter,
}
_DISPATCHERS: Dict[str, Callable[[], Dispatcher]] = {
    "rr": RoundRobin,
    "sq": ShortestQueue,
    "random": RandomDispatch,
}


def _router_dispatch_factory(rname: str, dname: str):
    def make(budget_clamp: bool = True, shed: bool = True, **router_kw):
        return RouterDispatchPolicy(_ROUTERS[rname](**router_kw),
                                    _DISPATCHERS[dname](),
                                    budget_clamp=budget_clamp, shed=shed)
    make.__doc__ = f"{rname} router -> {dname} dispatcher baseline"
    return make


def _routebalance_factory(**cfg_kw):
    return RouteBalancePolicy(RBConfig(**cfg_kw))


# name -> factory(**kw) -> SchedulingPolicy. RouteBalance kwargs are
# RBConfig fields; baseline kwargs are the router's (plus budget_clamp).
POLICIES: Dict[str, Callable[..., SchedulingPolicy]] = {
    "routebalance": _routebalance_factory,
}
for _r in _ROUTERS:
    for _d in _DISPATCHERS:
        POLICIES[f"{_r}-{_d}"] = _router_dispatch_factory(_r, _d)


def register_policy(name: str, factory: Callable[..., SchedulingPolicy]):
    """Add a custom policy to the registry (CLI + benches pick it up)."""
    if name in POLICIES:
        raise ValueError(f"policy {name!r} already registered")
    POLICIES[name] = factory
    return factory


def make_policy(name: str, **kw) -> SchedulingPolicy:
    try:
        factory = POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; "
                       f"have {sorted(POLICIES)}") from None
    return factory(**kw)


def train_data(bundle, ds, tiers: Sequence[Tier],
               model_names: List[str]
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(emb, quality, lengths, prices) for `SchedulingPolicy.fit`: the
    train-split supervision RouteBalance's KNN estimator consumed —
    the paper's fairness control for fitting decoupled routers. The
    embeddings are read back from the bundle's fitted KNN index
    (`EstimatorBundle.train` already embedded the train split with the
    shared encoder; re-encoding here would be pure recomputation), the
    float64 labels from the dataset split."""
    prompts, Q, L = ds.split("train")
    emb = bundle.knn._x
    assert emb is not None and len(emb) == len(prompts), \
        "bundle KNN was not fitted on this dataset's train split"
    by_model = {t.model: t.price_out for t in tiers}
    prices = np.array([by_model.get(m, 0.1) for m in model_names])
    return emb, Q, L, prices


def fit_policy(name: str, bundle, tiers: Sequence[Tier],
               model_names: List[str], ds, **kw) -> SchedulingPolicy:
    """`make_policy` + `fit` on the shared supervision in one call —
    what `repro.launch.serve --policy` resolves through. Policies that
    keep the base no-op `fit` (e.g. routebalance: its estimators live
    in the already-trained bundle) skip the supervision assembly."""
    policy = make_policy(name, **kw)
    if type(policy).fit is not SchedulingPolicy.fit:
        policy.fit(*train_data(bundle, ds, tiers, model_names))
    return policy
