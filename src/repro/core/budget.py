"""Equation 2 budget control: average-case admission filter at scoring
time, worst-case enforcement at dispatch (max_tokens clamp) plus the
engine's streaming early-stop (§4.1, §6.4)."""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np


def admission_mask(budgets: np.ndarray, len_in: np.ndarray,
                   pred_len: np.ndarray, price_in: np.ndarray,
                   price_out: np.ndarray) -> np.ndarray:
    """(R,) budgets (nan = none), (R,) len_in, (R, I) pred_len per
    instance's model, (I,) prices -> (R, I) allowed mask.

    Ĉ(r,i) = ℓ_in c_in + L̂ c_out <= b_r. Requests whose budget excludes
    every candidate keep their single cheapest candidate (the system still
    serves every request; §6.2)."""
    R, I = pred_len.shape
    c_hat = (len_in[:, None] * price_in[None, :]
             + pred_len * price_out[None, :]) / 1e6
    has_budget = ~np.isnan(budgets)
    allowed = np.ones((R, I), bool)
    constrained = np.where(has_budget[:, None],
                           c_hat <= budgets[:, None], True)
    none_fit = ~constrained.any(axis=1)
    cheapest = c_hat.argmin(axis=1)
    constrained[none_fit, :] = False
    constrained[none_fit, cheapest[none_fit]] = True
    return allowed & constrained, c_hat


def max_tokens_clamp(budget: Optional[float], len_in: int,
                     price_in: float, price_out: float) -> Optional[int]:
    """Worst-case enforcement at dispatch: the response may not exceed the
    remaining budget at the chosen model's output price."""
    if budget is None or np.isnan(budget):
        return None
    rem = budget - len_in * price_in / 1e6
    return max(int(rem / (price_out / 1e6 + 1e-30)), 1)
