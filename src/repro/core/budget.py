"""Equation 2 budget control: average-case admission filter at scoring
time, worst-case enforcement at dispatch (max_tokens clamp) plus the
engine's streaming early-stop (§4.1, §6.4).

`admission_math` is backend-agnostic (numpy or jax.numpy) so the numpy
production path and the jitted decision core (`repro.core.decision_jax`)
evaluate one shared definition of Eq. 2 — no fancy indexing, only
where/argmin, so it traces under jit unchanged.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np


def cost_matrix(len_in, pred_len, price_in, price_out, xp=np):
    """Ĉ(r,i) = (ℓ_in c_in + L̂ c_out) · 1e-6 over (R, I).

    The per-token scale is applied as a reciprocal multiply, not a
    division: XLA rewrites division by a constant into multiplication by
    its (correctly rounded) reciprocal, so spelling the multiply out
    keeps the numpy float32 evaluation on the jitted backends' exact
    arithmetic (the sole remaining cross-backend difference is FMA
    contraction of the mul-add, ~1 ulp, which the epsilon-quantized
    scoring grid absorbs)."""
    return (len_in[:, None] * price_in[None, :]
            + pred_len * price_out[None, :]) * 1e-6


def admission_math(budgets, len_in, pred_len, price_in, price_out, xp=np,
                   valid=None):
    """Shared Eq. 2 body; see `admission_mask` for semantics. Returns
    (allowed (R, I) bool, c_hat (R, I)).

    `valid` (I,) bool optionally restricts the candidate set (the fused
    hot path schedules over the full instance roster with dead instances
    masked instead of recompiling after a failure): disallowed columns
    never admit and never win the cheapest-candidate fallback."""
    I = pred_len.shape[1]
    c_hat = cost_matrix(len_in, pred_len, price_in, price_out, xp)
    has_budget = ~xp.isnan(budgets)
    constrained = xp.where(has_budget[:, None],
                           c_hat <= budgets[:, None], True)
    c_sel = c_hat
    if valid is not None:
        constrained = constrained & valid[None, :]
        c_sel = xp.where(valid[None, :], c_hat, xp.inf)
    none_fit = ~constrained.any(axis=1)
    cheapest = (xp.arange(I)[None, :]
                == c_sel.argmin(axis=1)[:, None])   # one-hot fallback
    allowed = xp.where(none_fit[:, None], cheapest, constrained)
    return allowed, c_hat


def admission_mask(budgets: np.ndarray, len_in: np.ndarray,
                   pred_len: np.ndarray, price_in: np.ndarray,
                   price_out: np.ndarray) -> np.ndarray:
    """(R,) budgets (nan = none), (R,) len_in, (R, I) pred_len per
    instance's model, (I,) prices -> (R, I) allowed mask.

    Ĉ(r,i) = ℓ_in c_in + L̂ c_out <= b_r. Requests whose budget excludes
    every candidate keep their single cheapest candidate (the system still
    serves every request; §6.2)."""
    return admission_math(budgets, len_in, pred_len, price_in, price_out,
                          np)


def max_tokens_clamp(budget: Optional[float], len_in: int,
                     price_in: float, price_out: float) -> Optional[int]:
    """Worst-case enforcement at dispatch: the response may not exceed the
    remaining budget at the chosen model's output price."""
    if budget is None or np.isnan(budget):
        return None
    rem = budget - len_in * price_in / 1e6
    return max(int(rem / (price_out / 1e6 + 1e-30)), 1)
