"""3-simplex scheduling weights and named operating points (§4.1)."""
from __future__ import annotations

import itertools
from typing import Dict, List, Tuple

Weights = Tuple[float, float, float]   # (w_qual, w_lat, w_cost)

PRESETS: Dict[str, Weights] = {
    "quality": (0.8, 0.1, 0.1),
    "uniform": (1 / 3, 1 / 3, 1 / 3),
    "latency": (0.1, 0.8, 0.1),
    "cost": (0.1, 0.1, 0.8),
}


def validate(w: Weights) -> Weights:
    wq, wl, wc = w
    s = wq + wl + wc
    assert abs(s - 1.0) < 1e-6, f"weights must lie on the 3-simplex: {w}"
    assert min(w) >= 0.0
    return w


def sweep(n: int = 16) -> List[Weights]:
    """The paper sweeps 16 weight tuples on the simplex (§6.1)."""
    pts = []
    for wq in (0.0, 0.2, 1 / 3, 0.4, 0.6, 0.8, 1.0):
        for wl in (0.0, 0.1, 0.2, 1 / 3, 0.4, 0.6):
            wc = 1.0 - wq - wl
            if wc < -1e-9:
                continue
            pts.append((round(wq, 4), round(wl, 4), round(max(wc, 0.0), 4)))
    # dedupe, keep a stable subset of n
    uniq = sorted(set(pts))
    if len(uniq) <= n:
        return uniq
    step = len(uniq) / n
    return [uniq[int(i * step)] for i in range(n)]
