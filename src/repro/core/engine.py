"""One engine, many policies: the policy-agnostic serving engine.

The paper's headline claims are *comparative* — RouteBalance is judged
against routers that are re-run as schedulers over the SAME serving
substrate once "router engineering is equalized" (§5–§6.3). This module
is that substrate, factored out of the RouteBalance scheduler so every
policy — RouteBalance's fused objective, the decoupled
router→dispatcher baselines, the paper's enhanced concurrent-scoring
variants — runs through one zero-allocation engine:

  * **batch formation** — the adaptive window loop (`deployment=
    "windowed"`, RouteBalance's amortized batch scoring) or the
    scoring-station models of the §6.3 deployment ladder
    (`"serial_published"`: one scoring call per request on one server,
    as the baselines shipped; `"microbatch"`: a co-located batch
    collector that pads to the longest sequence and cannot overlap
    batches; `"concurrent"`: scoring micro-batched off the scheduling
    loop on a worker pool — our engineering-equalized enhancement);
  * **SoA ingest** — the waiting queue keeps a row-index ring parallel
    to the request stream's `RequestColumns`, so a fired batch reaches
    the policy as a vectorized column slice with memoized embeddings
    (baselines inherit the zero-allocation host path for free);
  * **dispatch + residual accounting** — budget clamping, instance
    submission, and the paper's off-instance residual decomposition
    (compute / batch wait / stats fetch for windowed deployments,
    router queue wait for the station deployments) are charged here,
    identically for every policy;
  * **decision-time measurement** — per-batch wall time feeds the
    `charge_compute` model and `compute_log`, so
    `measured_decide_ms_per_req` is comparable across policies.

A policy implements the `SchedulingPolicy` protocol: `prepare(bundle,
tiers)` once per engine, `on_attach(sim)` per roster, and a batched
`assign(batch_view, cluster_view) -> AssignmentResult` per fired batch.
The engine never looks inside the decision; the policy never touches
the event loop, the queue, telemetry freshness, or dispatch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.cluster import ClusterSim, Instance
from repro.serving.request import Request
from repro.serving.tiers import Tier

from .budget import max_tokens_clamp

DEPLOYMENTS = ("windowed", "concurrent", "serial_published", "microbatch")
# legacy PipelineConfig spelling, accepted as an alias
_DEPLOYMENT_ALIASES = {"serial": "serial_published"}


@dataclasses.dataclass
class EngineConfig:
    """Policy-agnostic engine knobs. `deployment` is the §6.3 ladder
    axis, orthogonal to the policy: the same `SchedulingPolicy` can be
    served windowed (amortized batch scoring), concurrent (equalized
    worker-pool scoring), or serial_published (one call per request,
    charged at the policy's `serial_scoring_s` — the as-published
    deployments that collapse under load)."""
    deployment: str = "windowed"
    # windowed-deployment knobs (RouteBalance's batch formation)
    base_window: float = 0.10
    adaptive: bool = True
    fixed_batch: Optional[int] = None
    charge_compute: bool = True
    # scoring-station knobs (§6.3 ladder deployments)
    n_workers: int = 32            # concurrent scoring workers
    microbatch_size: int = 64
    microbatch_time: float = 1.72  # padded batch service time (§6.3)
    queue_capacity: Optional[int] = None   # bounded => drops (vLLM-SR)


class BatchView:
    """One fired decision batch as the policy sees it: the request
    objects plus (when the batch is a slice of one ingest stream) the
    shared `RequestColumns` and row indices, so policies stage with
    vectorized gathers instead of per-request Python."""

    __slots__ = ("reqs", "cols", "rows", "t", "_attempts")

    def __init__(self, reqs: Sequence[Request], cols=None,
                 rows: Optional[np.ndarray] = None, t: float = 0.0):
        self.reqs = reqs
        self.cols = cols
        self.rows = rows
        self.t = t
        self._attempts = None

    def __len__(self) -> int:
        return len(self.reqs)

    @property
    def attempts(self) -> np.ndarray:
        """(R,) int64 per-request dispatch attempts beyond the first —
        how the policy sees retries re-entering admission after an
        instance failure (repro.serving.recovery). Zero for the fresh
        arrivals that dominate steady state; lazily built so the hot
        path never pays for it."""
        if self._attempts is None:
            self._attempts = np.fromiter(
                (r.attempt for r in self.reqs), np.int64,
                count=len(self.reqs))
        return self._attempts

    def columns(self, encoder):
        """(cols, rows) with embeddings guaranteed — resolving the
        batch's shared stream columns, or building ephemeral
        non-stamping columns for direct/legacy callers."""
        if self.cols is None:
            from repro.serving.request import RequestColumns
            self.cols, self.rows = RequestColumns.for_batch(
                self.reqs, encoder)
        else:
            self.cols.ensure_embeddings(encoder)
        return self.cols, self.rows


class Ready:
    """Already-materialized decision payload: the eager twin of
    `repro.core.hotpath.LazyDecision`, so `AssignmentResult.fetch`
    goes through one interface regardless of backend."""

    __slots__ = ("_out",)

    def __init__(self, choice: np.ndarray, l_chosen: np.ndarray):
        self._out = (choice, l_chosen)

    def fetch(self):
        return self._out


class AssignmentResult:
    """A policy's answer for one batch: the candidate roster plus a
    possibly-deferred (choice, l_chosen) pair. `choice[r]` indexes
    `instances`; `l_chosen[r]` is the predicted output length at the
    chosen instance. The payload exposes `fetch()` — the fused
    backend hands a `LazyDecision` (device arrays, transfer deferred
    to the dispatch point), everything else a `Ready`."""

    __slots__ = ("instances", "_res")

    def __init__(self, instances: Sequence[Instance], res):
        self.instances = instances
        self._res = res

    def fetch(self) -> Tuple[np.ndarray, np.ndarray]:
        return self._res.fetch()


class SchedulingPolicy:
    """The pluggable decision layer. Subclasses override `assign`;
    `prepare`/`on_attach`/`fit` are optional hooks.

    Class attributes consumed by the engine:

      * `serial_scoring_s` — per-request scoring service time charged
        by the `serial_published` deployment (the as-published serial
        station of §6.3). Policies that batch by construction keep the
        default; decoupled baselines surface their router's measured
        serial forward.
      * `budget_clamp` — whether dispatch applies the runtime
        max-tokens budget clamp (Eq. 2's execution-side half).

    `engine_overrides()` lets a policy pin `EngineConfig` fields its
    own config owns (RouteBalance's batch-formation knobs live in
    `RBConfig`): the engine applies them over whatever config it was
    constructed with, so a policy built with e.g. `fixed_batch=8`
    behaves the same whether it reaches the engine through the
    `RouteBalance` convenience class, the `POLICIES` registry, or a
    hand-built `ServingEngine`.
    """

    name = "policy"
    serial_scoring_s = 0.0
    budget_clamp = True

    def engine_overrides(self) -> dict:
        """EngineConfig fields this policy's own config dictates."""
        return {}

    def prepare(self, bundle, tiers: Sequence[Tier]):
        """Bind the estimator stack once per engine. Policies that
        keep a reference may rebind a private copy (e.g. a different
        KNN backend) and expose it as `self.bundle` — the engine picks
        the rebound copy up."""
        self.bundle = bundle

    def fit(self, emb: np.ndarray, quality: np.ndarray,
            lengths: np.ndarray, prices: np.ndarray):
        """Train policy-owned predictors on the shared supervision
        (the paper's fairness control: identical labels, identical
        train split as RouteBalance's KNN estimator)."""
        return self

    def on_attach(self, sim: ClusterSim):
        """New roster: drop per-roster compiled/cached state."""

    def shed_verdict(self, req: Request, controller) -> bool:
        """Admission-control hook, consulted by the engine BEFORE the
        request can join batch formation whenever the sim carries an
        overload controller (`sim.overload`). The default defers to the
        controller's SLO-aware per-priority verdict; a policy may veto
        shedding (return False), tighten it, or reimplement it — the
        verdict is policy-visible state, like every other scheduling
        decision."""
        return controller.wants_shed(req.priority)

    def assign(self, batch: BatchView, cluster: ClusterSim
               ) -> AssignmentResult:
        raise NotImplementedError


class ServingEngine:
    """Event-driven scheduler over a ClusterSim, generic in the policy.

    Windowed deployment is the zero-allocation fused serving path of
    PR 4: SoA ingest ring, adaptive batch window, async dispatch with
    residual accounting. The station deployments reproduce the legacy
    `core/pipeline.py` event dynamics exactly (differential-parity
    tested in ``tests/test_engine_parity.py``), so the §6.3 ladder is
    now an engine knob rather than a separate scheduler."""

    def __init__(self, policy: SchedulingPolicy, bundle,
                 tiers: Sequence[Tier],
                 cfg: Optional[EngineConfig] = None):
        cfg = cfg if cfg is not None else EngineConfig()
        overrides = policy.engine_overrides()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        dep = _DEPLOYMENT_ALIASES.get(cfg.deployment, cfg.deployment)
        if dep != cfg.deployment:
            cfg = dataclasses.replace(cfg, deployment=dep)
        assert cfg.deployment in DEPLOYMENTS, cfg.deployment
        self.policy = policy
        self.ecfg = cfg
        self.tiers = list(tiers)
        policy.prepare(bundle, self.tiers)
        # a policy may rebind a private bundle copy (knn_backend): the
        # engine must stage/ingest through the same stack it decides on
        self.bundle = getattr(policy, "bundle", None) or bundle
        self.sim: Optional[ClusterSim] = None
        self._measured_compute = 0.004  # warm estimate, updated online
        self.decisions = 0
        self.shed_count = 0             # refused at admission (overload)
        self.batches = 0
        self.expected: Optional[int] = None   # stop firing once all served
        # windowed fire-loop liveness: the loop parks once the expected
        # count is met, and a late retry/requeue must be able to revive
        # it (repro.serving.recovery re-enters through `enqueue`)
        self._fire_armed = False
        self._next_fire = 0.0
        self.compute_log: List[Tuple[int, float]] = []
        # windowed deployment: the waiting queue's SoA twin — a
        # row-index buffer parallel to `self.waiting`, so a decision
        # batch is an index slice into the stream's RequestColumns with
        # no per-request work at fire time. _wait_cols: the stream's
        # columns | None (queue empty) | False (mixed/columnless
        # stream -> legacy AoS marshaling)
        self.waiting: List[Request] = []
        self._wait_rows = np.empty(256, np.int64)
        self._wait_start = 0
        self._wait_n = 0
        self._wait_cols = None
        # station deployments: scoring queue + worker occupancy
        self.queue: List[Request] = []
        self.busy_servers = 0
        self.n_servers = (cfg.n_workers if cfg.deployment == "concurrent"
                          else 1)

    # -- wiring ---------------------------------------------------------------
    def attach(self, sim: ClusterSim):
        self.sim = sim
        self.policy.on_attach(sim)            # new sim -> new roster
        mgr = getattr(sim, "recovery", None)
        if mgr is not None:
            mgr.bind(self)       # retries requeue into us; watchdog starts
        if self.ecfg.deployment != "windowed":
            return                            # station mode drains on arrival
        self._wait_start = self._wait_n = 0
        # requests queued from before a re-attach have no rows in the
        # (just-cleared) ring, so the ring is no longer parallel to
        # `waiting` — marshal AoS until the queue drains (`_fire`'s
        # drain reset re-enables the SoA path)
        self._wait_cols = False if self.waiting else None
        self._fire_armed = False
        self._arm_fire(sim.now + self.ecfg.base_window)

    def _arm_fire(self, t: float):
        if self._fire_armed:
            return
        self._fire_armed = True
        self._next_fire = t
        self.sim.push(t, self._fire)

    def _maybe_shed(self, req: Request, t: float) -> bool:
        """Overload admission control, ahead of batch formation for
        every deployment: when the sim carries an `ElasticController`
        (`sim.overload`, armed by `repro.serving.overload.arm_elastic`)
        the policy's shed verdict runs on arrival. Shed requests never
        reach a decision batch — they leave immediately, marked
        `shed` (charged to `shed_rate`, not to failures)."""
        ctl = getattr(self.sim, "overload", None)
        if ctl is None or not self.policy.shed_verdict(req, ctl):
            return False
        if req.attempt > 0:
            # retries are never shed: the request was already admitted
            # once — admission control gates NEW work, and shedding a
            # victim of an instance failure would double-charge it
            return False
        ctl.record_shed(req, t)
        self.shed_count += 1
        self.sim.completed.append(req)
        return True

    def enqueue(self, req: Request, t: float):
        if self._maybe_shed(req, t):
            return
        if self.ecfg.deployment != "windowed":
            self._enqueue_station(req, t)
            return
        # a retry delivered after the fire loop parked (expected count
        # met before the failure) must revive it, or the request waits
        # forever; queueing ahead of attach() is still allowed
        if self.sim is not None:
            self._arm_fire(t + self.ecfg.base_window)
        self.waiting.append(req)
        cols = req.cols
        if cols is None or req.row < 0 or (
                self._wait_cols is not None
                and self._wait_cols is not cols):
            self._wait_cols = False           # fall back to AoS marshaling
            return
        if self._wait_cols is None:
            # first sight of the stream: fill the embedding column now
            # (ingest time, off the measured decision path; a no-op when
            # the workload generator pre-embedded)
            cols.ensure_embeddings(self.bundle.encoder)
            self._wait_cols = cols
        end = self._wait_start + self._wait_n
        if end >= len(self._wait_rows):
            if self._wait_start:              # compact, then maybe grow
                self._wait_rows[:self._wait_n] = \
                    self._wait_rows[self._wait_start:end].copy()
                self._wait_start = 0
                end = self._wait_n
            if end >= len(self._wait_rows):
                self._wait_rows = np.concatenate(
                    [self._wait_rows, np.empty_like(self._wait_rows)])
        self._wait_rows[end] = req.row
        self._wait_n += 1

    # -- windowed deployment --------------------------------------------------
    def _window(self) -> float:
        if not self.ecfg.adaptive:
            return self.ecfg.base_window
        tel = self.sim.tel
        alive = tel.alive
        busy = float(np.mean(np.minimum(
            tel.batch[alive] / np.maximum(tel.max_batch[alive], 1.0),
            1.0))) if alive.any() else 0.0
        return float(np.clip(self.ecfg.base_window * (0.4 + 1.8 * busy),
                             0.04, 0.30))

    def _fire(self, t: float):
        self._fire_armed = False
        batch = self.waiting
        if self.ecfg.fixed_batch:
            batch = batch[:self.ecfg.fixed_batch]
        self.waiting = self.waiting[len(batch):]
        k = len(batch)
        cols = rows = None
        if self._wait_cols not in (None, False):
            cols = self._wait_cols
            rows = self._wait_rows[self._wait_start:self._wait_start + k]
            self._wait_start += k
            self._wait_n -= k
        if not self.waiting:                  # drained: accept a new
            self._wait_start = self._wait_n = 0   # stream (or recover
            self._wait_cols = None                # from a mixed one)
        if batch:
            t0 = time.perf_counter()
            self._decide(batch, t, cols, rows)
            dt_meas = time.perf_counter() - t0
            self._measured_compute = (0.8 * self._measured_compute
                                      + 0.2 * dt_meas)
            self.compute_log.append((len(batch), dt_meas))
        if (self.expected is not None and not self.waiting
                and self.decisions + self.shed_count >= self.expected):
            return              # all dispatched/shed; enqueue re-arms us
        self._arm_fire(t + self._window())

    def _assign(self, view: BatchView):
        """Route one batch through the policy — or, when the telemetry
        watchdog has declared the whole mirror dark, through the
        recovery manager's degraded least-loaded fallback (the policy's
        inputs are all stale; dead-reckoned occupancy is the only
        trustworthy signal left)."""
        mgr = getattr(self.sim, "recovery", None)
        if mgr is not None and mgr.degraded:
            return mgr.degraded_assign(view, self.sim)
        return self.policy.assign(view, self.sim)

    def _decide(self, batch: List[Request], t: float, cols=None,
                rows: Optional[np.ndarray] = None):
        res = self._assign(BatchView(batch, cols, rows, t))
        R = len(batch)
        I = int(self.sim.tel.alive.sum())

        # dispatch + residual accounting. The bookkeeping between the
        # dispatch above and res.fetch() below runs while an async
        # policy's device program executes; eager policies fetch here
        # for free (already numpy).
        compute = (self._measured_compute if self.ecfg.charge_compute
                   else 0.0)
        stats = 0.0005 * I / 13                       # non-blocking fetch
        per_req_compute = compute / max(R, 1) + compute * 0.2
        now = t + compute + stats
        choice, l_chosen = res.fetch()
        instances = res.instances
        clamp = self.policy.budget_clamp
        mgr = getattr(self.sim, "recovery", None)
        for r_idx, req in enumerate(batch):
            inst = instances[int(choice[r_idx])]
            req.sched_compute = per_req_compute
            req.sched_stats_fetch = stats
            req.sched_batch_wait = max(t - req.arrival, 0.0)
            mt = (max_tokens_clamp(req.budget, req.prompt.len_in,
                                   inst.tier.price_in,
                                   inst.tier.price_out)
                  if clamp else None)
            inst.submit(req, now, float(l_chosen[r_idx]), mt)
            self.decisions += 1
            if mgr is not None:
                mgr.watch_dispatch(req, inst, now)
        self.batches += 1

    # -- station deployments (§6.3 ladder) ------------------------------------
    def _enqueue_station(self, req: Request, t: float):
        cap = self.ecfg.queue_capacity
        if cap is not None and len(self.queue) >= cap:
            req.failed = True
            req.finish_time = t   # terminal-state invariant: failures
            self.sim.completed.append(req)   # carry a terminal timestamp
            return
        self.queue.append(req)
        self._drain(t)

    def _service_time(self, n: int) -> float:
        if self.ecfg.deployment == "microbatch":
            return self.ecfg.microbatch_time
        return self.policy.serial_scoring_s

    def _drain(self, t: float):
        while self.queue and self.busy_servers < self.n_servers:
            dep = self.ecfg.deployment
            if dep == "microbatch":
                n = min(len(self.queue), self.ecfg.microbatch_size)
            elif dep == "concurrent":
                # micro-batched off the scheduling loop: each worker
                # takes a small group; scoring latency ~ serial per
                # forward but workers overlap
                n = min(len(self.queue),
                        max(1, len(self.queue) // self.n_servers))
                n = min(n, 8)
            else:
                n = 1
            group = self.queue[:n]
            self.queue = self.queue[n:]
            self.busy_servers += 1
            dt = self._service_time(n)
            self.sim.push(t + dt, lambda tt, g=group: self._scored(g, tt))

    def _scored(self, group: List[Request], t: float):
        self.busy_servers -= 1
        t0 = time.perf_counter()
        res = self._assign(BatchView(group, t=t))
        choice, l_chosen = res.fetch()
        instances = res.instances
        clamp = self.policy.budget_clamp
        mgr = getattr(self.sim, "recovery", None)
        for j, req in enumerate(group):
            req.router_queue_wait = t - req.arrival
            inst = instances[int(choice[j])]
            mt = (max_tokens_clamp(req.budget, req.prompt.len_in,
                                   inst.tier.price_in,
                                   inst.tier.price_out)
                  if clamp else None)
            inst.submit(req, t, float(l_chosen[j]), mt)
            self.decisions += 1
            if mgr is not None:
                mgr.watch_dispatch(req, inst, t)
        self.batches += 1
        self.compute_log.append((len(group), time.perf_counter() - t0))
        self._drain(t)

    # -- checkpoint/restore (windowed deployment) -----------------------------
    # The controller's durable state — everything a fresh scheduler
    # process needs to resume a trace exactly where a crashed one
    # stopped — is tiny and flat: the waiting queue (rids; request
    # payloads are replayable from the trace), the admission counters,
    # the fire-loop clock, and the recovery manager's pending retry and
    # hedge timers. `repro.distributed.checkpoint.CheckpointManager`
    # persists it atomically; `resume` rebuilds a (possibly brand-new)
    # engine onto the surviving sim. Checkpoints must be coordinated
    # with the crash point (save at the instant the controller dies, as
    # a write-ahead log would guarantee): state that changed after the
    # snapshot is rolled back on the controller but not on the workers.

    def checkpoint_tree(self) -> dict:
        """The controller's durable state as a flat numpy tree (the
        shape `_checkpoint_template` describes)."""
        mgr = (getattr(self.sim, "recovery", None)
               if self.sim is not None else None)
        tree = self._checkpoint_template()
        tree["waiting_rids"] = np.array([r.rid for r in self.waiting],
                                        np.int64)
        tree["counters"] = np.array(
            [self.decisions, self.shed_count, self.batches,
             -1 if self.expected is None else self.expected], np.int64)
        tree["clock"] = np.array(
            [self._next_fire if self._fire_armed else -1.0,
             self._measured_compute], np.float64)
        if mgr is not None:
            tree.update(mgr.pending_state())
        return tree

    @staticmethod
    def _checkpoint_template() -> dict:
        """A dtype-correct skeleton of `checkpoint_tree` — what
        `CheckpointManager.restore` needs as its `tree_like` (restore
        takes shapes from the stored arrays, dtypes from this)."""
        return {
            "waiting_rids": np.zeros(0, np.int64),
            "counters": np.zeros(4, np.int64),
            "clock": np.zeros(2, np.float64),
            "retry_rids": np.zeros(0, np.int64),
            "retry_due": np.zeros(0, np.float64),
            "watch_keys": np.zeros((0, 3), np.int64),
            "watch_due": np.zeros(0, np.float64),
            "watch_slot": np.zeros(0, np.int64),
            "recovery_counters": np.zeros(7, np.int64),
        }

    def save_checkpoint(self, ckpt, step: int):
        """Persist the controller state via a
        `repro.distributed.checkpoint.CheckpointManager`."""
        ckpt.save(step, self.checkpoint_tree(),
                  metadata={"now": self.sim.now if self.sim else 0.0})

    def resume(self, sim: ClusterSim, tree: dict,
               requests: Sequence[Request]) -> "ServingEngine":
        """Rebuild this (typically freshly constructed) engine from a
        checkpoint onto a sim whose controller died
        (`repro.serving.recovery.simulate_controller_crash`): worker
        decode chains and future arrivals survived; the waiting queue,
        counters, pending retries/hedge timers and the fire loop come
        back from the tree. Windowed deployment only. `requests` is the
        trace the checkpointed rids index into."""
        assert self.ecfg.deployment == "windowed", self.ecfg.deployment
        by_rid = {r.rid: r for r in requests}
        c = tree["counters"]
        self.decisions, self.shed_count, self.batches = (
            int(c[0]), int(c[1]), int(c[2]))
        self.expected = None if int(c[3]) < 0 else int(c[3])
        self._measured_compute = float(tree["clock"][1])
        self.waiting = [by_rid[int(rid)] for rid in tree["waiting_rids"]]
        self.sim = sim
        self.policy.on_attach(sim)
        mgr = getattr(sim, "recovery", None)
        if mgr is not None:
            mgr.bind(self)
            mgr.restore_pending(tree, by_rid)
        self._wait_start = self._wait_n = 0
        self._wait_cols = False if self.waiting else None
        self._fire_armed = False
        next_fire = float(tree["clock"][0])
        if next_fire >= 0.0:
            self._arm_fire(max(next_fire, sim.now))
        elif self.waiting:
            self._arm_fire(sim.now + self.ecfg.base_window)
        return self
