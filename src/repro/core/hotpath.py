"""Single-dispatch fused hot path: the whole per-batch RouteBalance
decision as ONE jitted device program (§4.2/§6.3).

After PR 1 the hot path was still four device dispatches with host round
trips between them: encoder-jit → numpy → KNN-jit → numpy → a per-tier
Python loop over numpy GBM heads → decide-jit, re-marshalling instance
state from Python dict telemetry every `_fire`. This module fuses
encode → KNN top-k → per-tier packed-GBM TPOT heads
(`gbm.predict_packed_gathered`) → Eq. 2 admission → LPT-ordered greedy
scan into a single traced program, selectable via
``RBConfig(decision_backend="fused")``:

  * every constant — encoder params, the KNN index, the per-tier TPOT
    boosters stacked into one packed ensemble (`gbm.pack_ensemble`), the
    per-instance static vectors (model column, tier row, prices, max
    batch, nominal TPOT) — is closed over once and lives on device;
  * the dead-reckoned instance state (d, b, free, ctx) is
    device-resident across batches: the state buffers are donated into
    the jitted step and the post-scan state comes back out. Whenever
    fresh telemetry exists — ``TelemetryArrays.version`` moved, i.e. ANY
    instance iterated since the last batch — the whole state refreshes
    from the array view (matching the staged backends' reseed-per-batch
    semantics); only when nothing on the cluster moved at all is the
    dead-reckoned state carried forward, where the staged paths would
    re-read the identical stale snapshot minus the in-flight updates.
    Shape-padding rows apply no dead-reckoning update, so the carried
    state never accumulates phantom load;
  * batch size R, padded token length L and roster size I are bucketed
    to powers of two (`bucket_pow2`) so the program compiles
    O(log R · log L · log I) shape variants — short-prompt batches run
    the encoder at L=8/16/… instead of always paying max_len, and the
    scenario subsystem's rosters (13 … 128+ instances,
    `repro.serving.scenarios`) share one compiled scan geometry per
    pow2 bucket. Roster pad columns are permanently dead: never
    admitted, never scored, never dead-reckoned;
  * instance death is an ``alive`` mask over the full roster (scores of
    dead instances pin to -inf) — no recompile after a failure.

Parity: the masked-pooling encoder and the top-k feed are bitwise stable
under both R- and L-padding, and the packed GBM accumulates per tree in
the numpy rounding order, so the fused program makes exactly the staged
backends' assignments at fixed seeds (asserted across every mode arm in
``tests/test_hotpath.py``; the usual float32 argmax-tie caveat applies).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.estimators.embedding import pad_tokens
from repro.estimators.gbm import pack_ensemble, predict_packed_gathered
from repro.estimators.knn import topk_soft_lookup

from .budget import admission_math, cost_matrix
from .decision_jax import _greedy_scan, bucket_pow2


class FusedHotPath:
    """Compiled once per (bundle, roster signature, decision config);
    one call = one scheduler batch = one device dispatch."""

    @staticmethod
    def for_bundle(bundle, instances, cfg) -> "FusedHotPath":
        """Cached constructor: repeated cells over the same bundle with
        an equivalent roster and config (e.g. a sweep of run_cell calls)
        reuse one compiled program instead of paying a fresh XLA compile
        per sim. The cache lives on the bundle, so its lifetime — and
        the validity of the closed-over index/head arrays — tracks the
        bundle's. Carried state is reset on every cache hit."""
        roster = tuple((i.tier.name, i.model_idx, i.tier.max_batch,
                        i.tier.price_in, i.tier.price_out)
                       for i in instances)
        key = (roster, cfg.latency_mode, bool(cfg.lpt),
               bool(cfg.budget_filter), bool(cfg.learned_tpot),
               tuple(float(w) for w in cfg.weights))
        cache = bundle.__dict__.setdefault("_fused_cache", {})
        runner = cache.get(key)
        if runner is None:
            runner = cache[key] = FusedHotPath(bundle, instances, cfg)
        else:
            runner.reset()
        return runner

    def __init__(self, bundle, instances, cfg):
        enc = bundle.encoder
        knn = bundle.knn
        self.max_len = enc.max_len
        self._encode = enc._encode_impl      # pure fn over device params
        self._k = knn.k
        self._eps = knn.eps
        self._x = jnp.asarray(knn._x)
        self._xsq = jnp.asarray(knn._sq)
        self._qual = jnp.asarray(knn._quality)
        self._leng = jnp.asarray(knn._length)

        tier_names: List[str] = []
        for inst in instances:
            if inst.tier.name not in tier_names:
                tier_names.append(inst.tier.name)
        heads = [bundle.heads[t] for t in tier_names]
        # roster size is bucketed to a power of two, like R and L: pad
        # columns are permanently dead (never admitted, never scored),
        # so rosters of 65..128 instances share one compiled I=128 shape
        # and the scan geometry stays uniform across scenario sweeps
        I = len(instances)
        self._n_real = I
        self._Ipad = bucket_pow2(I) - I
        tier_of_i = self._pad_i(np.array(
            [tier_names.index(i.tier.name) for i in instances],
            np.int32))
        self._tier_of_i = jnp.asarray(tier_of_i)
        self._m_of_i = jnp.asarray(self._pad_i(
            np.array([i.model_idx for i in instances], np.int32)))
        self._maxb = jnp.asarray(self._pad_i(
            np.array([i.tier.max_batch for i in instances], np.float32),
            fill=1.0))
        self._price_in = jnp.asarray(self._pad_i(
            np.array([i.tier.price_in for i in instances], np.float32)))
        self._price_out = jnp.asarray(self._pad_i(
            np.array([i.tier.price_out for i in instances], np.float32)))
        self._nominal = jnp.asarray(
            np.array([h.nominal_tpot for h in heads],
                     np.float32)[tier_of_i])

        self._mode = cfg.latency_mode
        self._lpt = bool(cfg.lpt)
        self._budget_filter = bool(cfg.budget_filter)
        self._weights = tuple(float(w) for w in cfg.weights)
        self._use_gbm = (cfg.latency_mode != "static_prior"
                         and cfg.learned_tpot)
        if self._use_gbm:
            # partial fits would silently diverge from the staged
            # per-tier learned/nominal fallback — refuse instead
            assert all(h.model is not None for h in heads), \
                "fused backend needs every TPOT head fitted (or " \
                "learned_tpot=False): unfitted " + \
                str([t for t, h in zip(tier_names, heads)
                     if h.model is None])
            stacked = pack_ensemble([h.model for h in heads])
            self._gbm = {k: jnp.asarray(v) if isinstance(v, np.ndarray)
                         else v for k, v in stacked.items()}
        # d/b/free are donated in and returned post-scan; ctx and alive
        # are read-only (args: tokens 0, mask 1, row_valid 2, budgets 3,
        # len_in 4, d 5, b 6, free 7, ctx 8, alive 9)
        self._step = jax.jit(self._step_impl, donate_argnums=(5, 6, 7))
        self._state: Optional[Tuple] = None   # (d, b, free) device arrays
        self._ctx_dev = None
        self._alive_dev = None
        self._seen_version = -1

    def _pad_i(self, x: np.ndarray, fill=0) -> np.ndarray:
        """Pad an (I,) per-instance vector out to the pow2 roster
        bucket."""
        if self._Ipad == 0:
            return x
        return np.concatenate(
            [x, np.full(self._Ipad, fill, x.dtype)])

    # -- traced body --------------------------------------------------------
    def _step_impl(self, tokens, mask, row_valid, budgets, len_in,
                   d, b, free, ctx, alive):
        # 1. prompt-intrinsic estimation: encoder + KNN top-k, all models
        emb = self._encode(tokens, mask)
        qual, leng = topk_soft_lookup(emb, self._x, self._xsq,
                                      self._qual, self._leng,
                                      self._k, self._eps)    # (R, M)
        q_inst = qual[:, self._m_of_i]                       # (R, I)
        l_inst = leng[:, self._m_of_i]
        # pad rows order strictly after every real request (cf. decide())
        pred_len_max = jnp.where(row_valid, leng.max(axis=1), -1e30)

        # 2. state-dependent TPOT: all per-tier heads in one packed gather
        b_eff = jnp.maximum(b, 1.0)
        ctx_eff = jnp.maximum(ctx, 64.0)
        if self._use_gbm:
            feats = jnp.stack([b_eff, d, ctx_eff, b_eff * ctx_eff],
                              axis=1).astype(jnp.float32)
            tpot = jnp.maximum(
                predict_packed_gathered(self._gbm, self._tier_of_i, feats),
                1e-4)
        else:
            tpot = self._nominal

        # 3. Eq. 2 admission over the alive roster
        budgets = budgets.astype(jnp.float32)
        len_in = len_in.astype(jnp.float32)
        if self._budget_filter:
            allowed, c_hat = admission_math(
                budgets, len_in, l_inst, self._price_in, self._price_out,
                jnp, valid=alive)
        else:
            c_hat = cost_matrix(len_in, l_inst, self._price_in,
                                self._price_out, jnp)
            allowed = jnp.broadcast_to(alive[None, :], c_hat.shape)

        # 4. LPT order + dead-reckoned greedy scan (Eq. 1 per request)
        if self._lpt:
            order = jnp.argsort(-pred_len_max, stable=True)
        else:
            order = jnp.arange(q_inst.shape[0])
        choice, est_T, (d1, b1, f1) = _greedy_scan(
            order, q_inst, c_hat, l_inst, tpot, self._nominal,
            d, b_eff, free, self._maxb, self._weights, allowed,
            self._mode, row_valid=row_valid)
        l_chosen = jnp.take_along_axis(l_inst, choice[:, None],
                                       axis=1)[:, 0]
        return choice, est_T, l_chosen, d1, b1, f1

    # -- host side ----------------------------------------------------------
    def reset(self):
        """Forget carried device state (new sim / fresh telemetry)."""
        self._state = None
        self._ctx_dev = None
        self._alive_dev = None
        self._seen_version = -1

    def _sync_state(self, tel):
        """Refresh the device state from the array-telemetry view when
        any instance has iterated since the last batch; otherwise carry
        the dead-reckoned device buffers forward."""
        if self._state is None or tel.version != self._seen_version:
            self._seen_version = tel.version
            self._state = (
                jnp.asarray(self._pad_i(np.asarray(tel.pending,
                                                   np.float32))),
                jnp.asarray(self._pad_i(np.asarray(tel.batch,
                                                   np.float32))),
                jnp.asarray(self._pad_i(np.asarray(tel.free,
                                                   np.float32))))
            self._ctx_dev = jnp.asarray(
                self._pad_i(np.asarray(tel.ctx, np.float32)))
            # roster-bucket pad columns stay permanently dead
            self._alive_dev = jnp.asarray(
                self._pad_i(np.asarray(tel.alive), fill=False))
        return self._state

    def decide(self, batch, tel) -> Tuple[np.ndarray, np.ndarray]:
        """batch: requests; tel: ClusterSim.tel. Returns (choice (R,)
        int64 indexing the FULL instance roster, l_chosen (R,))."""
        R = len(batch)
        lens = np.minimum([len(r.prompt.tokens) for r in batch],
                          self.max_len)
        Lb = min(bucket_pow2(int(lens.max())), self.max_len)
        Rb = bucket_pow2(R)
        toks = np.zeros((Rb, Lb), np.int32)
        toks[:R] = pad_tokens([r.prompt.tokens for r in batch], Lb)
        lens_p = np.zeros(Rb, np.int64)
        lens_p[:R] = lens
        mask = np.arange(Lb)[None, :] < lens_p[:, None]
        row_valid = np.arange(Rb) < R
        budgets = np.full(Rb, np.nan, np.float32)
        budgets[:R] = [np.nan if r.budget is None else r.budget
                       for r in batch]
        len_in = np.zeros(Rb, np.float32)
        len_in[:R] = [r.prompt.len_in for r in batch]

        d, b, free = self._sync_state(tel)
        choice, est_T, l_chosen, d1, b1, f1 = self._step(
            toks, mask, row_valid, budgets, len_in, d, b, free,
            self._ctx_dev, self._alive_dev)
        self._state = (d1, b1, f1)          # dead-reckoned carry
        return (np.asarray(choice[:R], np.int64),
                np.asarray(l_chosen[:R], np.float64))
