"""Single-dispatch fused hot path: the whole per-batch RouteBalance
decision as ONE jitted device program (§4.2/§6.3), fed by the
zero-allocation SoA ingest layer.

After PR 2/3 the fused program was already one dispatch per batch, but
the steady-state host path around it still did per-request Python work
and fresh allocations every batch: four list comprehensions to marshal
tokens/budgets/lengths, a fresh (Rb, Lb) token matrix + mask, a full
host→device re-upload of the (I,)×5 telemetry state whenever
``TelemetryArrays.version`` moved (i.e. on every batch under real
traffic, so the dead-reckoned carry branch was dead code), a per-batch
encoder forward over the padded token matrix, and a blocking
``np.asarray`` on the result. This module removes all of it:

  * **SoA ingest** — token ids, lengths, ``len_in`` and budgets live in
    ``repro.serving.request.RequestColumns`` built once at
    workload-generation time, and the prompt embeddings are memoized
    there too (the masked-pooling encoder is bitwise stable under
    batch/length padding, so embedding a prompt once at ingest equals
    the per-batch encode bit for bit). A decision batch is a row-index
    slice into those columns;
  * **preallocated staging** — per-pow2(R)-bucket host buffers, double
    buffered so writing batch N+1 never aliases batch N's in-flight
    transfer; staging is a handful of vectorized ``np.take`` gathers
    with zero Python-level per-request work and zero steady-state
    allocation (the token/mask staging of earlier PRs disappears
    entirely: tokens stay at ingest, the program starts from
    embeddings);
  * **incremental device telemetry** — the (d, b, free, ctx) state is a
    device-resident mirror of ``TelemetryArrays``; each batch scatters
    only the rows written since the last sync (``tel.dirty_rows``)
    inside the jitted step, with a full reseed only on roster-shape
    events (fail/recover, tracked by ``tel.roster_version``) or when
    most of the roster is dirty. The refreshed mirror is bitwise the
    staged backends' reseed-per-batch host read — untouched rows'
    telemetry has not moved — so carry-forward is now the common case
    AND exact-parity-safe (the PR-2 semantics, which carried post-scan
    dead-reckoned state, only matched staged when nothing on the
    cluster moved; that branch almost never fired and silently diverged
    when it did not);
  * **async dispatch** — ``decide_cols`` returns a ``LazyDecision``
    whose host fetch is deferred to the scheduler's dispatch point, so
    residual accounting and next-batch staging overlap device
    execution. The carried mirror chains batch-to-batch on device
    through donated buffers without a host round trip.

Batch size R and roster size I are still bucketed to powers of two
(`bucket_pow2`) for O(log R · log I) compile variants; roster pad
columns stay permanently dead and instance death is an ``alive`` mask
(no recompile after a failure). Eq. 1 scores are epsilon-quantized in
the shared scoring math (`repro.core.scoring`), so the fused program
makes exactly the staged backends' assignments — numpy included — on
randomized worlds (``tests/test_soak.py``).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.estimators.gbm import pack_ensemble, predict_packed_gathered
from repro.estimators.knn import topk_soft_lookup
from repro.serving.affinity import SIG_WIDTH, SKETCH_SLOTS, hit_fraction

from .budget import admission_math, cost_matrix
from .decision_jax import _greedy_scan, bucket_pow2, sharded_greedy_scan


def _new_stats() -> Dict:
    return {"calls": 0, "host_s": 0.0, "stage_s": 0.0, "dispatch_s": 0.0,
            "device_s": 0.0, "sync_s": 0.0, "full_reseed": 0,
            "roster_reseed": 0,        # full reseeds caused by roster churn
            "delta_sync": 0, "delta_rows": 0, "carry": 0}


class LazyDecision:
    """An in-flight fused decision: device arrays whose host transfer is
    deferred until the caller actually needs the values (the dispatch
    point). `fetch()` blocks on the device program, slices off the
    shape-padding rows and returns numpy — idempotently, so diagnostics
    may re-fetch. This is the fused policy's `AssignmentResult` payload
    (`repro.core.engine`): the engine's windowed dispatch overlaps its
    host bookkeeping with the device program and fetches last."""

    __slots__ = ("_choice", "_l", "_R", "_stats", "_out")

    def __init__(self, choice, l_chosen, R: int, stats: Dict):
        self._choice = choice
        self._l = l_chosen
        self._R = R
        self._stats = stats
        self._out: Optional[Tuple[np.ndarray, np.ndarray]] = None

    def fetch(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._out is None:
            t0 = time.perf_counter()
            jax.block_until_ready((self._choice, self._l))
            t1 = time.perf_counter()
            self._out = (np.asarray(self._choice[:self._R], np.int64),
                         np.asarray(self._l[:self._R], np.float64))
            t2 = time.perf_counter()
            self._stats["device_s"] += t1 - t0
            self._stats["sync_s"] += t2 - t1
        return self._out


class FusedHotPath:
    """Compiled once per (bundle, roster signature, decision config);
    one call = one scheduler batch = one device dispatch."""

    @staticmethod
    def for_bundle(bundle, instances, cfg) -> "FusedHotPath":
        """Cached constructor: repeated cells over the same bundle with
        an equivalent roster and config (e.g. a sweep of run_cell calls)
        reuse one compiled program instead of paying a fresh XLA compile
        per sim. The cache lives on the bundle, so its lifetime — and
        the validity of the closed-over index/head arrays — tracks the
        bundle's. Carried state is reset on every cache hit."""
        roster = tuple((i.tier.name, i.model_idx, i.tier.max_batch,
                        i.tier.price_in, i.tier.price_out)
                       for i in instances)
        backend = ("megakernel"
                   if getattr(cfg, "decision_backend", "fused")
                   == "megakernel" else "fused")
        key = (roster, backend, cfg.latency_mode, bool(cfg.lpt),
               bool(cfg.budget_filter), bool(cfg.learned_tpot),
               tuple(float(w) for w in cfg.weights),
               float(getattr(cfg, "affinity_weight", 0.0)),
               # hierarchical scheduling: the cell-sharded scan compiles
               # a different program, and per-cell engines (cell_tag)
               # each need their own carried telemetry mirror even when
               # their rosters happen to be signature-identical
               int(getattr(cfg, "shard_cells", 0) or 0),
               getattr(cfg, "cell_tag", None))
        cache = bundle.__dict__.setdefault("_fused_cache", {})
        runner = cache.get(key)
        if runner is None:
            runner = cache[key] = FusedHotPath(bundle, instances, cfg)
        else:
            runner.reset()
        return runner

    def __init__(self, bundle, instances, cfg):
        self._encoder = bundle.encoder      # ingest-time embedding only
        # "megakernel" swaps the traced stage pipeline for the single
        # Pallas dispatch (repro.kernels.decision_megakernel); every
        # other backend value (the default "fused" included) keeps the
        # staged-XLA body. All host machinery — staging, delta sync,
        # LazyDecision, pow2 bucketing — is shared, so the two traced
        # bodies differ ONLY inside _step_impl.
        self._backend = ("megakernel"
                         if getattr(cfg, "decision_backend", "fused")
                         == "megakernel" else "fused")
        if self._backend == "megakernel":
            from repro.kernels.ops import INTERPRET
            self._interpret = INTERPRET
        knn = bundle.knn
        self._E = bundle.encoder.dim
        self._k = knn.k
        self._eps = knn.eps
        self._x = jnp.asarray(knn._x)
        self._xsq = jnp.asarray(knn._sq)
        self._qual = jnp.asarray(knn._quality)
        self._leng = jnp.asarray(knn._length)

        tier_names: List[str] = []
        for inst in instances:
            if inst.tier.name not in tier_names:
                tier_names.append(inst.tier.name)
        heads = [bundle.heads[t] for t in tier_names]
        # roster size is bucketed to a power of two, like R: pad columns
        # are permanently dead (never admitted, never scored), so
        # rosters of 65..128 instances share one compiled I=128 shape
        # and the scan geometry stays uniform across scenario sweeps
        I = len(instances)
        self._n_real = I
        self._Itot = bucket_pow2(I)
        self._Ipad = self._Itot - I
        tier_of_i = self._pad_i(np.array(
            [tier_names.index(i.tier.name) for i in instances],
            np.int32))
        self._tier_of_i = jnp.asarray(tier_of_i)
        self._m_of_i = jnp.asarray(self._pad_i(
            np.array([i.model_idx for i in instances], np.int32)))
        self._maxb = jnp.asarray(self._pad_i(
            np.array([i.tier.max_batch for i in instances], np.float32),
            fill=1.0))
        self._price_in = jnp.asarray(self._pad_i(
            np.array([i.tier.price_in for i in instances], np.float32)))
        self._price_out = jnp.asarray(self._pad_i(
            np.array([i.tier.price_out for i in instances], np.float32)))
        self._nominal = jnp.asarray(
            np.array([h.nominal_tpot for h in heads],
                     np.float32)[tier_of_i])

        self._mode = cfg.latency_mode
        self._lpt = bool(cfg.lpt)
        self._budget_filter = bool(cfg.budget_filter)
        self._weights = tuple(float(w) for w in cfg.weights)
        # cell-sharded scan (hierarchical scheduling): the pow2 column
        # axis splits into shard_cells contiguous blocks, combined with
        # exact max/argmax reductions — bitwise the single-controller
        # scan (see decision_jax.sharded_greedy_scan). The mesh comes
        # from the active shardctx when the launcher pinned one with a
        # matching "cell" axis, else launch.mesh.make_cell_mesh (which
        # degrades to None -> single-program emulation on hosts without
        # the devices).
        self._shard_cells = int(getattr(cfg, "shard_cells", 0) or 0)
        self._cell_mesh = None
        if self._shard_cells > 1:
            assert self._backend == "fused", \
                "shard_cells requires the fused backend (the megakernel" \
                " scan is a single monolithic dispatch)"
            assert self._Itot % self._shard_cells == 0, \
                (self._Itot, self._shard_cells)
            from repro.distributed.shardctx import current as _shardctx
            mesh, _ = _shardctx()
            if (mesh is not None and "cell" in mesh.axis_names
                    and mesh.shape["cell"] == self._shard_cells):
                self._cell_mesh = mesh
            else:
                from repro.launch.mesh import make_cell_mesh
                self._cell_mesh = make_cell_mesh(self._shard_cells)
        # prefix-affinity term: compiled in only when the weight is
        # nonzero — the disabled program is the pre-affinity program
        # verbatim (the dummy sig args below are dead inputs XLA drops),
        # so turning the feature off cannot perturb existing parity or
        # decide-time (perf-guarded in benchmarks/perf_guard.py)
        self._w_aff = float(getattr(cfg, "affinity_weight", 0.0))
        if self._w_aff > 0.0:
            # per-call upload of the instance sig plane: (Itot, 64)
            # int32 ≈ 32 KB at I=128 — double buffered like the other
            # staged inputs so a host write never aliases the previous
            # batch's in-flight transfer. Signatures ride their own
            # `tel.prefix_version` counter (sketch writes must not look
            # like telemetry heartbeats), so the plane is re-staged
            # every call rather than through the delta machinery.
            self._pstage = [
                np.zeros((self._Itot, SKETCH_SLOTS), np.int32),
                np.zeros((self._Itot, SKETCH_SLOTS), np.int32)]
            self._pflip = 0
        self._dummy_psig = np.zeros((1, 1), np.int32)
        self._dummy_plane = np.zeros((1, 1), np.int32)
        self._use_gbm = (cfg.latency_mode != "static_prior"
                         and cfg.learned_tpot)
        if self._use_gbm:
            # partial fits would silently diverge from the staged
            # per-tier learned/nominal fallback — refuse instead
            assert all(h.model is not None for h in heads), \
                "fused backend needs every TPOT head fitted (or " \
                "learned_tpot=False): unfitted " + \
                str([t for t, h in zip(tier_names, heads)
                     if h.model is None])
            stacked = pack_ensemble([h.model for h in heads])
            self._gbm = {k: jnp.asarray(v) if isinstance(v, np.ndarray)
                         else v for k, v in stacked.items()}
        # the telemetry mirror (d, b, free, ctx) is donated in and the
        # refreshed (pre-scan) mirror comes back out, so it chains
        # batch-to-batch on device; alive is read-only (re-uploaded on
        # roster events). args: emb 0, row_valid 1, budgets 2, len_in 3,
        # d 4, b 5, free 6, ctx 7, alive 8, delta idx/d/b/free/ctx 9-13,
        # psig 14, sig_plane 15 (appended so donate indices stay fixed)
        self._step = jax.jit(self._step_impl, donate_argnums=(4, 5, 6, 7))
        # multi-window megakernel dispatch: same signature with a
        # leading K axis on the per-window args; compiled per
        # (pow2 K, pow2 R) pair, so variants stay O(log K · log R)
        self._step_multi = (
            jax.jit(self._step_multi_impl, donate_argnums=(4, 5, 6, 7))
            if self._backend == "megakernel" else None)
        self._mstage: Dict[Tuple[int, int], list] = {}
        self._mflip: Dict[Tuple[int, int], int] = {}
        # the delta lane count is FIXED at one pow2 capacity (≥ the
        # mostly-dirty threshold where _sync_state reseeds instead), so
        # full-reseed, carry and every delta sync share one compiled
        # shape per R bucket — K never adds a compile dimension, and
        # warming the R buckets warms everything. Unused lanes carry
        # out-of-range indices and drop in the scatter.
        self._Kcap = bucket_pow2(max(8, (self._n_real + 1) // 2))
        self._empty_delta = (
            np.full(self._Kcap, self._Itot, np.int32),
            np.zeros(self._Kcap, np.float32),
            np.zeros(self._Kcap, np.float32),
            np.zeros(self._Kcap, np.float32),
            np.zeros(self._Kcap, np.float32))
        self._stage: Dict[int, list] = {}    # Rb -> [bufset, bufset]
        self._sflip: Dict[int, int] = {}
        self._dstage: Optional[list] = None  # [bufset, bufset]
        self._dflip = 0
        self.reset()                         # also installs fresh stats

    def _pad_i(self, x: np.ndarray, fill=0) -> np.ndarray:
        """Pad an (I,) per-instance vector out to the pow2 roster
        bucket."""
        if self._Ipad == 0:
            return x
        return np.concatenate(
            [x, np.full(self._Ipad, fill, x.dtype)])

    # -- traced bodies ------------------------------------------------------
    def _mega_stages(self, emb, row_valid, budgets, len_in,
                     d, b, free, ctx, alive, psig, sig_plane):
        """Stages 1–4 as the single Pallas megakernel dispatch. The
        per-window args carry a leading K axis (K=1 for the plain
        step); telemetry mirror + estimator constants are shared
        blocks. Returns (choice, est_T, l_chosen, d1, b1, f1) with the
        K axis intact."""
        from repro.kernels.decision_megakernel import (decision_call,
                                                       dummy_gbm)
        if self._use_gbm:
            gf, gt, gl, gb = (self._gbm["feature"],
                              self._gbm["threshold"],
                              self._gbm["leaf"], self._gbm["base"])
            depth, lr = self._gbm["depth"], self._gbm["lr"]
        else:
            gf, gt, gl, gb = dummy_gbm()
            depth, lr = 1, 0.1
        return decision_call(
            emb, row_valid, budgets, len_in, psig,
            d, b, free, ctx, alive,
            self._x, self._xsq, self._qual, self._leng,
            self._m_of_i, self._tier_of_i, self._maxb, self._price_in,
            self._price_out, self._nominal, sig_plane, gf, gt, gl, gb,
            k=self._k, eps=self._eps, weights=self._weights,
            latency_mode=self._mode, lpt=self._lpt,
            budget_filter=self._budget_filter, w_aff=self._w_aff,
            use_gbm=self._use_gbm, depth=depth, lr=lr,
            interpret=self._interpret)

    def _step_impl(self, emb, row_valid, budgets, len_in,
                   d, b, free, ctx, alive,
                   didx, dd, db, dfree, dctx, psig, sig_plane):
        # 0. incremental telemetry: scatter the dirty rows into the
        # donated device mirror (pad lanes carry out-of-range indices
        # and drop). The refreshed mirror is bitwise a full host
        # re-read — untouched rows' telemetry has not moved since they
        # were last synced — so this arm preserves the staged backends'
        # reseed-per-batch semantics exactly.
        d = d.at[didx].set(dd, mode="drop")
        b = b.at[didx].set(db, mode="drop")
        free = free.at[didx].set(dfree, mode="drop")
        ctx = ctx.at[didx].set(dctx, mode="drop")

        if self._backend == "megakernel":
            # stages 1–4 fused into one Pallas dispatch (K=1 window);
            # the refreshed pre-scan mirror still carries forward
            # exactly as below
            choice, est_T, l_chosen, d1, b1, f1 = (
                o[0] for o in self._mega_stages(
                    emb[None], row_valid[None], budgets[None],
                    len_in[None], d, b, free, ctx, alive,
                    psig[None], sig_plane))
            return (choice, est_T, l_chosen, d, b, free, ctx,
                    d1, b1, f1)

        # 1. prompt-intrinsic estimation: KNN top-k over the ingest
        # embedding column, all models at once
        qual, leng = topk_soft_lookup(emb, self._x, self._xsq,
                                      self._qual, self._leng,
                                      self._k, self._eps)    # (R, M)
        q_inst = qual[:, self._m_of_i]                       # (R, I)
        l_inst = leng[:, self._m_of_i]
        # pad rows order strictly after every real request
        pred_len_max = jnp.where(row_valid, leng.max(axis=1), -1e30)

        # 2. state-dependent TPOT: all per-tier heads in one packed gather
        b_eff = jnp.maximum(b, 1.0)
        ctx_eff = jnp.maximum(ctx, 64.0)
        if self._use_gbm:
            feats = jnp.stack([b_eff, d, ctx_eff, b_eff * ctx_eff],
                              axis=1).astype(jnp.float32)
            tpot = jnp.maximum(
                predict_packed_gathered(self._gbm, self._tier_of_i, feats),
                1e-4)
        else:
            tpot = self._nominal

        # 3. Eq. 2 admission over the alive roster
        budgets = budgets.astype(jnp.float32)
        len_in = len_in.astype(jnp.float32)
        if self._budget_filter:
            allowed, c_hat = admission_math(
                budgets, len_in, l_inst, self._price_in, self._price_out,
                jnp, valid=alive)
        else:
            c_hat = cost_matrix(len_in, l_inst, self._price_in,
                                self._price_out, jnp)
            allowed = jnp.broadcast_to(alive[None, :], c_hat.shape)

        # 3b. prefix-affinity: matched-fraction hit against the mirrored
        # per-instance sig planes, zeroed for dead/quarantined columns
        # (alive is the same mask Eq. 2 admission uses, so a quarantined
        # instance can neither be picked NOR attract affinity credit).
        # Python-level branch: w_aff == 0 compiles the term out and the
        # dummy psig/sig_plane inputs are dead.
        if self._w_aff > 0.0:
            hit = hit_fraction(psig, len_in, sig_plane, jnp)
            hit = jnp.where(alive[None, :], hit, jnp.float32(0.0))
            aff = jnp.float32(self._w_aff) * hit
        else:
            aff = None

        # 4. LPT order + dead-reckoned greedy scan (Eq. 1 per request)
        if self._lpt:
            order = jnp.argsort(-pred_len_max, stable=True)
        else:
            order = jnp.arange(q_inst.shape[0])
        choice, est_T, (d1, b1, f1) = self._scan(
            order, q_inst, c_hat, l_inst, tpot, d, b_eff, free,
            allowed, row_valid, aff)
        l_chosen = jnp.take_along_axis(l_inst, choice[:, None],
                                       axis=1)[:, 0]
        # the refreshed pre-scan mirror (d, b, free, ctx) is the carried
        # state; (d1, b1, f1) is the post-scan dead-reckoned view, kept
        # for diagnostics/invariant checks only — the next batch reseeds
        # from telemetry just like the staged backends
        return (choice, est_T, l_chosen, d, b, free, ctx, d1, b1, f1)

    def _scan(self, order, q_inst, c_hat, l_inst, tpot, d, b_eff, free,
              allowed, row_valid, aff):
        """Stage-4 greedy scan, factored so the scan strategy is the
        one seam hierarchical runners interpose on: the
        single-controller program traces `_greedy_scan`; with
        ``shard_cells > 1`` the bitwise-identical cell-sharded
        decomposition runs instead (single-program emulation or
        shard_map over the cell mesh)."""
        if self._shard_cells > 1:
            return sharded_greedy_scan(
                order, q_inst, c_hat, l_inst, tpot, self._nominal,
                d, b_eff, free, self._maxb, self._weights, allowed,
                self._mode, row_valid=row_valid, affinity=aff,
                n_cells=self._shard_cells, mesh=self._cell_mesh)
        return _greedy_scan(
            order, q_inst, c_hat, l_inst, tpot, self._nominal,
            d, b_eff, free, self._maxb, self._weights, allowed,
            self._mode, row_valid=row_valid, affinity=aff)

    def _step_multi_impl(self, emb, row_valid, budgets, len_in,
                         d, b, free, ctx, alive,
                         didx, dd, db, dfree, dctx, psig, sig_plane):
        """K coalesced scheduler windows, one megakernel dispatch. The
        delta scatter runs once; every window scans from the refreshed
        mirror — bitwise what K back-to-back `_step` calls see when
        telemetry has not moved between them (the mirror reseeds from
        telemetry per dispatch, never across-batch dead-reckoning), so
        coalescing only amortizes launch/sync overhead."""
        d = d.at[didx].set(dd, mode="drop")
        b = b.at[didx].set(db, mode="drop")
        free = free.at[didx].set(dfree, mode="drop")
        ctx = ctx.at[didx].set(dctx, mode="drop")
        choice, est_T, l_chosen, d1, b1, f1 = self._mega_stages(
            emb, row_valid, budgets, len_in, d, b, free, ctx, alive,
            psig, sig_plane)
        return (choice, est_T, l_chosen, d, b, free, ctx, d1, b1, f1)

    # -- host side ----------------------------------------------------------
    def reset(self):
        """Forget carried device state (new sim / fresh roster) and
        start a fresh stats window, so `stats` reads as per-cell
        counters rather than accumulating across cache-hit reuses. A
        `LazyDecision` still in flight keeps a reference to the old
        window and is unaffected."""
        self._state: Optional[Tuple] = None   # (d, b, free, ctx) mirror
        self._post_state: Optional[Tuple] = None   # post-scan (d, b, free)
        self._alive_dev = None
        self._seen_tel = None                 # identity of the synced view
        self._seen_version = -1
        self._seen_roster = -1
        self.stats = _new_stats()

    def compile_count(self) -> int:
        """Number of XLA programs compiled for the fused step — one per
        pow2 R bucket seen (plus one per (pow2 K, pow2 R) pair for the
        multi-window megakernel dispatch, when used). Roster events
        (fail/recover/autoscale) flip the alive mask and reseed the
        mirror but must NOT add entries here: that is the
        no-recompile-on-scale contract the elastic soak asserts
        (`compile_count() == len(distinct R buckets)`)."""
        n = int(self._step._cache_size())
        if self._step_multi is not None:
            n += int(self._step_multi._cache_size())
        return n

    def _stage_buffers(self, Rb: int) -> Dict[str, np.ndarray]:
        """The preallocated host staging set for the pow2 batch bucket.
        Two sets alternate per bucket so writing batch N+1 can never
        alias batch N's still-in-flight transfer (the async-dispatch
        window is one batch deep)."""
        pair = self._stage.get(Rb)
        if pair is None:
            def mk():
                buf = {"emb": np.zeros((Rb, self._E), np.float32),
                       "prow": np.zeros(Rb, np.int32),
                       "budgets": np.full(Rb, np.nan, np.float32),
                       "len_in": np.zeros(Rb, np.float32),
                       "rv": np.zeros(Rb, bool)}
                if self._w_aff > 0.0:
                    buf["psig"] = np.zeros((Rb, SIG_WIDTH), np.int32)
                return buf
            pair = self._stage[Rb] = [mk(), mk()]
            self._sflip[Rb] = 0
        self._sflip[Rb] ^= 1
        return pair[self._sflip[Rb]]

    def _delta_buffers(self) -> Dict[str, np.ndarray]:
        if self._dstage is None:
            def mk():
                return {"idx": np.full(self._Kcap, self._Itot, np.int32),
                        "d": np.zeros(self._Kcap, np.float32),
                        "b": np.zeros(self._Kcap, np.float32),
                        "free": np.zeros(self._Kcap, np.float32),
                        "ctx": np.zeros(self._Kcap, np.float32)}
            self._dstage = [mk(), mk()]
        self._dflip ^= 1
        return self._dstage[self._dflip]

    def _sync_state(self, tel) -> Tuple:
        """Assemble the telemetry-state args for `_step`: the carried
        device mirror plus a dirty-row delta, or a full reseed.

        Full reseed happens only on the first batch, after `reset()`,
        on roster-shape events (`tel.roster_version` moved: a fail or
        recover flipped the alive mask), or when most of the roster is
        dirty (the scatter would cost more than the re-upload). The
        common steady-state case is the delta arm: only rows with
        ``tel.last_write > seen_version`` are shipped. Either way the
        state handed to the scan equals the staged backends' fresh host
        read of `tel` bit for bit — which is what keeps the fused
        backend in exact assignment parity (regression-tested in
        ``tests/test_ingest.py``; the PR-2 semantics of carrying
        post-scan dead-reckoned state across batches did NOT have this
        property and is gone)."""
        st = self.stats
        rows = None
        # freshness is keyed to the telemetry OBJECT, not just its
        # counters: a caller that swaps in a new sim's TelemetryArrays
        # (rb.sim = ClusterSim(...) without attach()) must reseed — the
        # new view's counters can look "older" than the mirror's and
        # would otherwise silently carry the previous cluster's state
        if self._state is not None and tel is self._seen_tel:
            if tel.roster_version == self._seen_roster:
                rows = tel.dirty_rows(self._seen_version)
                if 2 * len(rows) > self._n_real:
                    rows = None              # mostly dirty: reseed outright
            else:
                # fail/recover/autoscale flipped the alive mask: the
                # reseed is roster-caused — kill() deliberately does not
                # stamp last_write, so a delta read would miss the dead
                # row; this counter is what lets the elastic soak assert
                # scale events resync WITHOUT recompiling
                st["roster_reseed"] += 1
        self._seen_version = tel.version
        if rows is None:
            self._seen_tel = tel
            self._seen_roster = tel.roster_version
            self._state = tuple(
                jnp.asarray(self._pad_i(np.asarray(x, np.float32)))
                for x in (tel.pending, tel.batch, tel.free, tel.ctx))
            self._alive_dev = jnp.asarray(
                self._pad_i(np.asarray(tel.alive), fill=False))
            st["full_reseed"] += 1
            return self._state + (self._alive_dev,) + self._empty_delta
        K = len(rows)
        if K == 0:
            st["carry"] += 1
            return self._state + (self._alive_dev,) + self._empty_delta
        st["delta_sync"] += 1
        st["delta_rows"] += K
        buf = self._delta_buffers()
        buf["idx"][:K] = rows
        buf["idx"][K:] = self._Itot          # out-of-range -> dropped
        buf["d"][:K] = tel.pending[rows]
        buf["b"][:K] = tel.batch[rows]
        buf["free"][:K] = tel.free[rows]
        buf["ctx"][:K] = tel.ctx[rows]
        return self._state + (self._alive_dev, buf["idx"], buf["d"],
                              buf["b"], buf["free"], buf["ctx"])

    def decide_cols(self, cols, rows: np.ndarray, tel) -> LazyDecision:
        """One scheduler batch as a row slice into the SoA ingest
        columns: stage via vectorized gathers into the preallocated
        double-buffered host set, sync the device telemetry mirror
        (delta scatter in the common case), dispatch the single fused
        program, and hand back a `LazyDecision` so the caller's host
        work overlaps device execution."""
        assert cols.emb is not None, \
            "RequestColumns.ensure_embeddings must run before decide"
        st = self.stats
        st["calls"] += 1
        t0 = time.perf_counter()
        R = len(rows)
        s = self._stage_buffers(bucket_pow2(R))
        np.take(cols.prompt_row, rows, out=s["prow"][:R])
        np.take(cols.emb, s["prow"][:R], axis=0, out=s["emb"][:R])
        s["emb"][R:] = 0.0
        s["budgets"][:R] = cols.budget[rows]
        s["budgets"][R:] = np.nan
        s["len_in"][:R] = cols.len_in[rows]
        s["len_in"][R:] = 0.0
        s["rv"][:R] = True
        s["rv"][R:] = False
        if self._w_aff > 0.0:
            np.take(cols.prefix_sig, s["prow"][:R], axis=0,
                    out=s["psig"][:R])
            s["psig"][R:] = 0
            self._pflip ^= 1
            plane = self._pstage[self._pflip]
            plane[:self._n_real] = tel.prefix_sig
            psig = s["psig"]
        else:
            psig, plane = self._dummy_psig, self._dummy_plane
        t1 = time.perf_counter()
        state_args = self._sync_state(tel)
        t2 = time.perf_counter()
        out = self._step(s["emb"], s["rv"], s["budgets"], s["len_in"],
                         *state_args, psig, plane)
        self._state = out[3:7]               # refreshed pre-scan mirror
        self._post_state = out[7:10]         # post-scan (diagnostics)
        t3 = time.perf_counter()
        st["stage_s"] += t1 - t0
        st["host_s"] += t2 - t0
        st["dispatch_s"] += t3 - t2
        return LazyDecision(out[0], out[2], R, st)

    def _multi_buffers(self, Kb: int, Rb: int) -> Dict[str, np.ndarray]:
        """Double-buffered host staging for the (pow2 K, pow2 R)
        multi-window bucket, mirroring `_stage_buffers`."""
        key = (Kb, Rb)
        pair = self._mstage.get(key)
        if pair is None:
            def mk():
                buf = {"emb": np.zeros((Kb, Rb, self._E), np.float32),
                       "prow": np.zeros((Kb, Rb), np.int32),
                       "budgets": np.full((Kb, Rb), np.nan, np.float32),
                       "len_in": np.zeros((Kb, Rb), np.float32),
                       "rv": np.zeros((Kb, Rb), bool),
                       "dummy_psig": np.zeros((Kb, 1, 1), np.int32)}
                if self._w_aff > 0.0:
                    buf["psig"] = np.zeros((Kb, Rb, SIG_WIDTH), np.int32)
                return buf
            pair = self._mstage[key] = [mk(), mk()]
            self._mflip[key] = 0
        self._mflip[key] ^= 1
        return pair[self._mflip[key]]

    def decide_cols_multi(self, batches, tel) -> List[LazyDecision]:
        """K scheduler windows sharing ONE megakernel dispatch
        (grid=(K,)). `batches` is a list of (cols, rows) window slices;
        returns one `LazyDecision` per window, in order.

        All windows decide from the same telemetry snapshot — exactly
        what K back-to-back `decide_cols` calls produce when telemetry
        has not moved between them (each dispatch reseeds the mirror
        from `tel`; dead-reckoned state never carries across batches) —
        so coalescing is assignment-exact while paying one kernel
        launch, one mirror sync and one staging pass for the K windows.
        Window count and row count both bucket to powers of two (pad
        windows are all-invalid rows), keeping compile variants at
        O(log K · log R). Megakernel backend only."""
        assert self._backend == "megakernel", self._backend
        if len(batches) == 1:
            cols, rows = batches[0]
            return [self.decide_cols(cols, rows, tel)]
        st = self.stats
        K = len(batches)
        st["calls"] += K
        st["multi_dispatch"] = st.get("multi_dispatch", 0) + 1
        t0 = time.perf_counter()
        Kb = bucket_pow2(K, lo=1)
        Rb = bucket_pow2(max(len(rows) for _, rows in batches))
        s = self._multi_buffers(Kb, Rb)
        for ki, (cols, rows) in enumerate(batches):
            assert cols.emb is not None, \
                "RequestColumns.ensure_embeddings must run before decide"
            R = len(rows)
            prow = s["prow"][ki, :R]
            np.take(cols.prompt_row, rows, out=prow)
            np.take(cols.emb, prow, axis=0, out=s["emb"][ki, :R])
            s["emb"][ki, R:] = 0.0
            s["budgets"][ki, :R] = cols.budget[rows]
            s["budgets"][ki, R:] = np.nan
            s["len_in"][ki, :R] = cols.len_in[rows]
            s["len_in"][ki, R:] = 0.0
            s["rv"][ki, :R] = True
            s["rv"][ki, R:] = False
            if self._w_aff > 0.0:
                np.take(cols.prefix_sig, prow, axis=0,
                        out=s["psig"][ki, :R])
                s["psig"][ki, R:] = 0
        for ki in range(K, Kb):               # pad windows: no-ops
            s["emb"][ki] = 0.0
            s["budgets"][ki] = np.nan
            s["len_in"][ki] = 0.0
            s["rv"][ki] = False
            if self._w_aff > 0.0:
                s["psig"][ki] = 0
        if self._w_aff > 0.0:
            self._pflip ^= 1
            plane = self._pstage[self._pflip]
            plane[:self._n_real] = tel.prefix_sig
            psig = s["psig"]
        else:
            psig, plane = s["dummy_psig"], self._dummy_plane
        t1 = time.perf_counter()
        state_args = self._sync_state(tel)
        t2 = time.perf_counter()
        out = self._step_multi(s["emb"], s["rv"], s["budgets"],
                               s["len_in"], *state_args, psig, plane)
        self._state = out[3:7]               # refreshed pre-scan mirror
        # diagnostics: the LAST real window's post-scan view (windows
        # are independent; pad windows apply no updates)
        self._post_state = tuple(o[K - 1] for o in out[7:10])
        t3 = time.perf_counter()
        st["stage_s"] += t1 - t0
        st["host_s"] += t2 - t0
        st["dispatch_s"] += t3 - t2
        return [LazyDecision(out[0][ki], out[2][ki],
                             len(batches[ki][1]), st)
                for ki in range(K)]

    def decide(self, batch, tel) -> Tuple[np.ndarray, np.ndarray]:
        """Legacy AoS entry (direct callers, tests): derive the column
        slice from the request list — ephemeral non-stamping columns if
        the batch has no shared stream — then fetch eagerly. Returns
        (choice (R,) int64 indexing the FULL instance roster, l_chosen
        (R,))."""
        from repro.serving.request import RequestColumns
        cols, rows = RequestColumns.for_batch(batch, self._encoder)
        return self.decide_cols(cols, rows, tel).fetch()
