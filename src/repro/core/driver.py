"""Serving-cell driver: arrivals -> scheduler -> cluster -> metrics.

One ``run_cell`` = one configuration cell of the paper's evaluation
(fixed scheduler, weights, arrival rate, prompt set).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.cluster import ClusterSim
from repro.serving.metrics import aggregate
from repro.serving.request import Request
from repro.serving.tiers import Tier
from repro.serving.workload import make_arrivals
from repro.serving.world import Dataset


def make_requests(dataset: Dataset, which: str, arrivals: np.ndarray,
                  budgets: Optional[np.ndarray] = None,
                  limit: Optional[int] = None,
                  encoder=None) -> List[Request]:
    """Build a request stream plus its SoA ingest columns
    (`repro.serving.request.RequestColumns`): token/length/budget
    columns are materialized here, once, at workload-generation time, so
    the scheduler's steady-state decision path stages batches with
    vectorized gathers instead of per-request Python. Pass `encoder`
    (e.g. ``bundle.encoder``) to also pre-fill the prompt-embedding
    column up front; otherwise the first scheduler to see the stream
    fills it lazily at enqueue time."""
    from repro.serving.request import RequestColumns

    prompts, Q, L = dataset.split(which)
    n = len(arrivals) if limit is None else min(limit, len(arrivals))
    reqs = []
    for i in range(n):
        j = i % len(prompts)
        reqs.append(Request(
            rid=i, prompt=prompts[j], arrival=float(arrivals[i]),
            true_quality=Q[j], true_length=L[j],
            budget=None if budgets is None or np.isnan(budgets[i])
            else float(budgets[i])))
    cols = RequestColumns.from_requests(reqs)
    if encoder is not None:
        cols.ensure_embeddings(encoder)
    return reqs


def run_cell(scheduler, tiers: Sequence[Tier], model_names: List[str],
             requests: List[Request], seed: int = 0,
             fail_at: Optional[Dict] = None,
             schedule: Optional[Sequence] = None,
             schedule_seed: int = 0,
             setup: Optional[Callable[[ClusterSim], None]] = None) -> Dict:
    """fail_at: optional {time: t, instances: [iids]} failure injection.
    schedule: optional scenario perturbation schedule (a sequence of
    `repro.serving.scenarios.FailureEvent`) armed on the sim.
    setup: optional hook called on the fresh sim before the scheduler
    attaches — the arming point for overload control
    (`repro.serving.overload.arm_elastic`) and other sim-scoped
    controllers."""
    sim = ClusterSim(list(tiers), model_names, seed=seed)
    if setup is not None:
        setup(sim)
    if hasattr(scheduler, "expected"):
        scheduler.expected = len(requests)
    scheduler.attach(sim)
    for r in requests:
        sim.push(r.arrival, lambda t, rr=r: scheduler.enqueue(rr, t))
    if fail_at:
        def kill(t):
            for iid in fail_at["instances"]:
                sim.by_id[iid].fail()
        sim.push(fail_at["time"], kill)
    if schedule:
        from repro.serving.scenarios import apply_schedule
        apply_schedule(sim, schedule, seed=schedule_seed)
    sim.run()
    # exactly-once delivery: the fault-tolerant lifecycle (retry,
    # hedging, controller restore) must terminate every request exactly
    # once — a double-append to `completed` means a retry raced a
    # completion and the cell's rates are garbage
    seen_ids = {id(r) for r in sim.completed}
    assert len(seen_ids) == len(sim.completed), (
        f"{len(sim.completed) - len(seen_ids)} requests "
        "terminated more than once")
    wall = (max((r.finish_time or r.arrival) for r in requests)
            - min((r.first_arrival if r.first_arrival is not None
                   else r.arrival) for r in requests))
    out = aggregate(requests, list(tiers), model_names, wall)
    # engine-backed schedulers self-identify: the policy/deployment
    # axes land in every cell row so BENCH artifacts stay comparable
    # across the registry sweep
    policy = getattr(scheduler, "policy", None)
    if policy is not None:
        out["policy"] = getattr(policy, "name", type(policy).__name__)
        ecfg = getattr(scheduler, "ecfg", None)
        if ecfg is not None:
            out["deployment"] = ecfg.deployment
    if hasattr(scheduler, "compute_log") and scheduler.compute_log:
        sizes = np.array([s for s, _ in scheduler.compute_log])
        times = np.array([dt for _, dt in scheduler.compute_log])
        out["measured_decide_ms_mean"] = float(times.mean() * 1e3)
        out["measured_decide_ms_per_req"] = float(
            times.sum() / max(sizes.sum(), 1) * 1e3)
        out["mean_batch_size"] = float(sizes.mean())
    ctl = getattr(sim, "overload", None)
    if ctl is not None:
        out["scale_ups"] = ctl.scale_ups
        out["scale_downs"] = ctl.scale_downs
        out["scale_up_lag_s"] = ctl.cfg.scale_up_lag_s
        out["peak_alive"] = ctl.peak_alive
    mgr = getattr(sim, "recovery", None)
    if mgr is not None:
        out["retries"] = mgr.retries
        out["gave_up"] = mgr.gave_up
        out["hedges"] = mgr.hedges
        out["duplicate_tokens"] = mgr.duplicate_tokens
        out["quarantines"] = mgr.quarantines
        out["degraded_decisions"] = mgr.degraded_decisions
    return out
