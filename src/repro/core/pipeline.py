"""DEPRECATED shim: pipeline mode is now the `ServingEngine` with a
`RouterDispatchPolicy` and a `deployment=` knob.

The decoupled router -> dispatcher baselines and the §6.3 deployment
ladder (serial-as-published / microbatch / concurrent, plus the
vLLM-SR bounded-queue variant via `queue_capacity`) live on the shared
engine (`repro.core.engine`), selected through the `POLICIES` registry
(`repro.core.policies`):

    from repro.core import EngineConfig, ServingEngine, make_policy
    policy = make_policy("bestroute-sq", threshold=0.5)
    policy.fit(emb, Q, L, prices)
    eng = ServingEngine(policy, bundle, tiers,
                        EngineConfig(deployment="serial_published"))

`PipelineScheduler(...)` keeps constructing exactly that engine (the
differential parity suite in ``tests/test_engine_parity.py`` pins the
trajectories against the frozen legacy implementation), but warns —
new code should build the engine directly.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence

from repro.serving.tiers import Tier

from .dispatchers import Dispatcher
from .engine import EngineConfig, ServingEngine
from .routers import Router


@dataclasses.dataclass
class PipelineConfig:
    deployment: str = "serial"     # serial | microbatch | concurrent
    n_workers: int = 32            # concurrent scoring workers
    microbatch_size: int = 64
    microbatch_time: float = 1.72  # padded batch service time (§6.3)
    queue_capacity: Optional[int] = None   # bounded => drops (vLLM-SR)
    budget_clamp: bool = True


def PipelineScheduler(router: Router, dispatcher: Dispatcher,
                      bundle, tiers: Sequence[Tier],
                      cfg: PipelineConfig = PipelineConfig()
                      ) -> ServingEngine:
    """Deprecated constructor for the legacy pipeline-mode scheduler;
    returns the equivalent `ServingEngine`."""
    warnings.warn(
        "PipelineScheduler is deprecated: build a ServingEngine with a "
        "RouterDispatchPolicy (repro.core.policies) and an EngineConfig "
        "deployment instead", DeprecationWarning, stacklevel=2)
    from .policies import RouterDispatchPolicy
    policy = RouterDispatchPolicy(router, dispatcher,
                                  budget_clamp=cfg.budget_clamp)
    return ServingEngine(policy, bundle, tiers, EngineConfig(
        deployment=cfg.deployment,          # "serial" alias accepted
        n_workers=cfg.n_workers,
        microbatch_size=cfg.microbatch_size,
        microbatch_time=cfg.microbatch_time,
        queue_capacity=cfg.queue_capacity))
