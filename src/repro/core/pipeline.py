"""Pipeline mode: decoupled router -> dispatcher baselines inside the
SAME batching/telemetry/dispatch path as RouteBalance (§5), plus the
deployment-model ladder of §6.3:

  serial      — one scoring call per request, one server (as published)
  microbatch  — co-located batch collector, pads to the longest sequence
                (1.72 s per batch of 64), batches cannot overlap
  concurrent  — our enhancement: scoring micro-batched off the scheduling
                loop on a thread-pool (32 workers), routing byte-identical

vLLM-SR runs as a separate-process classifier service with a BOUNDED
queue — overflow = failed requests (Table 6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.cluster import ClusterSim
from repro.serving.request import Request
from repro.serving.tiers import Tier

from .budget import max_tokens_clamp
from .dispatchers import Dispatcher
from .routers import Router
from repro.estimators.embedding import pad_tokens

from .scheduler import EstimatorBundle


@dataclasses.dataclass
class PipelineConfig:
    deployment: str = "serial"     # serial | microbatch | concurrent
    n_workers: int = 32            # concurrent scoring workers
    microbatch_size: int = 64
    microbatch_time: float = 1.72  # padded batch service time (§6.3)
    queue_capacity: Optional[int] = None   # bounded => drops (vLLM-SR)
    budget_clamp: bool = True


class PipelineScheduler:
    """Router station -> dispatcher -> instance, event-driven."""

    def __init__(self, router: Router, dispatcher: Dispatcher,
                 bundle: EstimatorBundle, tiers: Sequence[Tier],
                 cfg: PipelineConfig = PipelineConfig()):
        self.router = router
        self.dispatcher = dispatcher
        self.bundle = bundle
        self.tiers = list(tiers)
        self.cfg = cfg
        self.sim: Optional[ClusterSim] = None
        self.queue: List[Request] = []
        self.busy_servers = 0
        self.n_servers = (1 if cfg.deployment in ("serial", "microbatch")
                          else cfg.n_workers)

    def attach(self, sim: ClusterSim):
        self.sim = sim

    # -- arrival ------------------------------------------------------------
    def enqueue(self, req: Request, t: float):
        cap = self.cfg.queue_capacity
        if cap is not None and len(self.queue) >= cap:
            req.failed = True
            self.sim.completed.append(req)
            return
        self.queue.append(req)
        self._drain(t)

    # -- scoring station -----------------------------------------------------
    def _service_time(self, n: int) -> float:
        if self.cfg.deployment == "microbatch":
            return self.cfg.microbatch_time
        return self.router.serial_scoring_s

    def _drain(self, t: float):
        while self.queue and self.busy_servers < self.n_servers:
            if self.cfg.deployment == "microbatch":
                n = min(len(self.queue), self.cfg.microbatch_size)
            elif self.cfg.deployment == "concurrent":
                # micro-batched off the scheduling loop: each worker takes
                # a small group; scoring latency ~ serial per forward but
                # workers overlap
                n = min(len(self.queue),
                        max(1, len(self.queue) // self.n_servers))
                n = min(n, 8)
            else:
                n = 1
            group = self.queue[:n]
            self.queue = self.queue[n:]
            self.busy_servers += 1
            dt = self._service_time(n)
            self.sim.push(t + dt, lambda tt, g=group: self._scored(g, tt))

    def _scored(self, group: List[Request], t: float):
        self.busy_servers -= 1
        toks = pad_tokens([r.prompt.tokens for r in group],
                          self.bundle.encoder.max_len)
        lens = np.array([min(len(r.prompt.tokens),
                             self.bundle.encoder.max_len) for r in group])
        emb = self.bundle.encoder.encode(toks, lens)
        models = self.router.route(emb)
        _, L = self.bundle.knn.query(emb)
        tel = self.sim.telemetry()
        for j, req in enumerate(group):
            req.router_queue_wait = t - req.arrival
            m = int(models[j])
            cands = [i for i in self.sim.alive_instances()
                     if m < 0 or i.model_idx == m]
            if not cands:
                cands = self.sim.alive_instances()
            pick = self.dispatcher.pick(cands, tel)
            inst = cands[pick]
            pred = float(L[j, inst.model_idx])
            mt = None
            if self.cfg.budget_clamp:
                mt = max_tokens_clamp(req.budget, req.prompt.len_in,
                                      inst.tier.price_in,
                                      inst.tier.price_out)
            inst.submit(req, t, pred, mt)
        self._drain(t)
