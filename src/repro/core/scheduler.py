"""RouteBalance: the fused routing + load-balancing scheduler (§4).

Per fired batch: one batched embed+KNN call gives prompt-intrinsic Q̂/L̂
for every candidate model; per-tier TPOT heads + dead-reckoned instance
state give the state-dependent T̂; the LPT-ordered greedy pass maximizes
Eq. 1 per request, updating the local instance view after each dispatch.
Batch formation is adaptive (larger when the cluster is busy). The
off-instance residual decomposition (compute / batch wait / stats fetch)
is charged onto every request exactly as the paper reports it (Table 4).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.estimators.embedding import SentenceEncoder, pad_tokens
from repro.estimators.knn import KNNEstimator
from repro.estimators.latency import LatencyHead, tpot_features
from repro.serving.cluster import ClusterSim, Instance
from repro.serving.request import Request
from repro.serving.tiers import Tier

from .assignment import greedy_assign, lpt_order
from .budget import admission_mask, cost_matrix, max_tokens_clamp
from .decision_jax import LATENCY_MODES
from .weights import PRESETS, Weights, validate


@dataclasses.dataclass
class RBConfig:
    weights: Weights = PRESETS["uniform"]
    base_window: float = 0.10          # batch formation window (s)
    adaptive: bool = True
    lpt: bool = True
    fixed_batch: Optional[int] = None  # fixed-size batching ablation
    budget_filter: bool = True
    latency_mode: str = "full"         # full|off_reactive|off_predictive|
    #                                    static_prior (§6.3 arms)
    learned_tpot: bool = True
    knn_k: int = 10
    charge_compute: bool = True        # charge measured decision time
    decision_backend: str = "fused"    # fused (single-dispatch hot
    #                                    path, the default since it
    #                                    soaked under tests/test_soak) |
    #                                    jax (staged jitted core) |
    #                                    numpy (reference loop)
    knn_backend: Optional[str] = None  # override bundle's KNN backend
    #                                    (numpy | jax | pallas); staged
    #                                    backends only — fused has the
    #                                    estimator feed in-graph


class EstimatorBundle:
    """The in-process predictor stack: encoder + KNN + per-tier heads."""

    def __init__(self, encoder: SentenceEncoder, knn: KNNEstimator,
                 heads: Dict[str, LatencyHead], model_names: List[str]):
        self.encoder = encoder
        self.knn = knn
        self.heads = heads
        self.model_names = model_names

    @staticmethod
    def train(dataset, tiers: Sequence[Tier], model_names: List[str],
              k: int = 10, backend: str = "jax",
              seed: int = 0) -> "EstimatorBundle":
        enc = SentenceEncoder(seed=7)
        prompts, Q, L = dataset.split("train")
        toks = pad_tokens([p.tokens for p in prompts], enc.max_len)
        lens = np.array([min(len(p.tokens), enc.max_len) for p in prompts])
        emb = []
        for i in range(0, len(prompts), 512):
            emb.append(enc.encode(toks[i:i + 512], lens[i:i + 512]))
        emb = np.concatenate(emb)
        knn = KNNEstimator(k=k, backend=backend).fit(emb, Q, L)
        heads = {}
        rng = np.random.default_rng(seed)
        for t in tiers:
            X, y = _tier_sweep(t, rng)
            heads[t.name] = LatencyHead(
                t.name, nominal_tpot=t.tpot(8, 500)).fit(X, y)
        return EstimatorBundle(enc, knn, heads, model_names)

    def predict_prompts(self, reqs: Sequence[Request], cols=None,
                        rows: Optional[np.ndarray] = None
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched Q̂/L̂ for a request batch. When the batch is a slice
        of a SoA ingest stream (`repro.serving.request.RequestColumns`)
        the encoder is skipped entirely — the memoized per-prompt
        embedding column is gathered instead (bitwise the per-batch
        encode, which is padding-stable) — so the staged numpy/jax
        backends share the fused path's ingest win and the differential
        harness keeps comparing like for like."""
        if cols is None:
            from repro.serving.request import batch_columns
            cols, rows = batch_columns(reqs)
        if cols is not None:
            cols.ensure_embeddings(self.encoder)
            emb = cols.emb[cols.prompt_row[rows]]
        else:
            toks = pad_tokens([r.prompt.tokens for r in reqs],
                              self.encoder.max_len)
            lens = np.array([min(len(r.prompt.tokens),
                                 self.encoder.max_len) for r in reqs])
            emb = self.encoder.encode(toks, lens)
        return self.knn.query(emb)


def _tier_sweep(tier: Tier, rng) -> Tuple[np.ndarray, np.ndarray]:
    """Tier-local QPS sweep -> (features, true TPOT) training pairs."""
    rows, ys = [], []
    for _ in range(2000):
        b = rng.integers(1, tier.max_batch + 1)
        ctx = rng.uniform(32, 2048)
        pend = b * rng.uniform(8, 600)
        rows.append(tpot_features(b, pend, ctx))
        ys.append(tier.tpot(b, ctx) * np.exp(rng.normal(0, 0.03)))
    return np.stack(rows), np.asarray(ys, np.float32)


class _Ready:
    """Already-materialized decision result: the staged backends' twin
    of `repro.core.hotpath.LazyDecision`, so `_decide` fetches through
    one interface regardless of backend."""

    __slots__ = ("_out",)

    def __init__(self, choice: np.ndarray, l_chosen: np.ndarray):
        self._out = (choice, l_chosen)

    def fetch(self):
        return self._out


class RouteBalance:
    """Event-driven scheduler over a ClusterSim."""

    def __init__(self, cfg: RBConfig, bundle: EstimatorBundle,
                 tiers: Sequence[Tier]):
        self.cfg = cfg
        validate(cfg.weights)
        assert cfg.decision_backend in ("numpy", "jax", "fused"), \
            cfg.decision_backend
        assert cfg.knn_backend in (None, "numpy", "jax", "pallas"), \
            cfg.knn_backend
        assert cfg.latency_mode in LATENCY_MODES, cfg.latency_mode
        if (cfg.knn_backend is not None
                and cfg.knn_backend != bundle.knn.backend):
            # rebind the estimator feed (e.g. the Pallas knn_topk kernel)
            # on a copy so a shared bundle is not mutated across schedulers
            bundle = EstimatorBundle(bundle.encoder,
                                     bundle.knn.with_backend(
                                         cfg.knn_backend),
                                     bundle.heads, bundle.model_names)
        self.bundle = bundle
        self.tiers = list(tiers)
        self.waiting: List[Request] = []
        self.sim: Optional[ClusterSim] = None
        self._measured_compute = 0.004  # warm estimate, updated online
        self.decisions = 0
        self.batches = 0
        self.expected: Optional[int] = None   # stop firing once all served
        self.compute_log: List[Tuple[int, float]] = []
        self._fused = None                    # lazily-built FusedHotPath
        # the waiting queue's SoA twin: a row-index buffer parallel to
        # `self.waiting`, so a decision batch is an index slice into the
        # stream's RequestColumns with no per-request work at fire time.
        # _wait_cols: the stream's columns | None (queue empty) | False
        # (mixed/columnless stream -> legacy AoS marshaling)
        self._wait_rows = np.empty(256, np.int64)
        self._wait_start = 0
        self._wait_n = 0
        self._wait_cols = None

    # -- wiring ---------------------------------------------------------------
    def attach(self, sim: ClusterSim):
        self.sim = sim
        self._fused = None                    # new sim -> new roster
        self._wait_start = self._wait_n = 0
        # requests queued from before a re-attach have no rows in the
        # (just-cleared) ring, so the ring is no longer parallel to
        # `waiting` — marshal AoS until the queue drains (`_fire`'s
        # drain reset re-enables the SoA path)
        self._wait_cols = False if self.waiting else None
        sim.push(self.cfg.base_window, self._fire)

    def enqueue(self, req: Request, t: float):
        self.waiting.append(req)
        cols = req.cols
        if cols is None or req.row < 0 or (
                self._wait_cols is not None
                and self._wait_cols is not cols):
            self._wait_cols = False           # fall back to AoS marshaling
            return
        if self._wait_cols is None:
            # first sight of the stream: fill the embedding column now
            # (ingest time, off the measured decision path; a no-op when
            # the workload generator pre-embedded)
            cols.ensure_embeddings(self.bundle.encoder)
            self._wait_cols = cols
        end = self._wait_start + self._wait_n
        if end >= len(self._wait_rows):
            if self._wait_start:              # compact, then maybe grow
                self._wait_rows[:self._wait_n] = \
                    self._wait_rows[self._wait_start:end].copy()
                self._wait_start = 0
                end = self._wait_n
            if end >= len(self._wait_rows):
                self._wait_rows = np.concatenate(
                    [self._wait_rows, np.empty_like(self._wait_rows)])
        self._wait_rows[end] = req.row
        self._wait_n += 1

    # -- scheduling -----------------------------------------------------------
    def _window(self) -> float:
        if not self.cfg.adaptive:
            return self.cfg.base_window
        tel = self.sim.tel
        alive = tel.alive
        busy = float(np.mean(np.minimum(
            tel.batch[alive] / np.maximum(tel.max_batch[alive], 1.0),
            1.0))) if alive.any() else 0.0
        return float(np.clip(self.cfg.base_window * (0.4 + 1.8 * busy),
                             0.04, 0.30))

    def _fire(self, t: float):
        batch = self.waiting
        if self.cfg.fixed_batch:
            batch = batch[:self.cfg.fixed_batch]
        self.waiting = self.waiting[len(batch):]
        k = len(batch)
        cols = rows = None
        if self._wait_cols not in (None, False):
            cols = self._wait_cols
            rows = self._wait_rows[self._wait_start:self._wait_start + k]
            self._wait_start += k
            self._wait_n -= k
        if not self.waiting:                  # drained: accept a new
            self._wait_start = self._wait_n = 0   # stream (or recover
            self._wait_cols = None                # from a mixed one)
        if batch:
            t0 = time.perf_counter()
            self._decide(batch, t, cols, rows)
            dt_meas = time.perf_counter() - t0
            self._measured_compute = (0.8 * self._measured_compute
                                      + 0.2 * dt_meas)
            self.compute_log.append((len(batch), dt_meas))
        if (self.expected is not None and not self.waiting
                and self.decisions >= self.expected):
            return                          # all requests dispatched
        self.sim.push(t + self._window(), self._fire)

    def _decide_core(self, batch: List[Request]
                     ) -> Tuple[List[Instance], np.ndarray, np.ndarray]:
        """The pure per-batch decision (no dispatch): returns the
        candidate roster plus (choice (R,) indices into it, l_chosen
        (R,) predicted length at the chosen instance). This is the hot
        path `benchmarks/hotpath.py` measures; it fetches eagerly —
        the production `_decide` defers the fetch to the dispatch
        point instead."""
        instances, res = self._decide_lazy(batch)
        choice, l_chosen = res.fetch()
        return instances, choice, l_chosen

    def _decide_lazy(self, batch: List[Request], cols=None,
                     rows: Optional[np.ndarray] = None):
        """Dispatch the per-batch decision; returns (instances, result)
        where result.fetch() materializes (choice, l_chosen). The fused
        backend's result is a LazyDecision (device arrays, deferred
        transfer); the staged backends' is already numpy."""
        if self.cfg.decision_backend == "fused":
            return self._decide_fused(batch, cols, rows)
        instances, choice, l_chosen = self._decide_staged(batch, cols,
                                                          rows)
        return instances, _Ready(choice, l_chosen)

    def _decide_fused(self, batch: List[Request], cols=None,
                      rows: Optional[np.ndarray] = None):
        """Single-dispatch path: one jitted device program per batch
        over the full instance roster (dead instances masked), staged
        from the SoA ingest columns."""
        if not self.sim.tel.alive.any():
            raise RuntimeError("no alive instances to schedule onto")
        if self._fused is None:
            from .hotpath import FusedHotPath
            self._fused = FusedHotPath.for_bundle(
                self.bundle, self.sim.instances, self.cfg)
        if cols is None:
            # direct callers (tests, benches): derive the column slice
            # from the batch, building ephemeral columns if needed
            from repro.serving.request import RequestColumns
            cols, rows = RequestColumns.for_batch(batch,
                                                  self.bundle.encoder)
        return self.sim.instances, self._fused.decide_cols(
            cols, rows, self.sim.tel)

    def _decide_staged(self, batch: List[Request], cols=None,
                       rows: Optional[np.ndarray] = None):
        cfg = self.cfg
        instances = self.sim.alive_instances()
        I = len(instances)
        R = len(batch)
        m_of_i = np.array([inst.model_idx for inst in instances])
        tiers_of_i = [inst.tier for inst in instances]
        if cols is None:
            from repro.serving.request import batch_columns
            cols, rows = batch_columns(batch)

        # 1. batched prompt-intrinsic estimation (one call; the ingest
        # embedding column skips the encoder when available)
        Q, L = self.bundle.predict_prompts(batch, cols=cols, rows=rows)
        q_inst = Q[:, m_of_i]                            # (R, I)
        l_inst = L[:, m_of_i]

        # 2. telemetry seed from the columnar view (non-blocking)
        tel = self.sim.tel
        alive_rows = np.flatnonzero(tel.alive)
        d = tel.pending[alive_rows].copy()
        b = np.maximum(tel.batch[alive_rows], 1.0)
        free = tel.free[alive_rows].copy()
        ctx = np.maximum(tel.ctx[alive_rows], 64.0)
        maxb = tel.max_batch[alive_rows].copy()

        # 3. one TPOT-head call per TIER (not per instance)
        tpot = np.zeros(I)
        if cfg.latency_mode == "static_prior":
            tpot = np.array([self.bundle.heads[ti.name].nominal_tpot
                             for ti in tiers_of_i])
        else:
            by_tier: Dict[str, List[int]] = {}
            for i, ti in enumerate(tiers_of_i):
                by_tier.setdefault(ti.name, []).append(i)
            for tname, idxs in by_tier.items():
                feats = np.stack([
                    tpot_features(b[i], d[i], ctx[i]) for i in idxs])
                tpot[idxs] = self.bundle.heads[tname].tpot_batch(
                    feats, learned=cfg.learned_tpot)

        # 4+5. budget admission (Eq. 2) + LPT-ordered greedy with dead
        # reckoning — either the numpy loop or the jitted decision core
        price_in = np.array([ti.price_in for ti in tiers_of_i])
        price_out = np.array([ti.price_out for ti in tiers_of_i])
        if cols is not None:
            budgets = cols.budget[rows]
            len_in = cols.len_in[rows]
        else:
            budgets = np.array([np.nan if r.budget is None else r.budget
                                for r in batch])
            len_in = np.array([r.prompt.len_in for r in batch], float)
        nominal = np.array([self.bundle.heads[ti.name].nominal_tpot
                            for ti in tiers_of_i])
        if cfg.decision_backend == "jax":
            from . import decision_jax
            choice, _ = decision_jax.decide(
                q_inst, l_inst, L.max(axis=1), tpot, nominal, d, b, free,
                maxb, budgets, len_in, price_in, price_out, cfg.weights,
                latency_mode=cfg.latency_mode, lpt=cfg.lpt,
                budget_filter=cfg.budget_filter)
        else:
            # the reference loop evaluates the decision arithmetic in
            # float32 — the jitted cores' precision — so the quantized
            # Eq. 1 tie groups are identical across all three backends
            # (greedy_assign follows the dtype of its inputs)
            f32 = np.float32
            budgets32, len_in32 = budgets.astype(f32), len_in.astype(f32)
            pi32, po32 = price_in.astype(f32), price_out.astype(f32)
            if cfg.budget_filter:
                allowed, c_hat = admission_mask(budgets32, len_in32,
                                                l_inst, pi32, po32)
            else:
                allowed = np.ones((R, I), bool)
                c_hat = cost_matrix(len_in32, l_inst, pi32, po32)
            order = lpt_order(L.max(axis=1), enable=cfg.lpt)
            choice, _ = greedy_assign(
                order, q_inst.astype(f32), c_hat, l_inst.astype(f32),
                tpot.astype(f32), d.astype(f32), b.astype(f32),
                free.astype(f32), maxb.astype(f32),
                cfg.weights, allowed, latency_mode=cfg.latency_mode,
                nominal_tpot=nominal.astype(f32))
        l_chosen = l_inst[np.arange(R), choice]
        return instances, choice, l_chosen

    def _decide(self, batch: List[Request], t: float, cols=None,
                rows: Optional[np.ndarray] = None):
        cfg = self.cfg
        instances, res = self._decide_lazy(batch, cols, rows)
        R = len(batch)
        I = int(self.sim.tel.alive.sum())

        # 6. dispatch + residual accounting. The bookkeeping between
        # the dispatch above and res.fetch() below runs while the fused
        # device program executes (async dispatch); the staged backends
        # fetch here for free (already numpy).
        compute = self._measured_compute if cfg.charge_compute else 0.0
        stats = 0.0005 * I / 13                       # non-blocking fetch
        per_req_compute = compute / max(R, 1) + compute * 0.2
        now = t + compute + stats
        choice, l_chosen = res.fetch()
        for r_idx, req in enumerate(batch):
            i = int(choice[r_idx])
            inst = instances[i]
            req.sched_compute = per_req_compute
            req.sched_stats_fetch = stats
            req.sched_batch_wait = max(t - req.arrival, 0.0)
            mt = max_tokens_clamp(req.budget, req.prompt.len_in,
                                  inst.tier.price_in, inst.tier.price_out)
            inst.submit(req, now, float(l_chosen[r_idx]), mt)
            self.decisions += 1
        self.batches += 1
