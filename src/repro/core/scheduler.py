"""RouteBalance: the fused routing + load-balancing policy (§4) on the
policy-agnostic `ServingEngine`.

Per fired batch: one batched embed+KNN call gives prompt-intrinsic Q̂/L̂
for every candidate model; per-tier TPOT heads + dead-reckoned instance
state give the state-dependent T̂; the LPT-ordered greedy pass maximizes
Eq. 1 per request, updating the local instance view after each dispatch.
Batch formation, SoA ingest, async dispatch and residual charging live
in `repro.core.engine.ServingEngine` (shared with every baseline
policy); this module holds the decision itself — `RouteBalancePolicy`
implementing the `SchedulingPolicy` protocol over the fused / staged
jax / numpy backends — plus the `RouteBalance` convenience class that
binds policy and engine the way the paper deploys them (windowed
amortized batch scoring).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.estimators.embedding import SentenceEncoder, pad_tokens
from repro.estimators.knn import KNNEstimator
from repro.estimators.latency import LatencyHead, tpot_features
from repro.serving.cluster import ClusterSim, Instance
from repro.serving.request import Request
from repro.serving.tiers import Tier

from .assignment import greedy_assign, lpt_order
from .budget import admission_mask, cost_matrix
from .decision_jax import LATENCY_MODES
from .engine import (AssignmentResult, BatchView, EngineConfig, Ready,
                     SchedulingPolicy, ServingEngine)
from .weights import PRESETS, Weights, validate


@dataclasses.dataclass
class RBConfig:
    weights: Weights = PRESETS["uniform"]
    base_window: float = 0.10          # batch formation window (s)
    adaptive: bool = True
    lpt: bool = True
    fixed_batch: Optional[int] = None  # fixed-size batching ablation
    budget_filter: bool = True
    latency_mode: str = "full"         # full|off_reactive|off_predictive|
    #                                    static_prior (§6.3 arms)
    learned_tpot: bool = True
    knn_k: int = 10
    charge_compute: bool = True        # charge measured decision time
    decision_backend: str = "fused"    # fused (single-dispatch hot
    #                                    path, the default since it
    #                                    soaked under tests/test_soak) |
    #                                    megakernel (the whole decision
    #                                    as ONE Pallas kernel —
    #                                    repro.kernels.decision_megakernel
    #                                    — behind the same host
    #                                    machinery as fused) |
    #                                    jax (staged jitted core) |
    #                                    numpy (reference loop)
    window_coalesce: int = 1           # megakernel only: up to K
    #                                    scheduler windows share one
    #                                    kernel dispatch (grid=(K,))
    #                                    via assign_windows. 1 = one
    #                                    dispatch per window (default;
    #                                    matches every other backend)
    knn_backend: Optional[str] = None  # override bundle's KNN backend
    #                                    (numpy | jax | pallas); staged
    #                                    backends only — fused has the
    #                                    estimator feed in-graph
    shed: bool = True                  # honor overload admission control
    #                                    when the sim carries an
    #                                    ElasticController (sim.overload)
    affinity_weight: float = 0.0       # prefix-cache affinity term
    #                                    (serving.affinity): predicted
    #                                    latency scales by (1 - weight x
    #                                    matched-prefix fraction) in
    #                                    every backend. 0 disables —
    #                                    the term is compiled out of the
    #                                    fused program and skipped by
    #                                    the staged paths. Kept OUTSIDE
    #                                    `weights`: that tuple is the
    #                                    Eq. 1 simplex (sums to 1);
    #                                    affinity is a discount on the
    #                                    latency term, not a 4th vertex.
    shard_cells: int = 0               # hierarchical "span" routing:
    #                                    > 1 splits the fused scan's
    #                                    pow2 instance-column axis into
    #                                    that many cells (pow2, fused
    #                                    backend only), combined with
    #                                    exact reductions — bitwise the
    #                                    single-controller decision on
    #                                    any cell count. 0/1 = the
    #                                    unsharded program verbatim.
    cell_tag: Optional[int] = None     # per-cell engine identity under
    #                                    serving.hierarchy "balanced"
    #                                    routing: keys the FusedHotPath
    #                                    cache so signature-identical
    #                                    cell rosters still get their
    #                                    own carried telemetry mirrors.


class EstimatorBundle:
    """The in-process predictor stack: encoder + KNN + per-tier heads."""

    def __init__(self, encoder: SentenceEncoder, knn: KNNEstimator,
                 heads: Dict[str, LatencyHead], model_names: List[str]):
        self.encoder = encoder
        self.knn = knn
        self.heads = heads
        self.model_names = model_names

    @staticmethod
    def train(dataset, tiers: Sequence[Tier], model_names: List[str],
              k: int = 10, backend: str = "jax",
              seed: int = 0) -> "EstimatorBundle":
        enc = SentenceEncoder(seed=7)
        prompts, Q, L = dataset.split("train")
        toks = pad_tokens([p.tokens for p in prompts], enc.max_len)
        lens = np.array([min(len(p.tokens), enc.max_len) for p in prompts])
        emb = []
        for i in range(0, len(prompts), 512):
            emb.append(enc.encode(toks[i:i + 512], lens[i:i + 512]))
        emb = np.concatenate(emb)
        knn = KNNEstimator(k=k, backend=backend).fit(emb, Q, L)
        heads = {}
        rng = np.random.default_rng(seed)
        for t in tiers:
            X, y = _tier_sweep(t, rng)
            heads[t.name] = LatencyHead(
                t.name, nominal_tpot=t.tpot(8, 500)).fit(X, y)
        return EstimatorBundle(enc, knn, heads, model_names)

    def predict_prompts(self, reqs: Sequence[Request], cols=None,
                        rows: Optional[np.ndarray] = None
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched Q̂/L̂ for a request batch. When the batch is a slice
        of a SoA ingest stream (`repro.serving.request.RequestColumns`)
        the encoder is skipped entirely — the memoized per-prompt
        embedding column is gathered instead (bitwise the per-batch
        encode, which is padding-stable) — so the staged numpy/jax
        backends share the fused path's ingest win and the differential
        harness keeps comparing like for like."""
        if cols is None:
            from repro.serving.request import batch_columns
            cols, rows = batch_columns(reqs)
        if cols is not None:
            cols.ensure_embeddings(self.encoder)
            emb = cols.emb[cols.prompt_row[rows]]
        else:
            toks = pad_tokens([r.prompt.tokens for r in reqs],
                              self.encoder.max_len)
            lens = np.array([min(len(r.prompt.tokens),
                                 self.encoder.max_len) for r in reqs])
            emb = self.encoder.encode(toks, lens)
        return self.knn.query(emb)


def _tier_sweep(tier: Tier, rng) -> Tuple[np.ndarray, np.ndarray]:
    """Tier-local QPS sweep -> (features, true TPOT) training pairs."""
    rows, ys = [], []
    for _ in range(2000):
        b = rng.integers(1, tier.max_batch + 1)
        ctx = rng.uniform(32, 2048)
        pend = b * rng.uniform(8, 600)
        rows.append(tpot_features(b, pend, ctx))
        ys.append(tier.tpot(b, ctx) * np.exp(rng.normal(0, 0.03)))
    return np.stack(rows), np.asarray(ys, np.float32)


class RouteBalancePolicy(SchedulingPolicy):
    """The fused Eq.1/Eq.2 objective as a `SchedulingPolicy`: one
    batched decision over the full roster per fired batch, selectable
    across the fused single-dispatch program, the staged jitted core,
    and the numpy reference loop (`RBConfig.decision_backend`)."""

    name = "routebalance"
    # under the serial_published deployment ladder arm, charge the warm
    # per-batch decision estimate as the per-request service time — the
    # policy scores a batch in one call, so serial deployment is not
    # its natural habitat, but the axis stays orthogonal
    serial_scoring_s = 0.004
    budget_clamp = True

    def __init__(self, cfg: RBConfig):
        self.cfg = cfg
        validate(cfg.weights)
        assert cfg.decision_backend in ("numpy", "jax", "fused",
                                        "megakernel"), \
            cfg.decision_backend
        assert cfg.window_coalesce >= 1, cfg.window_coalesce
        assert (cfg.window_coalesce == 1
                or cfg.decision_backend == "megakernel"), \
            "window_coalesce > 1 needs decision_backend='megakernel'"
        assert cfg.knn_backend in (None, "numpy", "jax", "pallas"), \
            cfg.knn_backend
        assert cfg.latency_mode in LATENCY_MODES, cfg.latency_mode
        assert 0.0 <= cfg.affinity_weight <= 1.0, cfg.affinity_weight
        sc = int(cfg.shard_cells or 0)
        assert sc >= 0 and (sc & (sc - 1)) == 0, \
            f"shard_cells must be a power of two, got {cfg.shard_cells}"
        assert sc <= 1 or cfg.decision_backend == "fused", \
            "shard_cells > 1 requires decision_backend='fused'"
        self.bundle = None
        self._fused = None                    # lazily-built FusedHotPath

    def engine_overrides(self) -> dict:
        # batch formation belongs to RBConfig: honor it on ANY engine
        # this policy is mounted on (registry path included), not just
        # the RouteBalance convenience class
        cfg = self.cfg
        return dict(base_window=cfg.base_window, adaptive=cfg.adaptive,
                    fixed_batch=cfg.fixed_batch,
                    charge_compute=cfg.charge_compute)

    def prepare(self, bundle, tiers: Sequence[Tier]):
        cfg = self.cfg
        if (cfg.knn_backend is not None
                and cfg.knn_backend != bundle.knn.backend):
            # rebind the estimator feed (e.g. the Pallas knn_topk kernel)
            # on a copy so a shared bundle is not mutated across schedulers
            bundle = EstimatorBundle(bundle.encoder,
                                     bundle.knn.with_backend(
                                         cfg.knn_backend),
                                     bundle.heads, bundle.model_names)
        self.bundle = bundle

    def on_attach(self, sim: ClusterSim):
        self._fused = None                    # new sim -> new roster

    def shed_verdict(self, req: Request, controller) -> bool:
        # policy-visible admission control (RBConfig.shed): the
        # no-shedding ablation admits everything even under overload
        if not self.cfg.shed:
            return False
        return controller.wants_shed(req.priority)

    def assign(self, batch: BatchView, cluster: ClusterSim
               ) -> AssignmentResult:
        """Dispatch the per-batch decision; the fused backend's payload
        is a LazyDecision (device arrays, deferred transfer); the
        staged backends' is already numpy."""
        if self.cfg.decision_backend in ("fused", "megakernel"):
            instances, res = self._decide_fused(batch, cluster)
            return AssignmentResult(instances, res)
        instances, choice, l_chosen = self._decide_staged(batch, cluster)
        return AssignmentResult(instances, Ready(choice, l_chosen))

    def assign_windows(self, batches: List[BatchView],
                       cluster: ClusterSim) -> List[AssignmentResult]:
        """K scheduler windows as ONE device dispatch (megakernel only:
        `FusedHotPath.decide_cols_multi`, grid=(K,)). All K windows
        decide against the same telemetry snapshot — exactly what K
        back-to-back `assign` calls see when telemetry has not moved
        between them, so coalescing is assignment-exact there while
        paying one kernel launch for K windows. Falls back to per-window
        `assign` for every other backend (and for K == 1)."""
        if (self.cfg.decision_backend != "megakernel"
                or len(batches) <= 1):
            return [self.assign(bv, cluster) for bv in batches]
        runner = self._fused_runner(cluster)
        slices = [bv.columns(self.bundle.encoder) for bv in batches]
        lazies = runner.decide_cols_multi(slices, cluster.tel)
        return [AssignmentResult(cluster.instances, lz)
                for lz in lazies]

    def _fused_runner(self, sim: ClusterSim):
        """The lazily-built FusedHotPath over this sim's roster — THE
        seam hierarchical policies interpose on (a sharded runner, a
        per-cell runner), shared by `assign` and `assign_windows`."""
        if not sim.tel.alive.any():
            raise RuntimeError("no alive instances to schedule onto")
        if self._fused is None:
            from .hotpath import FusedHotPath
            self._fused = FusedHotPath.for_bundle(
                self.bundle, sim.instances, self.cfg)
        return self._fused

    def _decide_fused(self, batch: BatchView, sim: ClusterSim):
        """Single-dispatch path: one jitted device program per batch
        over the full instance roster (dead instances masked), staged
        from the SoA ingest columns."""
        runner = self._fused_runner(sim)
        # direct callers (tests, benches) arrive without a column
        # slice: derive one, building ephemeral columns if needed
        cols, rows = batch.columns(self.bundle.encoder)
        return sim.instances, runner.decide_cols(cols, rows, sim.tel)

    def _decide_staged(self, batch: BatchView, sim: ClusterSim):
        cfg = self.cfg
        reqs = batch.reqs
        # candidate roster = the SCHEDULER-VISIBLE rows: tel.alive, not
        # inst.alive — the telemetry watchdog quarantines stale rows by
        # masking them in the mirror while the worker stays up, and the
        # staged backends must see exactly the roster the fused backend
        # masks (slot k <-> sim.instances[k] by construction)
        tel = sim.tel
        alive_rows = np.flatnonzero(tel.alive)
        instances = [sim.instances[int(k)] for k in alive_rows]
        I = len(instances)
        R = len(reqs)
        m_of_i = np.array([inst.model_idx for inst in instances])
        tiers_of_i = [inst.tier for inst in instances]
        cols, rows = batch.cols, batch.rows
        if cols is None:
            from repro.serving.request import batch_columns
            cols, rows = batch_columns(reqs)

        # 1. batched prompt-intrinsic estimation (one call; the ingest
        # embedding column skips the encoder when available)
        Q, L = self.bundle.predict_prompts(reqs, cols=cols, rows=rows)
        q_inst = Q[:, m_of_i]                            # (R, I)
        l_inst = L[:, m_of_i]

        # 2. telemetry seed from the columnar view (non-blocking)
        d = tel.pending[alive_rows].copy()
        b = np.maximum(tel.batch[alive_rows], 1.0)
        free = tel.free[alive_rows].copy()
        ctx = np.maximum(tel.ctx[alive_rows], 64.0)
        maxb = tel.max_batch[alive_rows].copy()

        # 3. one TPOT-head call per TIER (not per instance)
        tpot = np.zeros(I)
        if cfg.latency_mode == "static_prior":
            tpot = np.array([self.bundle.heads[ti.name].nominal_tpot
                             for ti in tiers_of_i])
        else:
            by_tier: Dict[str, List[int]] = {}
            for i, ti in enumerate(tiers_of_i):
                by_tier.setdefault(ti.name, []).append(i)
            for tname, idxs in by_tier.items():
                feats = np.stack([
                    tpot_features(b[i], d[i], ctx[i]) for i in idxs])
                tpot[idxs] = self.bundle.heads[tname].tpot_batch(
                    feats, learned=cfg.learned_tpot)

        # 4+5. budget admission (Eq. 2) + LPT-ordered greedy with dead
        # reckoning — either the numpy loop or the jitted decision core
        price_in = np.array([ti.price_in for ti in tiers_of_i])
        price_out = np.array([ti.price_out for ti in tiers_of_i])
        if cols is not None:
            budgets = cols.budget[rows]
            len_in = cols.len_in[rows]
        else:
            budgets = np.array([np.nan if r.budget is None else r.budget
                                for r in reqs])
            len_in = np.array([r.prompt.len_in for r in reqs], float)
        nominal = np.array([self.bundle.heads[ti.name].nominal_tpot
                            for ti in tiers_of_i])
        # prefix-cache affinity (serving.affinity): both staged arms
        # compute the SAME host-side float32 discount matrix — the
        # fused backend evaluates the identical integer-compare +
        # float32 math in-graph, so all three backends score reuse
        # bit-identically
        aff = None
        if cfg.affinity_weight > 0.0:
            from repro.serving.affinity import (hit_fraction,
                                                prompt_signatures)
            if cols is not None:
                req_sig = cols.prefix_sig[cols.prompt_row[rows]]
            else:
                req_sig = np.stack([prompt_signatures(r.prompt)
                                    for r in reqs])
            hit = hit_fraction(req_sig, len_in.astype(np.float32),
                               tel.prefix_sig[alive_rows], np)
            aff = np.float32(cfg.affinity_weight) * hit
        if cfg.decision_backend == "jax":
            from . import decision_jax
            choice, _ = decision_jax.decide(
                q_inst, l_inst, L.max(axis=1), tpot, nominal, d, b, free,
                maxb, budgets, len_in, price_in, price_out, cfg.weights,
                latency_mode=cfg.latency_mode, lpt=cfg.lpt,
                budget_filter=cfg.budget_filter, affinity=aff)
        else:
            # the reference loop evaluates the decision arithmetic in
            # float32 — the jitted cores' precision — so the quantized
            # Eq. 1 tie groups are identical across all three backends
            # (greedy_assign follows the dtype of its inputs)
            f32 = np.float32
            budgets32, len_in32 = budgets.astype(f32), len_in.astype(f32)
            pi32, po32 = price_in.astype(f32), price_out.astype(f32)
            if cfg.budget_filter:
                allowed, c_hat = admission_mask(budgets32, len_in32,
                                                l_inst, pi32, po32)
            else:
                allowed = np.ones((R, I), bool)
                c_hat = cost_matrix(len_in32, l_inst, pi32, po32)
            order = lpt_order(L.max(axis=1), enable=cfg.lpt)
            choice, _ = greedy_assign(
                order, q_inst.astype(f32), c_hat, l_inst.astype(f32),
                tpot.astype(f32), d.astype(f32), b.astype(f32),
                free.astype(f32), maxb.astype(f32),
                cfg.weights, allowed, latency_mode=cfg.latency_mode,
                nominal_tpot=nominal.astype(f32), affinity=aff)
        l_chosen = l_inst[np.arange(R), choice]
        return instances, choice, l_chosen


class RouteBalance(ServingEngine):
    """The paper's deployment of the RouteBalance policy: windowed
    amortized batch scoring on the shared `ServingEngine`. Kept as a
    class so the historical constructor — ``RouteBalance(RBConfig(),
    bundle, tiers)`` — and the hot-path probes used by the benches and
    the differential harness (`_decide_core`, `_fused`) stay stable."""

    def __init__(self, cfg: RBConfig, bundle: EstimatorBundle,
                 tiers: Sequence[Tier]):
        # RBConfig's batch-formation knobs reach the engine through
        # RouteBalancePolicy.engine_overrides
        super().__init__(RouteBalancePolicy(cfg), bundle, tiers,
                         EngineConfig(deployment="windowed"))
        self.cfg = cfg

    @property
    def _fused(self):
        """The policy's lazily-built FusedHotPath (diagnostics)."""
        return self.policy._fused

    def _decide_core(self, batch: List[Request]
                     ) -> Tuple[List[Instance], np.ndarray, np.ndarray]:
        """The pure per-batch decision (no dispatch): returns the
        candidate roster plus (choice (R,) indices into it, l_chosen
        (R,) predicted length at the chosen instance). This is the hot
        path `benchmarks/hotpath.py` measures; it fetches eagerly —
        the engine's windowed dispatch defers the fetch to the
        dispatch point instead."""
        res = self.policy.assign(BatchView(batch), self.sim)
        choice, l_chosen = res.fetch()
        return res.instances, choice, l_chosen
