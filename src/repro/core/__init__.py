from .assignment import greedy_assign, greedy_assign_jax, hungarian, \
    lpt_order
from .budget import admission_mask, max_tokens_clamp
from .decision_jax import decide_batch as decide_batch_jax, \
    greedy_core as greedy_core_jax
from .dispatchers import DISPATCHERS, RandomDispatch, RoundRobin, \
    ShortestQueue
from .driver import make_requests, run_cell
from .engine import (AssignmentResult, BatchView, EngineConfig,
                     SchedulingPolicy, ServingEngine)
from .hotpath import FusedHotPath
from .pipeline import PipelineConfig, PipelineScheduler
from .policies import (POLICIES, RouterDispatchPolicy, fit_policy,
                       make_policy, register_policy, train_data)
from .routers import AvengersProRouter, BestRouteRouter, PassthroughRouter
from .scheduler import EstimatorBundle, RBConfig, RouteBalance, \
    RouteBalancePolicy
from .scoring import score_matrix, score_row
from .weights import PRESETS, sweep, validate
