"""Jitted full-parity decision core: the whole per-batch RouteBalance
decision as one array program (§4).

The numpy production loop (`assignment.greedy_assign`) walks the batch
request-by-request in Python; this module runs the identical math —
Eq. 1 scoring with per-request normalization (`scoring.masked_score`),
Eq. 2 budget admission (`budget.admission_math`), all four
``latency_mode`` isolation arms, LPT ordering and the dead-reckoned
state updates — as a single jitted ``lax.scan``, selectable in
production via ``RBConfig(decision_backend="jax")``.

Two jitted entry points:

  * ``greedy_core``  — the scan alone (order/mask precomputed), the
    drop-in twin of ``greedy_assign``; ``greedy_assign_jax`` delegates
    here.
  * ``decide_batch`` — the full per-batch pipeline (LPT order + Eq. 2
    admission + scan) traced end-to-end; ``decide`` is the numpy-in /
    numpy-out wrapper the scheduler calls.

The estimator step that feeds this core (batched KNN over prompt
embeddings) runs through the Pallas ``knn_topk`` kernel when the bundle
is built with ``KNNEstimator(backend="pallas")`` or the scheduler is
configured with ``RBConfig(knn_backend="pallas")``.

Differential parity with the numpy loop is asserted in
``tests/test_decision_parity.py`` across every mode arm. The math here
is float32 (the jit default) while numpy runs float64; the shared
scoring math epsilon-quantizes Eq. 1 scores (`repro.core.scoring`), so
sub-quantum float noise collapses to exact, identically-broken ties in
both precisions and the randomized soak asserts three-way assignment
parity on every seed with no pinned exclusions (``tests/test_soak.py``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .budget import admission_math, cost_matrix
from .scoring import affinity_discount, masked_score, quantize_scores

LATENCY_MODES = ("full", "off_reactive", "off_predictive", "static_prior")


def bucket_pow2(n: int, lo: int = 8) -> int:
    """Round a dynamic size up to the next power of two (floor `lo`) so
    jitted programs compile O(log) shape variants instead of one per
    size."""
    return max(lo, 1 << max(int(n) - 1, 0).bit_length())


def greedy_step(r, d, b, free, *, q_inst, c_hat, l_inst, tpot,
                nominal_tpot, b0, max_batch, weights, allowed,
                latency_mode, row_valid, affinity):
    """One greedy-scan step: Eq. 1 score for request ``r`` over the
    live dead-reckoned state, the pick, and the state update. THE one
    definition of the per-step arithmetic — `_greedy_scan`'s lax.scan
    (staged-jax and fused-XLA backends) and the Pallas megakernel's
    in-kernel fori_loop (`repro.kernels.decision_megakernel`) both
    trace this body, which is what makes their dead-reckoned carries
    bitwise identical by construction rather than by luck.

    Returns (d, b, free, i (int32 pick), est (float32 latency))."""
    wq, wl, wc = weights
    wait = jnp.where(free > 0, 0.0, d / jnp.maximum(b, 1.0))
    tpot_eff = tpot * jnp.maximum(b / b0, 1.0)
    if latency_mode == "static_prior":
        T = nominal_tpot * l_inst[r]
    else:
        T = tpot_eff * (wait + l_inst[r])
    if affinity is not None:
        T = affinity_discount(T, affinity[r], jnp)
    if latency_mode in ("off_reactive", "off_predictive"):
        s = masked_score(q_inst[r], c_hat[r], T, (wq, 0.0, wc),
                         allowed[r], jnp)
        # model score is instance-blind: tie-break within winner
        # model. The numpy loop subtracts 1e-9 * normalized tie in
        # float64; that term is below float32 eps for O(1) scores,
        # so realize the same order explicitly — least tie metric
        # among the score-tied candidates. Scores arrive
        # epsilon-quantized from masked_score, so the tie groups
        # are identical across float32/float64 backends.
        tie = (d + b) if latency_mode == "off_reactive" else T
        tn = tie / jnp.maximum(tie.max(), 1e-9)
        i = jnp.argmin(jnp.where(s >= s.max(), tn, jnp.inf))
    else:
        s = masked_score(q_inst[r], c_hat[r], T, (wq, wl, wc),
                         allowed[r], jnp)
        i = jnp.argmax(s)
    est = T[i]
    # dead reckoning: the chosen instance's pending work grows by L̂
    v = row_valid[r]
    d = d.at[i].add(jnp.where(v, l_inst[r, i], 0.0))
    has_free = (free[i] > 0) & v
    dec = jnp.where(has_free, 1.0, 0.0)
    free = free.at[i].add(-dec)
    b = b.at[i].set(jnp.where(has_free,
                              jnp.minimum(b[i] + 1.0, max_batch[i]),
                              b[i]))
    return d, b, free, i.astype(jnp.int32), est


def _greedy_scan(order, q_inst, c_hat, l_inst, tpot, nominal_tpot,
                 d, b, free, max_batch, weights, allowed,
                 latency_mode: str, row_valid=None, affinity=None):
    """Traced body shared by both entry points. Mirrors
    ``assignment.greedy_assign`` operation-for-operation.

    ``row_valid`` (R,) optionally marks shape-padding rows: invalid rows
    still pick (their choices are dropped by the caller) but apply NO
    dead-reckoning update, so callers that carry the post-scan state
    across batches (the fused hot path) don't accumulate phantom load.
    Defaults to all-valid, which is bitwise the original behavior.

    ``affinity`` (R, I) optionally carries the prefix-reuse discount
    (affinity_weight x matched-prefix fraction): T scales by
    (1 - affinity) before scoring/tie-break, identically to the numpy
    loop. None compiles the term out entirely."""
    b0 = jnp.maximum(b, 1.0)            # snapshot batch (TPOT reference)
    if row_valid is None:
        row_valid = jnp.ones(q_inst.shape[0], bool)

    def step(state, r):
        d, b, free = state
        d, b, free, i, est = greedy_step(
            r, d, b, free, q_inst=q_inst, c_hat=c_hat, l_inst=l_inst,
            tpot=tpot, nominal_tpot=nominal_tpot, b0=b0,
            max_batch=max_batch, weights=weights, allowed=allowed,
            latency_mode=latency_mode, row_valid=row_valid,
            affinity=affinity)
        return (d, b, free), (i, est)

    init = (d, b, free)
    (d, b, free), (picks, ests) = jax.lax.scan(step, init, order)
    # scan emits in LPT order; scatter back to request order
    choice = jnp.zeros_like(picks).at[order].set(picks)
    est_T = jnp.zeros_like(ests).at[order].set(ests)
    return choice, est_T, (d, b, free)


def _f(x):
    return jnp.asarray(x, jnp.float32)


@functools.partial(jax.jit, static_argnames=("latency_mode",))
def greedy_core(order, q_inst, c_hat, l_inst, tpot, nominal_tpot,
                d, b, free, max_batch, weights, allowed,
                latency_mode: str = "full", affinity=None):
    """Jitted greedy pass over a precomputed order + admission mask."""
    choice, est_T, state = _greedy_scan(
        jnp.asarray(order), _f(q_inst), _f(c_hat), _f(l_inst), _f(tpot),
        _f(nominal_tpot), _f(d), _f(b), _f(free), _f(max_batch),
        weights, jnp.asarray(allowed, bool), latency_mode,
        affinity=None if affinity is None else _f(affinity))
    return choice, est_T


@functools.partial(jax.jit, static_argnames=("latency_mode", "lpt",
                                             "budget_filter"))
def decide_batch(q_inst, l_inst, pred_len_max, tpot, nominal_tpot,
                 d, b, free, max_batch, budgets, len_in,
                 price_in, price_out, weights,
                 latency_mode: str = "full", lpt: bool = True,
                 budget_filter: bool = True, affinity=None):
    """The whole per-batch decision, traced end-to-end.

    q_inst/l_inst: (R, I) per-instance quality / predicted length;
    pred_len_max: (R,) max predicted length over *models* (LPT key);
    tpot/nominal_tpot/d/b/free/max_batch: (I,) instance state;
    budgets (R,) with nan = unconstrained; len_in (R,);
    price_in/price_out (I,); affinity optionally (R, I) prefix-reuse
    discount. Returns (choice (R,), est_T (R,), c_hat (R, I),
    allowed (R, I)).
    """
    q_inst, l_inst = _f(q_inst), _f(l_inst)
    budgets, len_in = _f(budgets), _f(len_in)
    price_in, price_out = _f(price_in), _f(price_out)
    R = q_inst.shape[0]
    if lpt:
        order = jnp.argsort(-_f(pred_len_max), stable=True)
    else:
        order = jnp.arange(R)
    if budget_filter:
        allowed, c_hat = admission_math(budgets, len_in, l_inst,
                                        price_in, price_out, jnp)
    else:
        c_hat = cost_matrix(len_in, l_inst, price_in, price_out, jnp)
        allowed = jnp.ones(c_hat.shape, bool)
    choice, est_T, _ = _greedy_scan(
        order, q_inst, c_hat, l_inst, _f(tpot), _f(nominal_tpot),
        _f(d), _f(b), _f(free), _f(max_batch), weights, allowed,
        latency_mode,
        affinity=None if affinity is None else _f(affinity))
    return choice, est_T, c_hat, allowed


def decide(q_inst: np.ndarray, l_inst: np.ndarray,
           pred_len_max: np.ndarray, tpot: np.ndarray,
           nominal_tpot: np.ndarray, d: np.ndarray, b: np.ndarray,
           free: np.ndarray, max_batch: np.ndarray,
           budgets: np.ndarray, len_in: np.ndarray,
           price_in: np.ndarray, price_out: np.ndarray, weights,
           latency_mode: str = "full", lpt: bool = True,
           budget_filter: bool = True,
           affinity: Optional[np.ndarray] = None
           ) -> Tuple[np.ndarray, np.ndarray]:
    """numpy-in / numpy-out wrapper for the scheduler hot path.

    Batches are padded up to the next power of two so the jit cache sees
    O(log R) distinct shapes instead of one per batch size. Padding is
    parity-safe: pad rows carry a -inf LPT key so they scan strictly
    after every real request — their dead-reckoning updates can only
    affect later (i.e. other pad) steps — and their choices are dropped.
    """
    R = q_inst.shape[0]
    Rp = bucket_pow2(R)
    if Rp != R:
        pad = Rp - R
        q_inst = np.pad(np.asarray(q_inst, float), ((0, pad), (0, 0)))
        l_inst = np.pad(np.asarray(l_inst, float), ((0, pad), (0, 0)))
        pred_len_max = np.concatenate(
            [np.asarray(pred_len_max, float), np.full(pad, -1e30)])
        budgets = np.concatenate(
            [np.asarray(budgets, float), np.full(pad, np.nan)])
        len_in = np.concatenate(
            [np.asarray(len_in, float), np.zeros(pad)])
        if affinity is not None:
            affinity = np.pad(np.asarray(affinity, np.float32),
                              ((0, pad), (0, 0)))
    weights = tuple(float(w) for w in weights)
    choice, est_T, _, _ = decide_batch(
        q_inst, l_inst, pred_len_max, tpot, nominal_tpot, d, b, free,
        max_batch, budgets, len_in, price_in, price_out, weights,
        latency_mode=latency_mode, lpt=lpt, budget_filter=budget_filter,
        affinity=affinity)
    return (np.asarray(choice[:R], np.int64),
            np.asarray(est_T[:R], np.float64))


# ---------------------------------------------------------------------------
# Cell-sharded greedy scan (hierarchical scheduling, ROADMAP item 1)
# ---------------------------------------------------------------------------
#
# `sharded_greedy_scan` is the cell-partitioned twin of `_greedy_scan`:
# the padded instance axis splits into `n_cells` contiguous blocks
# ("cells") and each step runs the per-instance arithmetic per block,
# combining across blocks with exact reductions only. The decomposition
# is bitwise-exact by construction, not by tolerance:
#
#   * every cross-instance reduction in `greedy_step` is a max / argmax
#     / argmin (the Eq. 1 normalizers cmax/tmax, s.max(), tie.max());
#     a max over the full axis equals the max of per-block maxima, with
#     no reassociation of additions anywhere;
#   * first-index argmax semantics survive the split: each block that
#     attains the global max contributes `block_offset + local_argmax`
#     (its own first attaining column) and the global winner is the
#     minimum of those, i.e. the globally-first attaining column;
#   * the per-step elementwise chain (wait/tpot_eff/T/score) is the
#     identical expression in the identical operation order as
#     `greedy_step`, evaluated on each block's slice of the same
#     float32 inputs; scores pass through the shared epsilon
#     quantization, which is what already makes numpy == jax == fused
#     exact across program boundaries;
#   * dead-reckoning updates land via drop-mode scatters so non-winner
#     cells are untouched bit-for-bit (no +0.0 writes that could flip a
#     -0.0).
#
# Two execution strategies share one step definition
# (`cell_greedy_step`), differing only in how the cross-cell reductions
# are spelled:
#
#   * mesh=None: single-program emulation — the cell axis is an array
#     dimension ((R, I) -> (R, C, Ic)) and the combines are reductions
#     over it. Runs anywhere, any cell count.
#   * mesh with a "cell" axis (see `repro.launch.mesh.make_cell_mesh`):
#     the same body under `shard_map`, one block per device, combines
#     as pmax/pmin/psum collectives (`repro.launch.sharding.cell_specs`
#     pins the layout). This is the arm that lets one logical decision
#     span cells when the mesh has the devices.


def _local_max(x):
    return jnp.max(x, axis=-1, keepdims=True)


def cell_greedy_step(r, d, b, free, *, q_inst, c_hat, l_inst, tpot,
                     nominal_tpot, b0, max_batch, weights, allowed,
                     latency_mode, row_valid, affinity, offs,
                     gmax, gmin, gsum):
    """One greedy step over cell-sharded state. All per-instance arrays
    carry a leading cell axis: (C, Ic) state, (R, C, Ic) per-request
    planes (C is the local cell count — `n_cells` in the single-program
    emulation, 1 per device under shard_map). `offs` (C, 1) int32 is
    each block's global column offset; gmax/gmin/gsum reduce a (C, 1)
    per-cell scalar across ALL cells (array reduction or collective).

    Mirrors `greedy_step` operation-for-operation; returns
    (d, b, free, i (int32 GLOBAL pick), est (float32))."""
    wq, wl, wc = weights
    Ic = d.shape[-1]
    rows = jnp.arange(d.shape[0])
    wait = jnp.where(free > 0, 0.0, d / jnp.maximum(b, 1.0))
    tpot_eff = tpot * jnp.maximum(b / b0, 1.0)
    if latency_mode == "static_prior":
        T = nominal_tpot * l_inst[r]
    else:
        T = tpot_eff * (wait + l_inst[r])
    if affinity is not None:
        T = affinity_discount(T, affinity[r], jnp)
    mask = allowed[r]
    q_r, c_r = q_inst[r], c_hat[r]
    neg = -jnp.inf
    # masked_score with GLOBAL normalizers: per-cell max of the masked
    # plane, cross-cell max, then the same maximum(., eps) clamp — the
    # identical value masked_score computes over the full axis.
    cmax = jnp.maximum(gmax(_local_max(jnp.where(mask, c_r, neg))), 1e-12)
    if latency_mode in ("off_reactive", "off_predictive"):
        sw_l = 0.0
    else:
        sw_l = wl
    tmax = jnp.maximum(gmax(_local_max(jnp.where(mask, T, neg))), 1e-12)
    s = wq * q_r + wc * (1.0 - c_r / cmax) + sw_l * (1.0 - T / tmax)
    s = jnp.where(mask, quantize_scores(s, jnp), neg)
    big = jnp.int32(2 ** 30)
    if latency_mode in ("off_reactive", "off_predictive"):
        # instance-blind model score: tie-break by least normalized tie
        # metric among the score-tied candidates (see greedy_step)
        tie = (d + b) if latency_mode == "off_reactive" else T
        tn = tie / jnp.maximum(gmax(_local_max(tie)), 1e-9)
        smax = gmax(_local_max(s))
        v = jnp.where(s >= smax, tn, jnp.inf)
        vloc = jnp.min(v, axis=-1, keepdims=True)
        aloc = jnp.argmin(v, axis=-1).astype(jnp.int32)
        vglob = gmin(vloc)
        cand = jnp.where(vloc == vglob, offs + aloc[:, None], big)
    else:
        sloc = _local_max(s)
        aloc = jnp.argmax(s, axis=-1).astype(jnp.int32)
        smax = gmax(sloc)
        cand = jnp.where(sloc == smax, offs + aloc[:, None], big)
    i = gmin(cand)[0, 0]                      # global first attaining col
    li = jnp.clip(i - offs[:, 0], 0, Ic - 1)  # winner's local column
    in_cell = (i >= offs[:, 0]) & (i < offs[:, 0] + Ic)
    # est = T at the winner: exactly one cell contributes, rest add 0.0
    est = gsum(jnp.where(in_cell, T[rows, li], 0.0)[:, None])[0, 0]
    # dead reckoning on the winner cell only; drop-mode scatters keep
    # every other cell's state bit-identical
    upd = in_cell & row_valid[r]
    sc = jnp.where(upd, li, Ic)               # Ic = out of range -> drop
    d = d.at[rows, sc].add(l_inst[r][rows, li], mode="drop")
    has_free = (free[rows, li] > 0) & upd
    scf = jnp.where(has_free, li, Ic)
    free = free.at[rows, scf].add(-1.0, mode="drop")
    b = b.at[rows, scf].set(
        jnp.minimum(b[rows, li] + 1.0, max_batch[rows, li]), mode="drop")
    return d, b, free, i.astype(jnp.int32), est


def cell_greedy_scan(order, q_inst, c_hat, l_inst, tpot, nominal_tpot,
                     d, b, free, max_batch, weights, allowed,
                     latency_mode: str, row_valid=None, affinity=None,
                     *, offs, gmax, gmin, gsum):
    """`_greedy_scan` over cell-sharded arrays (see `cell_greedy_step`
    for shapes). Returns (choice (R,) GLOBAL columns, est_T (R,),
    (d, b, free) still cell-sharded)."""
    b0 = jnp.maximum(b, 1.0)            # snapshot batch (TPOT reference)
    if row_valid is None:
        row_valid = jnp.ones(q_inst.shape[0], bool)

    def step(state, r):
        d, b, free = state
        d, b, free, i, est = cell_greedy_step(
            r, d, b, free, q_inst=q_inst, c_hat=c_hat, l_inst=l_inst,
            tpot=tpot, nominal_tpot=nominal_tpot, b0=b0,
            max_batch=max_batch, weights=weights, allowed=allowed,
            latency_mode=latency_mode, row_valid=row_valid,
            affinity=affinity, offs=offs, gmax=gmax, gmin=gmin,
            gsum=gsum)
        return (d, b, free), (i, est)

    init = (d, b, free)
    (d, b, free), (picks, ests) = jax.lax.scan(step, init, order)
    choice = jnp.zeros_like(picks).at[order].set(picks)
    est_T = jnp.zeros_like(ests).at[order].set(ests)
    return choice, est_T, (d, b, free)


def sharded_greedy_scan(order, q_inst, c_hat, l_inst, tpot,
                        nominal_tpot, d, b, free, max_batch, weights,
                        allowed, latency_mode: str, row_valid=None,
                        affinity=None, *, n_cells: int, mesh=None):
    """Drop-in cell-sharded replacement for `_greedy_scan`: same
    flat-array signature in and out, bitwise-identical results (see the
    section comment for the exactness argument). The padded instance
    axis must divide evenly into `n_cells` contiguous blocks — callers
    pass pow2 cell counts against the pow2-bucketed column axis.

    mesh=None runs the single-program emulation; a mesh carrying a
    "cell" axis of size `n_cells` runs one block per device under
    shard_map with pmax/pmin/psum combines."""
    I = q_inst.shape[-1]
    C = int(n_cells)
    if C <= 1:
        return _greedy_scan(order, q_inst, c_hat, l_inst, tpot,
                            nominal_tpot, d, b, free, max_batch,
                            weights, allowed, latency_mode,
                            row_valid=row_valid, affinity=affinity)
    assert I % C == 0, (I, C)
    Ic = I // C

    def r2(x):                                    # (R, I) -> (R, C, Ic)
        return x.reshape(x.shape[0], C, Ic)

    def r1(x):                                    # (I,)   -> (C, Ic)
        return x.reshape(C, Ic)

    q3, c3, l3, al3 = r2(q_inst), r2(c_hat), r2(l_inst), r2(allowed)
    tp2, nm2 = r1(tpot), r1(nominal_tpot)
    d2, b2, f2, mb2 = r1(d), r1(b), r1(free), r1(max_batch)
    a3 = None if affinity is None else r2(affinity)

    if mesh is None:
        offs = (jnp.arange(C, dtype=jnp.int32) * Ic)[:, None]
        choice, est_T, (d2, b2, f2) = cell_greedy_scan(
            order, q3, c3, l3, tp2, nm2, d2, b2, f2, mb2, weights,
            al3, latency_mode, row_valid=row_valid, affinity=a3,
            offs=offs,
            gmax=lambda x: jnp.max(x, axis=0, keepdims=True),
            gmin=lambda x: jnp.min(x, axis=0, keepdims=True),
            gsum=lambda x: jnp.sum(x, axis=0, keepdims=True))
        return choice, est_T, (d2.reshape(I), b2.reshape(I),
                               f2.reshape(I))

    from jax.experimental.shard_map import shard_map

    from ..launch.sharding import cell_specs
    pr, pi, pn = cell_specs()
    if row_valid is None:
        row_valid = jnp.ones(q_inst.shape[0], bool)
    has_aff = a3 is not None

    def body(order, q3, c3, l3, tp2, nm2, d2, b2, f2, mb2, al3, rv,
             *rest):
        idx = jax.lax.axis_index("cell").astype(jnp.int32)
        offs = (idx * Ic).reshape(1, 1)
        choice, est_T, state = cell_greedy_scan(
            order, q3, c3, l3, tp2, nm2, d2, b2, f2, mb2, weights,
            al3, latency_mode, row_valid=rv,
            affinity=rest[0] if has_aff else None, offs=offs,
            gmax=lambda x: jax.lax.pmax(
                jnp.max(x, axis=0, keepdims=True), "cell"),
            gmin=lambda x: jax.lax.pmin(
                jnp.min(x, axis=0, keepdims=True), "cell"),
            gsum=lambda x: jax.lax.psum(
                jnp.sum(x, axis=0, keepdims=True), "cell"))
        return choice, est_T, state

    in_specs = [pn, pr, pr, pr, pi, pi, pi, pi, pi, pi, pr, pn]
    args = [order, q3, c3, l3, tp2, nm2, d2, b2, f2, mb2, al3,
            row_valid]
    if has_aff:
        in_specs.append(pr)
        args.append(a3)
    choice, est_T, (d2, b2, f2) = shard_map(
        body, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(pn, pn, (pi, pi, pi)), check_rep=False)(*args)
    return choice, est_T, (d2.reshape(I), b2.reshape(I), f2.reshape(I))
