"""Jitted full-parity decision core: the whole per-batch RouteBalance
decision as one array program (§4).

The numpy production loop (`assignment.greedy_assign`) walks the batch
request-by-request in Python; this module runs the identical math —
Eq. 1 scoring with per-request normalization (`scoring.masked_score`),
Eq. 2 budget admission (`budget.admission_math`), all four
``latency_mode`` isolation arms, LPT ordering and the dead-reckoned
state updates — as a single jitted ``lax.scan``, selectable in
production via ``RBConfig(decision_backend="jax")``.

Two jitted entry points:

  * ``greedy_core``  — the scan alone (order/mask precomputed), the
    drop-in twin of ``greedy_assign``; ``greedy_assign_jax`` delegates
    here.
  * ``decide_batch`` — the full per-batch pipeline (LPT order + Eq. 2
    admission + scan) traced end-to-end; ``decide`` is the numpy-in /
    numpy-out wrapper the scheduler calls.

The estimator step that feeds this core (batched KNN over prompt
embeddings) runs through the Pallas ``knn_topk`` kernel when the bundle
is built with ``KNNEstimator(backend="pallas")`` or the scheduler is
configured with ``RBConfig(knn_backend="pallas")``.

Differential parity with the numpy loop is asserted in
``tests/test_decision_parity.py`` across every mode arm. The math here
is float32 (the jit default) while numpy runs float64; the shared
scoring math epsilon-quantizes Eq. 1 scores (`repro.core.scoring`), so
sub-quantum float noise collapses to exact, identically-broken ties in
both precisions and the randomized soak asserts three-way assignment
parity on every seed with no pinned exclusions (``tests/test_soak.py``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .budget import admission_math, cost_matrix
from .scoring import affinity_discount, masked_score

LATENCY_MODES = ("full", "off_reactive", "off_predictive", "static_prior")


def bucket_pow2(n: int, lo: int = 8) -> int:
    """Round a dynamic size up to the next power of two (floor `lo`) so
    jitted programs compile O(log) shape variants instead of one per
    size."""
    return max(lo, 1 << max(int(n) - 1, 0).bit_length())


def greedy_step(r, d, b, free, *, q_inst, c_hat, l_inst, tpot,
                nominal_tpot, b0, max_batch, weights, allowed,
                latency_mode, row_valid, affinity):
    """One greedy-scan step: Eq. 1 score for request ``r`` over the
    live dead-reckoned state, the pick, and the state update. THE one
    definition of the per-step arithmetic — `_greedy_scan`'s lax.scan
    (staged-jax and fused-XLA backends) and the Pallas megakernel's
    in-kernel fori_loop (`repro.kernels.decision_megakernel`) both
    trace this body, which is what makes their dead-reckoned carries
    bitwise identical by construction rather than by luck.

    Returns (d, b, free, i (int32 pick), est (float32 latency))."""
    wq, wl, wc = weights
    wait = jnp.where(free > 0, 0.0, d / jnp.maximum(b, 1.0))
    tpot_eff = tpot * jnp.maximum(b / b0, 1.0)
    if latency_mode == "static_prior":
        T = nominal_tpot * l_inst[r]
    else:
        T = tpot_eff * (wait + l_inst[r])
    if affinity is not None:
        T = affinity_discount(T, affinity[r], jnp)
    if latency_mode in ("off_reactive", "off_predictive"):
        s = masked_score(q_inst[r], c_hat[r], T, (wq, 0.0, wc),
                         allowed[r], jnp)
        # model score is instance-blind: tie-break within winner
        # model. The numpy loop subtracts 1e-9 * normalized tie in
        # float64; that term is below float32 eps for O(1) scores,
        # so realize the same order explicitly — least tie metric
        # among the score-tied candidates. Scores arrive
        # epsilon-quantized from masked_score, so the tie groups
        # are identical across float32/float64 backends.
        tie = (d + b) if latency_mode == "off_reactive" else T
        tn = tie / jnp.maximum(tie.max(), 1e-9)
        i = jnp.argmin(jnp.where(s >= s.max(), tn, jnp.inf))
    else:
        s = masked_score(q_inst[r], c_hat[r], T, (wq, wl, wc),
                         allowed[r], jnp)
        i = jnp.argmax(s)
    est = T[i]
    # dead reckoning: the chosen instance's pending work grows by L̂
    v = row_valid[r]
    d = d.at[i].add(jnp.where(v, l_inst[r, i], 0.0))
    has_free = (free[i] > 0) & v
    dec = jnp.where(has_free, 1.0, 0.0)
    free = free.at[i].add(-dec)
    b = b.at[i].set(jnp.where(has_free,
                              jnp.minimum(b[i] + 1.0, max_batch[i]),
                              b[i]))
    return d, b, free, i.astype(jnp.int32), est


def _greedy_scan(order, q_inst, c_hat, l_inst, tpot, nominal_tpot,
                 d, b, free, max_batch, weights, allowed,
                 latency_mode: str, row_valid=None, affinity=None):
    """Traced body shared by both entry points. Mirrors
    ``assignment.greedy_assign`` operation-for-operation.

    ``row_valid`` (R,) optionally marks shape-padding rows: invalid rows
    still pick (their choices are dropped by the caller) but apply NO
    dead-reckoning update, so callers that carry the post-scan state
    across batches (the fused hot path) don't accumulate phantom load.
    Defaults to all-valid, which is bitwise the original behavior.

    ``affinity`` (R, I) optionally carries the prefix-reuse discount
    (affinity_weight x matched-prefix fraction): T scales by
    (1 - affinity) before scoring/tie-break, identically to the numpy
    loop. None compiles the term out entirely."""
    b0 = jnp.maximum(b, 1.0)            # snapshot batch (TPOT reference)
    if row_valid is None:
        row_valid = jnp.ones(q_inst.shape[0], bool)

    def step(state, r):
        d, b, free = state
        d, b, free, i, est = greedy_step(
            r, d, b, free, q_inst=q_inst, c_hat=c_hat, l_inst=l_inst,
            tpot=tpot, nominal_tpot=nominal_tpot, b0=b0,
            max_batch=max_batch, weights=weights, allowed=allowed,
            latency_mode=latency_mode, row_valid=row_valid,
            affinity=affinity)
        return (d, b, free), (i, est)

    init = (d, b, free)
    (d, b, free), (picks, ests) = jax.lax.scan(step, init, order)
    # scan emits in LPT order; scatter back to request order
    choice = jnp.zeros_like(picks).at[order].set(picks)
    est_T = jnp.zeros_like(ests).at[order].set(ests)
    return choice, est_T, (d, b, free)


def _f(x):
    return jnp.asarray(x, jnp.float32)


@functools.partial(jax.jit, static_argnames=("latency_mode",))
def greedy_core(order, q_inst, c_hat, l_inst, tpot, nominal_tpot,
                d, b, free, max_batch, weights, allowed,
                latency_mode: str = "full", affinity=None):
    """Jitted greedy pass over a precomputed order + admission mask."""
    choice, est_T, state = _greedy_scan(
        jnp.asarray(order), _f(q_inst), _f(c_hat), _f(l_inst), _f(tpot),
        _f(nominal_tpot), _f(d), _f(b), _f(free), _f(max_batch),
        weights, jnp.asarray(allowed, bool), latency_mode,
        affinity=None if affinity is None else _f(affinity))
    return choice, est_T


@functools.partial(jax.jit, static_argnames=("latency_mode", "lpt",
                                             "budget_filter"))
def decide_batch(q_inst, l_inst, pred_len_max, tpot, nominal_tpot,
                 d, b, free, max_batch, budgets, len_in,
                 price_in, price_out, weights,
                 latency_mode: str = "full", lpt: bool = True,
                 budget_filter: bool = True, affinity=None):
    """The whole per-batch decision, traced end-to-end.

    q_inst/l_inst: (R, I) per-instance quality / predicted length;
    pred_len_max: (R,) max predicted length over *models* (LPT key);
    tpot/nominal_tpot/d/b/free/max_batch: (I,) instance state;
    budgets (R,) with nan = unconstrained; len_in (R,);
    price_in/price_out (I,); affinity optionally (R, I) prefix-reuse
    discount. Returns (choice (R,), est_T (R,), c_hat (R, I),
    allowed (R, I)).
    """
    q_inst, l_inst = _f(q_inst), _f(l_inst)
    budgets, len_in = _f(budgets), _f(len_in)
    price_in, price_out = _f(price_in), _f(price_out)
    R = q_inst.shape[0]
    if lpt:
        order = jnp.argsort(-_f(pred_len_max), stable=True)
    else:
        order = jnp.arange(R)
    if budget_filter:
        allowed, c_hat = admission_math(budgets, len_in, l_inst,
                                        price_in, price_out, jnp)
    else:
        c_hat = cost_matrix(len_in, l_inst, price_in, price_out, jnp)
        allowed = jnp.ones(c_hat.shape, bool)
    choice, est_T, _ = _greedy_scan(
        order, q_inst, c_hat, l_inst, _f(tpot), _f(nominal_tpot),
        _f(d), _f(b), _f(free), _f(max_batch), weights, allowed,
        latency_mode,
        affinity=None if affinity is None else _f(affinity))
    return choice, est_T, c_hat, allowed


def decide(q_inst: np.ndarray, l_inst: np.ndarray,
           pred_len_max: np.ndarray, tpot: np.ndarray,
           nominal_tpot: np.ndarray, d: np.ndarray, b: np.ndarray,
           free: np.ndarray, max_batch: np.ndarray,
           budgets: np.ndarray, len_in: np.ndarray,
           price_in: np.ndarray, price_out: np.ndarray, weights,
           latency_mode: str = "full", lpt: bool = True,
           budget_filter: bool = True,
           affinity: Optional[np.ndarray] = None
           ) -> Tuple[np.ndarray, np.ndarray]:
    """numpy-in / numpy-out wrapper for the scheduler hot path.

    Batches are padded up to the next power of two so the jit cache sees
    O(log R) distinct shapes instead of one per batch size. Padding is
    parity-safe: pad rows carry a -inf LPT key so they scan strictly
    after every real request — their dead-reckoning updates can only
    affect later (i.e. other pad) steps — and their choices are dropped.
    """
    R = q_inst.shape[0]
    Rp = bucket_pow2(R)
    if Rp != R:
        pad = Rp - R
        q_inst = np.pad(np.asarray(q_inst, float), ((0, pad), (0, 0)))
        l_inst = np.pad(np.asarray(l_inst, float), ((0, pad), (0, 0)))
        pred_len_max = np.concatenate(
            [np.asarray(pred_len_max, float), np.full(pad, -1e30)])
        budgets = np.concatenate(
            [np.asarray(budgets, float), np.full(pad, np.nan)])
        len_in = np.concatenate(
            [np.asarray(len_in, float), np.zeros(pad)])
        if affinity is not None:
            affinity = np.pad(np.asarray(affinity, np.float32),
                              ((0, pad), (0, 0)))
    weights = tuple(float(w) for w in weights)
    choice, est_T, _, _ = decide_batch(
        q_inst, l_inst, pred_len_max, tpot, nominal_tpot, d, b, free,
        max_batch, budgets, len_in, price_in, price_out, weights,
        latency_mode=latency_mode, lpt=lpt, budget_filter=budget_filter,
        affinity=affinity)
    return (np.asarray(choice[:R], np.int64),
            np.asarray(est_T[:R], np.float64))
