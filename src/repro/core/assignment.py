"""LPT-ordered greedy assignment with dead reckoning (Algorithm 1), plus a
jitted JAX variant (the whole per-batch decision as one array program) and
a Hungarian reference for the greedy-gap replay (§4.1).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .scoring import affinity_discount, score_row


def lpt_order(pred_len_max: np.ndarray, enable: bool = True) -> np.ndarray:
    """Longest-predicted-output-first (Graham's LPT rule; §4.1).
    Sort key is max over models since the model is not yet chosen."""
    if not enable:
        return np.arange(len(pred_len_max))
    return np.argsort(-pred_len_max, kind="stable")


def greedy_assign(order: np.ndarray, q_hat_inst: np.ndarray,
                  c_hat: np.ndarray, len_inst: np.ndarray,
                  tpot: np.ndarray, d: np.ndarray, b: np.ndarray,
                  free: np.ndarray, max_batch: np.ndarray, weights,
                  allowed: Optional[np.ndarray] = None,
                  latency_mode: str = "full",
                  nominal_tpot: Optional[np.ndarray] = None,
                  rr_state: int = 0,
                  affinity: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, Dict]:
    """Sequential greedy over the batch in LPT order.

    q_hat_inst/len_inst/c_hat: (R, I) per-instance expansions; tpot: (I,)
    predicted per-iteration time; d/b/free: (I,) dead-reckoned instance
    state (pending decode tokens, decode batch, free slots). Each dispatch
    updates the LOCAL copy of the chosen instance's state so later
    requests see the consequences of earlier ones — no herding (§4.2).

    latency_mode: full | off_reactive | off_predictive | static_prior
    (the four isolation arms of §6.3).

    affinity: optional (R, I) float32 prefix-reuse discount
    (affinity_weight x matched-prefix fraction, `serving.affinity`):
    the predicted latency T is scaled by (1 - affinity) BEFORE scoring,
    tie-breaks and est_latency — a warm prefix cache shortens this
    request's effective prefill on that instance.
    """
    R, I = q_hat_inst.shape
    choice = np.full(R, -1, np.int64)
    # the loop follows the input dtype: the scheduler's staged numpy
    # path passes float32 so all three backends share one arithmetic
    # contract (the T/score chains are then bitwise the jitted cores');
    # direct callers with float64 inputs keep the legacy double loop
    dt = np.float32 if q_hat_inst.dtype == np.float32 else np.float64
    q_hat_inst = np.asarray(q_hat_inst, dt)
    c_hat = np.asarray(c_hat, dt)
    len_inst = np.asarray(len_inst, dt)
    tpot = np.asarray(tpot, dt)
    if nominal_tpot is not None:
        nominal_tpot = np.asarray(nominal_tpot, dt)
    max_batch = np.asarray(max_batch, dt)
    d = d.astype(dt).copy()
    b = b.astype(dt).copy()
    b0 = np.maximum(b.copy(), dt(1.0))  # snapshot batch (TPOT reference)
    free = free.astype(dt).copy()
    est_T = np.zeros(R)
    for r in order:
        wait = np.where(free > 0, 0.0, d / np.maximum(b, 1.0))
        # in-batch dispatches grow the decode batch beyond the snapshot the
        # TPOT head saw; scale conservatively (compute-bound regime is
        # ~linear in batch) so idle-but-identical instances don't herd.
        tpot_eff = tpot * np.maximum(b / b0, 1.0)
        if latency_mode == "static_prior":
            T = (nominal_tpot if nominal_tpot is not None else tpot) \
                * len_inst[r]
        else:
            T = tpot_eff * (wait + len_inst[r])
        if affinity is not None:
            T = affinity_discount(T, affinity[r], np).astype(dt)
        if latency_mode in ("off_reactive", "off_predictive"):
            w = (weights[0], 0.0, weights[2])
            s = score_row(q_hat_inst[r], c_hat[r], T, w,
                          None if allowed is None else allowed[r])
            # model score is instance-blind: tie-break within winner
            # model. Scores come back epsilon-quantized (exact multiples
            # of SCORE_QUANTUM), so the 1e-9 nudge — far below the
            # quantum, far above float64 eps — orders candidates inside
            # a quantized tie group without ever crossing groups. The
            # nudge runs in float64 even when the loop is float32 (it
            # would underflow an O(1) float32 score).
            tie = (d + b) if latency_mode == "off_reactive" else T
            tn = (tie / max(tie.max(), 1e-9)).astype(np.float64)
            s = s.astype(np.float64) - 1e-9 * tn
        else:
            s = score_row(q_hat_inst[r], c_hat[r], T, weights,
                          None if allowed is None else allowed[r])
        i = int(np.argmax(s))
        choice[r] = i
        est_T[r] = T[i]
        # dead reckoning: the chosen instance's pending work grows by L̂
        d[i] += len_inst[r, i]
        if free[i] > 0:
            free[i] -= 1
            b[i] = min(b[i] + 1, max_batch[i])
    return choice, {"est_latency": est_T}


# ---------------------------------------------------------------------------
# JAX variant: delegates to the jitted full-parity decision core
# (repro.core.decision_jax) — the whole greedy pass as one lax.scan,
# sharing the Eq. 1 / dead-reckoning math with the numpy loop above.

def greedy_assign_jax(order, q_hat_inst, c_hat, len_inst, tpot, d, b, free,
                      max_batch, weights,
                      allowed: Optional[np.ndarray] = None,
                      latency_mode: str = "full",
                      nominal_tpot: Optional[np.ndarray] = None):
    from .decision_jax import greedy_core

    if allowed is None:
        allowed = np.ones(np.shape(q_hat_inst), bool)
    if nominal_tpot is None:
        nominal_tpot = tpot
    weights = tuple(float(w) for w in weights)
    choice, _ = greedy_core(np.asarray(order), q_hat_inst, c_hat,
                            len_inst, tpot, nominal_tpot, d, b, free,
                            max_batch, weights, allowed,
                            latency_mode=latency_mode)
    return choice


# ---------------------------------------------------------------------------
# Hungarian (Jonker-free O(n^3) reference) for the offline replay: a
# batch-level matching differs from greedy only through within-batch state
# updates; the paper measures 15.6% assignment divergence with -0.002
# realized quality (§4.1).

def hungarian(cost: np.ndarray) -> np.ndarray:
    """Minimal-cost assignment; cost (n, m), n <= m. Returns col of each
    row. Classic potentials implementation."""
    n, m = cost.shape
    assert n <= m
    INF = float("inf")
    u = np.zeros(n + 1)
    v = np.zeros(m + 1)
    p = np.zeros(m + 1, np.int64)      # p[j] = row matched to col j (1-idx)
    way = np.zeros(m + 1, np.int64)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(m + 1, INF)
        used = np.zeros(m + 1, bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            delta = INF
            j1 = -1
            for j in range(1, m + 1):
                if used[j]:
                    continue
                cur = cost[i0 - 1, j - 1] - u[i0] - v[j]
                if cur < minv[j]:
                    minv[j] = cur
                    way[j] = j0
                if minv[j] < delta:
                    delta = minv[j]
                    j1 = j
            for j in range(m + 1):
                if used[j]:
                    u[p[j]] += delta
                    v[j] -= delta
                else:
                    minv[j] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    ans = np.zeros(n, np.int64)
    for j in range(1, m + 1):
        if p[j] > 0:
            ans[p[j] - 1] = j - 1
    return ans
