"""AdamW with global-norm clipping and cosine/linear LR schedules.

Self-contained (no optax). State is a plain pytree of f32 moments shaped
like the params, so the launcher can ZeRO-shard it with ordinary
PartitionSpecs; the update is pure jnp and lowers under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"      # cosine | constant


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.float32(0.0)))


def init(params) -> Dict[str, Any]:
    zeros = lambda tree: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), tree)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


_NO_DECAY = ("norm", "scale", "bias", "lam", "A_log", "dt_bias", "D_skip",
             "positions", "pos_dec")


def _decay_mask(path) -> bool:
    s = jax.tree_util.keystr(path)
    return not any(t in s for t in _NO_DECAY)


def update(cfg: AdamWConfig, grads, state, params
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** (step.astype(jnp.float32) + 1.0)
    b2c = 1.0 - cfg.b2 ** (step.astype(jnp.float32) + 1.0)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    gs = jax.tree.leaves(grads)
    ms = jax.tree.leaves(state["m"])
    vs = jax.tree.leaves(state["v"])
    out = [upd(pa, p, g, m, v)
           for (pa, p), g, m, v in zip(flat, gs, ms, vs)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
