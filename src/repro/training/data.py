"""Deterministic synthetic token pipeline for the training substrate.

Zipf-distributed token streams with enough structure (topic blocks +
local n-gram correlations) that a small LM's loss visibly decreases over
a few hundred steps. Sharding-friendly: the iterator yields global
batches; the launcher shards them over the data axes.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_topics: int = 8):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = global_batch
        self.rng = np.random.default_rng(seed)
        self.n_topics = n_topics
        self.block = max(vocab // (2 * n_topics), 8)

    def _seq(self) -> np.ndarray:
        t = self.rng.integers(0, self.n_topics)
        base = t * self.block
        # zipfian draws inside the topic block + bigram-ish repetition
        z = self.rng.zipf(1.3, self.seq_len + 1) % self.block
        toks = base + z
        rep = self.rng.uniform(size=self.seq_len + 1) < 0.25
        toks[1:][rep[1:]] = toks[:-1][rep[1:]]
        return toks.astype(np.int32) % self.vocab

    def batches(self, n_steps: Optional[int] = None
                ) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while n_steps is None or step < n_steps:
            arr = np.stack([self._seq() for _ in range(self.batch)])
            yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
            step += 1


def batch_for(cfg, seq_len: int, global_batch: int, seed: int = 0):
    """One batch shaped for an arbitrary zoo config (incl. frontends)."""
    rng = np.random.default_rng(seed)
    if cfg.is_encdec:
        dec = min(cfg.dec_max_len, seq_len)
        return {
            "frames": rng.normal(size=(global_batch, seq_len,
                                       cfg.frontend_dim)).astype(np.float32),
            "tokens": rng.integers(0, cfg.vocab,
                                   (global_batch, dec)).astype(np.int32),
            "labels": rng.integers(0, cfg.vocab,
                                   (global_batch, dec)).astype(np.int32),
        }
    out = {}
    s = seq_len
    if cfg.frontend == "vision":
        nf = cfg.n_frontend_tokens
        out["frontend_embeds"] = rng.normal(
            size=(global_batch, nf, cfg.frontend_dim)).astype(np.float32)
        s = max(seq_len - nf, 1)
    ts = TokenStream(cfg.vocab, s, global_batch, seed)
    b = next(ts.batches(1))
    out.update(b)
    return out
