"""Fault-tolerant training loop: jitted step + periodic atomic
checkpoints + crash-restart resume. Used by examples/train_small.py and
the integration tests."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.distributed.checkpoint import CheckpointManager
from repro.launch.steps import init_opt_state, make_train_step
from repro.models import Model
from repro.training import optimizer as opt
from repro.training.data import TokenStream


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 20
    grad_compression: bool = False
    microbatches: int = 1
    ocfg: opt.AdamWConfig = dataclasses.field(
        default_factory=lambda: opt.AdamWConfig(
            lr=1e-3, warmup_steps=20, total_steps=400))


def train(model: Model, data: TokenStream, tcfg: TrainConfig,
          seed: int = 0, log: Callable[[str], None] = print) -> Dict:
    params = model.init(jax.random.key(seed))
    opt_state = init_opt_state(params, compression=tcfg.grad_compression)
    start_step = 0
    mgr = None
    if tcfg.ckpt_dir:
        mgr = CheckpointManager(tcfg.ckpt_dir)
        latest = mgr.latest_step()
        if latest is not None:
            (params, opt_state), start_step = (
                mgr.restore((params, opt_state))[0], latest)
            log(f"resumed from checkpoint step {start_step}")
    step_fn = jax.jit(make_train_step(
        model, tcfg.ocfg, microbatches=tcfg.microbatches,
        grad_compression=tcfg.grad_compression))
    losses = []
    it = data.batches()
    t0 = time.time()
    for step in range(start_step, tcfg.n_steps):
        batch = next(it)
        params, opt_state, mets = step_fn(params, opt_state, batch)
        losses.append(float(mets["loss"]))
        if step % tcfg.log_every == 0 or step == tcfg.n_steps - 1:
            log(f"step {step:5d} loss {losses[-1]:.4f} "
                f"gnorm {float(mets['grad_norm']):.3f} "
                f"({time.time()-t0:.0f}s)")
        if mgr and (step + 1) % tcfg.ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state))
    return {"params": params, "opt_state": opt_state,
            "losses": np.asarray(losses),
            "final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan")}
