"""Jittable train / prefill / decode steps + their sharding assignments.

``lower_cell`` builds the AOT-lowered computation for one (arch x shape x
mesh) dry-run cell entirely from ShapeDtypeStructs — nothing is allocated.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import input_specs
from repro.distributed import shardctx
from repro.launch import sharding as shr
from repro.models import Model, greedy_sample
from repro.models.config import ModelConfig, ShapeSpec
from repro.training import optimizer as opt


def make_train_step(model: Model, ocfg: opt.AdamWConfig,
                    microbatches: int = 1,
                    grad_compression: bool = False):
    """microbatches > 1 => gradient accumulation: the global batch is split
    into k sequential microbatches (scanned), bounding activation memory at
    fixed global batch size. Grads accumulate in f32 with the params'
    sharding. grad_compression => int8 error-feedback quantization of the
    grads before the DP reduction (opt_state carries the error buffers)."""
    def grad_fn(params, mb):
        return jax.value_and_grad(model.loss, has_aux=True)(params, mb)

    def train_step(params, opt_state, batch):
        if grad_compression and "ef" not in opt_state:
            raise ValueError("opt_state must carry 'ef' buffers; "
                             "use init_opt_state(..., compression=True)")
        if microbatches == 1:
            (loss, mets), grads = grad_fn(params, batch)
        else:
            k = microbatches
            mbs = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)

            def body(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = lsum / k
            mets = {"ce": loss, "aux": jnp.float32(0.0)}
        if grad_compression:
            from repro.distributed.compression import compress_decompress
            ef = opt_state.pop("ef")
            grads, ef, cmets = compress_decompress(grads, ef)
            opt_state = dict(opt_state)
            mets = dict(mets, **cmets)
        params, inner, omets = opt.update(
            ocfg, grads, {k_: v for k_, v in opt_state.items()
                          if k_ != "ef"}, params)
        opt_state = dict(inner, ef=ef) if grad_compression else inner
        mets = dict(mets, loss=loss, **omets)
        return params, opt_state, mets
    return train_step


def init_opt_state(params, compression: bool = False):
    state = opt.init(params)
    if compression:
        state = dict(state, ef=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
    return state


def make_prefill_step(model: Model, pad_to: int = 0):
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, pad_to=pad_to)
        return greedy_sample(logits), cache
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens):
        logits, cache = model.decode(params, cache, tokens)
        return greedy_sample(logits)[:, None], cache
    return decode_step


# ---------------------------------------------------------------------------
# AOT lowering of one dry-run cell

def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
               fsdp: Optional[bool] = None,
               seq_shard_resid: Optional[bool] = None,
               donate: bool = True):
    """Returns (lowered, meta) for the cell's step function."""
    cfg = cfg.replace(vocab_pad_to=256)
    model = Model(cfg)
    big = cfg.param_counts()["total"] * 2 >= 8e9       # >=8 GB of bf16
    fsdp = big if fsdp is None else fsdp
    if seq_shard_resid is None:
        # naive GSPMD sequence-parallelism constraint reshards inside the
        # flash-attention loops (measured: 20k+ extra gathers) — keep the
        # residual replicated over "model"; memory is bounded with
        # gradient accumulation instead (see microbatch rule below).
        seq_shard_resid = False

    pspecs = shr.param_pspecs(model.param_specs(), mesh, fsdp=fsdp)
    param_sh = shr.to_named(pspecs, mesh)
    batch = input_specs(cfg, shape)
    batch_sh = shr.to_named(shr.batch_pspecs(batch, mesh), mesh)
    rules = dict(residual=shr.residual_spec(mesh, seq_shard_resid))

    meta = {"arch": cfg.name, "shape": shape.name, "fsdp": fsdp,
            "seq_shard_resid": seq_shard_resid,
            "mesh": dict(zip(mesh.axis_names,
                             (mesh.shape[a] for a in mesh.axis_names)))}

    with shardctx.sharding_rules(mesh, **rules):
        if shape.kind == "train":
            ocfg = opt.AdamWConfig()
            ospecs = jax.eval_shape(
                lambda: opt.init(model.param_specs()))
            osh_specs = {
                "m": shr.opt_pspecs(model.param_specs(), mesh)["m"],
                "v": shr.opt_pspecs(model.param_specs(), mesh)["v"],
                "step": P(),
            }
            opt_sh = shr.to_named(osh_specs, mesh)
            # microbatch rule: bound the per-chip f32 saved-residual stack
            # (n_cycles x B_mb/dp x S x D x 4B). MoE under FSDP gets a
            # larger budget — every extra microbatch re-gathers the expert
            # weights (measured 360 GB/step at k=16 on mixtral; §Perf
            # iter 2), so fewer/larger microbatches win there.
            dp = 1
            for a in shardctx.batch_axes(mesh):
                dp *= mesh.shape[a]
            B = shape.global_batch
            resid = (4.0 * cfg.n_cycles * (B / dp)
                     * shape.seq_len * cfg.d_model)
            target = 8e9 if (fsdp and cfg.family == "moe") else 2e9
            k = 1
            while resid / k > target and k < max(B // dp, 1):
                k *= 2
            fn = make_train_step(model, ocfg, microbatches=k)
            meta["microbatches"] = k
            jfn = jax.jit(
                fn,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jfn.lower(model.param_specs(), ospecs, batch)
        elif shape.kind == "prefill":
            fn = make_prefill_step(model, pad_to=shape.seq_len)
            jfn = jax.jit(fn, in_shardings=(param_sh, batch_sh))
            lowered = jfn.lower(model.param_specs(), batch)
        else:  # decode
            cache = model.cache_specs(shape.global_batch, shape.seq_len)
            cache_sh = shr.to_named(
                shr.cache_pspecs(cache, mesh, shape.global_batch), mesh)
            tok_sh = shr.to_named(
                shr.batch_pspecs(batch, mesh), mesh)["tokens"]
            fn = make_decode_step(model)
            jfn = jax.jit(
                fn,
                in_shardings=(param_sh, cache_sh, tok_sh),
                out_shardings=(tok_sh, cache_sh),
                donate_argnums=(1,) if donate else ())
            lowered = jfn.lower(model.param_specs(), cache,
                                batch["tokens"])
    return lowered, meta
