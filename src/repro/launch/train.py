"""Training launcher CLI (any zoo arch, smoke or reduced scale on CPU;
the full configs lower via the dry-run on the production meshes).

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 60 --ckpt runs/ckpt_demo
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    from repro.configs import get_config, smoke_variant
    from repro.models import Model
    from repro.training.data import TokenStream
    from repro.training.train_loop import TrainConfig, train

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = Model(cfg)
    print(f"{cfg.name}: {cfg.param_counts()['total']/1e6:.1f}M params")
    data = TokenStream(cfg.vocab, args.seq, args.batch, seed=0)
    out = train(model, data, TrainConfig(
        n_steps=args.steps, ckpt_dir=args.ckpt or None,
        grad_compression=args.compress_grads,
        microbatches=args.microbatches))
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}")


if __name__ == "__main__":
    main()
