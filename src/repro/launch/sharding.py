"""PartitionSpec rules for every tree the launcher lowers.

Policy (baseline; §Perf iterates on it):
  * params     — Megatron TP over "model" (attention heads / ffn hidden /
                 vocab), optional FSDP over "data" on the largest free dim.
  * opt state  — ZeRO: moments take the param spec + "data" on a free dim.
  * batch      — leading (batch) dim over ("pod","data") when divisible.
  * KV caches  — batch over data axes; then KV-heads over "model" when
                 divisible, else head_dim, else the cache-sequence dim.
  * activations— residual stream constraint via shardctx (propagated
                 elsewhere by GSPMD).

Every dim is sharded only when evenly divisible — helpers degrade to
replication instead of relying on uneven-shard padding.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.shardctx import batch_axes


def _axsize(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def _div(dim: int, mesh: Mesh, names) -> bool:
    return dim % _axsize(mesh, names) == 0 and dim >= _axsize(mesh, names)


# ---------------------------------------------------------------------------
# parameter rules

def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    M = "model"

    def col():     # (.., D_in, D_out) shard output dim
        return P(*([None] * (len(shape) - 1)), M) \
            if _div(shape[-1], mesh, M) else P()

    def row():     # (.., D_in, D_out) shard input dim
        return P(*([None] * (len(shape) - 2)), M, None) \
            if len(shape) >= 2 and _div(shape[-2], mesh, M) else P()

    if "embed" in path or "lm_head" in path:
        if _div(shape[0], mesh, M):
            return P(M, None)                    # vocab-sharded
        if _div(shape[1], mesh, M):
            return P(None, M)
        return P()
    if any(k in path for k in ("wq", "wk", "wv", "up", "gate",
                               "w_in", "w_gate_branch", "w_i", "w_r",
                               "in_z", "in_x", "in_dt", "frontend_proj")):
        return col()
    if any(k in path for k in ("wo", "down", "out_proj", "w_out")):
        return row()
    if "conv_x" in path:                          # (width, di)
        return col()
    if ("conv" in path and "conv_B" not in path and "conv_C" not in path
            and len(shape) == 2):                 # rglru conv (width, W)
        return col()
    if "out_norm" in path and _div(shape[-1], mesh, M):
        return P(*([None] * (len(shape) - 1)), M)
    if "lam" in path and _div(shape[-1], mesh, M):
        return P(*([None] * (len(shape) - 1)), M)
    return P()  # norms, routers, scalars, biases, pos_dec, in_B/in_C


def _with_fsdp(spec: P, shape, mesh: Mesh) -> P:
    """Add "data" sharding on the largest spec-free, divisible dim."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    cand = [(shape[i], i) for i in range(len(shape))
            if parts[i] is None and _div(shape[i], mesh, "data")]
    if not cand:
        return spec
    _, i = max(cand)
    parts[i] = "data"
    return P(*parts)


def param_pspecs(param_tree, mesh: Mesh, fsdp: bool = False):
    """Tree of PartitionSpecs matching param_tree (of arrays or SDS)."""
    def one(path, leaf):
        s = jax.tree_util.keystr(path)
        spec = param_spec(s, leaf.shape, mesh)
        if fsdp:
            if "embed" in s or "lm_head" in s:
                # never FSDP the embedding: a d_model shard puts a
                # data-axis psum on every CE chunk, and a (model, data)
                # vocab shard conflicts with the data-sharded batch dim of
                # the chunked-CE logits (double-mapped axis -> gathers).
                return spec
            spec = _with_fsdp(spec, leaf.shape, mesh)
        return spec
    return jax.tree_util.tree_map_with_path(one, param_tree)


def opt_pspecs(param_tree, mesh: Mesh, fsdp: bool = False):
    """ZeRO: moments get the param spec plus a "data" dim."""
    def one(path, leaf):
        s = jax.tree_util.keystr(path)
        spec = param_spec(s, leaf.shape, mesh)
        return _with_fsdp(spec, leaf.shape, mesh)
    mv = jax.tree_util.tree_map_with_path(one, param_tree)
    return {"m": mv, "v": mv, "step": P()}


# ---------------------------------------------------------------------------
# batch / cache rules

def batch_pspecs(batch_tree, mesh: Mesh):
    ba = batch_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        lead = ba if (ba and _div(leaf.shape[0], mesh, ba)) else \
            ("data",) if _div(leaf.shape[0], mesh, "data") else None
        return P(lead, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(one, batch_tree)


def cache_pspecs(cache_tree, mesh: Mesh, batch: int):
    """Decode-cache rules; leaves are (n_cycles, B, ...) stacked or (B, ...)
    (remainder layers), plus scalars/positions."""
    ba = batch_axes(mesh)
    bdim_shard = ba if (ba and batch % _axsize(mesh, ba) == 0) else \
        (("data",) if batch % _axsize(mesh, "data") == 0 else None)

    def one(path, leaf):
        last = path[-1]
        name = getattr(last, "key", str(last))
        shp = leaf.shape
        if leaf.ndim == 0 or name in ("positions", "pos", "enc_len"):
            return P()
        # find the batch dim: stacked caches have it at 1, rem at 0
        bdim = 1 if (leaf.ndim >= 2 and shp[0] != batch
                     and shp[1] == batch) else 0
        parts = [None] * leaf.ndim
        if shp[bdim] == batch and bdim_shard:
            parts[bdim] = bdim_shard
        if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
            # prefer KV-head sharding; else the cache-sequence dim (decode
            # scores gather is small); hd-sharding LAST — GSPMD answers it
            # by all-gathering the whole cache (measured 21.5 GB/step on
            # granite decode_32k; §Perf iter 2)
            C, K, hd = shp[-3], shp[-2], shp[-1]
            if _div(K, mesh, "model"):
                parts[-2] = "model"
            elif _div(C, mesh, "model"):
                parts[-3] = "model"
            elif _div(hd, mesh, "model"):
                parts[-1] = "model"
        elif name == "state":                   # (.., B, nh, P, N)
            if _div(shp[-3], mesh, "model"):
                parts[-3] = "model"
        elif name.startswith("conv") or name == "h":   # (.., W) channels
            if _div(shp[-1], mesh, "model"):
                parts[-1] = "model"
        return P(*parts)
    return jax.tree_util.tree_map_with_path(one, cache_tree)


# ---------------------------------------------------------------------------

def to_named(tree_of_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def residual_spec(mesh: Mesh, seq_shard: bool = False) -> P:
    """(B, S, D) residual-stream constraint."""
    ba = batch_axes(mesh)
    if seq_shard:
        return P(ba, "model", None)
    return P(ba, None, None)


def cell_specs() -> Tuple[P, P, P]:
    """Layout for the cell-sharded decision scan
    (`repro.core.decision_jax.sharded_greedy_scan` under shard_map over
    a `make_cell_mesh` mesh): per-request planes (R, C, Ic) split on the
    cell axis, per-instance state (C, Ic) likewise, per-request vectors
    (R,) replicated. Returns (plane_spec, state_spec, replicated)."""
    return P(None, "cell", None), P("cell", None), P(None)
