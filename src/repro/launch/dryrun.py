import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # XLA:CPU hoists loop-invariant converts/iotas out of scan loops,
    # materializing stacked f32 copies of the residual stream (observed:
    # 14 GB convert hoists on gemma3-27b). TPU compilation bounds such
    # hoists by HBM budget; disabling the expensive-LICM pass makes the
    # CPU-proxy memory_analysis reflect the memory-lean schedule.
    "--xla_disable_hlo_passes=while-loop-expensive-invariant-code-motion,"
    "while-loop-invariant-code-motion,convert-mover")

"""Multi-pod dry-run driver.

Lowers + compiles every (arch x shape) cell on the production meshes
(16x16 single-pod and 2x16x16 multi-pod) using ShapeDtypeStructs only, and
records memory analysis, cost analysis, and the collective schedule parsed
from the optimized HLO. Results are cached as JSON under runs/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs 1]
  python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import gzip
import json
import pathlib
import re
import subprocess
import sys
import time
import traceback

RUNS = pathlib.Path(__file__).resolve().parents[3] / "runs" / "dryrun"
REPO = pathlib.Path(__file__).resolve().parents[3]

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\)|[a-z0-9\[\],{}:#*\s/_.-])*?)"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64|c64)"
                       r"\[([\d,]*)\]")

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dt]
    return total


# Link-traffic factor per collective kind (ring algorithms, per device):
#   all-gather: sends ~(n-1)/n of the OUTPUT; all-reduce: 2x input
#   (reduce-scatter + all-gather); reduce-scatter / all-to-all /
#   collective-permute: ~1x input.
_FACTORS = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}


def parse_collectives(hlo_text: str):
    """Per-device collective link bytes from post-SPMD optimized HLO."""
    out = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"= ([^=]*?)\b(all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        kind = m.group(2)
        # result type(s) precede the op name
        result_bytes = _shape_bytes(m.group(1))
        if kind in ("all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute"):
            ref_bytes = result_bytes  # result ~ input for these
        else:
            ref_bytes = result_bytes  # all-gather: result = gathered output
        d = out.setdefault(kind, {"count": 0, "bytes": 0.0,
                                  "link_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += ref_bytes
        d["link_bytes"] += ref_bytes * _FACTORS[kind]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides=None) -> dict:
    import jax
    from repro.configs import get_config, skip_reason
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell
    from repro.models.config import SHAPES

    t0 = time.time()
    reason = skip_reason(arch, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    with mesh:
        lowered, meta = lower_cell(cfg, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    # trip-count-aware walk (xla cost_analysis counts while bodies once)
    sys.path.insert(0, str(REPO))
    from benchmarks.hlo_cost import analyze as hlo_analyze
    walk = hlo_analyze(hlo)
    hlo_path = cell_path(arch, shape_name, multi_pod,
                         overrides and "ovr" or "").with_suffix(".hlo.gz")
    with gzip.open(hlo_path, "wt") as f:
        f.write(hlo)
    n_chips = 1
    for a in mesh.axis_names:
        n_chips *= mesh.shape[a]
    mem_d = {
        "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_size_bytes":
            getattr(mem, "generated_code_size_in_bytes", None),
        "alias_size_bytes": getattr(mem, "alias_size_in_bytes", None),
    }
    peak = ((mem_d["argument_size_bytes"] or 0)
            + (mem_d["output_size_bytes"] or 0)
            + (mem_d["temp_size_bytes"] or 0)
            - (mem_d["alias_size_bytes"] or 0))
    flops = float(cost.get("flops", -1)) if cost else -1.0
    bytes_acc = float(cost.get("bytes accessed", -1)) if cost else -1.0
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok", "meta": meta, "n_chips": n_chips,
        "memory": mem_d, "peak_bytes_per_device": peak,
        "xla_flops_per_device": flops, "xla_bytes_per_device": bytes_acc,
        "walk": walk,
        "flops_per_device": walk["flops"],
        "hbm_bytes_per_device": walk["hbm_bytes"],
        "collectives": walk["by_kind"],
        "collective_link_bytes_per_device": walk["coll_link_bytes"],
        "collectives_single_count": colls,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
    }


def cell_path(arch: str, shape: str, multi_pod: bool,
              tag: str = "") -> pathlib.Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{tag}" if tag else ""
    return RUNS / f"{arch}__{shape}__{mesh}{suffix}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--overrides", default="",
                    help="JSON dict of ModelConfig overrides (perf iter)")
    args = ap.parse_args()
    RUNS.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.configs import list_archs
        from repro.models.config import SHAPES
        cells = [(a, s, mp)
                 for a in list_archs() for s in SHAPES
                 for mp in ((False, True) if args.both_meshes
                            else (args.multi_pod,))]
        failures = 0
        for arch, shape, mp in cells:
            out = cell_path(arch, shape, mp, args.tag)
            if out.exists() and not args.force:
                print(f"cached  {out.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if mp:
                cmd.append("--multi-pod")
            if args.tag:
                cmd += ["--tag", args.tag]
            if args.overrides:
                cmd += ["--overrides", args.overrides]
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=3600)
            dt = time.time() - t0
            if r.returncode != 0 or not out.exists():
                failures += 1
                err = (r.stderr or "")[-2000:]
                out.write_text(json.dumps(
                    {"arch": arch, "shape": shape,
                     "mesh": "2x16x16" if mp else "16x16",
                     "status": "error", "stderr": err}, indent=1))
                print(f"FAIL    {out.name} ({dt:.0f}s)")
            else:
                print(f"ok      {out.name} ({dt:.0f}s)")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape required"
    overrides = json.loads(args.overrides) if args.overrides else None
    try:
        res = run_cell(args.arch, args.shape, args.multi_pod, overrides)
    except Exception:
        res = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "traceback": traceback.format_exc()}
    out = cell_path(args.arch, args.shape, args.multi_pod, args.tag)
    out.write_text(json.dumps(res, indent=1))
    status = res["status"]
    print(f"{status}: {out}")
    if status == "error":
        print(res.get("traceback", res.get("reason", ""))[-3000:])
    return 0 if status in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
