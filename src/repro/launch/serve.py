"""Serving launcher CLI.

    PYTHONPATH=src python -m repro.launch.serve --preset uniform --lam 12
    PYTHONPATH=src python -m repro.launch.serve --pool zoo --preset quality
    PYTHONPATH=src python -m repro.launch.serve --scenario multitenant \
        --preset cost --lam-scale 2.0
    PYTHONPATH=src python -m repro.launch.serve --policy bestroute-sq \
        --deployment serial_published --lam 24

--scenario selects a named world from `repro.serving.scenarios`
(roster + composite multi-tenant workload + failure/recovery schedule);
it overrides --pool/--arrivals/--lam.

--policy selects any scheduler from the `repro.core.policies.POLICIES`
registry (RouteBalance plus the router x dispatcher baseline grid);
--deployment picks the engine's serving arm (windowed amortized batch
scoring, concurrent equalized worker-pool scoring, serial_published
one-call-per-request as-published, microbatch collector) — every
combination runs through the one `ServingEngine`.

--cells > 1 runs the hierarchical scheduler (`repro.serving.hierarchy`,
routebalance policy only): the roster is partitioned into cells, each
with its own RouteBalance engine, and a GlobalBalancer assigns arrivals
from compressed telemetry digests exchanged every --digest-interval
seconds (usable for --digest-stale seconds; --digest-mode picks the
exact float32 or lossy int8 wire codec). --cell-routing span instead
shards the fused instance-column scan of ONE logical controller over
the cells (bitwise-identical decisions at any cell count).
"""
from __future__ import annotations

import argparse
import json


def main():
    from repro.core.engine import DEPLOYMENTS
    from repro.core.policies import POLICIES

    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", choices=("paper", "zoo"), default="paper")
    ap.add_argument("--scenario", default="",
                    help="named scenario from repro.serving.scenarios "
                         "(overrides --pool/--arrivals/--lam)")
    ap.add_argument("--policy", default="routebalance",
                    choices=sorted(POLICIES),
                    help="scheduling policy from the POLICIES registry")
    ap.add_argument("--deployment", default="windowed",
                    choices=DEPLOYMENTS,
                    help="engine serving arm (§6.3 ladder axis)")
    ap.add_argument("--preset", default="uniform",
                    help="weight preset (routebalance policy only)")
    ap.add_argument("--weights", default="",
                    help="wq,wl,wc overriding --preset")
    ap.add_argument("--lam", type=float, default=12.0)
    ap.add_argument("--lam-scale", type=float, default=1.0,
                    help="scenario load multiplier (with --scenario)")
    ap.add_argument("--n", type=int, default=600)
    ap.add_argument("--arrivals", default="poisson",
                    choices=("poisson", "gamma", "square", "flash"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cells", type=int, default=1,
                    help="partition the roster into N scheduling cells "
                         "(hierarchical path; routebalance only)")
    ap.add_argument("--cell-routing", default="balanced",
                    choices=("span", "balanced"),
                    help="balanced: per-cell engines + digest-routed "
                         "GlobalBalancer; span: one logical decision "
                         "sharded across cells")
    ap.add_argument("--digest-interval", type=float, default=0.25,
                    help="seconds between per-cell telemetry digests")
    ap.add_argument("--digest-stale", type=float, default=1.0,
                    help="digest staleness bound (cell goes dark past "
                         "this age)")
    ap.add_argument("--digest-mode", default="exact",
                    choices=("exact", "int8"),
                    help="digest wire codec")
    args = ap.parse_args()

    from repro.core import (EngineConfig, EstimatorBundle, PRESETS,
                            ServingEngine, fit_policy, make_requests,
                            run_cell)
    from repro.serving.tiers import assigned_pool_tiers, paper_pool_tiers
    from repro.serving.workload import make_arrivals
    from repro.serving.world import World, build_dataset, paper_world

    w = PRESETS[args.preset]
    if args.weights:
        w = tuple(float(x) for x in args.weights.split(","))
    policy_kw = dict(weights=w) if args.policy == "routebalance" else {}

    def hier_sched(bundle, tiers):
        from repro.core import RBConfig
        from repro.serving.hierarchy import (HierarchyConfig,
                                             build_scheduler)
        assert args.policy == "routebalance", \
            "--cells > 1 requires the routebalance policy"
        return build_scheduler(
            RBConfig(weights=w), bundle, tiers,
            HierarchyConfig(n_cells=args.cells,
                            routing=args.cell_routing,
                            digest_interval_s=args.digest_interval,
                            digest_stale_s=args.digest_stale,
                            digest_mode=args.digest_mode))

    def hier_cols(m, eng):
        m["cells"] = args.cells
        m["cell_routing"] = args.cell_routing
        bal = getattr(eng, "balancer", None)
        if bal is not None:
            m["intercell_imbalance"] = round(bal.imbalance(), 4)
            m["digests"] = bal.digests_sent
            m["digest_bytes"] = bal.bytes_sent

    if args.scenario:
        from repro.serving.scenarios import get_scenario
        run = get_scenario(args.scenario).build(dataset_n=6000)
        reqs = run.requests(args.n, lam_scale=args.lam_scale,
                            seed=args.seed)
        if args.cells > 1:
            eng = hier_sched(run.bundle(), run.tiers)
        else:
            eng = run.engine(run.policy(args.policy, **policy_kw),
                             deployment=args.deployment)
        m = run.run_cell(eng, reqs, seed=args.seed)
        if args.cells > 1:
            hier_cols(m, eng)
        m["scenario"] = args.scenario
        m["n_instances"] = run.n_instances
    else:
        if args.pool == "paper":
            world, names = paper_world(seed=args.seed)
            tiers = paper_pool_tiers()
        else:
            from examples.zoo_serving import CAPS, VERB
            tiers = assigned_pool_tiers()
            names = [t.model for t in tiers]
            world = World([CAPS[m] for m in names],
                          [VERB[m] for m in names], seed=args.seed)
        ds = build_dataset(world, n=6000)
        bundle = EstimatorBundle.train(ds, tiers, names)
        reqs = make_requests(
            ds, "test", make_arrivals(args.arrivals, args.lam, args.n,
                                      seed=args.seed))
        if args.cells > 1:
            eng = hier_sched(bundle, tiers)
        else:
            policy = fit_policy(args.policy, bundle, tiers, names, ds,
                                **policy_kw)
            eng = ServingEngine(policy, bundle, tiers,
                                EngineConfig(deployment=args.deployment))
        m = run_cell(eng, tiers, names, reqs, seed=args.seed)
        if args.cells > 1:
            hier_cols(m, eng)
    print(json.dumps({k: v for k, v in m.items()
                      if not isinstance(v, tuple)}, indent=1,
                     default=str))


if __name__ == "__main__":
    main()
