"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls these.
"""
from __future__ import annotations

import jax

# TPU v5e-class hardware constants used across the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip, bf16
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per chip, one direction)
HBM_PER_CHIP = 16e9             # bytes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh on the available device(s) — for CPU tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_cell_mesh(n_cells: int):
    """("cell",)-axis mesh for the cell-sharded decision scan
    (hierarchical scheduling): one device per cell. Returns None when
    the host lacks the devices — callers fall back to the
    bitwise-identical single-program cell emulation, so a CPU box (one
    device by default; more via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``) runs the
    same logical decision without the collectives."""
    if n_cells <= 1 or jax.device_count() < n_cells:
        return None
    return jax.make_mesh((n_cells,), ("cell",))
