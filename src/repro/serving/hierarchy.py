"""Hierarchical sharded scheduling: per-cell RouteBalance engines under
a digest-routed global balancer, for rosters far beyond one
controller's comfort.

Two routing modes, one exactness story:

  * **span** (``HierarchyConfig.routing="span"``) — every logical
    decision still covers the FULL roster; only the fused scan's
    instance-column axis is split into ``n_cells`` contiguous blocks
    and combined with exact max/argmax reductions
    (`repro.core.decision_jax.sharded_greedy_scan`, optionally
    `shard_map` over the ``launch.mesh.make_cell_mesh`` device mesh).
    Assignments are BITWISE the single-controller fused backend on any
    cell count — sharding is a compute layout, not a policy change.
  * **balanced** (`HierarchicalScheduler`) — the roster is partitioned
    into cells; each cell runs its own complete RouteBalance engine
    (fused hot path with its own carried telemetry mirror, alive mask,
    affinity planes, and — when the sim is armed — its own
    `CellRecovery` watchdog/retry manager). A `GlobalBalancer` assigns
    arriving requests to cells from compressed per-cell telemetry
    digests (`repro.distributed.compression`): each heartbeat tick the
    balancer encodes every cell's per-tier occupancy/depth/free
    summary to wire bytes, decodes them, and routes ONLY from what
    survived the round trip, under the `digest_fresh` staleness bound
    — a cell whose digests stop is first penalized
    (`ElasticMembership.staleness_penalty`), then treated as dark.
    With one cell the hierarchy is the single controller verbatim:
    same engine, same decisions, same trajectory (pinned by
    ``tests/test_hierarchy.py``).

Cells see the parent `ClusterSim` through two narrow views:
`CellSim` (what a cell's engine schedules against — local instance
list + a `_CellTelemetry` mirror in cell-local row order, refreshed
incrementally from the parent's version counters) and `_CellScope`
(what a cell's recovery manager probes — the PARENT telemetry, since
watchdog writes address global slots, with the instance list narrowed
to the cell). Dispatch needs no translation at all: a chosen
`Instance` is the parent's object, and `Instance.submit` writes the
parent telemetry through ``inst.slot`` like it always has.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.distributed.compression import (TelemetryDigest, decode_digest,
                                           digest_fresh,
                                           digest_from_telemetry,
                                           encode_digest)
from repro.distributed.elastic import ElasticMembership

from .cluster import ClusterSim, Instance
from .recovery import RecoveryManager
from .request import Request

ROUTINGS = ("span", "balanced")
_TEL_PLANES = ("pending", "batch", "free", "ctx", "queue", "t")


@dataclasses.dataclass(frozen=True)
class HierarchyConfig:
    """Two-level scheduling knobs. `digest_interval_s` is the control
    heartbeat; `digest_stale_s` the staleness bound past which a cell
    is dark to the balancer (also the membership quarantine timeout, so
    the hard and soft arms share one clock)."""
    n_cells: int = 1
    routing: str = "balanced"          # balanced | span
    digest_interval_s: float = 0.25
    digest_stale_s: float = 1.0
    digest_mode: str = "exact"         # exact | int8 wire codec
    staleness_decay: float = 2.0       # soft load inflation per bound

    def __post_init__(self):
        assert self.routing in ROUTINGS, self.routing
        assert self.n_cells >= 1, self.n_cells
        assert self.digest_interval_s > 0.0
        assert self.digest_stale_s >= self.digest_interval_s, \
            "a digest must live at least one heartbeat"


def partition_roster(instances: Sequence[Instance], n_cells: int
                     ) -> List[List[Instance]]:
    """Split a roster into `n_cells` cells, round-robin WITHIN each
    tier so every cell inherits (its share of) the full capacity
    ladder — a cell of only cheap replicas could never serve the
    quality frontier its requests were admitted against. Tiers with
    fewer replicas than cells land in a subset of cells; the digest's
    per-tier planes (global tier order) keep the balancer aware of
    where capacity actually lives. Cell membership lists stay in
    parent-slot order, so cell-local row k maps monotonically to a
    parent slot."""
    n = len(instances)
    assert 1 <= n_cells <= n, (n_cells, n)
    by_tier: Dict[str, List[Instance]] = {}
    for inst in instances:                   # instances are slot-ordered
        by_tier.setdefault(inst.tier.name, []).append(inst)
    cells: List[List[Instance]] = [[] for _ in range(n_cells)]
    k = 0
    for insts in by_tier.values():
        for inst in insts:
            cells[k % n_cells].append(inst)
            k += 1
    for cell in cells:
        cell.sort(key=lambda i: i.slot)
    return cells


class _CellTelemetry:
    """A cell-local mirror of the parent `TelemetryArrays`: the same
    SoA planes and version-counter contract, over the cell's slots in
    cell-local row order, so a cell's `FusedHotPath` syncs its device
    mirror (delta scatters, roster reseeds) exactly as it does against
    the real thing.

    Refresh is incremental and guarded by the parent's counters: rows
    whose parent ``last_write`` stamp moved are re-copied and stamped
    dirty locally; an alive-mask change (kill/quarantine — the parent
    deliberately does NOT stamp ``last_write`` for those) bumps the
    local ``roster_version`` so the cell's runner full-reseeds, with
    its already-compiled program. Mirrored rows are copies of the
    parent's float64 values — bitwise equal — which is what makes the
    1-cell hierarchy's decisions identical to the single controller's.
    """

    def __init__(self, parent, slots: np.ndarray):
        self.parent = parent
        self.slots = np.asarray(slots, np.int64)
        n = len(self.slots)
        for name in _TEL_PLANES:
            setattr(self, name, getattr(parent, name)[self.slots].copy())
        self.max_batch = parent.max_batch[self.slots].copy()
        self.alive = parent.alive[self.slots].copy()
        self.version = 1
        self.roster_version = 0
        self.last_write = np.full(n, 1, np.int64)
        self.prefix_sig = parent.prefix_sig[self.slots].copy()
        self.prefix_hit = parent.prefix_hit[self.slots].copy()
        self.prefix_version = 0
        self._seen_writes = parent.last_write[self.slots].copy()
        self._p_version = parent.version
        self._p_roster = parent.roster_version
        self._p_prefix = parent.prefix_version

    def refresh(self) -> "_CellTelemetry":
        p = self.parent
        if (p.version == self._p_version
                and p.roster_version == self._p_roster
                and p.prefix_version == self._p_prefix):
            return self
        if (p.version != self._p_version
                or p.roster_version != self._p_roster):
            pw = p.last_write[self.slots]
            changed = np.flatnonzero(pw != self._seen_writes)
            if len(changed):
                rows = self.slots[changed]
                self.version += 1
                for name in _TEL_PLANES:
                    getattr(self, name)[changed] = getattr(p, name)[rows]
                self.last_write[changed] = self.version
                self._seen_writes[changed] = pw[changed]
            a = p.alive[self.slots]
            if not np.array_equal(a, self.alive):
                self.alive[:] = a
                self.version += 1
                self.roster_version += 1
            self._p_version = p.version
            self._p_roster = p.roster_version
        if p.prefix_version != self._p_prefix:
            self.prefix_sig[:] = p.prefix_sig[self.slots]
            self.prefix_hit[:] = p.prefix_hit[self.slots]
            self.prefix_version += 1
            self._p_prefix = p.prefix_version
        return self

    def dirty_rows(self, since: int) -> np.ndarray:
        return np.flatnonzero(self.last_write > since)


class CellSim:
    """What a cell's engine schedules against: the parent sim's event
    loop, clock, completion list and overload controller, with the
    instance roster narrowed to the cell and telemetry served from the
    cell-local mirror. Same duck type as `ClusterSim` everywhere the
    engine and the fused policy touch it."""

    def __init__(self, parent: ClusterSim, instances: Sequence[Instance],
                 cell_id: int):
        self.parent = parent
        self.cell_id = cell_id
        self.instances = list(instances)
        self.by_id = {i.iid: i for i in self.instances}
        self._tel = _CellTelemetry(parent.tel,
                                   np.array([i.slot for i in instances]))
        self.recovery: Optional["CellRecovery"] = None

    @property
    def tel(self) -> _CellTelemetry:
        return self._tel.refresh()

    @property
    def now(self) -> float:
        return self.parent.now

    @property
    def completed(self):
        return self.parent.completed

    @property
    def overload(self):
        return getattr(self.parent, "overload", None)

    def push(self, t: float, fn):
        self.parent.push(t, fn)

    def has_noncontrol_events(self) -> bool:
        return self.parent.has_noncontrol_events()

    def alive_instances(self) -> List[Instance]:
        return [i for i in self.instances if i.alive]


class _CellScope:
    """What a cell's `CellRecovery` sees as ``sim``: the PARENT
    telemetry and event heap — watchdog probes and quarantine writes
    address global slots (``tel.t[inst.slot]``) — with the instance
    list narrowed to the cell so staleness scans, hedge targets and
    degraded picks stay inside the cell."""

    def __init__(self, parent: ClusterSim, instances: Sequence[Instance]):
        self.parent = parent
        self.tel = parent.tel
        self.instances = list(instances)
        self.by_id = {i.iid: i for i in self.instances}

    @property
    def now(self) -> float:
        return self.parent.now

    @property
    def completed(self):
        return self.parent.completed

    def push(self, t: float, fn):
        self.parent.push(t, fn)

    def has_noncontrol_events(self) -> bool:
        return self.parent.has_noncontrol_events()


class CellRecovery(RecoveryManager):
    """One cell's retry/hedge/watchdog manager over a `_CellScope`.
    Inherits the whole lifecycle — retries re-enter through the CELL's
    engine (`bind`), so a victim keeps its cell affinity — and
    overrides only the degraded fallback, whose base implementation
    uses ``inst.slot`` as an index into ``sim.instances`` (true for
    the parent roster, false for a cell's slice of it)."""

    def degraded_assign(self, batch, sim):
        from repro.core.engine import AssignmentResult, Ready
        cand = [(k, i) for k, i in enumerate(sim.instances) if i.alive]
        assert cand, "no alive instances to schedule onto"
        R = len(batch.reqs)
        choice = np.empty(R, np.int64)
        load = {k: len(i.running) + len(i.queue) for k, i in cand}
        for r in range(R):
            bk, _ = min(cand, key=lambda ki: (
                load[ki[0]] / max(ki[1].tier.max_batch, 1), ki[1].slot))
            choice[r] = bk             # cell-local POSITION, not slot
            load[bk] += 1
        self.degraded_decisions += R
        l_chosen = np.full(R, self.cfg.degraded_pred_len)
        return AssignmentResult(sim.instances, Ready(choice, l_chosen))


class _RecoveryRouter:
    """The parent sim's ``recovery`` attribute under balanced routing:
    `Instance.fail()` and direct `watch_dispatch` callers find the
    victim's OWNING cell manager here (by slot), and the driver's
    counter probes read fleet-wide sums. The cell engines bind their
    own managers at attach; binding the router is a no-op."""

    _is_controller = True

    def __init__(self, managers: List[CellRecovery],
                 slot_cell: Dict[int, int], cfg):
        self.managers = managers
        self._slot_cell = slot_cell
        self.cfg = cfg
        self.degraded = False          # engines consult their cell mgr

    def _mgr(self, inst: Instance) -> CellRecovery:
        return self.managers[self._slot_cell[inst.slot]]

    def bind(self, engine):
        return self

    def on_failure(self, req, inst: Instance, lost_tokens: int,
                   now: float) -> bool:
        return self._mgr(inst).on_failure(req, inst, lost_tokens, now)

    def watch_dispatch(self, req, inst: Instance, t: float):
        self._mgr(inst).watch_dispatch(req, inst, t)

    def __getattr__(self, name):
        # fleet-wide counter sums (retries, hedges, quarantines, ...)
        if name.startswith("_"):
            raise AttributeError(name)
        vals = [getattr(m, name) for m in self.managers]
        if vals and all(isinstance(v, (int, np.integer)) for v in vals):
            return int(sum(vals))
        raise AttributeError(name)


class _CellEngine:
    """Mixed into `RouteBalance` per cell (built lazily to keep the
    core->serving import direction clean): the fire-loop parking
    predicate consults the GLOBAL expected count instead of a local
    one. A cell cannot know its share of the trace upfront — placement
    is the balancer's runtime decision — and parking on a running
    local count would shift the idle-fire phase relative to a single
    controller, breaking the 1-cell == single-controller trajectory
    proof. The property makes ``decisions + shed >= expected`` hold on
    a cell exactly when it holds fleet-wide."""

    @property
    def expected(self):
        h = self._hier
        if h is None or h.expected is None:
            return None
        return (h.expected - (h.decisions - self.decisions)
                - (h.shed_count - self.shed_count))

    @expected.setter
    def expected(self, v):
        pass        # the global scheduler owns the count

    def _window(self) -> float:
        # A cell sees ~1/C of the arrival stream, so the same batching
        # window collects C× fewer requests per decide and the per-call
        # fixed dispatch cost stops amortizing. Stretch the adaptive
        # window by the cell count toward the same per-decision
        # occupancy as the flat controller, capped at the controller's
        # own adaptive ceiling. At C=1 this is the identity — the
        # 1-cell == single-controller trajectory proof is untouched.
        w = super()._window()
        h = self._hier
        if h is None:
            return w
        c = len(h.engines)
        if c <= 1:
            return w
        return float(min(w * c, max(self.ecfg.base_window, 0.30)))


def _make_cell_engine(cfg, bundle, tiers, hier):
    from repro.core.scheduler import RouteBalance

    cls = type("CellRouteBalance", (_CellEngine, RouteBalance), {})
    eng = cls.__new__(cls)
    eng._hier = None            # park-proof while __init__ fires
    RouteBalance.__init__(eng, cfg, bundle, tiers)
    eng._hier = hier
    return eng


class GlobalBalancer:
    """Inter-cell request placement from compressed telemetry digests.

    Every ``digest_interval_s`` the balancer summarizes each cell's
    mirror into a `TelemetryDigest`, serializes it with the configured
    codec, counts the wire bytes, and decodes — routing strictly from
    the post-wire digest, so the int8 mode's routing error is exactly
    the codec's quantization error. Digest arrival heartbeats the
    cell's `ElasticMembership` entry: a cell that stops publishing is
    soft-penalized (apparent load inflates with digest age) and then,
    past ``digest_stale_s``, treated as dark and routed around — blind
    round-robin only when EVERY cell is dark. Between heartbeats the
    balancer dead-reckons its own placements (``assigned_since``), the
    same correction the per-cell engines apply at instance grain.

    Dead-reckoning needs a unit conversion: digest depth is measured in
    work units (pending decode tokens + queued requests) while the
    balancer counts placements in requests. The balancer calibrates the
    conversion from its own digests — each heartbeat it divides the
    observed fleet-depth growth by the placements it made in the
    interval and folds that into an EWMA ``work quantum`` (floored at
    one unit). Without it a single placement perturbs apparent load by
    ~1/free_total and one digest interval's worth of fleet-rate traffic
    piles onto whichever cells the last digest ranked lightest."""

    _is_controller = True

    def __init__(self, hcfg: HierarchyConfig):
        self.hcfg = hcfg
        self.membership = ElasticMembership(
            heartbeat_timeout=hcfg.digest_stale_s,
            staleness_decay=hcfg.staleness_decay)
        self.digests: Dict[int, TelemetryDigest] = {}
        self.assigned_since: Dict[int, int] = {}
        self.assigned_total: Dict[int, int] = {}
        self.bytes_sent = 0
        self.digests_sent = 0
        self.seq = 0
        self._rr = 0
        # placement->work-unit conversion, calibrated from digests
        self._quantum = 1.0
        self._fleet_depth: Optional[float] = None
        self._armed = False
        self.sim: Optional[ClusterSim] = None
        self.cell_sims: List[CellSim] = []
        self._tos: List[np.ndarray] = []
        self.n_tiers = 0

    def attach(self, sim: ClusterSim, cell_sims: List[CellSim],
               tier_names: List[str]):
        self.sim = sim
        self.cell_sims = cell_sims
        self.n_tiers = len(tier_names)
        tindex = {n: k for k, n in enumerate(tier_names)}
        # per-cell slot->tier maps in GLOBAL tier order, so digest
        # planes are comparable across cells even when a small tier
        # lives in only some of them
        self._tos = [np.array([tindex[i.tier.name] for i in cs.instances])
                     for cs in cell_sims]
        for ci in range(len(cell_sims)):
            self.membership.register(f"cell{ci}", "cell", now=sim.now)
            self.assigned_since[ci] = 0
            self.assigned_total[ci] = 0
        self._tick(sim.now)

    # -- the heartbeat ----------------------------------------------------
    def _tick(self, t: float):
        self._armed = False
        placed = sum(self.assigned_since.values())
        for ci, cs in enumerate(self.cell_sims):
            d = digest_from_telemetry(cs.tel, self._tos[ci], self.n_tiers,
                                      cell=ci, seq=self.seq, t=t)
            wire = encode_digest(d, mode=self.hcfg.digest_mode)
            self.bytes_sent += len(wire)
            self.digests_sent += 1
            # route ONLY from what crossed the wire
            self.digests[ci] = decode_digest(wire)
            self.membership.heartbeat(f"cell{ci}", t)
            self.assigned_since[ci] = 0
        # calibrate the dead-reckoning quantum: fleet depth growth per
        # placement made this interval (drain makes this a lower bound
        # at steady state; the floor keeps request-count reckoning)
        depth = sum(d.depth_total for d in self.digests.values())
        if self._fleet_depth is not None and placed > 0:
            q = max(1.0, (depth - self._fleet_depth) / placed)
            self._quantum = 0.5 * self._quantum + 0.5 * q
        self._fleet_depth = depth
        self.seq += 1
        self._arm(t)

    def _arm(self, t: float):
        """Re-arm the heartbeat while real work remains; the loop is a
        controller event (`_is_controller`), so it can never keep the
        sim alive on its own, and `pick` revives it if a late arrival
        lands after it parked."""
        if self._armed or self.sim is None:
            return
        if self.sim.has_noncontrol_events():
            self._armed = True
            self.sim.push(t + self.hcfg.digest_interval_s, self._tick)

    # -- placement --------------------------------------------------------
    def pick(self, t: float, viable: Sequence[int]) -> int:
        """Choose a cell for one arriving request: staleness-penalized
        least load over the fresh digests (depth + local placements
        since the digest, relative to free headroom), round-robin when
        every cell is dark. Deterministic — a pure function of the
        digests and the placement history."""
        hcfg = self.hcfg
        best, best_key = None, None
        for ci in viable:
            d = self.digests.get(ci)
            if d is None or not digest_fresh(d, t, hcfg.digest_stale_s):
                continue
            if d.n_alive == 0:
                continue               # digest says: no capacity at all
            pen = self.membership.staleness_penalty(f"cell{ci}", t)
            load = pen * (d.depth_total
                          + self._quantum * self.assigned_since[ci]
                          + 1.0) / (d.free_total + 1.0)
            key = (load, self.assigned_total[ci], ci)
            if best_key is None or key < best_key:
                best, best_key = ci, key
        if best is None:               # every cell dark: blind rotation
            best = viable[self._rr % len(viable)]
            self._rr += 1
        self.assigned_since[best] += 1
        self.assigned_total[best] += 1
        self._arm(t)
        return best

    def imbalance(self) -> float:
        """Coefficient of variation of per-cell placements (0 = even)."""
        tot = np.array([self.assigned_total[ci]
                        for ci in sorted(self.assigned_total)], float)
        if len(tot) == 0 or tot.sum() == 0:
            return 0.0
        return float(tot.std() / max(tot.mean(), 1e-9))


class HierarchicalScheduler:
    """Balanced two-level scheduling with the single-controller driver
    contract (`repro.core.run_cell`): partition the roster at attach,
    run one full RouteBalance engine per cell (each with its own fused
    runner — ``cell_tag`` keys the compile cache so signature-twin
    cells still get their own carried mirrors — and, when the sim is
    recovery-armed, its own `CellRecovery`), and place each arrival
    through the `GlobalBalancer`. Cell engines park their fire loops
    on the GLOBAL expected count (`_CellEngine`), so batch-formation
    timing per cell matches a single controller's exactly."""

    def __init__(self, cfg, bundle, tiers, hcfg: HierarchyConfig):
        assert hcfg.routing == "balanced", hcfg.routing
        assert getattr(cfg, "shard_cells", 0) in (0, 1), \
            "balanced routing runs whole engines per cell; use " \
            "routing='span' for the sharded-scan mode"
        self.cfg = cfg                 # RBConfig template for the cells
        self.bundle = bundle
        self.tiers = list(tiers)
        self.hcfg = hcfg
        self.balancer = GlobalBalancer(hcfg)
        self.engines: List = []
        self.cells: List[List[Instance]] = []
        self.cell_sims: List[CellSim] = []
        self.expected: Optional[int] = None   # informational (driver)
        self.sim: Optional[ClusterSim] = None

    def attach(self, sim: ClusterSim):
        self.sim = sim
        self.cells = partition_roster(sim.instances, self.hcfg.n_cells)
        parent_mgr = getattr(sim, "recovery", None)
        self.engines, self.cell_sims = [], []
        managers: List[CellRecovery] = []
        slot_cell: Dict[int, int] = {}
        for ci, insts in enumerate(self.cells):
            for inst in insts:
                slot_cell[inst.slot] = ci
            cs = CellSim(sim, insts, ci)
            if parent_mgr is not None:
                mgr = CellRecovery(_CellScope(sim, insts), parent_mgr.cfg)
                cs.recovery = mgr
                managers.append(mgr)
            eng = _make_cell_engine(
                dataclasses.replace(self.cfg, cell_tag=ci),
                self.bundle, self.tiers, self)
            eng.attach(cs)             # binds the cell manager too
            self.engines.append(eng)
            self.cell_sims.append(cs)
        if parent_mgr is not None:
            # Instance.fail()/hedge probes on the PARENT sim route to
            # the victim's owning cell from here on
            sim.recovery = _RecoveryRouter(managers, slot_cell,
                                           parent_mgr.cfg)
        tier_names: List[str] = []
        for inst in sim.instances:
            if inst.tier.name not in tier_names:
                tier_names.append(inst.tier.name)
        self.balancer.attach(sim, self.cell_sims, tier_names)

    def enqueue(self, req: Request, t: float):
        # placement guard the digests cannot give: never hand work to a
        # cell with zero alive instances (its engine could not even
        # build a candidate roster), unless the whole fleet is down
        viable = [ci for ci, insts in enumerate(self.cells)
                  if any(i.alive for i in insts)]
        if not viable:
            viable = list(range(len(self.cells)))
        ci = self.balancer.pick(t, viable)
        self.engines[ci].enqueue(req, t)

    # -- driver contract (repro.core.run_cell) ----------------------------
    @property
    def decisions(self) -> int:
        return sum(e.decisions for e in self.engines)

    @property
    def shed_count(self) -> int:
        return sum(e.shed_count for e in self.engines)

    @property
    def compute_log(self):
        out = []
        for e in self.engines:
            out.extend(e.compute_log)
        return out

    @property
    def policy(self):
        return self.engines[0].policy

    @property
    def ecfg(self):
        return self.engines[0].ecfg


def build_scheduler(cfg, bundle, tiers, hcfg: HierarchyConfig):
    """The hierarchy factory: ``span`` routing returns a plain
    `RouteBalance` whose fused scan is cell-sharded
    (``RBConfig.shard_cells`` — bitwise the single controller), and
    ``balanced`` routing returns the two-level
    `HierarchicalScheduler`. ``n_cells=1`` in either mode is the
    single controller itself."""
    from repro.core.scheduler import RouteBalance
    if hcfg.routing == "span":
        return RouteBalance(
            dataclasses.replace(cfg, shard_cells=hcfg.n_cells),
            bundle, tiers)
    return HierarchicalScheduler(cfg, bundle, tiers, hcfg)
