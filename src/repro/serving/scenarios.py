"""Cluster-scale scenario subsystem: parameterized serving worlds far
beyond the seed fixture.

The paper's headline results are fleet-scale — a 13-instance, 28-GPU
heterogeneous pool traced across a quality-cost-throughput frontier at
up to 30 req/s, with serial-scoring baselines collapsing 23x under load
(§6). This module generates the worlds those experiments need, and the
randomized ones the differential soak harness (`tests/test_soak.py`)
feeds to the fused/staged/numpy backends:

  * **synthetic rosters** (`synthetic_pool`): capacity-laddered pools
    scaling from the paper's 4-tier/13-instance cell up to 16 tiers x
    128+ instances, with heterogeneous price / TPOT-roofline / batch
    profiles and a matched `World` so estimator training works exactly
    as on the paper pool;
  * **scripted failure, recovery and straggler injection**
    (`FailureEvent` + `apply_schedule`): timed events against a running
    `ClusterSim` — node death (`Instance.fail`), re-entry with a clean
    slate (`Instance.recover`) and hidden slowdowns
    (`Instance.set_slowdown`) that telemetry does NOT report, the
    model-mismatch stress dead reckoning must survive;
  * **composite workload traces** (`TenantSpec` + `build_requests`):
    multi-tenant mixes layered on `serving.workload` — each tenant has
    its own arrival process (poisson / gamma-bursty / diurnal square
    wave / flash crowd), prompt topic/length distribution and budget
    mix; traces are merged into one arrival-ordered request stream.

`SCENARIOS` names ready-made worlds (selectable via
``python -m repro.launch.serve --scenario <name>`` and swept by
``benchmarks/sweep.py``); `random_scenario` draws a seeded random world
for the soak suite.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import numpy as np

from .cluster import ClusterSim
from .overload import OverloadConfig, arm_elastic, provision_reserve
from .recovery import RecoveryConfig, arm_recovery
from .request import Request
from .tiers import Tier, paper_pool_tiers
from .workload import make_arrivals, sample_budgets
from .world import TOPICS, Dataset, World, build_dataset, paper_world


# -- synthetic rosters --------------------------------------------------------

def synthetic_pool(n_tiers: int, n_instances: int, seed: int = 0
                   ) -> Tuple[List[Tier], List[str], World]:
    """A heterogeneous capacity ladder of `n_tiers` models spread over
    `n_instances` instances, with per-tier price / roofline / batch
    profiles calibrated to bracket the paper pool (3b..72b-class).

    Replica counts are skewed toward the cheap tiers (as in Table 1:
    2/3/5/3), every tier keeps >= 1 instance, and the returned `World`
    uses the ladder's capacities/verbosities so datasets and estimator
    bundles train exactly as on the paper pool.
    """
    assert n_tiers >= 1 and n_instances >= n_tiers, (n_tiers, n_instances)
    rng = np.random.default_rng(seed)
    caps = np.linspace(0.26, 0.74, n_tiers) if n_tiers > 1 \
        else np.array([0.5])
    caps = np.clip(caps + rng.uniform(-0.015, 0.015, n_tiers), 0.05, 0.95)
    verb = (np.linspace(1.18, 0.82, n_tiers) if n_tiers > 1
            else np.array([1.0])) * np.exp(rng.normal(0, 0.04, n_tiers))
    # params grow geometrically with capacity rank: ~0.8B .. ~72B active
    n_params = np.geomspace(8e8, 7.2e10, n_tiers) if n_tiers > 1 \
        else np.array([7e9])
    n_params = n_params * np.exp(rng.normal(0.0, 0.08, n_tiers))
    # replicas skew cheap: weight ~ params^-0.4, largest remainder >= 1
    w = n_params ** -0.4
    share = w / w.sum() * n_instances
    counts = np.maximum(np.floor(share).astype(int), 1)
    while counts.sum() > n_instances:
        counts[np.argmax(counts)] -= 1
    while counts.sum() < n_instances:
        counts[np.argmin(counts - share)] += 1
    tiers, names = [], []
    for j in range(n_tiers):
        p = float(n_params[j])
        name = f"syn{p / 1e9:.1f}b"
        while name in names:                       # jitter collisions
            name += "x"
        names.append(name)
        chips = int(min(2 ** max(int(np.ceil(np.log2(p / 6e9))), 0), 16))
        price_out = 0.06 * (p / 3e9) ** 0.6 * \
            float(np.exp(rng.normal(0.0, 0.06)))
        tiers.append(Tier(
            name=f"{name}/v5e-{chips}", model=name, model_cfg=None,
            n_chips=chips, n_instances=int(counts[j]),
            price_in=price_out * float(rng.uniform(0.85, 1.0)),
            price_out=price_out,
            bw_eff=float(rng.uniform(0.3, 1.0)),
            overhead_s=float(rng.uniform(0.0015, 0.003)),
            max_batch=int(rng.choice((16, 24, 32, 48, 64))),
            n_params=p,
            kv_bytes_per_token=5.7e4 * (p / 7e9) ** 0.65))
    world = World(caps, verb, seed=seed)
    return tiers, names, world


# -- workload composition -----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SessionSpec:
    """Multi-turn session structure for a tenant (the prefix-affinity
    workload): the tenant's requests are grouped into conversations that
    share a growing prompt prefix — turn u's prompt is turn u-1's prompt
    plus `extend` fresh tokens, so a router that lands follow-up turns
    on the instance holding the conversation's KV prefix skips most of
    the prefill (`serving.affinity`)."""
    turns: int = 4                    # turns per conversation
    base_len: int = 48                # first-turn prompt cap (tokens)
    extend: Tuple[int, int] = (12, 28)   # fresh tokens per follow-up


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant class in a composite trace: its own arrival process,
    prompt-population slice, and budget mix."""
    name: str
    lam: float                                   # req/s for this tenant
    arrival: str = "poisson"                     # workload.make_arrivals
    arrival_kw: Tuple[Tuple[str, float], ...] = ()
    topics: Optional[Tuple[str, ...]] = None     # restrict world topics
    len_band: Optional[Tuple[float, float]] = None  # len_in quantile band
    budget_frac: float = 0.0                     # P(request has a budget)
    budget_range: Tuple[float, float] = (2e-5, 4e-4)   # log-uniform USD
    priority: int = 0        # SLO class for admission shedding (0=premium)
    session: Optional[SessionSpec] = None   # multi-turn prefix sessions


def _tenant_prompt_pool(prompts, tenant: TenantSpec) -> np.ndarray:
    idx = np.arange(len(prompts))
    if tenant.topics is not None:
        keep = {TOPICS.index(t) for t in tenant.topics}
        idx = np.array([i for i in idx if prompts[i].topic in keep],
                       dtype=int)
    if tenant.len_band is not None and len(idx):
        lens = np.array([prompts[i].len_in for i in idx], float)
        lo, hi = np.quantile(lens, tenant.len_band)
        sub = idx[(lens >= lo) & (lens <= hi)]
        idx = sub if len(sub) else idx
    return idx if len(idx) else np.arange(len(prompts))


def _session_prompts(prompts, pool: np.ndarray, sess: SessionSpec,
                     n_t: int, rng) -> Tuple[list, list]:
    """Materialize `n_t` session-turn prompts: conversations are
    interleaved round-robin over the tenant's (time-ordered) arrival
    slots, so turn u of a conversation always arrives after turn u-1.
    Each turn's prompt is a FRESH `Prompt` object — turn u's tokens are
    turn u-1's plus `extend` new ones (capped at the world's 128-token
    embedding window), so consecutive turns share a growing prefix and
    the rolling-hash signatures (`affinity.prefix_signatures`) of a
    follow-up begin with its predecessor's. Returns (prompt per slot,
    base dataset index per slot — follow-ups reuse the base row's Q/L
    supervision)."""
    from .world import VOCAB

    n_sess = max(1, -(-n_t // max(sess.turns, 1)))   # ceil
    base_js = rng.choice(pool, n_sess, replace=True)
    convo: list = [None] * n_sess                    # running token state
    out_prompts, out_js = [], []
    for i in range(n_t):
        s = i % n_sess
        j = int(base_js[s])
        base = prompts[j]
        if convo[s] is None:
            toks = np.asarray(base.tokens[:sess.base_len], np.int32).copy()
        else:
            ext = int(rng.integers(sess.extend[0], sess.extend[1] + 1))
            toks = np.concatenate(
                [convo[s],
                 rng.integers(1, VOCAB, ext).astype(np.int32)])[:128]
        convo[s] = toks
        p = dataclasses.replace(base, tokens=toks,
                                len_in=int(toks.size))
        out_prompts.append(p)
        out_js.append(j)
    return out_prompts, out_js


def build_requests(ds: Dataset, tenants: Tuple[TenantSpec, ...], n: int,
                   lam_scale: float = 1.0, seed: int = 0, which="test"
                   ) -> List[Request]:
    """A merged, arrival-ordered multi-tenant request stream. `n` total
    requests split across tenants proportionally to their rates; each
    tenant draws prompts from its own slice of the world and stamps its
    budget mix. `lam_scale` scales every tenant's rate (the sweep's
    load axis)."""
    prompts, Q, L = ds.split(which)
    lam_total = sum(t.lam for t in tenants)
    reqs: List[Request] = []
    for k, ten in enumerate(tenants):
        n_t = max(int(round(n * ten.lam / lam_total)), 1)
        rng = np.random.default_rng((seed, k, 0xA11CE))
        arr = make_arrivals(ten.arrival, ten.lam * lam_scale, n_t,
                            seed=int(rng.integers(2 ** 31)),
                            **dict(ten.arrival_kw))
        pool = _tenant_prompt_pool(prompts, ten)
        if ten.session is not None:
            # note the draw order (prompts, then budgets) mirrors the
            # one-shot arm below — session-free tenants must keep
            # byte-identical streams to before the affinity workloads
            # existed, so the branch never perturbs rng consumption
            # for anyone else
            sess_prompts, sess_js = _session_prompts(
                prompts, pool, ten.session, n_t, rng)
            lo, hi = ten.budget_range
            budgets = sample_budgets(n_t, ten.budget_frac, lo, hi,
                                     rng=rng)
            for i in range(n_t):
                j = sess_js[i]
                reqs.append(Request(
                    rid=0, prompt=sess_prompts[i], arrival=float(arr[i]),
                    true_quality=Q[j], true_length=L[j],
                    budget=None if np.isnan(budgets[i])
                    else float(budgets[i]),
                    tenant=ten.name, priority=ten.priority))
            continue
        picks = rng.choice(pool, n_t, replace=True)
        lo, hi = ten.budget_range
        budgets = sample_budgets(n_t, ten.budget_frac, lo, hi, rng=rng)
        for i in range(n_t):
            j = int(picks[i])
            reqs.append(Request(
                rid=0, prompt=prompts[j], arrival=float(arr[i]),
                true_quality=Q[j], true_length=L[j],
                budget=None if np.isnan(budgets[i]) else float(budgets[i]),
                tenant=ten.name, priority=ten.priority))
    reqs.sort(key=lambda r: r.arrival)
    for i, r in enumerate(reqs):
        r.rid = i
    from .request import RequestColumns
    RequestColumns.from_requests(reqs)
    return reqs


# -- failure / recovery / straggler schedules ---------------------------------

@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One timed perturbation. Targets are either explicit `instances`
    iids or `frac`/`count` of the eligible set drawn at fire time
    (alive instances for fail/straggle/mute, dead ones for recover,
    muted ones for unmute). A fail event always leaves at least one
    instance alive. `mute`/`unmute` drive the telemetry-blackout
    failure mode: a muted worker keeps serving (and keeps its local
    snapshot fresh) but stops publishing to the scheduler's mirror —
    the staleness the recovery watchdog exists to catch."""
    t: float
    kind: str = "fail"          # fail | recover | straggle | mute | unmute
    frac: float = 0.0
    count: int = 0
    factor: float = 4.0             # straggle slowdown multiplier
    instances: Tuple[str, ...] = ()


def _fire_event(sim: ClusterSim, ev: FailureEvent, rng, t: float):
    if ev.instances:
        targets = [sim.by_id[iid] for iid in ev.instances
                   if iid in sim.by_id]
    else:
        if ev.kind == "recover":
            pool = [i for i in sim.instances if not i.alive]
        elif ev.kind == "unmute":
            pool = [i for i in sim.instances if i.tel_mute]
        else:
            pool = sim.alive_instances()
        k = ev.count if ev.count else int(round(ev.frac * len(pool)))
        k = min(max(k, 0), len(pool))
        targets = list(rng.choice(pool, k, replace=False)) if k else []
    for inst in targets:
        if ev.kind == "fail":
            if sum(i.alive for i in sim.instances) <= 1:
                break                       # never kill the whole fleet
            inst.fail()
        elif ev.kind == "recover":
            inst.recover(t)
        elif ev.kind == "straggle":
            inst.set_slowdown(ev.factor)
        elif ev.kind == "mute":
            inst.tel_mute = True
        elif ev.kind == "unmute":
            inst.tel_mute = False
        else:
            raise ValueError(ev.kind)


def apply_schedule(sim: ClusterSim, schedule, seed: int = 0):
    """Arm a failure/recovery/straggler schedule on a ClusterSim. Target
    draws happen at fire time so they compose with whatever has already
    failed or recovered."""
    rng = np.random.default_rng((seed, 0xFA11))
    for ev in schedule:
        sim.push(ev.t, functools.partial(_fire_event, sim, ev, rng))


def randomize_telemetry(sim: ClusterSim, seed: int,
                        kill_frac: float = 0.0) -> ClusterSim:
    """Load a sim's telemetry arrays with mid-run-looking state (and
    optionally kill a fraction of the roster) — the shared fixture for
    the soak suite's decision-parity checks and the sweep benchmark's
    parity probe."""
    rng = np.random.default_rng((seed, 0xD1CE))
    tel, I = sim.tel, len(sim.instances)
    tel.pending[:] = rng.uniform(0, 3000, I)
    tel.batch[:] = rng.integers(0, 12, I)
    tel.free[:] = rng.integers(0, 6, I)
    tel.ctx[:] = rng.uniform(0, 2048, I)
    tel.mark_all_dirty()          # in-place edit: stamp every row
    if kill_frac:
        k = min(int(round(kill_frac * I)), I - 1)
        for inst in rng.choice(sim.instances, k, replace=False):
            inst.fail()
    return sim


def randomize_prefix_state(sim: ClusterSim, cols, seed: int,
                           frac: float = 0.6) -> ClusterSim:
    """Warm a random subset of instance prefix sketches with random
    prompt prefixes from a request stream's columns — the shared
    fixture for affinity-enabled decision-parity checks. State is
    installed through the live dead-reckoning path (`sketch.insert` +
    `tel.write_prefix`), so the host sketches and the mirrored
    `TelemetryArrays.prefix_sig` planes end up exactly as a real run
    would leave them (dead instances stay cold: `Instance.fail`
    clears both)."""
    rng = np.random.default_rng((seed, 0xAFF1))
    sig = cols.prefix_sig
    for inst in sim.instances:
        if not inst.alive or rng.uniform() > frac:
            continue
        for _ in range(int(rng.integers(1, 4))):
            p = int(rng.integers(0, sig.shape[0]))
            depth = int(rng.integers(1, sig.shape[1] + 1))
            inst.sketch.insert(sig[p, :depth])
        sim.tel.write_prefix(inst.slot, inst.sketch)
    return sim


# -- scenarios ----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ElasticSpec:
    """Overload control for a scenario: `reserve` pre-provisioned cold
    instances added to the roster (spread by `provision_reserve` — size
    them to stay inside the fused hot path's pow2-I bucket) plus the
    detector/autoscaler/shedding config armed on every sim the scenario
    builds."""
    reserve: int = 4
    overload: OverloadConfig = OverloadConfig()


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A full serving world: roster + composite workload + perturbation
    schedule. `build()` materializes the pool, world and dataset."""
    name: str
    pool: str = "paper"             # paper | synthetic
    n_tiers: int = 4
    n_instances: int = 13
    tenants: Tuple[TenantSpec, ...] = (TenantSpec("all", 12.0),)
    schedule: Tuple[FailureEvent, ...] = ()
    elastic: Optional[ElasticSpec] = None   # overload control, if any
    # fault-tolerant lifecycle (repro.serving.recovery): armed on every
    # sim the scenario builds, so failures in `schedule` feed the
    # retry/hedge path instead of terminally failing their victims
    recovery: Optional["RecoveryConfig"] = None
    seed: int = 0

    @property
    def lam(self) -> float:
        return sum(t.lam for t in self.tenants)

    def build(self, dataset_n: int = 1200) -> "ScenarioRun":
        if self.pool == "paper":
            world, names = paper_world(seed=self.seed)
            tiers = paper_pool_tiers()
        else:
            tiers, names, world = synthetic_pool(
                self.n_tiers, self.n_instances, seed=self.seed)
        reserve_iids: Tuple[str, ...] = ()
        if self.elastic is not None:
            tiers, reserve_iids = provision_reserve(
                tiers, self.elastic.reserve)
        ds = build_dataset(world, n=dataset_n, seed=self.seed + 1)
        return ScenarioRun(self, tiers, names, world, ds,
                           reserve_iids=reserve_iids)


class ScenarioRun:
    """A built scenario: roster, world, dataset, and helpers to train
    the estimator stack and run cells against it."""

    def __init__(self, scenario: Scenario, tiers: List[Tier],
                 names: List[str], world: World, ds: Dataset,
                 reserve_iids: Tuple[str, ...] = ()):
        self.scenario = scenario
        self.tiers = tiers
        self.names = names
        self.world = world
        self.ds = ds
        self.reserve_iids = reserve_iids
        # mutable copies of the scenario's control-plane configs so one
        # built world can be re-armed per experiment arm (the elastic
        # bench sweeps scale_up_lag_s / shed, the chaos bench sweeps
        # lost-work vs retry vs retry+hedge, on a single trained bundle)
        self.elastic: Optional[ElasticSpec] = scenario.elastic
        self.recovery: Optional[RecoveryConfig] = scenario.recovery
        self._bundle = None
        self._train_data = None

    @property
    def n_instances(self) -> int:
        return sum(t.n_instances for t in self.tiers)

    def bundle(self, **kw):
        """Train (and cache) the estimator bundle for this roster."""
        if self._bundle is None:
            from repro.core import EstimatorBundle
            self._bundle = EstimatorBundle.train(
                self.ds, self.tiers, self.names, **kw)
        return self._bundle

    def train_data(self):
        """(emb, Q, L, prices) for fitting decoupled baseline routers
        on this world's shared supervision (cached)."""
        if self._train_data is None:
            from repro.core.policies import train_data
            self._train_data = train_data(self.bundle(), self.ds,
                                          self.tiers, self.names)
        return self._train_data

    def policy(self, name: str, **kw):
        """A fitted `SchedulingPolicy` from the registry for this
        world: `make_policy(name, **kw)` trained on `train_data()`."""
        from repro.core.policies import make_policy
        return make_policy(name, **kw).fit(*self.train_data())

    def engine(self, policy, deployment: str = "windowed", **engine_kw):
        """A `ServingEngine` over this world's roster. `policy` is a
        registry name (fitted via `self.policy`) or an already-built
        `SchedulingPolicy`."""
        from repro.core import EngineConfig, ServingEngine
        if isinstance(policy, str):
            policy = self.policy(policy)
        return ServingEngine(policy, self.bundle(), self.tiers,
                             EngineConfig(deployment=deployment,
                                          **engine_kw))

    def requests(self, n: int, lam_scale: float = 1.0, seed: int = 0
                 ) -> List[Request]:
        return build_requests(self.ds, self.scenario.tenants, n,
                              lam_scale=lam_scale, seed=seed)

    def arm(self, sim: ClusterSim) -> ClusterSim:
        """Arm this run's control plane (if any) on a sim: overload
        reserves go cold and the detector loop starts (`sim.overload`);
        the fault-tolerant lifecycle attaches (`sim.recovery`) so the
        schedule's failures feed retry/hedge instead of terminal
        failure."""
        if self.elastic is not None:
            arm_elastic(sim, self.elastic.overload, self.reserve_iids)
        if self.recovery is not None:
            arm_recovery(sim, self.recovery)
        return sim

    def sim(self, seed: int = 0) -> ClusterSim:
        s = ClusterSim(self.tiers, self.names, seed=seed)
        self.arm(s)
        apply_schedule(s, self.scenario.schedule,
                       seed=self.scenario.seed + seed)
        return s

    def run_cell(self, scheduler, reqs: List[Request], seed: int = 0
                 ) -> Dict:
        """`repro.core.run_cell` with this scenario's schedule armed."""
        from repro.core import run_cell
        return run_cell(scheduler, self.tiers, self.names, reqs,
                        seed=seed, schedule=self.scenario.schedule,
                        schedule_seed=self.scenario.seed + seed,
                        setup=self.arm)


def random_scenario(seed: int, max_tiers: int = 16,
                    max_instances: int = 128, max_lam: float = 30.0
                    ) -> Scenario:
    """A seeded random serving world for the differential soak harness:
    random roster scale, 1-3 tenants with random arrival processes and
    prompt slices, and a random fail/recover/straggle schedule."""
    rng = np.random.default_rng((seed, 0x5CEB))
    n_tiers = int(rng.integers(2, max_tiers + 1))
    n_instances = int(rng.integers(n_tiers, max_instances + 1))
    kinds = ("poisson", "gamma", "square", "flash")
    tenants = []
    for k in range(int(rng.integers(1, 4))):
        kind = str(rng.choice(kinds))
        kw: Tuple[Tuple[str, float], ...] = ()
        if kind == "gamma":
            kw = (("cv", float(rng.uniform(1.5, 4.0))),)
        elif kind == "square":
            kw = (("period", float(rng.uniform(10.0, 60.0))),
                  ("high_frac", float(rng.uniform(1.2, 1.8))))
        elif kind == "flash":
            kw = (("burst_start", float(rng.uniform(2.0, 10.0))),
                  ("burst_mult", float(rng.uniform(2.0, 6.0))))
        topics = None
        if rng.uniform() < 0.5:
            m = int(rng.integers(1, len(TOPICS)))
            topics = tuple(rng.choice(TOPICS, m, replace=False))
        tenants.append(TenantSpec(
            name=f"t{k}", lam=float(rng.uniform(2.0, max_lam / 2)),
            arrival=kind, arrival_kw=kw, topics=topics,
            budget_frac=float(rng.choice((0.0, 0.3, 0.6))),
        ))
    total = sum(t.lam for t in tenants)
    if total > max_lam:                # keep the aggregate rate bounded
        tenants = [dataclasses.replace(t, lam=t.lam * max_lam / total)
                   for t in tenants]
    schedule = []
    if rng.uniform() < 0.7:
        t_fail = float(rng.uniform(1.0, 6.0))
        schedule.append(FailureEvent(t=t_fail, kind="fail",
                                     frac=float(rng.uniform(0.1, 0.3))))
        if rng.uniform() < 0.6:
            schedule.append(FailureEvent(
                t=t_fail + float(rng.uniform(2.0, 6.0)), kind="recover",
                frac=1.0))
    if rng.uniform() < 0.5:
        schedule.append(FailureEvent(
            t=float(rng.uniform(1.0, 8.0)), kind="straggle",
            frac=float(rng.uniform(0.1, 0.4)),
            factor=float(rng.uniform(2.0, 6.0))))
    return Scenario(
        name=f"random{seed}", pool="synthetic", n_tiers=n_tiers,
        n_instances=n_instances, tenants=tuple(tenants),
        schedule=tuple(schedule), seed=seed)


# Named worlds: the paper cell, its non-stationary variants, and the
# beyond-paper cluster scales.
SCENARIOS: Dict[str, Scenario] = {
    "paper": Scenario(name="paper"),
    "flashcrowd": Scenario(
        name="flashcrowd",
        tenants=(TenantSpec("all", 12.0, arrival="flash",
                            arrival_kw=(("burst_start", 8.0),
                                        ("burst_dur", 6.0),
                                        ("burst_mult", 4.0))),)),
    "diurnal": Scenario(
        name="diurnal",
        tenants=(TenantSpec("all", 12.0, arrival="square",
                            arrival_kw=(("period", 30.0),
                                        ("high_frac", 1.7))),)),
    "failover": Scenario(
        name="failover",
        schedule=(FailureEvent(t=4.0, kind="fail", frac=0.25),
                  FailureEvent(t=8.0, kind="straggle", frac=0.2,
                               factor=3.0),
                  FailureEvent(t=12.0, kind="recover", frac=1.0))),
    # Multi-turn conversations sharing growing prompt prefixes — the
    # workload the prefix-affinity term (RBConfig.affinity_weight,
    # serving.affinity) is for. `benchmarks/affinity.py` runs this
    # world affinity-on vs affinity-off across all three backends.
    "session_chat": Scenario(
        name="session_chat",
        tenants=(
            TenantSpec("chat", 10.0, arrival="gamma",
                       arrival_kw=(("cv", 2.0),),
                       session=SessionSpec(turns=5)),
            TenantSpec("oneshot", 4.0),
        )),
    "multitenant": Scenario(
        name="multitenant", pool="synthetic", n_tiers=6, n_instances=24,
        seed=2,
        tenants=(
            TenantSpec("chat", 8.0, arrival="gamma",
                       arrival_kw=(("cv", 3.0),),
                       topics=("chat", "instruct"),
                       len_band=(0.0, 0.6)),
            TenantSpec("code", 4.0, topics=("code", "math"),
                       len_band=(0.4, 1.0)),
            TenantSpec("batch", 4.0, topics=("reading", "reward"),
                       budget_frac=0.8, budget_range=(1e-5, 1.5e-4)),
        )),
    "cluster": Scenario(
        name="cluster", pool="synthetic", n_tiers=8, n_instances=48,
        seed=3,
        tenants=(
            TenantSpec("interactive", 10.0, arrival="gamma",
                       arrival_kw=(("cv", 2.5),), len_band=(0.0, 0.7)),
            TenantSpec("bulk", 6.0, budget_frac=0.5),
        ),
        schedule=(FailureEvent(t=6.0, kind="fail", frac=0.15),
                  FailureEvent(t=14.0, kind="recover", frac=1.0))),
    "hyperscale": Scenario(
        name="hyperscale", pool="synthetic", n_tiers=16, n_instances=128,
        seed=4,
        tenants=(
            TenantSpec("interactive", 20.0, arrival="gamma",
                       arrival_kw=(("cv", 2.0),)),
            TenantSpec("batch", 10.0, budget_frac=0.4),
        )),
    # The 10k-instance world the hierarchical scheduler
    # (`repro.serving.hierarchy`) exists for: a single fused controller
    # scans a 16384-row bucket per decision; partitioned into 8-32
    # cells each engine rides a 1024-2048 bucket while the
    # GlobalBalancer spreads the fleet-rate multi-tenant arrival mix
    # from per-cell digests. Built only by `benchmarks/hierarchy.py`
    # and opt-in tests — a 10k roster is deliberately not tier-1.
    "hyperfleet_10k": Scenario(
        name="hyperfleet_10k", pool="synthetic", n_tiers=16,
        n_instances=10000, seed=7,
        tenants=(
            TenantSpec("interactive", 220.0, arrival="gamma",
                       arrival_kw=(("cv", 2.0),), len_band=(0.0, 0.7),
                       priority=0),
            TenantSpec("agents", 90.0, arrival="gamma",
                       arrival_kw=(("cv", 3.0),),
                       topics=("code", "math"), priority=1),
            TenantSpec("batch", 90.0, budget_frac=0.5, priority=2),
        )),
    # Elastic worlds: overload control armed on every sim. The 6-base
    # + 2-reserve roster is deliberate — bucket_pow2(6) == bucket_pow2
    # (8) == 8, so the autoscaler's whole range rides one compiled
    # fused-hot-path I bucket (the no-recompile-on-scale contract the
    # elastic soak asserts), and the small fleet actually overloads
    # during the diurnal peaks / flash burst instead of absorbing them.
    "diurnal_elastic": Scenario(
        name="diurnal_elastic", pool="synthetic", n_tiers=4,
        n_instances=6, seed=5,
        tenants=(
            TenantSpec("premium", 14.0, arrival="square",
                       arrival_kw=(("period", 20.0),
                                   ("high_frac", 1.8)),
                       priority=0),
            TenantSpec("standard", 8.0, arrival="gamma",
                       arrival_kw=(("cv", 2.5),), priority=1),
            TenantSpec("batch", 6.0, budget_frac=0.6,
                       budget_range=(1e-5, 1.5e-4), priority=2),
        ),
        elastic=ElasticSpec(reserve=2, overload=OverloadConfig())),
    "flashcrowd_elastic": Scenario(
        name="flashcrowd_elastic", pool="synthetic", n_tiers=4,
        n_instances=6, seed=6,
        tenants=(
            TenantSpec("premium", 9.0, arrival="flash",
                       arrival_kw=(("burst_start", 4.0),
                                   ("burst_dur", 6.0),
                                   ("burst_mult", 5.0)),
                       priority=0),
            TenantSpec("batch", 7.0, budget_frac=0.5, priority=2),
        ),
        elastic=ElasticSpec(
            reserve=2,
            overload=OverloadConfig(up_patience=1, cooldown_s=1.0))),
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}") from None
