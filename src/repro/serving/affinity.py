"""Prefix-cache / session-affinity signal (ROADMAP item 2).

Real heterogeneous routers win big on KV reuse: routing a follow-up
turn to the instance already holding its prefix cuts prefill nearly to
zero. This module is the shared vocabulary for that signal across the
whole stack:

  * `prefix_signatures` — a rolling-hash prefix sketch of a prompt:
    one 32-bit signature per `PREFIX_BLOCK`-token block boundary, so
    two prompts sharing a prefix share the leading signature columns.
    Signatures are 32-bit ON PURPOSE: the fused hot path compares them
    in-graph and jax runs with x64 disabled — a 64-bit hash would be
    silently truncated on device and break numpy==jax==fused parity.
  * `PrefixSketch` — the per-instance host-side cache model: a
    flattened hash-trie (each signature encodes its whole root path,
    so a dict IS the trie) with LRU eviction at `SKETCH_SLOTS`
    entries, dead-reckoned on dispatch and cleared on failure.
    `mirror()` renders it as the fixed-width `prefix_sig` row that
    `TelemetryArrays` carries for the scheduler.
  * `hit_fraction` — the scoring-side lookup: matched-prefix fraction
    per (request, instance), written once over a generic `xp`
    (numpy or jax.numpy) so the staged and fused decision backends
    score bit-identically by construction.

The affinity term itself (`RBConfig.affinity_weight`) discounts the
predicted prefill/latency term by the matched fraction — see
`core/scoring.py` and the greedy scans in `core/assignment.py` /
`core/decision_jax.py` / `core/hotpath.py`.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

PREFIX_BLOCK = 16     # tokens per hashed prefix block
SIG_WIDTH = 8         # signature columns per prompt (covers 128 tokens)
SKETCH_SLOTS = 64     # sketch capacity per instance = mirror row width

_MULT = np.uint32(2654435761)        # Knuth multiplicative constant
_ONE = np.uint32(1)


def prefix_signatures(tokens, lens) -> np.ndarray:
    """Rolling-hash prefix signatures.

    (P, L) token matrix + (P,) true token counts -> (P, SIG_WIDTH)
    int32. Column `d` holds the hash of the first
    min(len, (d+1)*PREFIX_BLOCK) tokens, or 0 where the prompt does
    not reach block `d` (0 is the empty-slot sentinel; real hashes
    that land on 0 are remapped to 1). Updates are masked by the true
    length, so zero-padded SoA token matrices and raw per-prompt
    arrays produce identical signatures — the dispatch path (which
    hashes single prompts) and the scoring path (which hashes the
    padded `RequestColumns.tokens` matrix) must agree exactly.
    """
    toks = np.atleast_2d(np.asarray(tokens))
    P, L = toks.shape
    lens_ = np.asarray(lens, np.int64).reshape(P)
    out = np.zeros((P, SIG_WIDTH), np.int32)
    h = np.zeros(P, np.uint32)
    width = min(L, SIG_WIDTH * PREFIX_BLOCK)
    for t in range(width):
        step = h * _MULT + toks[:, t].astype(np.uint32) + _ONE
        h = np.where(t < lens_, step, h)
        if (t + 1) % PREFIX_BLOCK == 0 or t + 1 == width:
            d = t // PREFIX_BLOCK
            sig = h.view(np.int32).copy()
            sig[sig == 0] = 1
            out[:, d] = np.where(lens_ > d * PREFIX_BLOCK, sig, 0)
    return out


def prompt_signatures(prompt) -> np.ndarray:
    """Signature row for one `Prompt`, memoized on the prompt object.

    The dispatch-side sketch update hashes at `Instance.submit` time —
    hedged re-dispatch submits directly to the target instance,
    bypassing the SoA columns entirely, so the sketch bookkeeping
    cannot rely on `RequestColumns` being present.
    """
    sig = getattr(prompt, "_prefix_sig", None)
    if sig is None:
        toks = np.asarray(prompt.tokens)
        sig = prefix_signatures(toks[None, :],
                                np.array([toks.size], np.int64))[0]
        prompt._prefix_sig = sig
    return sig


class PrefixSketch:
    """Dead-reckoned model of one instance's prefix cache.

    A flattened hash-trie: each stored signature encodes its entire
    path from the root (hash of all tokens up to that block boundary),
    so membership of the *longest matched run* of a prompt's signature
    columns is exactly a trie walk. LRU-evicts beyond `capacity` —
    matching the fixed-width `TelemetryArrays.prefix_sig` mirror row
    the scheduler scores against.
    """

    __slots__ = ("capacity", "slots", "_seq")

    def __init__(self, capacity: int = SKETCH_SLOTS):
        self.capacity = capacity
        self.slots: dict = {}        # sig -> last-touch sequence number
        self._seq = 0

    def __len__(self) -> int:
        return len(self.slots)

    def insert(self, sigs: Iterable[int]):
        """Credit the cache with a dispatched prompt's signature row
        (0 sentinels skipped). Touch order is the eviction order."""
        for s in sigs:
            s = int(s)
            if s == 0:
                continue
            self._seq += 1
            if s not in self.slots and len(self.slots) >= self.capacity:
                victim = min(self.slots, key=self.slots.get)
                del self.slots[victim]
            self.slots[s] = self._seq

    def hit_tokens(self, sigs: Iterable[int], len_in: float) -> int:
        """Matched-prefix tokens for one prompt: the leading run of
        signature columns present in the sketch, in token units,
        capped at the prompt length. Integer math — must agree with
        `hit_fraction`'s vectorized form."""
        run = 0
        for s in sigs:
            if int(s) == 0 or int(s) not in self.slots:
                break
            run += 1
        return int(min(run * PREFIX_BLOCK, int(len_in)))

    def clear(self):
        self.slots.clear()

    def mirror(self, out: Optional[np.ndarray] = None) -> np.ndarray:
        """Fixed-width int32 render for `TelemetryArrays.prefix_sig`.
        Insertion-ordered and zero-padded; order is irrelevant to the
        scoring lookup (set membership) but keeps the mirror
        deterministic for checkpoint/restore bitwise identity."""
        if out is None:
            out = np.zeros(self.capacity, np.int32)
        out[:] = 0
        vals = list(self.slots)
        out[:len(vals)] = vals
        return out


def hit_fraction(req_sig, len_in, sig_plane, xp):
    """Matched-prefix fraction per (request, instance).

    (R, SIG_WIDTH) int32 request signatures x (I, SKETCH_SLOTS) int32
    sketch mirrors -> (R, I) float32 in [0, 1]: leading-run block
    match, converted to tokens, capped at and normalized by the
    request's input length. Pure integer compares plus one IEEE
    float32 divide, written once over `xp` (numpy or jax.numpy) so
    the staged and fused backends are bit-identical by construction.
    The 0 sentinel (empty sketch slot / absent signature column)
    never matches.
    """
    present = (req_sig[:, :, None, None]
               == sig_plane[None, None, :, :]).any(-1)     # (R, D, I)
    present = present & (req_sig != 0)[:, :, None]
    run = xp.cumprod(present.astype(xp.int32), axis=1).sum(axis=1)
    lenf = xp.maximum(len_in.astype(xp.float32), xp.float32(1.0))
    matched = xp.minimum(
        run.astype(xp.float32) * xp.float32(PREFIX_BLOCK), lenf[:, None])
    return matched / lenf[:, None]
