"""Heterogeneous serving tiers: (model, TPU-slice) pairs with a decode
roofline TPOT model and public per-token prices.

The paper's Table 1 (GPU) pool maps to TPU v5e slices (DESIGN.md §3):
per-iteration decode time is the max of the weight-read, KV-read and
compute terms on the slice, plus a fixed dispatch overhead. A per-tier
bandwidth-efficiency constant is calibrated so the reference-point TPOT
matches Table 1's measured values — the *functional form* (TPOT grows
with batch and context) is the roofline's.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Tier:
    name: str                 # e.g. "qwen2.5-72b/v5e-16"
    model: str                # model name in the routing pool
    model_cfg: Optional[ModelConfig]
    n_chips: int
    n_instances: int
    price_in: float           # USD per 1M input tokens
    price_out: float          # USD per 1M output tokens
    bw_eff: float             # calibrated HBM efficiency
    flops_eff: float = 0.5
    overhead_s: float = 0.002
    max_batch: int = 48
    n_params: float = 0.0     # active params
    kv_bytes_per_token: float = 0.0

    def tpot(self, batch_size: float, mean_ctx: float) -> float:
        """Roofline decode-iteration time (s) = max of three terms."""
        b = max(batch_size, 1.0)
        weight_read = 2.0 * self.n_params / (HBM_BW * self.n_chips
                                             * self.bw_eff)
        kv_read = (b * mean_ctx * self.kv_bytes_per_token
                   / (HBM_BW * self.n_chips * self.bw_eff))
        compute = (2.0 * self.n_params * b
                   / (PEAK_FLOPS_BF16 * self.n_chips * self.flops_eff))
        return max(weight_read, kv_read, compute) + self.overhead_s

    def prefill_time(self, prompt_tokens: float) -> float:
        flops = 2.0 * self.n_params * prompt_tokens
        return flops / (PEAK_FLOPS_BF16 * self.n_chips * 0.45) + 0.004

    def cost(self, tokens_in: float, tokens_out: float) -> float:
        return (tokens_in * self.price_in
                + tokens_out * self.price_out) / 1e6


def _mk(name, model, cfg, chips, inst, pin, pout, bw_eff, **kw) -> Tier:
    n_params = cfg.param_counts()["active"] if cfg else 0
    kvb = 0.0
    if cfg:
        kvb = (cfg.n_layers * cfg.n_kv_heads * cfg.hd * 2 * 2)  # bf16 k+v
    return Tier(name=name, model=model, model_cfg=cfg, n_chips=chips,
                n_instances=inst, price_in=pin, price_out=pout,
                bw_eff=bw_eff, n_params=n_params,
                kv_bytes_per_token=kvb, **kw)


def paper_pool_tiers() -> List[Tier]:
    """The 13-instance, 4-tier pool of Table 1, mapped to v5e slices.

    bw_eff calibrated so tpot(b=8, ctx=500) ~ Table 1's measured TPOT
    (41.6 / 13.9 / 19.6 / 10.2 ms).
    """
    from repro.configs import QWEN25_POOL
    return [
        _mk("qwen2.5-72b/v5e-16", "qwen2.5-72b",
            QWEN25_POOL["qwen2.5-72b"], 16, 2, 0.38, 0.40, bw_eff=0.28),
        _mk("qwen2.5-14b/v5e-4", "qwen2.5-14b",
            QWEN25_POOL["qwen2.5-14b"], 4, 3, 0.15, 0.15, bw_eff=0.75),
        _mk("qwen2.5-7b/v5e-1", "qwen2.5-7b",
            QWEN25_POOL["qwen2.5-7b"], 1, 5, 0.07, 0.07, bw_eff=1.00),
        _mk("qwen2.5-3b/v5e-1", "qwen2.5-3b",
            QWEN25_POOL["qwen2.5-3b"], 1, 3, 0.06, 0.06, bw_eff=0.80),
    ]


def assigned_pool_tiers() -> List[Tier]:
    """A heterogeneous pool built from the ASSIGNED architectures —
    RouteBalance routing across the model zoo itself (examples/)."""
    from repro.configs import ARCHS
    rows = [
        ("gemma3-27b", 8, 1, 0.30, 0.32, 0.45),
        ("mixtral-8x7b", 8, 1, 0.24, 0.24, 0.50),
        ("phi3-mini-3.8b", 1, 3, 0.08, 0.08, 0.75),
        ("granite-3-2b", 1, 3, 0.06, 0.06, 0.80),
        ("mamba2-1.3b", 1, 2, 0.04, 0.04, 0.85),
        ("qwen3-0.6b", 1, 2, 0.03, 0.03, 0.85),
    ]
    return [_mk(f"{m}/v5e-{c}", m, ARCHS[m], c, i, pi, po, eff)
            for m, c, i, pi, po, eff in rows]


def tpot_table(tiers: List[Tier], batch: float = 8, ctx: float = 500):
    return {t.name: round(t.tpot(batch, ctx) * 1e3, 1) for t in tiers}
