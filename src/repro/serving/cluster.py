"""Discrete-event simulation of a heterogeneous continuous-batching
cluster (the vLLM-analogue substrate).

Each instance runs iteration-level continuous batching: every decode
iteration advances all running sequences by one token in
``tier.tpot(batch, mean_ctx)`` seconds (the calibrated roofline), admits
queued requests into free slots (charging roofline prefill time, which
blocks the engine like vLLM's default non-chunked prefill), and retires
finished sequences. Telemetry is a non-blocking snapshot refreshed at
iteration boundaries — the paper's worker-side-cache contract (§5) — so
the scheduler always reads slightly-stale state, which is exactly what
dead reckoning exists to correct.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import heapq
import itertools
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from .affinity import SKETCH_SLOTS, PrefixSketch, prompt_signatures
from .request import Request
from .tiers import Tier


@dataclasses.dataclass
class _Seq:
    req: Request
    target_tokens: int          # true completion length for this model
    max_tokens: int             # dispatch-time clamp (budget worst case)
    budget_tokens: Optional[int]  # streaming early-stop bound
    generated: int = 0
    ctx: int = 0                # prompt + generated


class TelemetryArrays:
    """Structure-of-arrays telemetry over the full instance roster.

    The dict snapshots (`Instance.snapshot`) are the worker-side cache
    the paper describes; this is the scheduler-side columnar view of the
    same numbers, written in place at iteration boundaries so the hot
    path reads (I,) arrays instead of marshalling one dict per instance
    per batch.

    Incremental consumers (the fused hot path's device-resident state
    mirror) track two counters plus a per-row stamp instead of copying
    the whole view every batch:

      * `version` bumps on every write; `last_write[slot]` records the
        version at which each row last changed, so a reader that synced
        at version v refreshes exactly the rows with
        ``last_write > v`` — a handful of scatter rows per batch instead
        of a full (I,)x5 re-upload;
      * `roster_version` bumps only on roster-shape events (kill /
        revive). Those flip the alive mask, which incremental readers
        keep device-resident, so they fall back to a full reseed.

    Bulk in-place edits of the columns (test fixtures, benchmarks) must
    call `mark_all_dirty()` so stamp-based readers see them.
    """

    def __init__(self, instances: List["Instance"]):
        I = len(instances)
        self.pending = np.zeros(I)                  # pending decode tokens
        self.batch = np.zeros(I)                    # decode batch size
        self.free = np.array([i.tier.max_batch for i in instances], float)
        self.ctx = np.zeros(I)                      # mean context length
        self.queue = np.zeros(I)                    # queue depth
        self.t = np.zeros(I)                        # snapshot timestamp
        self.max_batch = np.array([i.tier.max_batch for i in instances],
                                  float)
        self.alive = np.ones(I, bool)
        self.version = 0
        self.roster_version = 0
        self.last_write = np.zeros(I, np.int64)     # version stamp per row
        # prefix-cache affinity planes (serving.affinity): per-instance
        # sketch mirrors the decision backends score reuse against, and
        # a cumulative matched-token diagnostic. Dead-reckoned on the
        # SCHEDULER side (Instance.submit), not at iteration
        # boundaries, so they ride their own version counter: bumping
        # `version`/`last_write` here would make the fused mirror
        # re-pull d/b/free/ctx rows the worker never reported, and a
        # sketch write must never look like a telemetry heartbeat to
        # the staleness watchdog.
        self.prefix_sig = np.zeros((I, SKETCH_SLOTS), np.int32)
        self.prefix_hit = np.zeros(I)       # cumulative matched tokens
        self.prefix_version = 0

    def write(self, slot: int, pending: float, batch: int, free: int,
              ctx: float, queue: int, t: float):
        self.pending[slot] = pending
        self.batch[slot] = batch
        self.free[slot] = free
        self.ctx[slot] = ctx
        self.queue[slot] = queue
        self.t[slot] = t
        self.version += 1
        self.last_write[slot] = self.version

    def write_prefix(self, slot: int, sketch: PrefixSketch,
                     hit_tokens: float = 0.0):
        """Mirror an instance's prefix sketch into its `prefix_sig` row
        (dead-reckoned at dispatch) and accrue the matched tokens the
        dispatch was credited with. Deliberately does NOT touch
        `version`/`last_write` — see the class docstring."""
        sketch.mirror(out=self.prefix_sig[slot])
        self.prefix_hit[slot] += hit_tokens
        self.prefix_version += 1

    def clear_prefix(self, slot: int):
        """Drop a row's prefix credit (instance failure: the cache died
        with the node, and a revived instance comes back cold)."""
        self.prefix_sig[slot, :] = 0
        self.prefix_version += 1

    def dirty_rows(self, since: int) -> np.ndarray:
        """Rows written after version `since` (ascending slot order)."""
        return np.flatnonzero(self.last_write > since)

    def mark_all_dirty(self):
        """Stamp every row as freshly written — required after editing
        the columns in place (rather than through `write`)."""
        self.version += 1
        self.last_write[:] = self.version

    def kill(self, slot: int):
        self.alive[slot] = False
        self.version += 1
        self.roster_version += 1

    def revive(self, slot: int, t: float):
        """Recovered instance re-enters the roster with a clean slate
        (it lost all running/queued work when it failed)."""
        self.alive[slot] = True
        self.roster_version += 1
        self.write(slot, pending=0.0, batch=0, free=int(self.max_batch[slot]),
                   ctx=0.0, queue=0, t=t)

    def quarantine(self, slot: int):
        """Mask a stale row exactly like a dead one (the telemetry
        watchdog's path): an alive-mask flip + `roster_version` bump, so
        incremental readers full-reseed with their already-compiled
        program — quarantine churn never costs an XLA recompile. The
        instance itself keeps serving what it has; it just receives no
        new dispatches until the row is released."""
        self.alive[slot] = False
        self.version += 1
        self.roster_version += 1

    def unquarantine(self, slot: int):
        """Release a quarantined row back into the roster. The caller
        reseeds the row with a fresh `write` (the instance was serving
        the whole time, so — unlike `revive` — its true state is not a
        clean slate)."""
        self.alive[slot] = True
        self.version += 1
        self.roster_version += 1


class Instance:
    def __init__(self, iid: str, tier: Tier, model_idx: int, sim: "ClusterSim"):
        self.iid = iid
        self.tier = tier
        self.model_idx = model_idx
        self.sim = sim
        self.slot = 0               # row in ClusterSim.tel (set by the sim)
        # FIFO of (req, pred_len); deque so _admit pops are O(1) even
        # when overload piles thousands of requests behind one instance
        self.queue: Deque[Tuple[Request, float]] = collections.deque()
        self.running: List[_Seq] = []
        self.iter_scheduled = False
        self.busy_until = 0.0
        self.alive = True
        self.epoch = 0              # bumped on fail(): one life = one epoch
        self.quarantined = False    # watchdog-masked (tel row dark)
        self.tel_mute = False       # blackout: stop publishing telemetry
        self.slowdown = 1.0         # >1 = straggler (hidden from telemetry)
        # dead-reckoned model of this instance's prefix cache
        # (serving.affinity): credited at submit, cleared on fail
        self.sketch = PrefixSketch()
        # telemetry snapshot (refreshed at iteration boundaries)
        self.snapshot: Dict = self._idle_snapshot(0.0)
        self.total_tokens = 0

    def _idle_snapshot(self, t: float) -> Dict:
        return {"queue_depth": 0, "pending_decode": 0.0, "batch_size": 0,
                "free_slots": self.tier.max_batch, "mean_ctx": 0.0,
                "t": t}

    # -- scheduler-facing ---------------------------------------------------
    def submit(self, req: Request, t: float, pred_len: float,
               max_tokens: Optional[int]):
        req.instance = self.iid
        req.model_idx = self.model_idx
        req.dispatch_time = t
        req.pred_len = pred_len
        req.max_tokens = max_tokens
        # prefix-cache dead reckoning, here because submit is the ONE
        # dispatch funnel (windowed engine, station drain, AND the
        # hedge's direct re-submit): stamp the achieved hit against the
        # sketch as it stands, then credit the sketch and refresh the
        # scheduler-side mirror. A requeued retry re-hashes against the
        # CURRENT target — never the cache its failed victim lost.
        sigs = prompt_signatures(req.prompt)
        hit_tok = self.sketch.hit_tokens(sigs, req.prompt.len_in)
        req.prefix_hit = hit_tok / max(float(req.prompt.len_in), 1.0)
        self.sketch.insert(sigs)
        self.sim.tel.write_prefix(self.slot, self.sketch, hit_tok)
        self.queue.append((req, pred_len))
        self._kick(t)

    def telemetry(self) -> Dict:
        return dict(self.snapshot)

    # -- engine -------------------------------------------------------------
    def _kick(self, t: float):
        if not self.iter_scheduled and self.alive:
            self.iter_scheduled = True
            # iterate events carry the epoch they were scheduled in: an
            # event from a previous life (pre-fail) is a no-op when it
            # fires, so it can never race a post-recovery chain
            self.sim.push(max(t, self.busy_until),
                          functools.partial(self._iterate, epoch=self.epoch))

    def _admit(self, t: float) -> float:
        """Admit queued requests into free slots; returns prefill seconds."""
        dt = 0.0
        while self.queue and len(self.running) < self.tier.max_batch:
            req, pred_len = self.queue.popleft()
            true_len = int(req.true_length[self.model_idx])
            # None means "no dispatch-time clamp"; 0 is a real (1-token,
            # see the post-increment limit check) budget, not unlimited
            max_tok = req.max_tokens if req.max_tokens is not None else 10 ** 9
            budget_tok = None
            if req.budget is not None:
                # streaming early-stop: remaining budget at output prices
                in_cost = req.prompt.len_in * self.tier.price_in / 1e6
                rem = max(req.budget - in_cost, 0.0)
                budget_tok = int(rem / (self.tier.price_out / 1e6 + 1e-30))
            # matched-prefix KV reuse skips the cached share of prefill
            # — the physical effect the affinity term routes toward
            # (the cache exists whether or not the scheduler scores it,
            # so incidental hits discount the affinity-off arms too)
            dt += (self.tier.prefill_time(req.prompt.len_in)
                   * self.slowdown * (1.0 - req.prefix_hit))
            req.first_token_time = t + dt
            self.running.append(_Seq(
                req=req, target_tokens=true_len, max_tokens=max_tok,
                budget_tokens=budget_tok, ctx=req.prompt.len_in))
        return dt

    def _iterate(self, t: float, epoch: Optional[int] = None):
        if epoch is not None and epoch != self.epoch:
            return                  # stale event from a previous life
        self.iter_scheduled = False
        if not self.alive:
            return
        dt = self._admit(t)
        if self.running:
            b = len(self.running)
            mean_ctx = sum(s.ctx for s in self.running) / b
            dt += self.tier.tpot(b, mean_ctx) * self.slowdown
            done = []
            for s in self.running:
                s.generated += 1
                s.ctx += 1
                self.total_tokens += 1
                limit = min(s.target_tokens, s.max_tokens,
                            s.budget_tokens if s.budget_tokens is not None
                            else 10 ** 9)
                if s.generated >= limit:
                    done.append(s)
            for s in done:
                self.running.remove(s)
                r = s.req
                r.finish_time = t + dt
                r.tokens_out = s.generated
                r.exhausted = s.generated < s.target_tokens
                self.sim.completed.append(r)
        self.busy_until = t + dt
        self.snapshot = {
            "queue_depth": len(self.queue),
            "pending_decode": float(sum(
                max(min(s.max_tokens,
                        int(s.req.pred_len) if s.req.pred_len is not None
                        else s.max_tokens)
                    - s.generated, 1) for s in self.running)),
            "batch_size": len(self.running),
            "free_slots": self.tier.max_batch - len(self.running),
            "mean_ctx": (sum(s.ctx for s in self.running)
                         / max(len(self.running), 1)),
            "t": t + dt,
        }
        if not self.tel_mute:
            # telemetry blackout: the worker keeps its own snapshot
            # fresh but the scheduler-side mirror goes dark — the
            # staleness the telemetry watchdog exists to catch
            self.sim.tel.write(self.slot, self.snapshot["pending_decode"],
                               self.snapshot["batch_size"],
                               self.snapshot["free_slots"],
                               self.snapshot["mean_ctx"],
                               self.snapshot["queue_depth"], t + dt)
        if self.running or self.queue:
            self.sim.push(t + dt,
                          functools.partial(self._iterate, epoch=self.epoch))
            self.iter_scheduled = True

    def fail(self):
        """Node failure: mark dead; running + queued requests either
        re-enter the scheduler's admission path (when the sim carries a
        `RecoveryManager`, `sim.recovery` — see
        `repro.serving.recovery`) or fail terminally.

        Terminally failed requests get the failure instant stamped as
        their finish_time — they really do leave the system at that
        moment, and metrics' wall-clock span and per-tenant denominators
        would otherwise skew on failure-heavy cells."""
        self.alive = False
        # new epoch: any _iterate event still in the heap belongs to the
        # old life and no-ops when it fires, so the flag can be reset
        # here and recover() can start a fresh chain immediately
        self.epoch += 1
        self.iter_scheduled = False
        self.quarantined = False
        # the KV cache dies with the node: drop the sketch AND its
        # scheduler-side mirror, so retries/hedges of the victims are
        # never scored against credit this instance no longer holds,
        # and a later recover() re-enters cold
        self.sketch.clear()
        self.sim.tel.clear_prefix(self.slot)
        self.sim.tel.kill(self.slot)
        victims = ([(s.req, s.generated) for s in self.running]
                   + [(req, 0) for req, _ in self.queue])
        self.running = []
        self.queue.clear()
        mgr = getattr(self.sim, "recovery", None)
        for req, lost in victims:
            if mgr is not None and mgr.on_failure(req, self, lost,
                                                  self.sim.now):
                continue            # requeued for retry — not terminal
            req.failed = True
            if req.finish_time is None:
                req.finish_time = self.sim.now
            self.sim.completed.append(req)

    def cancel(self, req: Request) -> Optional[int]:
        """Withdraw a request without completing it (the hedge loser's
        path): remove it from the queue or the running batch. Returns
        the tokens it had already generated here — duplicate work the
        hedge spent — or None when the request is not on this instance
        (it already finished or was never dispatched here)."""
        for j, (r, _) in enumerate(self.queue):
            if r is req:
                del self.queue[j]
                return 0
        for s in self.running:
            if s.req is req:
                self.running.remove(s)
                return s.generated
        return None

    def recover(self, t: float):
        """Node recovery: re-enter the roster with a genuinely clean
        slate — empty engine, healthy speed (a recovered node is a
        replacement, not the same degraded hardware). With no
        `sim.recovery` armed, failed work is not replayed — the paper's
        fleet treats failed requests as lost."""
        if self.alive:
            return
        self.alive = True
        self.busy_until = t
        # fail() reset iter_scheduled and bumped the epoch, so a
        # pre-failure _iterate still pending in the heap is inert — a
        # new submit can safely start a fresh single iteration chain
        # (pinned by tests/test_recovery.py::test_stale_iterate_epoch)
        self.iter_scheduled = False
        self.quarantined = False
        self.tel_mute = False
        self.slowdown = 1.0
        self.snapshot = self._idle_snapshot(t)
        self.sim.tel.revive(self.slot, t)

    def set_slowdown(self, factor: float):
        """Straggler injection: scale this instance's real prefill/decode
        time by `factor` (>1 = slower). Telemetry is NOT adjusted — the
        scheduler's TPOT heads keep predicting healthy-node speed, which
        is exactly the model-mismatch stress the paper's dead-reckoning
        arm is meant to survive."""
        self.slowdown = float(factor)


class ClusterSim:
    """Event-driven cluster + pluggable scheduler callback."""

    def __init__(self, tiers: List[Tier], model_names: List[str],
                 seed: int = 0):
        self.tiers = tiers
        self.model_names = model_names
        self.instances: List[Instance] = []
        for tier in tiers:
            midx = model_names.index(tier.model)
            for j in range(tier.n_instances):
                self.instances.append(
                    Instance(f"{tier.name}#{j}", tier, midx, self))
        self.by_id = {i.iid: i for i in self.instances}
        for slot, inst in enumerate(self.instances):
            inst.slot = slot
        self.tel = TelemetryArrays(self.instances)
        self.completed: List[Request] = []
        self._events: List = []
        self._counter = itertools.count()
        self.now = 0.0

    def push(self, t: float, fn: Callable[[float], None]):
        heapq.heappush(self._events, (t, next(self._counter), fn))

    def run(self, until: float = float("inf")):
        while self._events:
            t, _, fn = heapq.heappop(self._events)
            if t > until:
                heapq.heappush(self._events, (t, next(self._counter), fn))
                break
            self.now = t
            fn(t)

    def telemetry(self) -> Dict[str, Dict]:
        return {i.iid: i.telemetry() for i in self.instances
                if i.alive}

    def alive_instances(self) -> List[Instance]:
        return [i for i in self.instances if i.alive]

    def has_noncontrol_events(self) -> bool:
        """True while the heap holds anything besides controller
        self-loops (overload detector, telemetry watchdog). Periodic
        controllers re-arm on THIS predicate instead of bare
        `sim._events`, so two controllers can never keep each other —
        and the run — alive forever."""
        for _, _, fn in self._events:
            owner = getattr(fn, "__self__", None)
            if owner is not None and getattr(owner, "_is_controller",
                                             False):
                continue
            return True
        return False
