"""Overload control + elastic roster: the control surface that makes
the overload regime a first-class scenario axis (ROADMAP item 3).

The paper's headline claim — pricing latency at model-selection time
keeps the joint decision on the quality-cost-throughput frontier *under
load* — only bites when the cluster is actually allowed to overload.
This module supplies the three production controls real routers wrap
around that regime (the vLLM production-stack shape: an overload
detector plus an autoscaling operator plus admission control):

  * **overload detector** — a periodic probe over the scheduler-side
    columnar telemetry (`TelemetryArrays`): ``load_score`` folds decode
    slot occupancy and queue backlog into one scalar where 1.0 means
    the alive fleet is exactly at decode capacity. Hysteresis
    (`up_patience`/`down_patience` consecutive checks + a cooldown)
    keeps the controller from flapping on burst noise;
  * **elastic autoscaler** — scale-up/scale-down through the existing
    kill/revive/alive-mask machinery. Spare instances are
    *pre-provisioned cold* (`provision_reserve`): they are real roster
    rows, built into the sim and failed at arm time, sized to ride in
    the pow2-I bucket the fused hot path already compiled — so a scale
    event is an alive-mask flip + telemetry reseed
    (``roster_version``), never an XLA recompile. Scale-up pays a
    configurable provisioning lag (`scale_up_lag_s`) before the slot
    revives; scale-down only retires reserve slots that are fully
    idle, so no in-flight work is ever revoked by elasticity;
  * **SLO-aware admission shedding** — per-tenant priority classes
    (`Request.priority`, 0 = premium): class p is shed at admission
    once the detector's load crosses ``shed_thresholds[p]``. The
    verdict is wired through ``ServingEngine`` *before* batch
    formation and is policy-visible (`SchedulingPolicy.shed_verdict`),
    so a policy can veto or tighten shedding; shed requests never
    reach a decision batch and are charged to the new ``shed_rate``
    metric axis, not to failures.

``arm_elastic(sim, cfg, reserve_iids)`` attaches one
`ElasticController` to a `ClusterSim` (exposed as ``sim.overload``,
which the engine consults on every admission); the scenario subsystem
(`repro.serving.scenarios.ElasticSpec`) does this automatically for
elastic scenarios, and ``benchmarks/elastic.py`` sweeps the
cost-vs-SLO frontier over the shed / autoscale / scale-up-lag arms.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from .cluster import ClusterSim, Instance, TelemetryArrays
from .tiers import Tier


@dataclasses.dataclass(frozen=True)
class OverloadConfig:
    """Detector thresholds + autoscaler/shedding knobs.

    `load_score` units: 1.0 = the alive fleet's decode slots are
    exactly full with zero queue backlog; queue backlog adds on top in
    units of fleet decode capacity (so 2.0 ≈ one full fleet of work
    queued behind a full fleet running)."""
    check_interval: float = 0.25   # detector probe period (s)
    # -- autoscaler -----------------------------------------------------
    autoscale: bool = True
    up_threshold: float = 1.25     # load above => hot check
    down_threshold: float = 0.40   # load below => cold check
    up_patience: int = 2           # consecutive hot checks to scale up
    down_patience: int = 12        # consecutive cold checks to scale down
    cooldown_s: float = 1.5        # min gap between scale events
    scale_up_lag_s: float = 1.5    # provisioning delay before revive
    max_step: int = 2              # instances per scale-up event
    # -- SLO-aware shedding ---------------------------------------------
    shed_enabled: bool = True
    # priority class p (0 = premium) sheds at load >= shed_thresholds[p]
    # (classes beyond the tuple use the last entry)
    shed_thresholds: Tuple[float, ...] = (6.0, 3.0, 1.8)


def load_score(tel: TelemetryArrays) -> float:
    """Scalar cluster load off the columnar telemetry view: decode
    slot occupancy plus queue backlog, both normalized by the ALIVE
    fleet's decode capacity. Dead/cold rows contribute nothing, so the
    score rises when capacity is lost and falls when a reserve slot
    revives — exactly the feedback the autoscaler closes on."""
    alive = tel.alive
    if not alive.any():
        return float("inf")
    cap = float(tel.max_batch[alive].sum())
    if cap <= 0:
        return float("inf")
    util = float(tel.batch[alive].sum()) / cap
    backlog = float(tel.queue[alive].sum()) / cap
    return util + backlog


def provision_reserve(tiers: Sequence[Tier], k: int
                      ) -> Tuple[List[Tier], Tuple[str, ...]]:
    """Add `k` pre-provisioned reserve replicas to a roster, spread
    round-robin over the tiers that already concentrate capacity
    (highest replica count first — elasticity adds where the fleet is
    already cheap to grow). Returns the expanded tier list plus the
    iids of the reserve instances (``ClusterSim`` numbers replicas
    ``{tier.name}#{j}``, so the reserves are the trailing j's of each
    expanded tier). The reserves are real roster rows: size them so
    ``bucket_pow2(base + k) == bucket_pow2(base)`` and the fused hot
    path's compiled I bucket absorbs them for free."""
    if k <= 0:
        return list(tiers), ()
    order = sorted(range(len(tiers)),
                   key=lambda i: (-tiers[i].n_instances, i))
    extra = [0] * len(tiers)
    for j in range(k):
        extra[order[j % len(order)]] += 1
    out: List[Tier] = []
    reserve: List[str] = []
    for i, t in enumerate(tiers):
        out.append(dataclasses.replace(
            t, n_instances=t.n_instances + extra[i]))
        reserve.extend(f"{t.name}#{j}"
                       for j in range(t.n_instances,
                                      t.n_instances + extra[i]))
    return out, tuple(reserve)


class ElasticController:
    """Overload detector + autoscaler + admission shedder over one
    `ClusterSim`. Armed once per sim (`arm_elastic`); the detector is
    an ordinary sim event that re-schedules itself while the cell has
    work in flight, so controller decisions are deterministic functions
    of the telemetry trajectory — identical across decision backends,
    which keeps the numpy/jax/fused differential soak meaningful under
    roster churn."""

    # marks the detector's self-loop as controller-owned: it re-arms on
    # `ClusterSim.has_noncontrol_events`, and a simulated controller
    # crash (`repro.serving.recovery.simulate_controller_crash`) strips
    # its pending events from the heap
    _is_controller = True

    def __init__(self, sim: ClusterSim, cfg: OverloadConfig,
                 reserve_iids: Sequence[str] = ()):
        self.sim = sim
        self.cfg = cfg
        self.reserve = [sim.by_id[iid] for iid in reserve_iids
                        if iid in sim.by_id]
        self.load = 0.0
        self._hot = 0
        self._cold = 0
        self._last_scale = -float("inf")
        self._provisioning: Dict[str, float] = {}   # iid -> ready time
        # counters / audit trail
        self.scale_ups = 0
        self.scale_downs = 0
        self.sheds = 0
        self.shed_by_priority: Dict[int, int] = {}
        self.events: List[Tuple[float, str, str]] = []  # (t, kind, iid)
        self.peak_alive = int(sim.tel.alive.sum())

    # -- wiring ---------------------------------------------------------
    def arm(self) -> "ElasticController":
        """Cold-start the reserve pool (kill/alive-mask path — the rows
        stay in the compiled roster) and start the detector loop."""
        for inst in self.reserve:
            if inst.alive:
                inst.fail()                    # empty engine: nothing lost
        self.peak_alive = int(self.sim.tel.alive.sum())
        self.sim.push(self.cfg.check_interval, self._check)
        self.sim.overload = self
        return self

    # -- detector ---------------------------------------------------------
    def _check(self, t: float):
        cfg = self.cfg
        self.load = load_score(self.sim.tel)
        self.peak_alive = max(self.peak_alive,
                              int(self.sim.tel.alive.sum()))
        if self.load >= cfg.up_threshold:
            self._hot += 1
            self._cold = 0
        elif self.load <= cfg.down_threshold:
            self._cold += 1
            self._hot = 0
        else:
            self._hot = self._cold = 0
        if cfg.autoscale and t - self._last_scale >= cfg.cooldown_s:
            if self._hot >= cfg.up_patience:
                self._scale_up(t)
            elif self._cold >= cfg.down_patience:
                self._scale_down(t)
        # the detector only re-arms while the cell still has work in
        # flight (arrivals, decode iterations, provisioning timers) —
        # once only controller self-loops remain, the run is over
        # (bare `sim._events` would let this loop and the telemetry
        # watchdog keep each other alive forever)
        if self.sim.has_noncontrol_events():
            self.sim.push(t + cfg.check_interval, self._check)

    # -- autoscaler -------------------------------------------------------
    def _scale_up(self, t: float):
        cold = [i for i in self.reserve
                if not i.alive and i.iid not in self._provisioning]
        took = cold[:max(self.cfg.max_step, 1)]
        for inst in took:
            self._provisioning[inst.iid] = t + self.cfg.scale_up_lag_s
            self.sim.push(t + self.cfg.scale_up_lag_s,
                          lambda tt, ii=inst: self._provisioned(ii, tt))
            self.scale_ups += 1
            self.events.append((t, "scale_up", inst.iid))
        if took:
            self._last_scale = t
            self._hot = 0

    def _provisioned(self, inst: Instance, t: float):
        self._provisioning.pop(inst.iid, None)
        if not inst.alive:
            inst.recover(t)                    # alive-mask flip, no recompile
            self.events.append((t, "ready", inst.iid))
        self.peak_alive = max(self.peak_alive,
                              int(self.sim.tel.alive.sum()))

    def _scale_down(self, t: float):
        idle = [i for i in self.reserve
                if i.alive and not i.running and not i.queue]
        if not idle:
            return                             # nothing safely retirable
        inst = idle[0]
        inst.fail()                            # empty engine: nothing lost
        self.scale_downs += 1
        self.events.append((t, "scale_down", inst.iid))
        self._last_scale = t
        self._cold = 0

    # -- admission shedding -------------------------------------------------
    def wants_shed(self, priority: int) -> bool:
        """The default SLO-aware verdict: class `priority` sheds once
        the detector's load crosses its threshold. Policies route
        through `SchedulingPolicy.shed_verdict`, which defaults to this
        but may veto or tighten it."""
        cfg = self.cfg
        if not cfg.shed_enabled or not cfg.shed_thresholds:
            return False
        p = min(max(int(priority), 0), len(cfg.shed_thresholds) - 1)
        return self.load >= cfg.shed_thresholds[p]

    def record_shed(self, req, t: float):
        req.shed = True
        self.sheds += 1
        p = int(req.priority)
        self.shed_by_priority[p] = self.shed_by_priority.get(p, 0) + 1


def arm_elastic(sim: ClusterSim, cfg: OverloadConfig,
                reserve_iids: Sequence[str] = ()) -> ElasticController:
    """Attach + arm an `ElasticController` on a sim. The controller is
    exposed as ``sim.overload`` — `ServingEngine` finds it there and
    routes every admission through the policy's shed verdict."""
    return ElasticController(sim, cfg, reserve_iids).arm()
