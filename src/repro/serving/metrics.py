"""Per-cell metric aggregation: the paper's four axes + residual
decomposition + tails + per-tenant SLO breakdowns."""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .request import Request
from .tiers import Tier


def _pct(x, p):
    return float(np.percentile(x, p)) if len(x) else float("nan")


def check_terminal_states(reqs: List[Request]):
    """Terminal-state invariant: every request that entered the system
    ends in EXACTLY one of {served, failed, shed}. The fault-tolerant
    lifecycle (retry/requeue, hedged re-dispatch, controller
    crash/restore) makes this worth asserting at aggregation time —
    a request silently dropped by a failure path, or double-terminated
    by a retry racing a hedge, corrupts every rate metric downstream.
    """
    for r in reqs:
        assert not (r.failed and r.shed), \
            f"rid={r.rid}: both failed and shed"
        if r.shed:
            assert r.finish_time is None, \
                f"rid={r.rid}: shed but has finish_time"
        elif r.failed:
            assert r.finish_time is not None, \
                f"rid={r.rid}: failed without a terminal timestamp"
        else:
            assert r.finish_time is not None, \
                f"rid={r.rid}: lost — neither served, failed, nor shed"


def aggregate(reqs: List[Request], tiers: List[Tier],
              model_names: List[str], wall: Optional[float] = None,
              slo_s: float = 30.0, strict: bool = True) -> Dict:
    """`slo_s`: end-to-end latency SLO for the goodput metric (served
    requests finishing within the SLO, per wall second). `strict`
    asserts the terminal-state invariant over the whole stream (opt out
    only for deliberately-truncated partial traces, e.g. a checkpoint
    taken mid-run)."""
    if strict:
        check_terminal_states(reqs)
    done = [r for r in reqs
            if r.finish_time is not None and not r.failed and not r.shed]
    failed = [r for r in reqs if r.failed]
    shed = [r for r in reqs if r.shed]
    e2e = np.array([r.e2e for r in done])
    ttft = np.array([r.ttft for r in done if r.ttft is not None])
    lookup_q = np.array([r.lookup_quality() for r in done])
    served_q = np.array([r.served_quality() for r in done])
    tier_by_model = {t.model: t for t in tiers}
    costs = []
    for r in done:
        t = tier_by_model[model_names[r.model_idx]]
        costs.append(t.cost(r.prompt.len_in, r.tokens_out))
    costs = np.asarray(costs)
    if wall is None:
        # span over EVERY request that left the system (served or
        # failed) — a done-only max under-reports the wall on
        # failure-heavy cells and inflates goodput/throughput
        ends = [r.finish_time for r in reqs if r.finish_time is not None]
        if ends:
            wall = max(ends) - min(r.arrival for r in reqs)
    mix = {}
    for r in done:
        m = model_names[r.model_idx]
        mix[m] = mix.get(m, 0) + 1
    mix = {m: c / max(len(done), 1) for m, c in sorted(mix.items())}
    resid = np.array([(r.sched_compute + r.sched_batch_wait
                       + r.sched_stats_fetch + r.router_queue_wait)
                      for r in done])
    return {
        "tenants": tenant_breakdown(reqs, wall, slo_s=slo_s),
        "priorities": priority_breakdown(reqs, wall, slo_s=slo_s),
        "n": len(done), "failed": len(failed),
        "shed": len(shed),
        "shed_rate": len(shed) / max(len(reqs), 1),
        "quality": float(lookup_q.mean()) if len(done) else 0.0,
        "served_quality": float(served_q.mean()) if len(done) else 0.0,
        "mean_e2e": float(e2e.mean()) if len(done) else float("nan"),
        "p50_e2e": _pct(e2e, 50),
        "p95_e2e": _pct(e2e, 95), "p99_e2e": _pct(e2e, 99),
        "goodput": (float((e2e <= slo_s).sum()) / wall
                    if wall and len(done) else 0.0),
        "mean_ttft": float(ttft.mean()) if len(ttft) else float("nan"),
        "p99_ttft": _pct(ttft, 99),
        # mean matched-prefix fraction at final dispatch (the KV-cache
        # reuse the affinity term routes for; serving.affinity)
        "cache_hit_rate": float(np.mean([r.prefix_hit for r in done]))
        if done else 0.0,
        "cost_per_req": float(costs.mean()) if len(done) else 0.0,
        "throughput": len(done) / wall if wall else 0.0,
        "mix": mix,
        # fault-tolerant lifecycle accounting (repro.serving.recovery):
        # retried/hedged requests that ultimately SERVED, plus the
        # duplicate work burned to get them there
        "retried": sum(1 for r in done if r.attempt > 0),
        "hedged": sum(1 for r in done if r.hedges > 0),
        "wasted_tokens": int(sum(r.wasted_tokens for r in reqs)),
        "exhausted_frac": float(np.mean([r.exhausted for r in done]))
        if done else 0.0,
        "mean_residual": float(resid.mean()) if len(done) else 0.0,
        "residual_compute": float(np.mean(
            [r.sched_compute for r in done])) if done else 0.0,
        "residual_batch_wait": float(np.mean(
            [r.sched_batch_wait for r in done])) if done else 0.0,
        "residual_stats_fetch": float(np.mean(
            [r.sched_stats_fetch for r in done])) if done else 0.0,
        "residual_router_queue": float(np.mean(
            [r.router_queue_wait for r in done])) if done else 0.0,
    }


def tenant_breakdown(reqs: List[Request], wall: Optional[float],
                     slo_s: float = 30.0) -> Dict[str, Dict]:
    """Per-`TenantSpec` SLO view of a cell: one entry per tenant class
    in the trace (empty dict for single-class streams built outside the
    scenario subsystem), with the latency tail and goodput the tenant
    actually experienced — the multi-tenant isolation axis the
    composite scenarios exist to expose. Surfaced as `t_<name>_p50` /
    `_p99` / `_goodput` columns in `BENCH_sweep.json` and
    `BENCH_frontier.json`."""
    names = sorted({r.tenant for r in reqs if r.tenant is not None})
    out: Dict[str, Dict] = {}
    for name in names:
        mine = [r for r in reqs if r.tenant == name]
        done = [r for r in mine
                if r.finish_time is not None and not r.failed
                and not r.shed]
        e2e = np.array([r.e2e for r in done])
        within = int((e2e <= slo_s).sum()) if len(done) else 0
        out[name] = {
            "n": len(done),
            "failed": sum(r.failed for r in mine),
            "shed": sum(r.shed for r in mine),
            "p50_e2e": _pct(e2e, 50),
            "p99_e2e": _pct(e2e, 99),
            "goodput": (within / wall if wall and len(done) else 0.0),
            # SLO attainment over everything the tenant SENT — failed
            # and shed requests count against the tenant, not nowhere
            "slo_attainment": within / max(len(mine), 1),
            "quality": (float(np.mean([r.lookup_quality()
                                       for r in done]))
                        if done else 0.0),
        }
    return out


def priority_breakdown(reqs: List[Request], wall: Optional[float],
                       slo_s: float = 30.0) -> Dict[int, Dict]:
    """Per-priority-class SLO view (0 = premium): what admission
    shedding buys the premium class and charges the batch class.
    Surfaced as `prio<k>_goodput` / `prio<k>_shed` columns in
    `BENCH_elastic.json`. Empty for single-class streams (all
    priority 0, no sheds) to keep legacy cells noise-free."""
    classes = sorted({int(r.priority) for r in reqs})
    if classes == [0] and not any(r.shed for r in reqs):
        return {}
    out: Dict[int, Dict] = {}
    for p in classes:
        mine = [r for r in reqs if int(r.priority) == p]
        done = [r for r in mine
                if r.finish_time is not None and not r.failed
                and not r.shed]
        e2e = np.array([r.e2e for r in done])
        within = int((e2e <= slo_s).sum()) if len(done) else 0
        out[p] = {
            "n": len(mine),
            "shed": sum(r.shed for r in mine),
            "failed": sum(r.failed for r in mine),
            "p99_e2e": _pct(e2e, 99),
            "goodput": (within / wall if wall and len(done) else 0.0),
            "slo_attainment": within / max(len(mine), 1),
        }
    return out
