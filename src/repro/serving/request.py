"""Request record flowing through the serving stack."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from .world import Prompt


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Prompt
    arrival: float
    true_quality: np.ndarray       # (M,) hidden from the scheduler
    true_length: np.ndarray        # (M,) hidden from the scheduler
    budget: Optional[float] = None  # USD, optional per-request cost budget
    tenant: Optional[str] = None   # tenant class in composite scenarios

    # filled at dispatch
    instance: Optional[str] = None
    model_idx: Optional[int] = None
    dispatch_time: Optional[float] = None
    pred_len: Optional[float] = None
    max_tokens: Optional[int] = None

    # filled at completion
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    tokens_out: int = 0
    exhausted: bool = False        # stopped by budget early-stop/clamp
    failed: bool = False

    # scheduler-side accounting (off-instance residual decomposition)
    sched_compute: float = 0.0
    sched_batch_wait: float = 0.0
    sched_stats_fetch: float = 0.0
    router_queue_wait: float = 0.0

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def served_quality(self) -> float:
        """Quality of the actually-served text: the routing-decision
        lookup value, discounted when the response was truncated
        (budget exhaustion -> near-empty answers score near zero)."""
        if self.model_idx is None or self.finish_time is None:
            return 0.0
        q = float(self.true_quality[self.model_idx])
        need = float(self.true_length[self.model_idx])
        if self.tokens_out + 0.5 >= need or need <= 0:
            return q
        frac = self.tokens_out / need
        return q * frac ** 0.7

    def lookup_quality(self) -> float:
        """The routing-decision metric (§4.2): offline per-(prompt, model)
        score of the chosen model, independent of truncation."""
        if self.model_idx is None:
            return 0.0
        return float(self.true_quality[self.model_idx])
