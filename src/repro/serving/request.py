"""Request record flowing through the serving stack, plus the
structure-of-arrays ingest columns the zero-allocation hot path reads.

`RequestColumns` is built once, at workload-generation time: everything
the scheduler's decision needs per request — token ids, token lengths,
`len_in`, budgets, and (lazily, the first time a scheduler sees the
stream) the prompt embeddings — lives in columnar arrays, and each
`Request` carries its row index. A steady-state decision batch is then a
handful of vectorized gathers into preallocated staging buffers instead
of four Python list comprehensions and fresh numpy allocations per
batch (the host-path bottleneck isolated by the data-parallel
load-balancing line of work; see README "hot path anatomy").

Prompts repeat across requests (traces cycle a finite prompt set), so
the token matrix and the embedding column are per *unique prompt*, with
a (N,) `prompt_row` indirection; per-request columns hold only scalars.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .world import Prompt


class RequestColumns:
    """Columnar (SoA) view over a request stream.

    Per unique prompt (P rows): `tokens` (P, L) int32 zero-padded with
    L the longest prompt (full width — the ENCODER applies its own
    `max_len` cap at encode time, so the columns never silently
    truncate what a wider-context encoder would read), `tok_len` (P,),
    and — once `ensure_embeddings` has run — `emb` (P, E) float32. Per
    request (N rows): `prompt_row` (N,) int32 into the prompt axis,
    `len_in` (N,) float64, `budget` (N,) float64 with nan =
    unconstrained (matching the AoS marshaling dtypes exactly, so
    columnar and legacy staging are bitwise-identical).
    """

    def __init__(self, tokens: np.ndarray, tok_len: np.ndarray,
                 prompt_row: np.ndarray, len_in: np.ndarray,
                 budget: np.ndarray):
        self.tokens = tokens
        self.tok_len = tok_len
        self.prompt_row = prompt_row
        self.len_in = len_in
        self.budget = budget
        self.emb: Optional[np.ndarray] = None       # (P, E) float32
        self._prefix_sig: Optional[np.ndarray] = None  # (P, SIG_WIDTH)
        self._toks_padded: Optional[np.ndarray] = None  # pow2-width cache
        self._emb_partial = None    # [out, rows_done] resume bookkeeping

    @property
    def n(self) -> int:
        return len(self.prompt_row)

    @property
    def prefix_sig(self) -> np.ndarray:
        """(P, SIG_WIDTH) int32 rolling-hash prefix signatures per
        unique prompt (lazy, memoized like `emb`). Same masked hash as
        `affinity.prompt_signatures`, so the scoring path (columnar
        gathers) and the dispatch path (per-prompt) agree exactly."""
        if self._prefix_sig is None:
            from .affinity import prefix_signatures
            self._prefix_sig = prefix_signatures(self.tokens,
                                                 self.tok_len)
        return self._prefix_sig

    @staticmethod
    def from_requests(reqs: Sequence["Request"], stamp: bool = True
                      ) -> "RequestColumns":
        """Build the columns for a request stream (ingest time — the one
        place per-request Python work is allowed) and, with `stamp`,
        mark each request with its row. Prompts are deduplicated by
        identity. `stamp=False` builds ephemeral columns for a one-off
        batch WITHOUT touching the requests — requests that already
        belong to a stream keep their stream's cols/row (and their
        budget write-through target)."""
        slot: Dict[int, int] = {}
        prompts: List[Prompt] = []
        prompt_row = np.empty(len(reqs), np.int32)
        len_in = np.empty(len(reqs), np.float64)
        budget = np.empty(len(reqs), np.float64)
        for i, r in enumerate(reqs):
            key = id(r.prompt)
            j = slot.get(key)
            if j is None:
                j = slot[key] = len(prompts)
                prompts.append(r.prompt)
            prompt_row[i] = j
            len_in[i] = r.prompt.len_in
            budget[i] = np.nan if r.budget is None else r.budget
        from repro.estimators.embedding import pad_tokens
        L = int(max((len(p.tokens) for p in prompts), default=1))
        tokens = pad_tokens([p.tokens for p in prompts], L)
        tok_len = np.array([len(p.tokens) for p in prompts], np.int64)
        cols = RequestColumns(tokens, tok_len, prompt_row, len_in, budget)
        if stamp:
            for i, r in enumerate(reqs):
                r.cols = cols
                r.row = i
        return cols

    @staticmethod
    def for_batch(reqs: Sequence["Request"], encoder):
        """(cols, rows) for a decision batch, embeddings guaranteed: the
        batch's shared stream columns when it has them, else ephemeral
        non-stamping columns. The single fallback for direct/legacy
        callers reaching a columnar decision path."""
        cols, rows = batch_columns(reqs)
        if cols is None:
            cols = RequestColumns.from_requests(reqs, stamp=False)
            rows = np.arange(len(reqs), dtype=np.int64)
        cols.ensure_embeddings(encoder)
        return cols, rows

    def ensure_embeddings(self, encoder) -> "RequestColumns":
        """Embed the unique prompts once (chunked, pow2-padded so the
        encoder jit cache stays warm across streams). Embedding depends
        only on the prompt, and the masked-pooling encoder is bitwise
        stable under batch/length padding, so precomputing here is pure
        memoization of the per-batch encode the staged path used to run
        inside every decision. Lengths are capped at the encoder's own
        `max_len` — the same truncation the per-batch encode applies."""
        if self.emb is not None:
            return self
        from repro.core.decision_jax import bucket_pow2
        P = len(self.tokens)
        cap_len = np.minimum(self.tok_len, encoder.max_len)
        # pow2-pad the token WIDTH as well as the batch: encode slices
        # width at its own max_len before tracing, so streams whose
        # longest prompts differ still land on O(log max_len) compiled
        # encoder shapes instead of one per distinct stream width.
        # The padded matrix is built ONCE and cached — re-entry (a
        # resume after a mid-chunk encoder failure) must not
        # concatenate a fresh zero block per call.
        toks_all = self._toks_padded
        if toks_all is None:
            toks_all = self.tokens
            Wb = bucket_pow2(toks_all.shape[1])
            if Wb != toks_all.shape[1]:
                toks_all = np.concatenate(
                    [toks_all,
                     np.zeros((P, Wb - toks_all.shape[1]),
                              toks_all.dtype)], axis=1)
            self._toks_padded = toks_all
        # all-or-nothing: `self.emb` is assigned only after EVERY chunk
        # encoded, so a mid-chunk raise can never expose garbage rows.
        # Partial progress is kept in `_emb_partial` — a retry resumes
        # from the first unencoded row instead of recomputing (or
        # worse, serving) the rows a failed pass left behind.
        if (self._emb_partial is None
                or self._emb_partial[0].shape[1] != encoder.dim):
            self._emb_partial = [np.empty((P, encoder.dim), np.float32),
                                 0]
        out, done = self._emb_partial
        chunk = 256
        for i in range(done, P, chunk):
            toks = toks_all[i:i + chunk]
            lens = cap_len[i:i + chunk]
            n = len(toks)
            pad = bucket_pow2(n) - n
            if pad:
                toks = np.concatenate(
                    [toks, np.zeros((pad,) + toks.shape[1:], toks.dtype)])
                lens = np.concatenate([lens, np.zeros(pad, lens.dtype)])
            out[i:i + n] = encoder.encode(toks, lens)[:n]
            self._emb_partial[1] = i + n
        self.emb = out
        self._emb_partial = None
        return self


def batch_columns(reqs: Sequence["Request"]):
    """(cols, rows (R,) int64) when every request in the batch shares
    one `RequestColumns`, else (None, None). This walks the batch in
    Python, so it is for direct/legacy callers only — the scheduler
    tracks the shared-columns invariant incrementally at enqueue time
    and never calls it on the steady-state path."""
    c0 = reqs[0].cols if reqs else None
    if c0 is None:
        return None, None
    for r in reqs:
        # the upper bound matters as much as the identity check: a
        # request stamped by a DIFFERENT (larger) stream that was
        # re-pointed at these columns would otherwise gather another
        # request's tokens/embedding row — or read out of bounds
        if r.cols is not c0 or not (0 <= r.row < c0.n):
            return None, None
    return c0, np.fromiter((r.row for r in reqs), np.int64,
                           count=len(reqs))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Prompt
    arrival: float
    true_quality: np.ndarray       # (M,) hidden from the scheduler
    true_length: np.ndarray        # (M,) hidden from the scheduler
    budget: Optional[float] = None  # USD, optional per-request cost budget
    tenant: Optional[str] = None   # tenant class in composite scenarios
    priority: int = 0              # SLO class for shedding (0 = premium)

    # SoA ingest columns (set by RequestColumns.from_requests)
    cols: Optional[RequestColumns] = dataclasses.field(
        default=None, repr=False, compare=False)
    row: int = -1

    def __setattr__(self, name, value):
        # keep the ingest columns coherent when a caller edits a
        # columnar field on the object after ingest (tests and benches
        # stamp budgets onto already-built streams) — the decision path
        # reads the columns, not the objects
        object.__setattr__(self, name, value)
        if name == "budget":
            cols = getattr(self, "cols", None)
            if cols is not None and self.row >= 0:
                cols.budget[self.row] = np.nan if value is None else value

    # filled at dispatch
    instance: Optional[str] = None
    model_idx: Optional[int] = None
    dispatch_time: Optional[float] = None
    pred_len: Optional[float] = None
    max_tokens: Optional[int] = None
    # matched-prefix fraction against the target instance's sketch at
    # submit time (serving.affinity): drives the prefill discount in
    # `Instance._admit` and the cache_hit_rate metric
    prefix_hit: float = 0.0

    # fault-tolerant lifecycle (repro.serving.recovery). `arrival` is
    # the SCHEDULING arrival — a requeued retry re-enters admission with
    # a fresh arrival so batch-wait accounting charges the retry, not
    # the whole outage — while `first_arrival` keeps the true ingest
    # time so e2e/ttft metrics charge the full client-visible latency.
    first_arrival: Optional[float] = None
    attempt: int = 0               # dispatch attempts beyond the first
    hedges: int = 0                # hedged re-dispatches taken
    wasted_tokens: int = 0         # tokens generated then thrown away
    #                                (failed mid-decode or hedge loser)

    # filled at completion
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    tokens_out: int = 0
    exhausted: bool = False        # stopped by budget early-stop/clamp
    failed: bool = False
    shed: bool = False             # refused at admission by overload control

    # scheduler-side accounting (off-instance residual decomposition)
    sched_compute: float = 0.0
    sched_batch_wait: float = 0.0
    sched_stats_fetch: float = 0.0
    router_queue_wait: float = 0.0

    def __post_init__(self):
        if self.first_arrival is None:
            self.first_arrival = self.arrival

    def requeue(self, t: float):
        """Reset dispatch state for a retry re-entering admission: the
        request looks freshly arrived to the scheduler (arrival = now,
        clean dispatch/completion fields) while `first_arrival` keeps
        charging the true end-to-end clock."""
        self.attempt += 1
        self.arrival = t
        self.instance = None
        self.model_idx = None
        self.dispatch_time = None
        self.pred_len = None
        self.max_tokens = None
        self.prefix_hit = 0.0
        self.first_token_time = None
        self.tokens_out = 0
        self.exhausted = False
        self.failed = False

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        start = (self.first_arrival if self.first_arrival is not None
                 else self.arrival)
        return self.finish_time - start

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        start = (self.first_arrival if self.first_arrival is not None
                 else self.arrival)
        return self.first_token_time - start

    def served_quality(self) -> float:
        """Quality of the actually-served text: the routing-decision
        lookup value, discounted when the response was truncated
        (budget exhaustion -> near-empty answers score near zero)."""
        if self.model_idx is None or self.finish_time is None:
            return 0.0
        q = float(self.true_quality[self.model_idx])
        need = float(self.true_length[self.model_idx])
        if self.tokens_out + 0.5 >= need or need <= 0:
            return q
        frac = self.tokens_out / need
        return q * frac ** 0.7

    def lookup_quality(self) -> float:
        """The routing-decision metric (§4.2): offline per-(prompt, model)
        score of the chosen model, independent of truncation."""
        if self.model_idx is None:
            return 0.0
        return float(self.true_quality[self.model_idx])
