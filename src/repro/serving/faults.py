"""Chaos harness: named fault campaigns over the scenario subsystem.

`repro.serving.scenarios.FailureEvent` gives timed, seeded
perturbations; this module composes them into the recurring failure
*shapes* production serving fleets actually see, so the recovery stack
(`repro.serving.recovery`) is exercised against campaigns rather than
single events:

  * **crash_storm** — rolling waves of node death and re-entry: each
    wave kills a fraction of the alive fleet mid-decode, then revives
    everything a few seconds later. The retry path's bread and butter;
  * **correlated_failure** — every replica of one tier dies at the same
    instant (a rack/PSU/rollout-shaped blast radius), so the victims'
    work must re-route across *heterogeneous* capacity, not to a twin;
  * **telemetry_blackout** — a fraction of workers keep serving but
    stop publishing to the scheduler's mirror (`mute`), then come back
    (`unmute`): the watchdog's quarantine/release cycle, plus the
    degraded-mode fallback when the blackout covers the whole fleet;
  * **straggler_storm** — a hidden slowdown sweeps the fleet and then
    clears; telemetry keeps reporting, TPOT quietly multiplies. What
    hedged re-dispatch exists to cap;
  * **controller_crash** — not a `FailureEvent`: the scheduler process
    itself dies (`repro.serving.recovery.simulate_controller_crash`)
    and resumes from its checkpoint. Driven directly by the tests and
    ``benchmarks/chaos.py``, listed here for the campaign registry.

Every campaign is a pure function of (tiers, base time), returning a
`FailureEvent` tuple — target draws stay seeded and fire-time-resolved
exactly as for hand-written schedules, so chaos cells remain
deterministic and backend-parity-comparable.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

from .overload import OverloadConfig
from .recovery import RecoveryConfig
from .scenarios import ElasticSpec, FailureEvent, Scenario, TenantSpec
from .tiers import Tier


def crash_storm(tiers: Sequence[Tier], t0: float = 3.0, waves: int = 3,
                period: float = 5.0, frac: float = 0.3
                ) -> Tuple[FailureEvent, ...]:
    """`waves` rolling kill/revive cycles: at each wave start, `frac`
    of the alive fleet dies mid-decode; everything dead revives before
    the next wave hits."""
    ev: List[FailureEvent] = []
    for w in range(waves):
        t = t0 + w * period
        ev.append(FailureEvent(t=t, kind="fail", frac=frac))
        ev.append(FailureEvent(t=t + 0.6 * period, kind="recover",
                               frac=1.0))
    return tuple(ev)


def correlated_failure(tiers: Sequence[Tier], t0: float = 4.0,
                       recover_after: float = 6.0
                       ) -> Tuple[FailureEvent, ...]:
    """Kill EVERY replica of one tier at the same instant — the tier
    with the most replicas, so the blast radius is maximal and the
    displaced work must land on heterogeneous capacity. Explicit iids:
    the point is correlation, not a random draw."""
    victim = max(tiers, key=lambda t: (t.n_instances, t.name))
    iids = tuple(f"{victim.name}#{j}" for j in range(victim.n_instances))
    return (FailureEvent(t=t0, kind="fail", instances=iids),
            FailureEvent(t=t0 + recover_after, kind="recover",
                         instances=iids))


def telemetry_blackout(tiers: Sequence[Tier], t0: float = 3.0,
                       duration: float = 4.0, frac: float = 0.5
                       ) -> Tuple[FailureEvent, ...]:
    """`frac` of the fleet stops publishing telemetry for `duration`
    seconds while continuing to serve. frac=1.0 drives the scheduler's
    whole mirror dark — the degraded-fallback path."""
    return (FailureEvent(t=t0, kind="mute", frac=frac),
            FailureEvent(t=t0 + duration, kind="unmute", frac=1.0))


def straggler_storm(tiers: Sequence[Tier], t0: float = 3.0,
                    duration: float = 6.0, frac: float = 0.4,
                    factor: float = 5.0) -> Tuple[FailureEvent, ...]:
    """A hidden `factor`x slowdown hits `frac` of the fleet, then
    clears (straggle back to factor 1.0). Telemetry keeps flowing, so
    only deadline-based hedging notices."""
    return (FailureEvent(t=t0, kind="straggle", frac=frac,
                         factor=factor),
            FailureEvent(t=t0 + duration, kind="straggle", frac=1.0,
                         factor=1.0))


def compose(*campaigns: Sequence[FailureEvent]
            ) -> Tuple[FailureEvent, ...]:
    """Merge campaigns into one time-ordered schedule."""
    ev = [e for c in campaigns for e in c]
    return tuple(sorted(ev, key=lambda e: e.t))


# campaign registry: name -> schedule builder. `controller_crash` has
# an empty schedule — the crash/restore cycle is driven by the harness
# (tests, benchmarks/chaos.py) via simulate_controller_crash + the
# engine checkpoint, not by a sim event.
CHAOS_SUITES: Dict[str, Callable[[Sequence[Tier]],
                                 Tuple[FailureEvent, ...]]] = {
    "crash_storm": crash_storm,
    "correlated_failure": correlated_failure,
    "telemetry_blackout": telemetry_blackout,
    "straggler_storm": straggler_storm,
    "controller_crash": lambda tiers: (),
}


def chaos_world(seed: int = 7) -> Scenario:
    """The shared world chaos campaigns run against: a small synthetic
    fleet (pow2-friendly roster, so kill/revive/quarantine churn rides
    one compiled fused-hot-path bucket) under enough sustained load
    that lost work actually moves goodput. No elastic reserve — the
    chaos bench isolates the recovery stack from the autoscaler."""
    return Scenario(
        name="chaos", pool="synthetic", n_tiers=4, n_instances=8,
        seed=seed,
        tenants=(
            TenantSpec("interactive", 10.0, arrival="gamma",
                       arrival_kw=(("cv", 2.0),)),
            TenantSpec("bulk", 5.0, budget_frac=0.3),
        ),
        recovery=RecoveryConfig())


def elastic_chaos_world(seed: int = 8) -> Scenario:
    """chaos_world plus overload control: asserts the recovery stack
    and the autoscaler coexist (two controllers, one heap) without
    keeping each other alive or double-terminating sheds."""
    base = chaos_world(seed)
    return Scenario(
        name="elastic_chaos", pool=base.pool, n_tiers=base.n_tiers,
        n_instances=6, seed=seed, tenants=base.tenants,
        recovery=RecoveryConfig(),
        elastic=ElasticSpec(reserve=2, overload=OverloadConfig()))
