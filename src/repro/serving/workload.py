"""Arrival processes: Poisson (the paper's default), gamma-bursty and
square-wave (§6.9 non-stationary robustness), plus the flash-crowd
piecewise-Poisson trace used by the scenario subsystem
(`repro.serving.scenarios`)."""
from __future__ import annotations

import numpy as np


def poisson_arrivals(lam: float, n: int, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / lam, n)
    return start + np.cumsum(gaps)


def gamma_bursty_arrivals(lam: float, n: int, cv: float = 3.0,
                          seed: int = 0) -> np.ndarray:
    """Gamma-distributed gaps with mean 1/lam and coefficient of
    variation cv (cv > 1 = bursty)."""
    rng = np.random.default_rng(seed)
    shape = 1.0 / cv ** 2
    scale = 1.0 / (lam * shape)
    return np.cumsum(rng.gamma(shape, scale, n))


def square_wave_arrivals(lam: float, n: int, period: float = 60.0,
                         high_frac: float = 1.5, seed: int = 0
                         ) -> np.ndarray:
    """Alternates between high_frac*lam and (2-high_frac)*lam every
    period/2 seconds; matched mean lam."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    lo = (2.0 - high_frac) * lam
    hi = high_frac * lam
    for _ in range(n):
        phase_hi = (t % period) < period / 2
        rate = hi if phase_hi else lo
        t += rng.exponential(1.0 / max(rate, 1e-9))
        out.append(t)
    return np.asarray(out)


def flash_crowd_arrivals(lam: float, n: int, burst_start: float = 20.0,
                         burst_dur: float = 10.0, burst_mult: float = 5.0,
                         seed: int = 0) -> np.ndarray:
    """Baseline-Poisson trace with one flash crowd: the rate jumps to
    burst_mult*lam inside [burst_start, burst_start+burst_dur). Unlike
    the square wave this is NOT mean-matched — a flash crowd adds load,
    which is the point (high-load separation, §6.5)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for _ in range(n):
        in_burst = burst_start <= t < burst_start + burst_dur
        rate = lam * (burst_mult if in_burst else 1.0)
        t += rng.exponential(1.0 / max(rate, 1e-9))
        out.append(t)
    return np.asarray(out)


def sample_budgets(n: int, frac: float, lo: float = 2e-5, hi: float = 4e-4,
                   seed=0, rng: np.random.Generator = None) -> np.ndarray:
    """Vectorized per-request budget mix: each request independently
    carries a log-uniform USD budget in [lo, hi] with probability
    `frac`, nan otherwise (nan = unconstrained, the column convention of
    `repro.serving.request.RequestColumns`). One draw per stream at
    workload-generation time — budgets are ingest data, not per-request
    hot-path work."""
    rng = rng if rng is not None else np.random.default_rng(seed)
    has = rng.uniform(size=n) < frac
    vals = np.exp(rng.uniform(np.log(lo), np.log(hi), n))
    return np.where(has, vals, np.nan)


ARRIVAL_KINDS = ("poisson", "gamma", "square", "flash")


def make_arrivals(kind: str, lam: float, n: int, seed: int = 0,
                  **kw) -> np.ndarray:
    """Dispatch on `kind`, forwarding process-specific kwargs (cv for
    gamma; period/high_frac for square; burst_* for flash)."""
    if kind == "poisson":
        return poisson_arrivals(lam, n, seed, **kw)
    if kind == "gamma":
        return gamma_bursty_arrivals(lam, n, seed=seed, **kw)
    if kind == "square":
        return square_wave_arrivals(lam, n, seed=seed, **kw)
    if kind == "flash":
        return flash_crowd_arrivals(lam, n, seed=seed, **kw)
    raise ValueError(kind)
