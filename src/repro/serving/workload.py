"""Arrival processes: Poisson (the paper's default), gamma-bursty and
square-wave (§6.9 non-stationary robustness)."""
from __future__ import annotations

from typing import Iterator, List, Optional

import numpy as np


def poisson_arrivals(lam: float, n: int, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / lam, n)
    return start + np.cumsum(gaps)


def gamma_bursty_arrivals(lam: float, n: int, cv: float = 3.0,
                          seed: int = 0) -> np.ndarray:
    """Gamma-distributed gaps with mean 1/lam and coefficient of
    variation cv (cv > 1 = bursty)."""
    rng = np.random.default_rng(seed)
    shape = 1.0 / cv ** 2
    scale = 1.0 / (lam * shape)
    return np.cumsum(rng.gamma(shape, scale, n))


def square_wave_arrivals(lam: float, n: int, period: float = 60.0,
                         high_frac: float = 1.5, seed: int = 0
                         ) -> np.ndarray:
    """Alternates between high_frac*lam and (2-high_frac)*lam every
    period/2 seconds; matched mean lam."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    lo = (2.0 - high_frac) * lam
    hi = high_frac * lam
    for _ in range(n):
        phase_hi = (t % period) < period / 2
        rate = hi if phase_hi else lo
        t += rng.exponential(1.0 / max(rate, 1e-9))
        out.append(t)
    return np.asarray(out)


def make_arrivals(kind: str, lam: float, n: int, seed: int = 0
                  ) -> np.ndarray:
    if kind == "poisson":
        return poisson_arrivals(lam, n, seed)
    if kind == "gamma":
        return gamma_bursty_arrivals(lam, n, seed=seed)
    if kind == "square":
        return square_wave_arrivals(lam, n, seed=seed)
    raise ValueError(kind)
