"""Synthetic prompt world with per-(prompt, model) ground truth.

Replicates the *structure* of the paper's released dataset (18,608 prompts
from 7 public datasets, each broadcast to 4 Qwen2.5 candidates, scored
offline by a DeepEval judge; §6.1): each prompt carries a latent (topic,
difficulty, verbosity); tokens are drawn from topic+difficulty-conditioned
vocab regions so a frozen random-feature encoder recovers the latents by
similarity; true quality is a calibrated logistic in (model capacity −
difficulty) — larger models better on hard prompts, ties on easy ones —
and true output length is verbosity-scaled per model with bigger models
answering more concisely (the paper's cost observation, §2).

The estimator stack sees only embeddings + train-split labels; serving
reveals the true values. Greedy decoding makes the (prompt, model) lookup
deterministic — the paper's precompute-validity contract (§4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

TOPICS = ("instruct", "code", "safety", "chat", "math", "reading", "reward")

# topic-conditioned generation parameters
_TOPIC_LEN_IN = (90, 160, 60, 120, 110, 260, 140)     # mean prompt tokens
_TOPIC_LEN_OUT = (220, 340, 90, 180, 260, 120, 160)   # mean response tokens
_TOPIC_DIFF_AB = ((2.0, 2.6), (2.6, 1.8), (1.6, 3.2), (1.8, 2.8),
                  (3.2, 1.5), (2.2, 2.4), (2.0, 2.2))  # Beta(a, b)
_TOPIC_BIAS = (0.02, -0.03, 0.05, 0.03, -0.06, 0.00, -0.01)

VOCAB = 4096
_TOPIC_BLOCK = 480          # tokens [t*B, (t+1)*B) signal the topic
_DIFF_BASE = 3400           # ids 3400..3900 encode difficulty


@dataclasses.dataclass
class Prompt:
    pid: int
    topic: int
    difficulty: float
    verbosity: float
    tokens: np.ndarray
    len_in: int
    safety_flagged: bool = False


class World:
    """Generative ground truth for a pool of M candidate models."""

    def __init__(self, capacities, verbosities, seed: int = 0,
                 quality_noise: float = 0.14, length_noise: float = 0.30,
                 slope: float = 5.5):
        self.capacity = np.asarray(capacities, np.float64)     # (M,)
        self.verbosity = np.asarray(verbosities, np.float64)   # (M,)
        self.M = len(capacities)
        self.rng = np.random.default_rng(seed)
        self.qn = quality_noise
        self.ln = length_noise
        self.slope = slope

    def sample(self, n: int, max_len: int = 128
               ) -> Tuple[List[Prompt], np.ndarray, np.ndarray]:
        """-> (prompts, quality (n, M) in [0,1], out_lengths (n, M))."""
        rng = self.rng
        prompts: List[Prompt] = []
        Q = np.zeros((n, self.M))
        L = np.zeros((n, self.M))
        topics = rng.integers(0, len(TOPICS), n)
        for i in range(n):
            t = int(topics[i])
            a, b = _TOPIC_DIFF_AB[t]
            z = float(rng.beta(a, b))
            v = float(np.exp(rng.normal(0.0, 0.35)))
            ln_in = int(np.clip(rng.lognormal(
                np.log(_TOPIC_LEN_IN[t]), 0.5), 8, 2048))
            ntok = min(ln_in, max_len)
            n_diff = max(2, ntok // 8)
            topic_tok = (t * _TOPIC_BLOCK
                         + rng.zipf(1.35, ntok - n_diff) % _TOPIC_BLOCK)
            diff_tok = (_DIFF_BASE + int(z * 480)
                        + rng.integers(-12, 13, n_diff))
            toks = np.concatenate([topic_tok, diff_tok]).astype(np.int32)
            rng.shuffle(toks)
            prompts.append(Prompt(
                pid=i, topic=t, difficulty=z, verbosity=v, tokens=toks,
                len_in=ln_in, safety_flagged=(t == 2)))
            # quality: logistic in (capacity - difficulty) + topic bias
            base = 1.0 / (1.0 + np.exp(-self.slope
                                       * (self.capacity - z)))
            q = 0.14 + 0.60 * base + _TOPIC_BIAS[t] \
                + rng.normal(0.0, self.qn, self.M)
            Q[i] = np.clip(q, 0.02, 0.98)
            # length: topic base x prompt verbosity x model verbosity
            mean = _TOPIC_LEN_OUT[t] * v * self.verbosity
            L[i] = np.clip(mean * np.exp(
                rng.normal(0.0, self.ln, self.M)), 8, 1536).round()
        return prompts, Q, L


@dataclasses.dataclass
class Dataset:
    prompts: List[Prompt]
    quality: np.ndarray        # (n, M)
    lengths: np.ndarray        # (n, M)
    train_idx: np.ndarray
    test_idx: np.ndarray

    def split(self, which: str):
        idx = self.train_idx if which == "train" else self.test_idx
        return ([self.prompts[i] for i in idx], self.quality[idx],
                self.lengths[idx])


def build_dataset(world: World, n: int = 18608, train_frac: float = 0.8,
                  seed: int = 1) -> Dataset:
    prompts, Q, L = world.sample(n)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_train = int(n * train_frac)
    return Dataset(prompts, Q, L, np.sort(perm[:n_train]),
                   np.sort(perm[n_train:]))


# The paper's four-model pool, calibrated so fixed-model means and the
# oracle headroom are in the paper's ballpark (§6.8: always-3B 0.346,
# always-14B 0.398, oracle 0.582).
PAPER_CAPACITIES = {"qwen2.5-3b": 0.30, "qwen2.5-7b": 0.41,
                    "qwen2.5-14b": 0.53, "qwen2.5-72b": 0.68}
PAPER_VERBOSITY = {"qwen2.5-3b": 1.15, "qwen2.5-7b": 1.10,
                   "qwen2.5-14b": 1.00, "qwen2.5-72b": 0.85}


def paper_world(seed: int = 0) -> Tuple[World, List[str]]:
    names = list(PAPER_CAPACITIES)
    w = World([PAPER_CAPACITIES[m] for m in names],
              [PAPER_VERBOSITY[m] for m in names], seed=seed)
    return w, names
