"""Fault-tolerant request lifecycle: retry/requeue, hedged re-dispatch,
telemetry watchdog, and scheduler checkpoint/restore.

PR 6 made the *roster* resilient (alive-mask autoscaling, SLO
shedding); this module makes the *requests* resilient, the way
production routers do (the Intelligent-Router / data-parallel
load-balancing lines of work in PAPERS.md):

  * **retry/requeue** — `Instance.fail()` hands its in-flight and
    queued work to `RecoveryManager.on_failure` instead of stamping it
    terminally failed: bounded attempts, exponential backoff with
    seeded jitter, and re-entry through the ordinary
    `ServingEngine.enqueue` admission path. The `Request.attempt` /
    `first_arrival` split keeps metrics charging the true end-to-end
    clock while the scheduler sees a freshly-arrived request, and the
    policy sees retries via `BatchView.attempts`;
  * **timeouts + hedged re-dispatch** — every dispatch arms a deadline
    derived from the tier's roofline TPOT at the predicted output
    length. On expiry (a hidden straggler, an overloaded loser) the
    request is re-dispatched to the next-best instance off the live
    telemetry mirror and the loser is cancelled; the loser's generated
    tokens are charged to `duplicate-work`, not thrown away silently;
  * **telemetry watchdog** — a staleness detector over
    `TelemetryArrays.t`/`last_write`: rows that stop publishing while
    they hold work are *quarantined* through the existing alive-mask +
    `roster_version` path (`TelemetryArrays.quarantine`) — masked like
    dead instances, ZERO XLA recompiles — and released with a fresh
    reseed when they publish again. If the whole mirror goes dark the
    engine falls back to a degraded least-loaded policy
    (`degraded_assign`) until rows come back;
  * **checkpoint/restore** — `ServingEngine.checkpoint_tree()` +
    `RecoveryManager.pending_state()` capture the controller's dead-
    reckoned scheduler state (waiting queue, counters, pending retry
    and hedge timers) as a flat numpy tree the atomic
    `repro.distributed.checkpoint.CheckpointManager` persists;
    `simulate_controller_crash` strips every controller-owned event
    from a live sim (worker decode chains survive — a controller crash
    is not a node crash) and `ServingEngine.resume` rebuilds the
    scheduler mid-trace with no lost or duplicated requests.

Determinism contract: every decision here — backoff jitter (counter-
based, keyed on (seed, rid, attempt) so no RNG state needs
checkpointing), hedge targets, quarantine verdicts — is a function of
the simulation trajectory, never of wall clock or shared RNG state, so
the numpy/jax/fused differential parity soak holds through retry /
hedge / quarantine churn, and a crash/restore replays bitwise.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from .cluster import ClusterSim, Instance


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """Retry / hedge / watchdog knobs (see module docstring)."""
    # -- retry/requeue ---------------------------------------------------
    max_attempts: int = 3          # total dispatch attempts per request
    backoff_base_s: float = 0.25   # first-retry delay
    backoff_mult: float = 2.0      # exponential growth per attempt
    backoff_jitter: float = 0.25   # ± fraction, drawn per (rid, attempt)
    # -- timeouts + hedged re-dispatch -----------------------------------
    hedge: bool = True
    hedge_factor: float = 4.0      # deadline = factor * predicted service
    hedge_slack_s: float = 2.0     # + constant slack
    max_hedges: int = 1            # hedged re-dispatches per request
    # -- telemetry watchdog ----------------------------------------------
    watchdog: bool = True
    check_interval_s: float = 0.5  # staleness probe period
    stale_after_s: float = 2.0     # no write for this long + work = stale
    degraded_pred_len: float = 128.0   # l_chosen stand-in in degraded mode
    seed: int = 0


def _jitter_u(seed: int, rid: int, attempt: int) -> float:
    """Deterministic uniform draw in [0, 1) per (request, attempt):
    counter-based so retries replay bitwise across backends and across
    a controller crash/restore (no RNG state to checkpoint)."""
    return float(np.random.default_rng(
        (seed, 0xFA117, rid, attempt)).random())


def least_loaded_instance(sim: ClusterSim, exclude: Tuple[str, ...] = ()
                          ) -> Optional[Instance]:
    """Deterministic degraded-mode pick: the alive, un-quarantined
    instance with the lowest occupancy fraction (slot order breaks
    ties). Quarantined rows are suspect and only used when nothing else
    is left."""
    def key(i: Instance):
        return ((len(i.running) + len(i.queue))
                / max(i.tier.max_batch, 1), i.slot)
    pool = [i for i in sim.instances
            if i.alive and not i.quarantined and i.iid not in exclude]
    if not pool:
        pool = [i for i in sim.instances
                if i.alive and i.iid not in exclude]
    return min(pool, key=key) if pool else None


def fastest_drain_instance(sim: ClusterSim, exclude: Tuple[str, ...] = ()
                           ) -> Optional[Instance]:
    """Hedge-target pick: minimize expected time-to-serve, not raw
    occupancy. An empty heavyweight tier is a WORSE hedge target than a
    moderately loaded fast one — the whole point of hedging is to beat
    the loser's clock — so the score is the tier's nominal TPOT scaled
    by the instance's load. Pure sim-side state + tier constants: the
    pick is identical under every decision backend."""
    def key(i: Instance):
        occ = ((len(i.running) + len(i.queue))
               / max(i.tier.max_batch, 1))
        return (i.tier.tpot(float(i.tier.max_batch), 1024.0)
                * (1.0 + occ), i.slot)
    pool = [i for i in sim.instances
            if i.alive and not i.quarantined and i.iid not in exclude]
    return min(pool, key=key) if pool else None


class RecoveryManager:
    """Retry + hedge + watchdog controller over one `ClusterSim`,
    exposed as ``sim.recovery`` (`arm_recovery`). `Instance.fail()`
    routes victims through `on_failure`; `ServingEngine` binds itself
    at attach time, registers every dispatch (`watch_dispatch`), and
    consults `degraded` before each policy call."""

    _is_controller = True          # see ClusterSim.has_noncontrol_events

    def __init__(self, sim: ClusterSim, cfg: RecoveryConfig):
        self.sim = sim
        self.cfg = cfg
        self.engine = None                     # bound by ServingEngine
        self.degraded = False                  # whole mirror dark
        self._watch_armed = False
        # pending retries: rid -> (req, due) — checkpointed
        self._pending: Dict[int, Tuple[object, float]] = {}
        # armed hedge timers: (rid, attempt, hedges) -> (due, slot)
        self._watches: Dict[Tuple[int, int, int], Tuple[float, int]] = {}
        # counters / audit trail
        self.retries = 0
        self.gave_up = 0
        self.hedges = 0
        self.duplicate_tokens = 0
        self.quarantines = 0
        self.releases = 0
        self.degraded_decisions = 0
        self.degraded_entries = 0

    # -- wiring -----------------------------------------------------------
    def bind(self, engine) -> "RecoveryManager":
        """Attach the scheduler the manager requeues into; starts the
        watchdog loop (idempotent across re-binds)."""
        self.engine = engine
        if self.cfg.watchdog and not self._watch_armed:
            self._watch_armed = True
            self.sim.push(self.sim.now + self.cfg.check_interval_s,
                          self._watch)
        return self

    # -- retry/requeue ----------------------------------------------------
    def on_failure(self, req, inst: Instance, lost_tokens: int,
                   now: float) -> bool:
        """Instance death handed us a victim. True = requeued for retry
        (the caller must NOT mark it terminal); False = attempts
        exhausted (or already terminal) — the caller fails it."""
        if req.finish_time is not None or req.shed:
            return False           # already terminal; don't resurrect
        req.wasted_tokens += lost_tokens
        if req.attempt + 1 >= self.cfg.max_attempts:
            self.gave_up += 1
            return False
        req.requeue(now)           # attempt += 1, dispatch state cleared
        a = req.attempt
        delay = (self.cfg.backoff_base_s
                 * self.cfg.backoff_mult ** (a - 1)
                 * (1.0 + self.cfg.backoff_jitter
                    * (2.0 * _jitter_u(self.cfg.seed, req.rid, a) - 1.0)))
        due = now + delay
        self.retries += 1
        self._pending[req.rid] = (req, due)
        self.sim.push(due, self._make_delivery(req))
        return True

    def _make_delivery(self, req):
        def deliver(t):
            if self._pending.pop(req.rid, None) is None:
                return             # superseded (crash/restore re-armed it)
            if self.engine is not None:
                self.engine.enqueue(req, t)
        deliver._controller = True     # dies with the controller; the
        return deliver                 # checkpoint re-arms it on resume

    # -- timeouts + hedged re-dispatch ------------------------------------
    def watch_dispatch(self, req, inst: Instance, t: float):
        """Arm the per-request deadline for a dispatch that just
        happened. Deadline = hedge_factor x the tier's roofline service
        estimate at the predicted output length (prefill + pred_len
        decode steps at worst-case batch), plus slack — generous enough
        that healthy instances never trip it, tight enough that a 4x
        hidden straggler does."""
        cfg = self.cfg
        if not cfg.hedge or req.hedges >= cfg.max_hedges:
            return
        due = t + self._deadline_s(req, inst)
        key = (req.rid, req.attempt, req.hedges)
        self._watches[key] = (due, inst.slot)
        self.sim.push(due, self._make_hedge_check(
            req, inst.iid, req.attempt, req.hedges))

    def _deadline_s(self, req, inst: Instance) -> float:
        tier = inst.tier
        pred = (float(req.pred_len) if req.pred_len is not None
                else self.cfg.degraded_pred_len)
        est = (tier.prefill_time(req.prompt.len_in)
               + max(pred, 8.0) * tier.tpot(tier.max_batch, 1024.0))
        return self.cfg.hedge_factor * est + self.cfg.hedge_slack_s

    def _make_hedge_check(self, req, iid: str, attempt: int, hedges: int):
        def check(t):
            self._watches.pop((req.rid, attempt, hedges), None)
            self._maybe_hedge(req, iid, attempt, hedges, t)
        check._controller = True
        return check

    def _maybe_hedge(self, req, iid: str, attempt: int, hedges: int,
                     t: float):
        if req.finish_time is not None or req.failed or req.shed:
            return
        if (req.attempt != attempt or req.hedges != hedges
                or req.instance != iid):
            return                 # moved since the timer was armed
        loser = self.sim.by_id.get(iid)
        if loser is None or not loser.alive:
            return                 # the failure path owns this request
        target = fastest_drain_instance(self.sim, exclude=(iid,))
        if target is None:
            return
        gen = loser.cancel(req)
        if gen is None:
            return                 # completing concurrently — let it win
        req.wasted_tokens += gen
        req.hedges += 1
        self.hedges += 1
        self.duplicate_tokens += gen
        mt = req.max_tokens
        if self.engine is not None and self.engine.policy.budget_clamp:
            from repro.core.budget import max_tokens_clamp
            mt = max_tokens_clamp(req.budget, req.prompt.len_in,
                                  target.tier.price_in,
                                  target.tier.price_out)
        pred = (float(req.pred_len) if req.pred_len is not None
                else self.cfg.degraded_pred_len)
        target.submit(req, t, pred, mt)
        self.watch_dispatch(req, target, t)

    # -- telemetry watchdog -----------------------------------------------
    def _watch(self, t: float):
        cfg = self.cfg
        tel = self.sim.tel
        stale: List[Instance] = []
        fresh = 0
        for inst in self.sim.instances:
            if not inst.alive:
                continue
            has_work = bool(inst.running or inst.queue)
            is_stale = has_work and (t - tel.t[inst.slot]
                                     ) > cfg.stale_after_s
            if inst.quarantined:
                if not is_stale:
                    self._release(inst, t)
                continue
            if is_stale:
                stale.append(inst)
            else:
                fresh += 1
        if stale and fresh == 0:
            # whole mirror dark: masking everything would leave the
            # policy nothing to schedule onto — flip to the degraded
            # least-loaded fallback instead and leave the masks alone
            if not self.degraded:
                self.degraded_entries += 1
            self.degraded = True
        else:
            self.degraded = False
            for inst in stale:
                if int(tel.alive.sum()) <= 1:
                    break          # never mask the last visible row
                inst.quarantined = True
                tel.quarantine(inst.slot)
                self.quarantines += 1
        if self.sim.has_noncontrol_events():
            self.sim.push(t + cfg.check_interval_s, self._watch)
        else:
            self._watch_armed = False

    def _release(self, inst: Instance, t: float):
        """A quarantined row published again (or drained): unmask it
        and reseed the row from the worker's live snapshot — unlike a
        revive, the instance was serving the whole time."""
        inst.quarantined = False
        tel = self.sim.tel
        tel.unquarantine(inst.slot)
        s = inst.snapshot
        tel.write(inst.slot, s["pending_decode"], s["batch_size"],
                  s["free_slots"], s["mean_ctx"], s["queue_depth"], t)
        self.releases += 1

    def degraded_assign(self, batch, sim: ClusterSim):
        """Mirror-dark fallback: least-loaded dispatch off the live
        instance state, bypassing the policy (whose telemetry inputs
        are all stale). Deterministic, backend-independent."""
        from repro.core.engine import AssignmentResult, Ready
        cand = [i for i in sim.instances if i.alive]
        assert cand, "no alive instances to schedule onto"
        R = len(batch.reqs)
        choice = np.empty(R, np.int64)
        load = {i.slot: len(i.running) + len(i.queue) for i in cand}
        for r in range(R):
            best = min(cand, key=lambda i: (
                load[i.slot] / max(i.tier.max_batch, 1), i.slot))
            choice[r] = best.slot      # slot == index into sim.instances
            load[best.slot] += 1       # spread the batch, dead-reckoned
        self.degraded_decisions += R
        l_chosen = np.full(R, self.cfg.degraded_pred_len)
        return AssignmentResult(sim.instances, Ready(choice, l_chosen))

    # -- checkpoint/restore -----------------------------------------------
    def pending_state(self) -> Dict[str, np.ndarray]:
        """The manager's durable state as flat numpy arrays (merged
        into `ServingEngine.checkpoint_tree`): pending retry deliveries
        and armed hedge timers, plus the counters."""
        pend = sorted(self._pending.values(), key=lambda p: p[0].rid)
        watches = sorted((rid, att, hg, due, slot) for
                         (rid, att, hg), (due, slot)
                         in self._watches.items())
        return {
            "retry_rids": np.array([p[0].rid for p in pend], np.int64),
            "retry_due": np.array([p[1] for p in pend], np.float64),
            "watch_keys": np.array([w[:3] for w in watches],
                                   np.int64).reshape(-1, 3),
            "watch_due": np.array([w[3] for w in watches], np.float64),
            "watch_slot": np.array([w[4] for w in watches], np.int64),
            "recovery_counters": np.array(
                [self.retries, self.gave_up, self.hedges,
                 self.duplicate_tokens, self.quarantines, self.releases,
                 self.degraded_decisions], np.int64),
        }

    def restore_pending(self, tree: Dict[str, np.ndarray], by_rid):
        """Re-arm checkpointed retry deliveries and hedge timers on a
        (possibly fresh) manager after a controller crash."""
        (self.retries, self.gave_up, self.hedges, self.duplicate_tokens,
         self.quarantines, self.releases, self.degraded_decisions) = (
            int(x) for x in tree["recovery_counters"])
        for rid, due in zip(tree["retry_rids"], tree["retry_due"]):
            req = by_rid[int(rid)]
            self._pending[req.rid] = (req, float(due))
            self.sim.push(float(due), self._make_delivery(req))
        for (rid, att, hg), due, slot in zip(
                tree["watch_keys"].reshape(-1, 3).tolist(),
                tree["watch_due"], tree["watch_slot"]):
            req = by_rid[int(rid)]
            iid = self.sim.instances[int(slot)].iid
            self._watches[(int(rid), int(att), int(hg))] = (
                float(due), int(slot))
            self.sim.push(float(due), self._make_hedge_check(
                req, iid, int(att), int(hg)))


def arm_recovery(sim: ClusterSim,
                 cfg: Optional[RecoveryConfig] = None) -> RecoveryManager:
    """Attach a `RecoveryManager` to a sim as ``sim.recovery``.
    `Instance.fail()` finds it there; `ServingEngine.attach` binds
    itself and starts the watchdog."""
    mgr = RecoveryManager(sim, cfg if cfg is not None
                          else RecoveryConfig())
    sim.recovery = mgr
    return mgr


def simulate_controller_crash(sim: ClusterSim, engine=None) -> int:
    """Kill the scheduler side of a live sim: strip every controller-
    owned event from the heap — the engine's fire loop, retry
    deliveries, hedge timers, the watchdog and overload detector loops
    — while worker decode chains and future arrivals survive (a
    controller crash is not a node crash). Detaches ``sim.recovery``;
    the restore path re-arms a fresh manager from the checkpoint.
    Returns the number of events dropped."""
    from repro.core.engine import ServingEngine

    def is_controller_event(fn) -> bool:
        if getattr(fn, "_controller", False):
            return True
        owner = getattr(fn, "__self__", None)
        if owner is None:
            return False
        return (owner is engine or isinstance(owner, ServingEngine)
                or getattr(owner, "_is_controller", False))

    kept = [e for e in sim._events if not is_controller_event(e[2])]
    dropped = len(sim._events) - len(kept)
    heapq.heapify(kept)
    sim._events = kept
    sim.recovery = None
    return dropped
