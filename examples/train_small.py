"""End-to-end training driver: train a small LM with the full substrate
(AdamW, cosine schedule, gradient accumulation, atomic checkpoints,
optional int8-EF gradient compression). Crash-safe: re-running the same
command resumes from the latest checkpoint.

    PYTHONPATH=src python examples/train_small.py              # tiny/CPU
    PYTHONPATH=src python examples/train_small.py --preset 100m --steps 300
"""
import argparse

from repro.configs import ARCHS, smoke_variant
from repro.models import Model
from repro.training.data import TokenStream
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("tiny", "100m"), default="tiny")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt", default="runs/train_small")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    if args.preset == "tiny":
        cfg = smoke_variant(ARCHS["granite-3-2b"]).replace(vocab=512)
        batch, seq = 8, 64
    else:  # ~100M-param granite-family config
        cfg = ARCHS["granite-3-2b"].replace(
            n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
            head_dim=64, d_ff=2560, vocab=32000, remat=True)
        batch, seq = 16, 512
    model = Model(cfg)
    n = cfg.param_counts()["total"]
    print(f"arch={cfg.name} params={n/1e6:.1f}M batch={batch} seq={seq}")
    data = TokenStream(cfg.vocab, seq, batch, seed=0)
    out = train(model, data,
                TrainConfig(n_steps=args.steps, ckpt_every=50,
                            ckpt_dir=args.ckpt,
                            grad_compression=args.compress_grads))
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}")


if __name__ == "__main__":
    main()
