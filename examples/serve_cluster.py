"""End-to-end serving driver: policies on one engine — a deployed
RouteBalance stack sweeping its weight vector across the frontier, vs
an engineering-equalized BEST-Route baseline, all through the SAME
`ServingEngine` (only the `SchedulingPolicy` and the `deployment=` knob
differ) — the paper's headline experiment in miniature. A final arm
runs the hierarchical path end to end: the same roster partitioned into
--cells scheduling cells, per-cell RouteBalance engines, and a
GlobalBalancer routing arrivals from telemetry digests exchanged every
--digest-interval seconds.

    PYTHONPATH=src python examples/serve_cluster.py [--lam 12] [--n 600]
        [--cells 2] [--digest-interval 0.25]
"""
import argparse

from repro.core import (EngineConfig, EstimatorBundle, PRESETS,
                        ServingEngine, fit_policy, make_requests,
                        run_cell)
from repro.serving.tiers import paper_pool_tiers
from repro.serving.workload import poisson_arrivals
from repro.serving.world import build_dataset, paper_world


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lam", type=float, default=12.0)
    ap.add_argument("--n", type=int, default=600)
    ap.add_argument("--cells", type=int, default=2,
                    help="scheduling cells for the hierarchical arm")
    ap.add_argument("--digest-interval", type=float, default=0.25,
                    help="seconds between per-cell telemetry digests")
    args = ap.parse_args()

    world, names = paper_world(seed=0)
    ds = build_dataset(world, n=6000)
    tiers = paper_pool_tiers()
    bundle = EstimatorBundle.train(ds, tiers, names)

    def cell(policy_name, deployment, **policy_kw):
        policy = fit_policy(policy_name, bundle, tiers, names, ds,
                            **policy_kw)
        eng = ServingEngine(policy, bundle, tiers,
                            EngineConfig(deployment=deployment))
        reqs = make_requests(ds, "test",
                             poisson_arrivals(args.lam, args.n, seed=1))
        return run_cell(eng, tiers, names, reqs)

    print(f"{'cell':32s} {'quality':>8s} {'E2E s':>7s} {'p99 s':>7s} "
          f"{'cost $':>9s} {'tput':>6s}")

    def show(name, m):
        print(f"{name:32s} {m['quality']:8.3f} {m['mean_e2e']:7.2f} "
              f"{m['p99_e2e']:7.1f} {m['cost_per_req']:9.2e} "
              f"{m['throughput']:6.1f}")

    # one policy family, three weight vectors, windowed deployment
    for wname, w in (("cost", PRESETS["cost"]),
                     ("uniform", PRESETS["uniform"]),
                     ("quality", PRESETS["quality"])):
        m = cell("routebalance", "windowed", weights=w)
        show(f"routebalance/{wname} (windowed)", m)
    # the equalized baseline on the SAME engine: concurrent scoring
    for t in (0.5, 0.7):
        m = cell("bestroute-sq", "concurrent", threshold=t)
        show(f"bestroute-sq/t{t} (concurrent)", m)
    # the as-published deployment, one knob away: serial scoring
    m = cell("bestroute-sq", "serial_published", threshold=0.5)
    show("bestroute-sq/t0.5 (serial)", m)
    # the hierarchical path end to end: same roster split into cells,
    # per-cell engines, digest-routed GlobalBalancer
    from repro.core import RBConfig
    from repro.serving.hierarchy import HierarchyConfig, build_scheduler
    sched = build_scheduler(
        RBConfig(weights=PRESETS["uniform"]), bundle, tiers,
        HierarchyConfig(n_cells=args.cells,
                        digest_interval_s=args.digest_interval))
    reqs = make_requests(ds, "test",
                         poisson_arrivals(args.lam, args.n, seed=1))
    m = run_cell(sched, tiers, names, reqs)
    show(f"routebalance/uniform ({args.cells} cells)", m)
    bal = sched.balancer
    print(f"{'':32s} digests={bal.digests_sent} "
          f"wire_bytes={bal.bytes_sent} "
          f"imbalance={bal.imbalance():.3f}")


if __name__ == "__main__":
    main()
