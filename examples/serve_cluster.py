"""End-to-end serving driver: one deployed RouteBalance stack sweeping
its weight vector across the frontier, vs an engineering-equalized
BEST-Route baseline — the paper's headline experiment in miniature.

    PYTHONPATH=src python examples/serve_cluster.py [--lam 12] [--n 600]
"""
import argparse

from repro.core import (EstimatorBundle, PRESETS, PipelineConfig,
                        PipelineScheduler, RBConfig, RouteBalance,
                        make_requests, run_cell)
from repro.core.dispatchers import ShortestQueue
from repro.core.routers import BestRouteRouter
from repro.serving.tiers import paper_pool_tiers
from repro.serving.workload import poisson_arrivals
from repro.serving.world import build_dataset, paper_world


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lam", type=float, default=12.0)
    ap.add_argument("--n", type=int, default=600)
    args = ap.parse_args()

    world, names = paper_world(seed=0)
    ds = build_dataset(world, n=6000)
    tiers = paper_pool_tiers()
    bundle = EstimatorBundle.train(ds, tiers, names)

    def cell(sched):
        reqs = make_requests(ds, "test",
                             poisson_arrivals(args.lam, args.n, seed=1))
        return run_cell(sched, tiers, names, reqs)

    print(f"{'cell':26s} {'quality':>8s} {'E2E s':>7s} {'p99 s':>7s} "
          f"{'cost $':>9s} {'tput':>6s}")
    for name, w in (("rb/cost", PRESETS["cost"]),
                    ("rb/uniform", PRESETS["uniform"]),
                    ("rb/quality", PRESETS["quality"])):
        m = cell(RouteBalance(RBConfig(weights=w), bundle, tiers))
        print(f"{name:26s} {m['quality']:8.3f} {m['mean_e2e']:7.2f} "
              f"{m['p99_e2e']:7.1f} {m['cost_per_req']:9.2e} "
              f"{m['throughput']:6.1f}")
    for t in (0.5, 0.7):
        r = BestRouteRouter(threshold=t)
        r.fit_from = None
        prompts, Q, L = ds.split("train")
        import numpy as np
        from benchmarks.common import _embed_all
        emb = _embed_all(bundle, prompts)
        prices = np.array([tt.price_out for m_ in names
                           for tt in tiers if tt.model == m_])
        r.fit(emb, Q, L, prices)
        m = cell(PipelineScheduler(r, ShortestQueue(), bundle, tiers,
                                   PipelineConfig(deployment="concurrent")))
        print(f"{'bestroute/t%.1f' % t:26s} {m['quality']:8.3f} "
              f"{m['mean_e2e']:7.2f} {m['p99_e2e']:7.1f} "
              f"{m['cost_per_req']:9.2e} {m['throughput']:6.1f}")


if __name__ == "__main__":
    main()
