"""Budget-aware serving (§6.4): per-request cost budgets with the Eq. 2
admission filter, dispatch-time max_tokens clamp and streaming early-stop
— the filter converts budget exhaustion into served quality.

    PYTHONPATH=src python examples/budget_serving.py
"""
import numpy as np

from repro.core import EstimatorBundle, RBConfig, RouteBalance, \
    make_requests, run_cell
from repro.serving.tiers import paper_pool_tiers
from repro.serving.workload import poisson_arrivals
from repro.serving.world import build_dataset, paper_world


def main():
    world, names = paper_world(seed=0)
    ds = build_dataset(world, n=4000)
    tiers = paper_pool_tiers()
    bundle = EstimatorBundle.train(ds, tiers, names)
    n = 400
    rng = np.random.default_rng(0)
    budgets = np.full(n, np.nan)
    mask = rng.uniform(size=n) < 0.75          # the paper's tight mix
    budgets[mask] = 3.2e-5 * rng.uniform(0.4, 1.2, mask.sum())

    for label, filt in (("with Eq.2 admission filter", True),
                        ("runtime cap only", False)):
        reqs = make_requests(ds, "test", poisson_arrivals(16.0, n, seed=1),
                             budgets=budgets)
        rb = RouteBalance(RBConfig(budget_filter=filt), bundle, tiers)
        m = run_cell(rb, tiers, names, reqs)
        print(f"{label:28s} exhausted={m['exhausted_frac']:.3f} "
              f"served_quality={m['served_quality']:.3f} "
              f"cost=${m['cost_per_req']:.2e}")


if __name__ == "__main__":
    main()
