"""RouteBalance over the ASSIGNED architecture zoo: a heterogeneous pool
of gemma3-27b / mixtral-8x7b / phi3-mini / granite-3-2b / mamba2-1.3b /
qwen3-0.6b tiers — the paper's technique is model-agnostic, so the whole
model zoo becomes one routed cluster (DESIGN.md §4).

    PYTHONPATH=src python examples/zoo_serving.py
"""
from repro.core import EstimatorBundle, PRESETS, RBConfig, RouteBalance, \
    make_requests, run_cell
from repro.serving.tiers import assigned_pool_tiers, tpot_table
from repro.serving.workload import poisson_arrivals
from repro.serving.world import World, build_dataset

# capacities/verbosities for the zoo pool (capability-ordered)
CAPS = {"gemma3-27b": 0.68, "mixtral-8x7b": 0.62, "phi3-mini-3.8b": 0.50,
        "granite-3-2b": 0.42, "mamba2-1.3b": 0.34, "qwen3-0.6b": 0.28}
VERB = {"gemma3-27b": 0.85, "mixtral-8x7b": 0.9, "phi3-mini-3.8b": 1.0,
        "granite-3-2b": 1.05, "mamba2-1.3b": 1.1, "qwen3-0.6b": 1.2}


def main():
    tiers = assigned_pool_tiers()
    names = [t.model for t in tiers]
    world = World([CAPS[m] for m in names], [VERB[m] for m in names],
                  seed=3)
    ds = build_dataset(world, n=4000)
    bundle = EstimatorBundle.train(ds, tiers, names)
    print("zoo pool TPOT ms (b=8, ctx=500):", tpot_table(tiers))
    for pname in ("cost", "uniform", "quality"):
        reqs = make_requests(ds, "test", poisson_arrivals(10.0, 400, seed=1))
        rb = RouteBalance(RBConfig(weights=PRESETS[pname]), bundle, tiers)
        m = run_cell(rb, tiers, names, reqs)
        mix = {k.split("/")[0]: round(v, 2) for k, v in m["mix"].items()}
        print(f"{pname:8s} q={m['quality']:.3f} e2e={m['mean_e2e']:.2f}s "
              f"cost=${m['cost_per_req']:.2e} mix={mix}")


if __name__ == "__main__":
    main()
