"""Quickstart: one fused RouteBalance scheduling decision, end to end.

Builds the synthetic prompt world + the paper's 13-instance tier pool,
trains the in-process predictor stack (MiniLM-analogue encoder -> KNN;
per-tier GBM TPOT heads), then walks a single batch through Eq. 1:
batched estimation -> budget filter -> LPT order -> greedy dispatch with
dead reckoning.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import EstimatorBundle, PRESETS, RBConfig, RouteBalance, \
    make_requests, run_cell
from repro.serving.tiers import paper_pool_tiers, tpot_table
from repro.serving.workload import poisson_arrivals
from repro.serving.world import build_dataset, paper_world


def main():
    world, names = paper_world(seed=0)
    ds = build_dataset(world, n=3000)
    tiers = paper_pool_tiers()
    print("tier pool (TPOT ms at b=8, ctx=500):", tpot_table(tiers))

    print("training estimator bundle (encoder + KNN + TPOT heads)...")
    bundle = EstimatorBundle.train(ds, tiers, names)

    # one cell at lambda = 12 with the uniform preset
    reqs = make_requests(ds, "test", poisson_arrivals(12.0, 300, seed=1))
    rb = RouteBalance(RBConfig(weights=PRESETS["uniform"]), bundle, tiers)
    m = run_cell(rb, tiers, names, reqs)
    print(f"\nuniform preset @ lambda=12: quality={m['quality']:.3f} "
          f"mean E2E={m['mean_e2e']:.2f}s cost/req=${m['cost_per_req']:.2e}")
    print("tier mix:", {k: round(v, 2) for k, v in m["mix"].items()})
    print(f"decision compute: {m['measured_decide_ms_mean']:.1f} ms/batch "
          f"({m['measured_decide_ms_per_req']:.2f} ms/request amortized)")


if __name__ == "__main__":
    main()
