"""Scenario subsystem: synthetic rosters, composite multi-tenant
workloads, failure/recovery/straggler schedules, and the arrival-process
statistics they are built on."""
import numpy as np
import pytest

from repro.serving.cluster import ClusterSim
from repro.serving.scenarios import (FailureEvent, SCENARIOS, TenantSpec,
                                     apply_schedule, build_requests,
                                     get_scenario, random_scenario,
                                     synthetic_pool)
from repro.serving.workload import (flash_crowd_arrivals,
                                    gamma_bursty_arrivals, make_arrivals,
                                    poisson_arrivals,
                                    square_wave_arrivals)
from repro.serving.world import TOPICS, World, build_dataset


# -- arrival-process statistics ----------------------------------------------

@pytest.mark.parametrize("lam", [2.0, 10.0, 30.0])
def test_gamma_bursty_matches_mean_rate(lam):
    """Gamma gaps have mean 1/lam regardless of cv: the empirical rate
    over a long trace must converge to lam."""
    n = 40_000
    arr = gamma_bursty_arrivals(lam, n, cv=3.0, seed=0)
    assert np.all(np.diff(arr) >= 0)
    rate = n / arr[-1]
    assert rate == pytest.approx(lam, rel=0.05)


@pytest.mark.parametrize("lam", [4.0, 12.0])
def test_square_wave_matches_mean_rate(lam):
    """The square wave alternates high_frac*lam and (2-high_frac)*lam on
    equal half-periods, so the time-averaged rate is lam."""
    n = 40_000
    arr = square_wave_arrivals(lam, n, period=20.0, high_frac=1.6, seed=1)
    rate = n / arr[-1]
    assert rate == pytest.approx(lam, rel=0.05)


def test_square_wave_actually_modulates():
    """High half-periods must contain more arrivals than low ones."""
    lam, period = 10.0, 40.0
    arr = square_wave_arrivals(lam, 20_000, period=period, high_frac=1.8,
                               seed=2)
    phase = arr % period
    hi = int((phase < period / 2).sum())
    lo = len(arr) - hi
    assert hi > 1.5 * lo


def test_flash_crowd_burst_rate():
    arr = flash_crowd_arrivals(8.0, 20_000, burst_start=10.0,
                               burst_dur=20.0, burst_mult=5.0, seed=0)
    in_burst = (arr >= 10.0) & (arr < 30.0)
    burst_rate = in_burst.sum() / 20.0
    pre = arr < 10.0
    pre_rate = pre.sum() / 10.0
    assert burst_rate == pytest.approx(40.0, rel=0.15)
    assert pre_rate == pytest.approx(8.0, rel=0.3)


def test_make_arrivals_plumbs_kwargs():
    """cv / period / high_frac / burst_* must reach the generators (they
    used to be silently dropped)."""
    direct = gamma_bursty_arrivals(5.0, 200, cv=1.2, seed=3)
    np.testing.assert_array_equal(
        make_arrivals("gamma", 5.0, 200, seed=3, cv=1.2), direct)
    assert not np.array_equal(
        make_arrivals("gamma", 5.0, 200, seed=3, cv=4.0), direct)
    direct = square_wave_arrivals(5.0, 200, period=7.0, high_frac=1.9,
                                  seed=3)
    np.testing.assert_array_equal(
        make_arrivals("square", 5.0, 200, seed=3, period=7.0,
                      high_frac=1.9), direct)
    direct = flash_crowd_arrivals(5.0, 200, burst_mult=9.0, seed=3)
    np.testing.assert_array_equal(
        make_arrivals("flash", 5.0, 200, seed=3, burst_mult=9.0), direct)
    np.testing.assert_array_equal(
        make_arrivals("poisson", 5.0, 200, seed=3, start=2.0),
        poisson_arrivals(5.0, 200, seed=3, start=2.0))
    with pytest.raises(ValueError):
        make_arrivals("nope", 5.0, 10)


# -- synthetic rosters --------------------------------------------------------

@pytest.mark.parametrize("n_tiers,n_instances",
                         [(1, 1), (2, 3), (4, 13), (8, 48), (16, 128),
                          (16, 200)])
def test_synthetic_pool_shape(n_tiers, n_instances):
    tiers, names, world = synthetic_pool(n_tiers, n_instances, seed=1)
    assert len(tiers) == n_tiers == len(names) == world.M
    assert sum(t.n_instances for t in tiers) == n_instances
    assert all(t.n_instances >= 1 for t in tiers)
    assert len(set(names)) == n_tiers
    for t in tiers:
        assert 0 < t.price_in <= t.price_out * 1.01
        assert t.max_batch >= 16 and t.n_chips >= 1
        tpot = t.tpot(8, 500)
        assert np.isfinite(tpot) and 1e-4 < tpot < 1.0
        assert np.isfinite(t.prefill_time(256))


def test_synthetic_pool_is_heterogeneous_and_seeded():
    tiers, _, _ = synthetic_pool(8, 48, seed=5)
    tpots = [t.tpot(8, 500) for t in tiers]
    assert max(tpots) / min(tpots) > 2.0          # real spread
    prices = [t.price_out for t in tiers]
    assert max(prices) / min(prices) > 3.0
    again, _, _ = synthetic_pool(8, 48, seed=5)
    assert [t.name for t in again] == [t.name for t in tiers]
    other, _, _ = synthetic_pool(8, 48, seed=6)
    assert [t.price_out for t in other] != prices


def test_synthetic_pool_world_trains_estimators(small_ctx):
    """The synthetic world must feed the estimator stack exactly like
    the paper world (shared train path)."""
    from repro.core import EstimatorBundle
    tiers, names, world = synthetic_pool(3, 6, seed=0)
    ds = build_dataset(world, n=150)
    bundle = EstimatorBundle.train(ds, tiers, names)
    assert set(bundle.heads) == {t.name for t in tiers}
    assert all(h.model is not None for h in bundle.heads.values())


# -- composite workloads ------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_ds():
    world = World([0.3, 0.6], [1.1, 0.9], seed=0)
    return build_dataset(world, n=300)


def test_build_requests_multitenant(tiny_ds):
    tenants = (
        TenantSpec("chat", 6.0, arrival="gamma", arrival_kw=(("cv", 2.0),),
                   topics=("chat", "instruct")),
        TenantSpec("code", 3.0, topics=("code",), budget_frac=1.0,
                   budget_range=(1e-5, 1e-4)),
    )
    reqs = build_requests(tiny_ds, tenants, 120, seed=0)
    arr = np.array([r.arrival for r in reqs])
    assert np.all(np.diff(arr) >= 0)               # merged & sorted
    assert [r.rid for r in reqs] == list(range(len(reqs)))
    by_tenant = {}
    for r in reqs:
        by_tenant.setdefault(r.tenant, []).append(r)
    assert set(by_tenant) == {"chat", "code"}
    # rate-proportional split: chat gets ~2/3
    assert len(by_tenant["chat"]) == pytest.approx(80, abs=2)
    # topic slices respected
    ok_topics = {TOPICS.index("chat"), TOPICS.index("instruct")}
    assert all(r.prompt.topic in ok_topics for r in by_tenant["chat"])
    assert all(r.prompt.topic == TOPICS.index("code")
               for r in by_tenant["code"])
    # budget mix respected
    assert all(r.budget is not None and 1e-5 <= r.budget <= 1e-4
               for r in by_tenant["code"])
    assert all(r.budget is None for r in by_tenant["chat"])


def test_build_requests_len_band_and_scale(tiny_ds):
    band = (TenantSpec("short", 5.0, len_band=(0.0, 0.3)),)
    short = build_requests(tiny_ds, band, 150, seed=1)
    all_r = build_requests(tiny_ds, (TenantSpec("all", 5.0),), 150, seed=1)
    assert (np.mean([r.prompt.len_in for r in short])
            < np.mean([r.prompt.len_in for r in all_r]))
    # lam_scale compresses the trace
    slow = build_requests(tiny_ds, band, 150, lam_scale=1.0, seed=2)
    fast = build_requests(tiny_ds, band, 150, lam_scale=4.0, seed=2)
    assert fast[-1].arrival < slow[-1].arrival / 2


# -- schedules ----------------------------------------------------------------

def _sim(small_ctx):
    return ClusterSim(small_ctx["tiers"], small_ctx["names"], seed=0)


def test_schedule_fail_and_recover(small_ctx):
    sim = _sim(small_ctx)
    I = len(sim.instances)
    apply_schedule(sim, (FailureEvent(t=1.0, kind="fail", frac=0.5),
                         FailureEvent(t=2.0, kind="recover", frac=1.0)),
                   seed=0)
    sim.run(until=1.5)
    down = int((~sim.tel.alive).sum())
    assert down == round(0.5 * I)
    assert [i.alive for i in sim.instances] == list(sim.tel.alive)
    v = sim.tel.version
    sim.run(until=3.0)
    assert sim.tel.alive.all()
    assert sim.tel.version > v                     # revive bumps version
    # recovered rows are clean slates
    for i in sim.instances:
        assert sim.tel.free[i.slot] == i.tier.max_batch
        assert sim.tel.batch[i.slot] == 0


def test_schedule_never_kills_whole_fleet(small_ctx):
    sim = _sim(small_ctx)
    apply_schedule(sim, (FailureEvent(t=1.0, kind="fail", frac=1.0),),
                   seed=0)
    sim.run(until=2.0)
    assert sim.tel.alive.sum() == 1


def test_schedule_explicit_instances_and_straggle(small_ctx):
    sim = _sim(small_ctx)
    iid = sim.instances[0].iid
    apply_schedule(sim, (FailureEvent(t=1.0, kind="straggle", factor=5.0,
                                      instances=(iid,)),), seed=0)
    sim.run(until=2.0)
    assert sim.by_id[iid].slowdown == 5.0
    assert all(i.slowdown == 1.0 for i in sim.instances[1:])


def test_straggler_slows_served_requests(small_ctx):
    """A hidden slowdown must lengthen wall-clock service time without
    touching what telemetry reports about capacity."""
    from repro.serving.request import Request
    times = {}
    for factor in (1.0, 6.0):
        sim = _sim(small_ctx)
        inst = sim.instances[0]
        inst.set_slowdown(factor)
        prompts, Q, L = small_ctx["ds"].split("test")
        r = Request(rid=0, prompt=prompts[0], arrival=0.0,
                    true_quality=Q[0], true_length=L[0])
        inst.submit(r, 0.0, float(L[0][inst.model_idx]), None)
        sim.run()
        times[factor] = r.finish_time
        assert sim.tel.max_batch[inst.slot] == inst.tier.max_batch
    assert times[6.0] > 3 * times[1.0]


def test_recover_does_not_double_iterate(small_ctx):
    """Fail->recover within one decode iteration must not spawn a second
    concurrent iteration chain: a pre-failure _iterate event can still
    be pending in the heap when recover() runs, and double-chaining
    would serve requests at exactly 2x real speed."""
    from repro.serving.request import Request
    prompts, Q, L = small_ctx["ds"].split("test")
    times = {}
    for gap in (1e-4, 5.0):           # recover inside vs long after the
        sim = _sim(small_ctx)         # in-flight iteration
        inst = sim.instances[0]
        r0 = Request(rid=0, prompt=prompts[0], arrival=0.0,
                     true_quality=Q[0], true_length=L[0])
        inst.submit(r0, 0.0, float(L[0][inst.model_idx]), None)
        sim.push(0.05, lambda t: inst.fail())
        sim.push(0.05 + gap, lambda t: inst.recover(t))
        r1 = Request(rid=1, prompt=prompts[1], arrival=0.0,
                     true_quality=Q[1], true_length=L[1])
        sim.push(0.05 + gap + 1e-6,
                 lambda t: inst.submit(
                     r1, t, float(L[1][inst.model_idx]), None))
        sim.run()
        times[gap] = r1.finish_time - r1.dispatch_time
    assert times[1e-4] == pytest.approx(times[5.0], rel=0.05)


# -- registry -----------------------------------------------------------------

def test_registry_and_random_scenarios_build():
    assert {"paper", "flashcrowd", "diurnal", "failover", "multitenant",
            "cluster", "hyperscale"} <= set(SCENARIOS)
    with pytest.raises(KeyError):
        get_scenario("does-not-exist")
    hs = get_scenario("hyperscale")
    assert hs.n_tiers == 16 and hs.n_instances == 128
    run = get_scenario("failover").build(dataset_n=120)
    assert run.n_instances == 13
    reqs = run.requests(20, seed=0)
    assert len(reqs) >= 20 and reqs[0].arrival <= reqs[-1].arrival
    for seed in range(20):
        sc = random_scenario(seed, max_tiers=16, max_instances=128)
        assert 2 <= sc.n_tiers <= 16
        assert sc.n_tiers <= sc.n_instances <= 128
        assert sc.tenants and sc.lam > 0
        for ev in sc.schedule:
            assert ev.kind in ("fail", "recover", "straggle")
