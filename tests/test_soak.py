"""Randomized differential soak harness for the fused decision backend.

Random serving worlds from `repro.serving.scenarios.random_scenario`
(rosters up to 16 tiers x 128 instances, composite multi-tenant traces,
scripted failure/recovery/straggler schedules) are fed identically to
the numpy reference loop, the staged jax core, and the fused
single-dispatch program:

  * decision-level: exact fused == jax == numpy assignment parity on
    randomized rosters and telemetry states, on every seed with no
    pinned exclusions (epsilon-quantized tie-break, PR 4 — the floor
    that justified flipping ``RBConfig.decision_backend`` to
    ``"fused"`` and keeping it there through the zero-allocation
    host-path rebuild);
  * serving-level: full `ClusterSim` runs land on identical
    request->instance trajectories and metrics under all three
    backends, including through failure injection;
  * invariant-level: `TelemetryArrays` and the fused dead-reckoned
    device state stay physical under any perturbation schedule
    (free >= 0, batch <= capacity, dead slots never dispatched to,
    version strictly monotonic, columnar view == dict snapshots).

A seeded small-case subset runs in tier-1; the full soak (seeds x
128-instance rosters) is marked `slow` per the pytest.ini convention
and runs in the nightly CI job.
"""
import numpy as np
import pytest

from repro.core import RBConfig, RouteBalance, run_cell
from repro.serving.cluster import ClusterSim, Instance
from repro.serving.scenarios import (random_scenario,
                                     randomize_prefix_state,
                                     randomize_telemetry)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # tier-1 must collect without it
    HAVE_HYPOTHESIS = False

BACKENDS = ("numpy", "jax", "fused")
_RUNS = {}                              # (seed, scale) -> ScenarioRun


def _run_for(seed, max_tiers, max_instances, dataset_n=220):
    key = (seed, max_tiers, max_instances)
    if key not in _RUNS:
        sc = random_scenario(seed, max_tiers=max_tiers,
                             max_instances=max_instances)
        _RUNS[key] = sc.build(dataset_n=dataset_n)
        _RUNS[key].bundle()
    return _RUNS[key]


def _loaded_sim(run, seed, kill_frac=0.0):
    return randomize_telemetry(
        ClusterSim(run.tiers, run.names, seed=0), seed, kill_frac)


def _decision_parity(run, seed, R, kill_frac=0.0, affinity_weight=0.0,
                     backends=BACKENDS):
    reqs = run.requests(R, seed=seed)[:R]
    for r in reqs:
        r.arrival = 0.0
    out = {}
    for be in backends:
        rb = RouteBalance(RBConfig(decision_backend=be,
                                   affinity_weight=affinity_weight),
                          run.bundle(), run.tiers)
        sim = _loaded_sim(run, seed, kill_frac)
        if affinity_weight:
            # warm a random subset of sketches through the live
            # dead-reckoning path (dead instances stay cold)
            randomize_prefix_state(sim, reqs[0].cols, seed)
        rb.sim = sim
        instances, choice, l_chosen = rb._decide_core(reqs)
        dead = {inst.iid for inst in rb.sim.instances if not inst.alive}
        picked = [instances[int(i)].iid for i in choice]
        assert not dead.intersection(picked), (be, dead & set(picked))
        out[be] = (picked, np.asarray(l_chosen, np.float64))
    anchor = "fused" if "fused" in out else backends[0]
    for be in backends:
        assert out[be][0] == out[anchor][0], (be, anchor)
        if be in (anchor, "numpy"):
            continue
        # every float32 backend (jax / fused / megakernel) must agree
        # bitwise; the float64 numpy reference only to tolerance
        np.testing.assert_array_equal(out[be][1], out[anchor][1],
                                      err_msg=f"{be} vs {anchor}")
    if "numpy" in out and anchor != "numpy":
        np.testing.assert_allclose(out[anchor][1], out["numpy"][1],
                                   rtol=2e-4)


# -- decision-level soak ------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_soak_decision_parity_small(seed):
    """Tier-1 subset: random rosters up to 32 instances."""
    run = _run_for(seed, max_tiers=6, max_instances=32)
    _decision_parity(run, seed, R=16)


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(10)))
@pytest.mark.parametrize("kill_frac", [0.0, 0.25])
def test_soak_decision_parity_full(seed, kill_frac):
    """Full soak: rosters up to 16 tiers x 128 instances, with and
    without a quarter of the fleet dead. Exact three-way parity on
    EVERY seed — the epsilon-quantized score tie-break
    (`repro.core.scoring`) collapses float32-vs-float64 argmax
    near-ties, so the grid no longer pins worlds away from same-tier
    replica flips."""
    run = _run_for(seed, max_tiers=16, max_instances=128)
    _decision_parity(run, seed, R=48, kill_frac=kill_frac)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_soak_decision_parity_affinity_small(seed):
    """Tier-1 subset with the prefix-affinity term live: warmed random
    sketches, exact numpy == jax == fused parity including the
    quantized tie-break (the fourth term rides the same float32
    arithmetic and epsilon-quantization as the other three)."""
    run = _run_for(seed, max_tiers=6, max_instances=32)
    _decision_parity(run, seed, R=16, affinity_weight=0.35)


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(10)))
@pytest.mark.parametrize("kill_frac", [0.0, 0.25])
def test_soak_decision_parity_affinity_full(seed, kill_frac):
    """Full affinity soak: 16x128 worlds, warmed sketches, with and
    without a quarter of the fleet dead (killed AFTER warming in some
    orders via randomize_prefix_state's alive check — dead rows must
    never contribute affinity in any backend)."""
    run = _run_for(seed, max_tiers=16, max_instances=128)
    _decision_parity(run, seed, R=48, kill_frac=kill_frac,
                     affinity_weight=0.35)


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(10)))
@pytest.mark.parametrize("kill_frac", [0.0, 0.25])
def test_soak_fused_matches_staged_jax_everywhere(seed, kill_frac):
    """The graduation guarantee behind decision_backend="fused": the
    fused program makes bitwise the staged jax core's assignments on
    EVERY random world — both are float32, so no tie caveat applies and
    no seed is excluded."""
    run = _run_for(seed, max_tiers=16, max_instances=128)
    reqs = run.requests(48, seed=seed)[:48]
    for r in reqs:
        r.arrival = 0.0
    out = {}
    for be in ("jax", "fused"):
        rb = RouteBalance(RBConfig(decision_backend=be),
                          run.bundle(), run.tiers)
        rb.sim = _loaded_sim(run, seed, kill_frac)
        instances, choice, l_chosen = rb._decide_core(reqs)
        out[be] = ([instances[int(i)].iid for i in choice],
                   np.asarray(l_chosen))
    assert out["jax"][0] == out["fused"][0]
    np.testing.assert_array_equal(out["jax"][1], out["fused"][1])


@pytest.mark.parametrize("kill_frac", [0.0, 0.25])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_soak_decision_parity_megakernel_small(seed, kill_frac):
    """Tier-1 subset for the Pallas megakernel backend: exact assignment
    parity with the fused-XLA program (bitwise l_chosen included) and
    the staged references on random rosters, with and without a quarter
    of the fleet dead (alive-mask churn through the one-kernel path)."""
    run = _run_for(seed, max_tiers=6, max_instances=32)
    _decision_parity(run, seed, R=16, kill_frac=kill_frac,
                     backends=("numpy", "fused", "megakernel"))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_soak_decision_parity_megakernel_affinity_small(seed):
    """Megakernel with the prefix-affinity term live: the in-kernel
    integer sig compares + float32 discount must stay bitwise the fused
    program's on warmed random sketches."""
    run = _run_for(seed, max_tiers=6, max_instances=32)
    _decision_parity(run, seed, R=16, affinity_weight=0.35,
                     backends=("numpy", "fused", "megakernel"))


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(10)))
@pytest.mark.parametrize("kill_frac", [0.0, 0.25])
def test_soak_decision_parity_megakernel_full(seed, kill_frac):
    """Full megakernel soak: 16-tier x 128-instance worlds, exact
    four-way parity on every seed — the megakernel traces the SAME
    shared stage math as the fused program (greedy_step, admission_math,
    masked_score, packed GBM), so no tolerance is needed against it."""
    run = _run_for(seed, max_tiers=16, max_instances=128)
    _decision_parity(run, seed, R=48, kill_frac=kill_frac,
                     backends=("numpy", "jax", "fused", "megakernel"))


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(10)))
@pytest.mark.parametrize("kill_frac", [0.0, 0.25])
def test_soak_decision_parity_megakernel_affinity_full(seed, kill_frac):
    run = _run_for(seed, max_tiers=16, max_instances=128)
    _decision_parity(run, seed, R=48, kill_frac=kill_frac,
                     affinity_weight=0.35,
                     backends=("numpy", "fused", "megakernel"))


# -- serving-level soak -------------------------------------------------------

def _trajectory(run, be, reqs_seed, n):
    reqs = run.requests(n, seed=reqs_seed)
    rb = RouteBalance(RBConfig(decision_backend=be, charge_compute=False),
                      run.bundle(), run.tiers)
    m = run.run_cell(rb, reqs, seed=0)
    return [r.instance for r in reqs], m


@pytest.mark.parametrize("seed", [0, 2])
def test_soak_e2e_trajectory_small(seed):
    """A full cluster run through the scenario's own failure schedule
    lands on the identical trajectory under all three backends."""
    run = _run_for(seed, max_tiers=5, max_instances=20)
    results = {be: _trajectory(run, be, seed, n=40) for be in BACKENDS}
    assert results["numpy"][0] == results["fused"][0]
    assert results["jax"][0] == results["fused"][0]
    for k in ("quality", "mean_e2e", "cost_per_req", "goodput"):
        assert results["fused"][1][k] == pytest.approx(
            results["numpy"][1][k], rel=1e-9), k


@pytest.mark.parametrize("seed", [0, 2])
def test_soak_e2e_trajectory_megakernel(seed):
    """A full cluster run under the megakernel backend lands on the
    fused backend's trajectory request-for-request (same failure
    schedule, same metrics)."""
    run = _run_for(seed, max_tiers=5, max_instances=20)
    results = {be: _trajectory(run, be, seed, n=40)
               for be in ("fused", "megakernel")}
    assert results["megakernel"][0] == results["fused"][0]
    for k in ("quality", "mean_e2e", "cost_per_req", "goodput"):
        assert results["megakernel"][1][k] == pytest.approx(
            results["fused"][1][k], rel=1e-12), k


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(4)))
def test_soak_e2e_trajectory_full(seed):
    run = _run_for(seed, max_tiers=16, max_instances=128)
    results = {be: _trajectory(run, be, seed + 10, n=150)
               for be in BACKENDS}
    assert results["numpy"][0] == results["fused"][0]
    assert results["jax"][0] == results["fused"][0]


# -- sharded-parity soak (hierarchical scheduling, PR 10) ---------------------

def _hier_fingerprint(reqs):
    return [(r.rid, r.instance, r.finish_time, r.tokens_out,
             bool(r.failed), bool(r.shed), r.attempt) for r in reqs]


def _cells_trajectory(run, n_cells, reqs_seed, n):
    """One full run under `n_cells` cells with the cell assignment
    pinned — span routing shards the scan of ONE logical controller,
    so placement is independent of the cell count by construction."""
    reqs = run.requests(n, seed=reqs_seed)
    rb = RouteBalance(
        RBConfig(charge_compute=False,
                 shard_cells=0 if n_cells == 1 else n_cells),
        run.bundle(), run.tiers)
    run.run_cell(rb, reqs, seed=0)
    return _hier_fingerprint(reqs)


@pytest.mark.parametrize("seed", [0, 2])
def test_soak_sharded_parity_small(seed):
    """Random scenarios under 1/2/4 cells land on identical per-request
    trajectories when the cell assignment is pinned (span routing: the
    sharded scan is bitwise the single controller), and the balanced
    hierarchy — where placement IS the cell count's decision — stays
    invariant-clean on the same worlds."""
    from repro.serving.hierarchy import HierarchyConfig, build_scheduler
    from repro.serving.metrics import check_terminal_states
    run = _run_for(seed, max_tiers=5, max_instances=20)
    trajs = {C: _cells_trajectory(run, C, seed + 5, n=40)
             for C in (1, 2, 4)}
    assert trajs[1] == trajs[2] == trajs[4]
    for C in (2, 3):
        reqs = run.requests(40, seed=seed + 6)
        sched = build_scheduler(
            RBConfig(charge_compute=False), run.bundle(), run.tiers,
            HierarchyConfig(n_cells=C, routing="balanced"))
        run.run_cell(sched, reqs, seed=0)
        check_terminal_states(reqs)
        assert sched.decisions + sched.shed_count == len(reqs)


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(6)))
def test_soak_sharded_parity_full(seed):
    """Nightly-scale sharded parity: 16-tier x 128-instance random
    worlds, span trajectories identical across 1/2/4 cells through each
    scenario's own failure schedule, balanced runs invariant-clean at
    2/4 cells with every cell taking traffic."""
    from repro.serving.hierarchy import HierarchyConfig, build_scheduler
    from repro.serving.metrics import check_terminal_states
    run = _run_for(seed, max_tiers=16, max_instances=128)
    trajs = {C: _cells_trajectory(run, C, seed + 20, n=120)
             for C in (1, 2, 4)}
    assert trajs[1] == trajs[2] == trajs[4]
    for C in (2, 4):
        reqs = run.requests(120, seed=seed + 21)
        sched = build_scheduler(
            RBConfig(charge_compute=False), run.bundle(), run.tiers,
            HierarchyConfig(n_cells=C, routing="balanced"))
        run.run_cell(sched, reqs, seed=0)
        check_terminal_states(reqs)
        assert sched.decisions + sched.shed_count == len(reqs)
        assert all(sched.balancer.assigned_total[ci] > 0
                   for ci in range(C))


# -- invariant-level ----------------------------------------------------------

def _probe_invariants(sim, log):
    def probe(t):
        tel = sim.tel
        log.append(tel.version)
        assert np.all(tel.free >= 0)
        assert np.all(tel.free <= tel.max_batch)
        assert np.all(tel.batch <= tel.max_batch)
        assert np.all(tel.batch >= 0) and np.all(tel.pending >= 0)
        for inst in sim.instances:
            # the mirror masks quarantined rows (watchdog) as well as
            # dead ones; muted rows go stale by design, so snapshot
            # equality only holds for publishing rows
            assert bool(tel.alive[inst.slot]) == (
                inst.alive and not inst.quarantined)
            if inst.alive and not inst.tel_mute and not inst.quarantined:
                s = inst.snapshot
                assert s["pending_decode"] == tel.pending[inst.slot]
                assert s["batch_size"] == tel.batch[inst.slot]
                assert s["free_slots"] == tel.free[inst.slot]
                assert s["mean_ctx"] == tel.ctx[inst.slot]
                assert s["queue_depth"] == tel.queue[inst.slot]
        if sim._events:
            sim.push(t + 0.2, probe)
    sim.push(0.05, probe)


def _guard_dead_dispatch(monkeypatch):
    orig = Instance.submit

    def guarded(self, req, t, pred_len, max_tokens):
        assert self.alive, f"dispatched to dead instance {self.iid}"
        return orig(self, req, t, pred_len, max_tokens)

    monkeypatch.setattr(Instance, "submit", guarded)


@pytest.mark.parametrize("seed", [1, 3])
def test_telemetry_invariants_under_failures(seed, monkeypatch):
    """Property-check TelemetryArrays + dead-reckoned dispatch under the
    scenario's failure/recovery/straggler schedule: free >= 0, batch <=
    capacity, dead slots never dispatched to, version monotonic, and the
    columnar view always equals the per-instance dict snapshots."""
    _guard_dead_dispatch(monkeypatch)
    run = _run_for(seed, max_tiers=5, max_instances=20)
    reqs = run.requests(50, seed=seed)
    rb = RouteBalance(RBConfig(charge_compute=False), run.bundle(),
                      run.tiers)
    sim = run.sim(seed=0)
    rb.expected = len(reqs)
    rb.attach(sim)
    for r in reqs:
        sim.push(r.arrival, lambda t, rr=r: rb.enqueue(rr, t))
    versions = []
    _probe_invariants(sim, versions)
    sim.run()
    assert versions == sorted(versions)            # monotonic
    assert versions[-1] > versions[0]
    served = [r for r in reqs if r.finish_time is not None
              and not r.failed]
    assert served                                  # the cell made progress


def test_fused_carried_state_stays_physical(monkeypatch):
    """The fused backend's device-resident state must stay physical
    through an entire failure-perturbed run: the carried telemetry
    mirror (delta-synced, never fully re-uploaded in steady state) and
    the post-scan dead-reckoned view must respect d >= 0, free >= 0,
    b <= max_batch incl. the pow2 roster pads. (The mirror reflects the
    telemetry *as of the last sync* — the sim keeps writing telemetry
    after the final batch fires, so end-of-run exact equality is not an
    invariant; ``tests/test_hotpath.py`` asserts mirror == telemetry
    immediately after a sync, and ``tests/test_ingest.py`` asserts the
    delta path's assignment parity per batch.)"""
    _guard_dead_dispatch(monkeypatch)
    run = _run_for(4, max_tiers=6, max_instances=40)
    reqs = run.requests(60, seed=4)
    rb = RouteBalance(RBConfig(decision_backend="fused",
                               charge_compute=False),
                      run.bundle(), run.tiers)
    run.run_cell(rb, reqs, seed=0)
    assert rb._fused is not None
    # the delta path must have been the common case, not dead code
    st = rb._fused.stats
    assert st["delta_sync"] + st["carry"] > st["full_reseed"]
    d, b, free, ctx = (np.asarray(x, np.float64)
                       for x in rb._fused._state)
    maxb = np.asarray(rb._fused._maxb, np.float64)
    assert d.shape == b.shape == free.shape == maxb.shape
    assert len(d) >= run.n_instances               # pow2 roster bucket
    I = run.n_instances
    assert np.all(d >= 0) and np.all(free >= 0) and np.all(ctx >= 0)
    assert np.all(b[:I] <= maxb[:I] + 1e-6)        # mirror stays physical
    d1, b1, f1 = (np.asarray(x, np.float64)
                  for x in rb._fused._post_state)
    assert np.all(d1 >= 0) and np.all(f1 >= 0)
    assert np.all(b1 <= maxb + 1e-6)
    # pad columns accumulate no load (b carries the scan's max(b,1)
    # floor, nothing more)
    pad = slice(run.n_instances, None)
    assert np.all(d1[pad] == 0) and np.all(b1[pad] <= 1.0)


# -- fault-lifecycle soak (retry / hedge / watchdog, PR 7) --------------------

def _random_fault_schedule(seed, n_events=6, horizon=8.0):
    """A seeded random mix of every perturbation kind the lifecycle has
    to survive: crashes, recoveries, stragglers and telemetry
    blackouts. Target draws happen at fire time (apply_schedule), so
    the same tuple composes deterministically with whatever already
    failed."""
    from repro.serving.scenarios import FailureEvent
    rng = np.random.default_rng((seed, 0xC405))
    events = []
    for _ in range(n_events):
        kind = str(rng.choice(("fail", "recover", "straggle",
                               "mute", "unmute")))
        events.append(FailureEvent(
            t=float(rng.uniform(0.5, horizon)), kind=kind,
            frac=float(rng.uniform(0.2, 0.7)),
            factor=float(rng.uniform(2.0, 6.0))))
    return tuple(sorted(events, key=lambda ev: ev.t))


def _fault_cell(run, be, reqs_seed, n, schedule, cfg):
    """One manual cell with the recovery manager armed (run_cell is
    bypassed so the cached ScenarioRun's own schedule/recovery fields
    stay untouched for the other soak tests)."""
    from repro.serving.recovery import arm_recovery
    from repro.serving.scenarios import apply_schedule
    reqs = run.requests(n, seed=reqs_seed)
    rb = RouteBalance(RBConfig(decision_backend=be, charge_compute=False),
                      run.bundle(), run.tiers)
    sim = ClusterSim(run.tiers, run.names, seed=0)
    arm_recovery(sim, cfg)
    rb.expected = len(reqs)
    rb.attach(sim)
    for r in reqs:
        sim.push(r.arrival, lambda t, rr=r: rb.enqueue(rr, t))
    apply_schedule(sim, schedule, seed=reqs_seed)
    sim.run()
    return reqs, sim


def _lifecycle_fingerprint(reqs):
    return [(r.rid, r.instance, r.attempt, r.hedges, r.tokens_out,
             r.failed, r.shed) for r in reqs]


def _assert_exactly_once(reqs, sim, cfg):
    from repro.serving.metrics import check_terminal_states
    check_terminal_states(reqs)                     # no lost requests
    done = [r for r in sim.completed]
    assert len({id(r) for r in done}) == len(done)  # no duplicates
    assert len({r.rid for r in done}) == len(done)
    for r in reqs:                                  # attempt bound
        assert r.attempt < cfg.max_attempts, (r.rid, r.attempt)
        if r.failed:
            assert r.attempt == cfg.max_attempts - 1, \
                "gave up before exhausting attempts"


@pytest.mark.parametrize("seed", [0, 2])
def test_soak_exactly_once_under_random_faults(seed, monkeypatch):
    """Property soak over seeded random fault schedules with the
    recovery manager armed: every request reaches exactly one terminal
    state (served, failed-after-max-attempts, or shed — never lost,
    never duplicated), the retry bound holds, dead instances are never
    dispatched to, and the full lifecycle trajectory — including
    attempt counts and hedges — is identical under all three decision
    backends."""
    from repro.serving.recovery import RecoveryConfig
    _guard_dead_dispatch(monkeypatch)
    run = _run_for(seed, max_tiers=5, max_instances=20)
    cfg = RecoveryConfig()
    schedule = _random_fault_schedule(seed)
    out = {}
    for be in BACKENDS:
        reqs, sim = _fault_cell(run, be, seed, 50, schedule, cfg)
        _assert_exactly_once(reqs, sim, cfg)
        served = [r for r in reqs if r.finish_time is not None
                  and not r.failed]
        assert served                               # progress under churn
        out[be] = (_lifecycle_fingerprint(reqs),
                   [r.finish_time or -1.0 for r in reqs])
    assert out["numpy"][0] == out["jax"][0] == out["fused"][0]
    np.testing.assert_allclose(out["fused"][1], out["numpy"][1],
                               rtol=1e-6)
    np.testing.assert_array_equal(out["jax"][1], out["fused"][1])


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(6)))
def test_soak_exactly_once_under_random_faults_full(seed, monkeypatch):
    """Nightly-scale version: bigger rosters, longer schedules, tighter
    hedge deadlines so the hedge path actually fires across the seed
    sweep."""
    from repro.serving.recovery import RecoveryConfig
    _guard_dead_dispatch(monkeypatch)
    run = _run_for(seed, max_tiers=10, max_instances=64)
    cfg = RecoveryConfig(hedge_factor=2.5, hedge_slack_s=1.0)
    schedule = _random_fault_schedule(seed + 100, n_events=10,
                                      horizon=14.0)
    out = {}
    for be in BACKENDS:
        reqs, sim = _fault_cell(run, be, seed, 120, schedule, cfg)
        _assert_exactly_once(reqs, sim, cfg)
        out[be] = _lifecycle_fingerprint(reqs)
    assert out["numpy"] == out["jax"] == out["fused"]


if HAVE_HYPOTHESIS:
    from repro.serving.scenarios import FailureEvent, apply_schedule
    from repro.serving.world import World, build_dataset
    from repro.serving.request import Request

    _TINY = {}

    def _tiny_world():
        if not _TINY:
            from repro.serving.scenarios import synthetic_pool
            tiers, names, world = synthetic_pool(3, 6, seed=11)
            _TINY["tiers"], _TINY["names"] = tiers, names
            _TINY["ds"] = build_dataset(world, n=120)
        return _TINY

    @settings(max_examples=25, deadline=None, derandomize=True)
    @given(st.integers(0, 10 ** 6))
    def test_hypothesis_scenario_generation_is_wellformed(seed):
        sc = random_scenario(seed, max_tiers=16, max_instances=128)
        assert sc.n_tiers <= sc.n_instances
        run_n = sum(1 for ev in sc.schedule if ev.kind == "recover")
        fails = sum(1 for ev in sc.schedule if ev.kind == "fail")
        assert run_n <= fails or run_n == 0
        assert 0 < sc.lam <= 30.0 + 1e-9       # max_lam is a real bound

    @settings(max_examples=15, deadline=None, derandomize=True)
    @given(st.integers(0, 10 ** 6))
    def test_hypothesis_telemetry_invariants(seed):
        """Random submissions + random fail/recover/straggle schedules
        never drive TelemetryArrays out of its physical envelope."""
        tiny = _tiny_world()
        rng = np.random.default_rng(seed)
        sim = ClusterSim(tiny["tiers"], tiny["names"], seed=0)
        prompts, Q, L = tiny["ds"].split("test")
        for i in range(int(rng.integers(5, 30))):
            j = int(rng.integers(0, len(prompts)))
            inst = sim.instances[int(rng.integers(0,
                                                  len(sim.instances)))]
            r = Request(rid=i, prompt=prompts[j],
                        arrival=float(rng.uniform(0, 3)),
                        true_quality=Q[j], true_length=L[j])
            sim.push(r.arrival,
                     lambda t, rr=r, ii=inst: ii.alive and ii.submit(
                         rr, t, float(rr.true_length[ii.model_idx]),
                         None))
        events = []
        for _ in range(int(rng.integers(0, 4))):
            kind = str(rng.choice(("fail", "recover", "straggle")))
            events.append(FailureEvent(
                t=float(rng.uniform(0, 4)), kind=kind,
                frac=float(rng.uniform(0.1, 0.9)),
                factor=float(rng.uniform(1.5, 8.0))))
        apply_schedule(sim, events, seed=seed)
        versions = []
        _probe_invariants(sim, versions)
        sim.run()
        assert versions == sorted(versions)
        assert np.all(sim.tel.free >= 0)
        assert np.all(sim.tel.batch <= sim.tel.max_batch)

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(st.integers(0, 10 ** 6))
    def test_hypothesis_exactly_once_with_recovery(seed):
        """Hypothesis sweep of the full fault-tolerant lifecycle on a
        tiny world: random fault schedules (incl. telemetry blackouts
        that trip the watchdog) never lose or duplicate a request, and
        the retry bound always holds."""
        from repro.serving.recovery import RecoveryConfig
        run = _run_for(seed % 3, max_tiers=4, max_instances=12)
        cfg = RecoveryConfig()
        schedule = _random_fault_schedule(seed, n_events=5)
        reqs, sim = _fault_cell(run, "fused", seed % 7, 30, schedule,
                                cfg)
        _assert_exactly_once(reqs, sim, cfg)
