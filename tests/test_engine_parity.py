"""Differential parity: every baseline policy run through the shared
`ServingEngine` must reproduce the LEGACY `core/pipeline.py` scheduler
assignment for assignment — same instance, same dispatch/finish times,
same drops — on seeded scenarios. The legacy implementation (dict
telemetry snapshots, per-group encoder forwards, per-request dispatcher
dict scans) is FROZEN HERE as the reference, the same idiom as the
vectorized-BestRoute regression pin in `test_scheduler.py`; the live
`core/pipeline.py` is a deprecation shim onto the engine.

Covers the three station deployments of the §6.3 ladder (serial /
microbatch / concurrent), the bounded-queue drop path (vLLM-SR), the
full router x dispatcher grid, and multi-tenant scenario streams
(fast subset in tier-1, full grid under `-m slow`). Also pins the
shim's DeprecationWarning and the POLICIES registry surface.
"""
import numpy as np
import pytest

from repro.core import (EngineConfig, POLICIES, PipelineConfig,
                        PipelineScheduler, RouteBalance, RBConfig,
                        ServingEngine, make_policy, make_requests,
                        run_cell)
from repro.core.budget import max_tokens_clamp
from repro.core.policies import train_data
from repro.serving.workload import poisson_arrivals


# -- the frozen legacy reference ----------------------------------------------
# Verbatim pre-redesign `core/pipeline.py` + dict-based dispatchers:
# router station -> dispatcher -> instance over per-instance telemetry
# dict snapshots, one encoder forward per scored group.

class _LegacyRR:
    def __init__(self):
        self._n = 0

    def pick(self, candidates, telemetry):
        i = self._n % len(candidates)
        self._n += 1
        return i


class _LegacySQ:
    def pick(self, candidates, telemetry):
        loads = []
        for inst in candidates:
            s = telemetry.get(inst.iid, inst.telemetry())
            loads.append(s["queue_depth"] * 1000 + s["pending_decode"])
        return int(np.argmin(loads))


class _LegacyRandom:
    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def pick(self, candidates, telemetry):
        return int(self.rng.integers(0, len(candidates)))


_LEGACY_DISPATCH = {"rr": _LegacyRR, "sq": _LegacySQ,
                    "random": _LegacyRandom}


class _LegacyPipelineScheduler:
    def __init__(self, router, dispatcher, bundle, tiers,
                 deployment="serial", n_workers=32, microbatch_size=64,
                 microbatch_time=1.72, queue_capacity=None,
                 budget_clamp=True):
        self.router = router
        self.dispatcher = dispatcher
        self.bundle = bundle
        self.deployment = deployment
        self.microbatch_size = microbatch_size
        self.microbatch_time = microbatch_time
        self.queue_capacity = queue_capacity
        self.budget_clamp = budget_clamp
        self.sim = None
        self.queue = []
        self.busy_servers = 0
        self.n_servers = (1 if deployment in ("serial", "microbatch")
                          else n_workers)

    def attach(self, sim):
        self.sim = sim

    def enqueue(self, req, t):
        cap = self.queue_capacity
        if cap is not None and len(self.queue) >= cap:
            req.failed = True
            req.finish_time = t   # terminal-state invariant (metrics)
            self.sim.completed.append(req)
            return
        self.queue.append(req)
        self._drain(t)

    def _service_time(self, n):
        if self.deployment == "microbatch":
            return self.microbatch_time
        return self.router.serial_scoring_s

    def _drain(self, t):
        while self.queue and self.busy_servers < self.n_servers:
            if self.deployment == "microbatch":
                n = min(len(self.queue), self.microbatch_size)
            elif self.deployment == "concurrent":
                n = min(len(self.queue),
                        max(1, len(self.queue) // self.n_servers))
                n = min(n, 8)
            else:
                n = 1
            group = self.queue[:n]
            self.queue = self.queue[n:]
            self.busy_servers += 1
            dt = self._service_time(n)
            self.sim.push(t + dt, lambda tt, g=group: self._scored(g, tt))

    def _scored(self, group, t):
        from repro.estimators.embedding import pad_tokens
        self.busy_servers -= 1
        toks = pad_tokens([r.prompt.tokens for r in group],
                          self.bundle.encoder.max_len)
        lens = np.array([min(len(r.prompt.tokens),
                             self.bundle.encoder.max_len)
                         for r in group])
        emb = self.bundle.encoder.encode(toks, lens)
        models = self.router.route(emb)
        _, L = self.bundle.knn.query(emb)
        tel = self.sim.telemetry()
        for j, req in enumerate(group):
            req.router_queue_wait = t - req.arrival
            m = int(models[j])
            cands = [i for i in self.sim.alive_instances()
                     if m < 0 or i.model_idx == m]
            if not cands:
                cands = self.sim.alive_instances()
            pick = self.dispatcher.pick(cands, tel)
            inst = cands[pick]
            pred = float(L[j, inst.model_idx])
            mt = None
            if self.budget_clamp:
                mt = max_tokens_clamp(req.budget, req.prompt.len_in,
                                      inst.tier.price_in,
                                      inst.tier.price_out)
            inst.submit(req, t, pred, mt)
        self._drain(t)


# -- harness ------------------------------------------------------------------

ROUTER_KW = {"avengers": dict(p_w=0.8, n_clusters=16),
             "bestroute": dict(threshold=0.5),
             "passthrough": {}}


def _legacy_router(name, ctx):
    from repro.core.routers import AvengersProRouter, BestRouteRouter, \
        PassthroughRouter
    cls = {"avengers": AvengersProRouter, "bestroute": BestRouteRouter,
           "passthrough": PassthroughRouter}[name]
    r = cls(**ROUTER_KW[name])
    return r.fit(*_train(ctx))


_TRAIN_CACHE = {}


def _train(ctx):
    key = id(ctx["bundle"])
    if key not in _TRAIN_CACHE:
        _TRAIN_CACHE[key] = train_data(ctx["bundle"], ctx["ds"],
                                       ctx["tiers"], ctx["names"])
    return _TRAIN_CACHE[key]


def _trajectory(reqs):
    return [(r.rid, r.instance, r.model_idx, r.dispatch_time,
             r.finish_time, r.tokens_out, bool(r.failed),
             round(r.router_queue_wait, 12)) for r in reqs]


def _run_pair(ctx, rname, dname, deployment, lam=16.0, n=80, seed=0,
              queue_capacity=None, serial_scoring_s=None):
    """Run the same seeded stream through the frozen legacy scheduler
    and the engine-backed policy; return both trajectories."""
    out = []
    for which in ("legacy", "engine"):
        reqs = make_requests(ctx["ds"], "test",
                             poisson_arrivals(lam, n, seed=seed))
        if which == "legacy":
            router = _legacy_router(rname, ctx)
            if serial_scoring_s is not None:
                router.serial_scoring_s = serial_scoring_s
            sched = _LegacyPipelineScheduler(
                router, _LEGACY_DISPATCH[dname](), ctx["bundle"],
                ctx["tiers"],
                deployment={"serial_published": "serial"}.get(
                    deployment, deployment),
                queue_capacity=queue_capacity)
        else:
            policy = make_policy(f"{rname}-{dname}",
                                 **ROUTER_KW[rname]).fit(*_train(ctx))
            if serial_scoring_s is not None:
                policy.router.serial_scoring_s = serial_scoring_s
            sched = ServingEngine(
                policy, ctx["bundle"], ctx["tiers"],
                EngineConfig(deployment=deployment,
                             queue_capacity=queue_capacity))
        run_cell(sched, ctx["tiers"], ctx["names"], reqs, seed=0)
        out.append(_trajectory(reqs))
    return out


# -- tier-1 subset ------------------------------------------------------------

@pytest.mark.parametrize("rname,dname,deployment", [
    ("bestroute", "sq", "serial_published"),
    ("bestroute", "rr", "microbatch"),
    ("avengers", "sq", "concurrent"),
    ("passthrough", "random", "concurrent"),
    ("passthrough", "rr", "serial_published"),
])
def test_engine_matches_legacy_pipeline(small_ctx, rname, dname,
                                        deployment):
    legacy, engine = _run_pair(small_ctx, rname, dname, deployment)
    assert engine == legacy


def test_engine_matches_legacy_bounded_queue_drops(small_ctx):
    """The vLLM-SR arm: an overloaded bounded queue must drop exactly
    the same requests."""
    legacy, engine = _run_pair(small_ctx, "passthrough", "rr",
                               "serial_published", lam=20.0, n=100,
                               queue_capacity=8, serial_scoring_s=0.5)
    assert engine == legacy
    assert any(t[6] for t in engine)          # some requests dropped


def test_pipeline_shim_is_engine_and_warns(small_ctx):
    from repro.core.dispatchers import RoundRobin
    from repro.core.routers import BestRouteRouter
    router = BestRouteRouter(threshold=0.5).fit(*_train(small_ctx))
    with pytest.warns(DeprecationWarning):
        sched = PipelineScheduler(router, RoundRobin(),
                                  small_ctx["bundle"], small_ctx["tiers"],
                                  PipelineConfig(deployment="serial"))
    assert isinstance(sched, ServingEngine)
    assert sched.ecfg.deployment == "serial_published"


def _fitted_policy(ctx, name, **kw):
    return make_policy(name, **ROUTER_KW.get(name.rsplit("-", 1)[0], {}),
                       **kw).fit(*_train(ctx))


def test_policies_registry_covers_grid_and_routebalance():
    """Every router x dispatcher combination plus RouteBalance resolves
    through the registry to a SchedulingPolicy."""
    from repro.core import RouteBalancePolicy, SchedulingPolicy
    from repro.core.policies import RouterDispatchPolicy
    expect = {f"{r}-{d}" for r in ("avengers", "bestroute", "passthrough")
              for d in ("rr", "sq", "random")} | {"routebalance"}
    assert expect <= set(POLICIES)
    rb = make_policy("routebalance", weights=(0.5, 0.3, 0.2))
    assert isinstance(rb, RouteBalancePolicy)
    for name in expect - {"routebalance"}:
        p = make_policy(name)
        assert isinstance(p, RouterDispatchPolicy), name
        assert isinstance(p, SchedulingPolicy)
        assert p.name.split("-")[-1] == name.split("-")[-1]


def test_routebalance_engine_overrides_reach_registry_engines(small_ctx):
    """RBConfig's batch-formation knobs must bind wherever the policy
    is mounted — a registry-built ServingEngine, not just the
    RouteBalance convenience class (regression: they were silently
    dropped on the registry path)."""
    policy = make_policy("routebalance", fixed_batch=8, adaptive=False,
                         base_window=0.05, charge_compute=False)
    eng = ServingEngine(policy, small_ctx["bundle"], small_ctx["tiers"],
                        EngineConfig(deployment="windowed"))
    assert eng.ecfg.fixed_batch == 8
    assert eng.ecfg.adaptive is False
    assert eng.ecfg.base_window == 0.05
    assert eng.ecfg.charge_compute is False
    # and the two construction paths agree end to end
    def cell(sched):
        reqs = make_requests(small_ctx["ds"], "test",
                             poisson_arrivals(12.0, 40, seed=4))
        run_cell(sched, small_ctx["tiers"], small_ctx["names"], reqs,
                 seed=0)
        return _trajectory(reqs)
    via_registry = cell(ServingEngine(
        make_policy("routebalance", fixed_batch=8, adaptive=False,
                    charge_compute=False),
        small_ctx["bundle"], small_ctx["tiers"], EngineConfig()))
    via_class = cell(RouteBalance(
        RBConfig(fixed_batch=8, adaptive=False, charge_compute=False),
        small_ctx["bundle"], small_ctx["tiers"]))
    assert via_registry == via_class


def test_routebalance_is_engine_backed(small_ctx):
    """RouteBalance is the windowed deployment of RouteBalancePolicy on
    the same engine the baselines use."""
    rb = RouteBalance(RBConfig(), small_ctx["bundle"], small_ctx["tiers"])
    assert isinstance(rb, ServingEngine)
    assert rb.ecfg.deployment == "windowed"
    assert rb.policy.name == "routebalance"


def test_baseline_policy_runs_windowed(small_ctx):
    """Deployment is policy-orthogonal: a decoupled baseline runs under
    the windowed (amortized batch scoring) deployment too."""
    eng = ServingEngine(_fitted_policy(small_ctx, "bestroute-sq"),
                        small_ctx["bundle"], small_ctx["tiers"],
                        EngineConfig(deployment="windowed"))
    reqs = make_requests(small_ctx["ds"], "test",
                         poisson_arrivals(12.0, 60, seed=1))
    m = run_cell(eng, small_ctx["tiers"], small_ctx["names"], reqs)
    assert m["n"] == 60 and m["failed"] == 0
    assert m["policy"] == "best-route-sq"
    assert m["deployment"] == "windowed"
    # windowed deployments charge the batch-formation residuals, not
    # the router station queue
    assert m["residual_router_queue"] == 0.0
    assert m["residual_batch_wait"] > 0.0


# -- slow grid ----------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("deployment", ["serial_published", "microbatch",
                                        "concurrent"])
def test_engine_matches_legacy_full_grid(small_ctx, deployment):
    for rname in ("avengers", "bestroute", "passthrough"):
        for dname in ("rr", "sq", "random"):
            legacy, engine = _run_pair(small_ctx, rname, dname,
                                       deployment, lam=14.0, n=120,
                                       seed=3)
            assert engine == legacy, (rname, dname, deployment)


@pytest.mark.slow
def test_engine_matches_legacy_on_scenario_stream(small_ctx):
    """Multi-tenant composite traces (tenant-stamped, budget-mixed)
    through both paths."""
    from repro.serving.scenarios import get_scenario
    run = get_scenario("multitenant").build(dataset_n=400)
    bundle = run.bundle()
    tdata = run.train_data()
    for which in ("legacy", "engine"):
        reqs = run.requests(150, seed=5)
        if which == "legacy":
            from repro.core.routers import BestRouteRouter
            sched = _LegacyPipelineScheduler(
                BestRouteRouter(threshold=0.5).fit(*tdata),
                _LegacySQ(), bundle, run.tiers, deployment="concurrent")
        else:
            sched = run.engine(run.policy("bestroute-sq", threshold=0.5),
                               deployment="concurrent")
        m = run_cell(sched, run.tiers, run.names, reqs, seed=0)
        if which == "legacy":
            legacy = _trajectory(reqs)
        else:
            engine = _trajectory(reqs)
            assert set(m["tenants"]) == {t.name
                                         for t in run.scenario.tenants}
    assert engine == legacy
