"""Zero-allocation host path: SoA ingest, staging-buffer reuse,
incremental telemetry deltas, and async double-buffered dispatch.

Four contracts from the host-path rebuild (PR 4):

  * **ingest** — `RequestColumns` mirrors the AoS request fields
    exactly (dtypes included), the memoized per-prompt embedding column
    is bitwise the per-batch encode it replaces, and `Request.budget`
    writes through to its column so post-ingest edits stay coherent;
  * **staging reuse** — the per-pow2(R)-bucket host staging buffers are
    double buffered: dispatching batch B must not corrupt batch A's
    still-unfetched `LazyDecision`, across same-bucket and
    cross-bucket sequences;
  * **delta telemetry** — `FusedHotPath._sync_state`'s dirty-row
    scatter must reproduce a from-scratch full reseed (the staged
    backends' reseed-per-batch semantics) assignment-for-assignment,
    with the delta/carry arms the steady-state common case and full
    reseed reserved for roster-shape events and mostly-dirty batches;
  * **async dispatch** — deferring the result fetch to the dispatch
    point changes nothing observable: full cluster runs through an
    explicit fail/straggle/recover `FailureEvent` schedule land on the
    staged backends' exact trajectories.
"""
import numpy as np
import pytest

from repro.core import RBConfig, RouteBalance, make_requests, run_cell
from repro.core.hotpath import FusedHotPath
from repro.serving.cluster import ClusterSim
from repro.serving.request import RequestColumns, batch_columns
from repro.serving.scenarios import FailureEvent, randomize_telemetry
from repro.serving.workload import poisson_arrivals


def _loaded_sim(ctx, seed=9, kill_frac=0.0):
    return randomize_telemetry(
        ClusterSim(ctx["tiers"], ctx["names"], seed=0), seed, kill_frac)


def _batch(ctx, R=16, seed=5, with_budgets=True):
    reqs = make_requests(ctx["ds"], "test", np.zeros(R))
    if with_budgets:
        rng = np.random.default_rng(seed)
        budgets = np.where(rng.uniform(size=R) < 0.5,
                           rng.uniform(1e-5, 3e-4, R), np.nan)
        for r, b in zip(reqs, budgets):
            r.budget = None if np.isnan(b) else float(b)
    return reqs


def _runner(ctx, sim, **cfg_kw):
    """A private FusedHotPath (not the for_bundle cache — tests here
    need two independent runners against one telemetry view)."""
    return FusedHotPath(ctx["bundle"], sim.instances,
                        RBConfig(decision_backend="fused", **cfg_kw))


# -- SoA ingest ---------------------------------------------------------------

def test_request_columns_mirror_aos_fields(small_ctx):
    reqs = _batch(small_ctx, R=24, seed=3)
    cols = reqs[0].cols
    assert cols is not None and cols.n == 24
    for i, r in enumerate(reqs):
        assert r.cols is cols and r.row == i
        assert cols.len_in[i] == r.prompt.len_in
        if r.budget is None:
            assert np.isnan(cols.budget[i])
        else:
            assert cols.budget[i] == r.budget
        p = cols.prompt_row[i]
        n_tok = min(len(r.prompt.tokens), cols.tokens.shape[1])
        assert cols.tok_len[p] == n_tok
        np.testing.assert_array_equal(cols.tokens[p, :n_tok],
                                      r.prompt.tokens[:n_tok])
    # prompt deduplication: the token matrix has one row per unique
    # prompt object, not one per request
    assert len(cols.tokens) == len({id(r.prompt) for r in reqs})


def test_budget_edit_writes_through_to_column(small_ctx):
    reqs = _batch(small_ctx, R=4, with_budgets=False)
    cols = reqs[0].cols
    assert np.isnan(cols.budget[1])
    reqs[1].budget = 2.5e-4
    assert cols.budget[1] == 2.5e-4
    reqs[1].budget = None
    assert np.isnan(cols.budget[1])


def test_batch_columns_rejects_mixed_streams(small_ctx):
    s1 = _batch(small_ctx, R=6, with_budgets=False)
    s2 = _batch(small_ctx, R=6, with_budgets=False)
    cols, rows = batch_columns(s1[:3] + s2[:3])
    assert cols is None and rows is None
    cols, rows = batch_columns(s1[2:5])
    assert cols is s1[0].cols
    np.testing.assert_array_equal(rows, [2, 3, 4])
    assert batch_columns([]) == (None, None)


def test_ingest_embeddings_bitwise_match_batch_encode(small_ctx):
    from repro.estimators.embedding import pad_tokens
    enc = small_ctx["bundle"].encoder
    reqs = _batch(small_ctx, R=24, seed=7, with_budgets=False)
    cols = reqs[0].cols.ensure_embeddings(enc)
    toks = pad_tokens([r.prompt.tokens for r in reqs], enc.max_len)
    lens = np.array([min(len(r.prompt.tokens), enc.max_len)
                     for r in reqs])
    batch_emb = np.asarray(enc.encode(toks, lens))
    np.testing.assert_array_equal(cols.emb[cols.prompt_row], batch_emb)


def test_predict_prompts_gather_matches_encode_path(small_ctx):
    bundle = small_ctx["bundle"]
    reqs = _batch(small_ctx, R=12, with_budgets=False)
    Q1, L1 = bundle.predict_prompts(reqs)          # ingest gather path
    for r in reqs:                                 # strip -> legacy AoS
        r.cols, r.row = None, -1
    Q2, L2 = bundle.predict_prompts(reqs)
    np.testing.assert_array_equal(np.asarray(Q1), np.asarray(Q2))
    np.testing.assert_array_equal(np.asarray(L1), np.asarray(L2))


# -- staging-buffer reuse / async dispatch ------------------------------------

def test_staging_double_buffer_no_alias(small_ctx):
    """Write batch A, dispatch, overwrite the bucket with batch B (and a
    different bucket with C) while A is still in flight: every fetched
    result must equal an independent eager decide. R=13 and R=10 share
    the 16 bucket (forcing the flip); R=5 lands in the 8 bucket."""
    sim = _loaded_sim(small_ctx)
    fp = _runner(small_ctx, sim)
    ref = _runner(small_ctx, sim)
    enc = small_ctx["bundle"].encoder
    batches = [_batch(small_ctx, R=R, seed=R) for R in (13, 10, 5)]
    lazies = []
    for b in batches:                     # dispatch all, fetch nothing
        cols, rows = batch_columns(b)
        cols.ensure_embeddings(enc)
        lazies.append(fp.decide_cols(cols, rows, sim.tel))
    # telemetry never moved: first call reseeds, the rest carry
    assert fp.stats["full_reseed"] == 1 and fp.stats["carry"] == 2
    for b, lz in zip(batches, lazies):
        choice, l_chosen = lz.fetch()
        c_ref, l_ref = ref.decide(b, sim.tel)
        np.testing.assert_array_equal(choice, c_ref)
        np.testing.assert_array_equal(l_chosen, l_ref)
    # fetch is idempotent (diagnostics may re-read)
    again = lazies[0].fetch()
    np.testing.assert_array_equal(again[0], ref.decide(batches[0],
                                                       sim.tel)[0])


def test_async_dispatch_parity_through_failure_schedule(small_ctx):
    """Full cluster runs through an explicit fail -> straggle -> recover
    schedule: the async fused path (lazy fetch at the dispatch point)
    must land on the staged backends' exact trajectories."""
    schedule = (FailureEvent(t=1.0, kind="fail", count=3),
                FailureEvent(t=2.5, kind="straggle", frac=0.25,
                             factor=3.0),
                FailureEvent(t=4.0, kind="recover", count=3))

    def cell(backend):
        reqs = make_requests(small_ctx["ds"], "test",
                             poisson_arrivals(12.0, 60, seed=11))
        rb = RouteBalance(RBConfig(decision_backend=backend,
                                   charge_compute=False),
                          small_ctx["bundle"], small_ctx["tiers"])
        m = run_cell(rb, small_ctx["tiers"], small_ctx["names"], reqs,
                     seed=0, schedule=schedule, schedule_seed=7)
        return [r.instance for r in reqs], m

    traj = {be: cell(be) for be in ("numpy", "jax", "fused")}
    assert traj["fused"][0] == traj["jax"][0] == traj["numpy"][0]
    for k in ("quality", "mean_e2e", "cost_per_req", "goodput"):
        assert traj["fused"][1][k] == pytest.approx(
            traj["numpy"][1][k], rel=1e-9), k


# -- incremental telemetry deltas ---------------------------------------------

def test_delta_scatter_reproduces_full_reseed(small_ctx):
    """After a handful of telemetry writes, the delta arm must make
    exactly the assignments a from-scratch full reseed makes (the
    staged backends' reseed-per-batch contract)."""
    sim = _loaded_sim(small_ctx)
    tel = sim.tel
    fp = _runner(small_ctx, sim)
    fp.decide(_batch(small_ctx, R=16, seed=1), tel)   # seed the mirror
    assert fp.stats["full_reseed"] == 1
    for slot in (0, 3, 7):                            # a few dirty rows
        tel.write(slot, pending=123.0 + slot, batch=4, free=2,
                  ctx=900.0, queue=1, t=1.0)
    b2 = _batch(small_ctx, R=16, seed=2)
    c_delta, l_delta = fp.decide(b2, tel)
    assert fp.stats["delta_sync"] == 1
    assert fp.stats["delta_rows"] == 3
    c_ref, l_ref = _runner(small_ctx, sim).decide(b2, tel)
    np.testing.assert_array_equal(c_delta, c_ref)
    np.testing.assert_array_equal(l_delta, l_ref)


def test_delta_path_matches_staged_backends_per_batch(small_ctx):
    """Chained batches with telemetry churn between them: every fused
    decision off the delta-synced mirror equals the staged numpy/jax
    decision off a fresh host read."""
    sim_f = _loaded_sim(small_ctx)
    rb_f = RouteBalance(RBConfig(decision_backend="fused"),
                        small_ctx["bundle"], small_ctx["tiers"])
    rb_f.sim = sim_f
    staged = {}
    for be in ("numpy", "jax"):
        staged[be] = RouteBalance(RBConfig(decision_backend=be),
                                  small_ctx["bundle"],
                                  small_ctx["tiers"])
        staged[be].sim = _loaded_sim(small_ctx)
    rng = np.random.default_rng(0)
    for step in range(4):
        batch = _batch(small_ctx, R=12, seed=100 + step)
        ids = {}
        for name, rb in [("fused", rb_f)] + list(staged.items()):
            instances, choice, _ = rb._decide_core(batch)
            ids[name] = [instances[int(i)].iid for i in choice]
        assert ids["fused"] == ids["jax"] == ids["numpy"], step
        slots = rng.choice(len(sim_f.instances), 4, replace=False)
        for sim in [sim_f] + [s.sim for s in staged.values()]:
            for slot in slots:                # same writes for every sim
                sim.tel.write(int(slot), pending=float(50 * step + slot),
                              batch=3, free=1, ctx=500.0, queue=0,
                              t=float(step))
    st = rb_f._fused.stats
    assert st["delta_sync"] >= 3              # the common case, not dead code
    assert st["full_reseed"] == 1


def test_roster_event_forces_full_reseed(small_ctx):
    """kill/revive bump `roster_version`; the mirror must full-reseed
    (the alive mask is device-resident) and keep avoiding dead slots."""
    sim = _loaded_sim(small_ctx)
    tel = sim.tel
    fp = _runner(small_ctx, sim)
    fp.decide(_batch(small_ctx, R=16, seed=1), tel)
    dead = sim.instances[2]
    dead.fail()
    assert not tel.alive[dead.slot]
    b2 = _batch(small_ctx, R=16, seed=2)
    choice, _ = fp.decide(b2, tel)
    assert fp.stats["full_reseed"] == 2 and fp.stats["delta_sync"] == 0
    assert dead.slot not in set(int(i) for i in choice)
    dead.recover(t=1.0)
    choice, _ = fp.decide(_batch(small_ctx, R=16, seed=3), tel)
    assert fp.stats["full_reseed"] == 3


def test_mostly_dirty_telemetry_reseeds_outright(small_ctx):
    """When more than half the roster is dirty the scatter would cost
    more than the re-upload — `_sync_state` reseeds instead."""
    sim = _loaded_sim(small_ctx)
    fp = _runner(small_ctx, sim)
    fp.decide(_batch(small_ctx, R=8, seed=1), sim.tel)
    sim.tel.mark_all_dirty()
    b = _batch(small_ctx, R=8, seed=2)
    c, _ = fp.decide(b, sim.tel)
    assert fp.stats["full_reseed"] == 2 and fp.stats["delta_sync"] == 0
    np.testing.assert_array_equal(
        c, _runner(small_ctx, sim).decide(b, sim.tel)[0])


def test_swapped_telemetry_object_forces_reseed(small_ctx):
    """Swapping in a different sim's TelemetryArrays (rb.sim = ... with
    no attach()) must full-reseed even though the new view's counters
    can look 'older' than the mirror's — freshness is keyed to the
    telemetry object's identity."""
    sim1 = _loaded_sim(small_ctx, seed=1)
    sim2 = _loaded_sim(small_ctx, seed=2)
    fp = _runner(small_ctx, sim1)
    b = _batch(small_ctx, R=8, seed=1)
    fp.decide(b, sim1.tel)
    c, _ = fp.decide(b, sim2.tel)             # same shapes, new object
    assert fp.stats["full_reseed"] == 2 and fp.stats["carry"] == 0
    np.testing.assert_array_equal(
        c, _runner(small_ctx, sim2).decide(b, sim2.tel)[0])


def test_reattach_with_queued_requests_falls_back_to_aos(small_ctx):
    """attach() clears the waiting queue's row ring; requests queued
    from before the re-attach have no rows in it, so the scheduler must
    marshal them AoS rather than pair them with the wrong columns."""
    rb = RouteBalance(RBConfig(), small_ctx["bundle"],
                      small_ctx["tiers"])
    rb.attach(_loaded_sim(small_ctx, seed=1))
    reqs = _batch(small_ctx, R=4, with_budgets=False)
    for r in reqs:
        rb.enqueue(r, 0.0)
    assert rb._wait_cols is reqs[0].cols
    rb.attach(_loaded_sim(small_ctx, seed=2))  # waiting is non-empty
    assert rb._wait_cols is False
    instances, choice, _ = rb._decide_core(reqs)   # still decides fine
    assert len(choice) == len(reqs)


def test_ephemeral_columns_do_not_restamp_stream_requests(small_ctx):
    """A mixed batch (stream + columnless requests) reaching the fused
    fallback builds ephemeral columns WITHOUT restamping the stream
    requests — their budget write-through target must stay the stream
    column."""
    stream = _batch(small_ctx, R=6, with_budgets=False)
    scols = stream[0].cols
    loner = _batch(small_ctx, R=1, with_budgets=False)[0]
    loner.cols, loner.row = None, -1
    sim = _loaded_sim(small_ctx)
    fp = _runner(small_ctx, sim)
    mixed = stream[:3] + [loner]
    choice, _ = fp.decide(mixed, sim.tel)
    assert len(choice) == 4
    assert all(r.cols is scols and r.row == i
               for i, r in enumerate(stream))
    stream[1].budget = 3e-4                    # write-through intact
    assert scols.budget[1] == 3e-4


def test_dirty_row_tracking(small_ctx):
    """TelemetryArrays stamps: dirty_rows(since) returns exactly the
    rows written after `since`, and mark_all_dirty stamps everything."""
    sim = ClusterSim(small_ctx["tiers"], small_ctx["names"], seed=0)
    tel = sim.tel
    v0 = tel.version
    assert len(tel.dirty_rows(v0)) == 0
    tel.write(5, pending=1.0, batch=1, free=1, ctx=10.0, queue=0, t=0.1)
    tel.write(2, pending=2.0, batch=1, free=1, ctx=10.0, queue=0, t=0.2)
    np.testing.assert_array_equal(tel.dirty_rows(v0), [2, 5])
    v1 = tel.version
    assert len(tel.dirty_rows(v1)) == 0
    r0 = tel.roster_version
    tel.kill(3)
    assert tel.roster_version == r0 + 1
    tel.revive(3, t=0.5)
    assert tel.roster_version == r0 + 2
    assert 3 in tel.dirty_rows(v1)                 # revive rewrites row 3
    tel.mark_all_dirty()
    assert len(tel.dirty_rows(v1)) == len(tel.alive)
