"""Flash attention vs naive oracle: forward + gradients, causal/window/
cross, block skipping parity, decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attention, flash_attention, \
    repeat_kv


def naive_attention(q, k, v, causal=True, window=0, q_offset=0):
    B, Sq, H, d = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * d ** -0.5
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


CASES = [
    dict(B=2, Sq=64, Sk=64, H=4, d=16, causal=True, window=0, off=0),
    dict(B=1, Sq=64, Sk=64, H=2, d=32, causal=True, window=16, off=0),
    dict(B=2, Sq=32, Sk=96, H=2, d=16, causal=True, window=0, off=64),
    dict(B=2, Sq=48, Sk=80, H=3, d=8, causal=False, window=0, off=0),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("skip", [False, True])
def test_flash_vs_naive_fwd_bwd(case, skip):
    c = dict(case)
    key = jax.random.key(0)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (c["B"], c["Sq"], c["H"], c["d"]))
    k = jax.random.normal(ks[1], (c["B"], c["Sk"], c["H"], c["d"]))
    v = jax.random.normal(ks[2], (c["B"], c["Sk"], c["H"], c["d"]))
    g = jax.random.normal(ks[3], q.shape)

    def f_flash(q, k, v):
        o = flash_attention(q, k, v, causal=c["causal"], window=c["window"],
                            q_offset=c["off"], block_q=16, block_kv=16,
                            skip_masked_blocks=skip)
        return jnp.sum(o * g)

    def f_naive(q, k, v):
        return jnp.sum(naive_attention(q, k, v, c["causal"], c["window"],
                                       c["off"]) * g)

    o1 = flash_attention(q, k, v, causal=c["causal"], window=c["window"],
                         q_offset=c["off"], block_q=16, block_kv=16,
                         skip_masked_blocks=skip)
    o2 = naive_attention(q, k, v, c["causal"], c["window"], c["off"])
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_decode_matches_naive():
    key = jax.random.key(1)
    B, C, K, g, d = 2, 40, 2, 3, 16
    H = K * g
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, d))
    kc = jax.random.normal(ks[1], (B, C, K, d))
    vc = jax.random.normal(ks[2], (B, C, K, d))
    pos = 30
    cpos = jnp.where(jnp.arange(C) <= pos, jnp.arange(C), -1)
    o = decode_attention(q, kc, vc, cpos, pos)
    # naive: take valid prefix, repeat KV heads
    kk = repeat_kv(kc[:, :pos + 1], g)
    vv = repeat_kv(vc[:, :pos + 1], g)
    ref = naive_attention(q, kk, vv, causal=True, q_offset=pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_gqa_repeat():
    x = jnp.arange(2 * 3 * 2 * 4).reshape(2, 3, 2, 4)
    r = repeat_kv(x, 3)
    assert r.shape == (2, 3, 6, 4)
    np.testing.assert_array_equal(np.asarray(r[:, :, 0]),
                                  np.asarray(r[:, :, 2]))
