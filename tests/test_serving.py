"""End-to-end serving integration: conservation, tier loss, baselines."""
import numpy as np
import pytest

from repro.core import (EstimatorBundle, PRESETS, PipelineConfig,
                        PipelineScheduler, RBConfig, RouteBalance,
                        make_requests, run_cell)
from repro.core.dispatchers import RoundRobin, ShortestQueue
from repro.core.routers import BestRouteRouter, PassthroughRouter
from repro.serving.tiers import paper_pool_tiers
from repro.serving.workload import make_arrivals, poisson_arrivals
from repro.serving.world import build_dataset, paper_world


@pytest.fixture(scope="module")
def ctx():
    world, names = paper_world(seed=0)
    ds = build_dataset(world, n=1200)
    tiers = paper_pool_tiers()
    bundle = EstimatorBundle.train(ds, tiers, names)
    return dict(world=world, names=names, ds=ds, tiers=tiers,
                bundle=bundle)


def _reqs(ctx, lam=10.0, n=150, seed=0, budgets=None):
    arr = poisson_arrivals(lam, n, seed=seed)
    return make_requests(ctx["ds"], "test", arr, budgets=budgets)


def test_routebalance_serves_all(ctx):
    reqs = _reqs(ctx)
    rb = RouteBalance(RBConfig(), ctx["bundle"], ctx["tiers"])
    m = run_cell(rb, ctx["tiers"], ctx["names"], reqs)
    assert m["n"] == len(reqs)
    assert m["failed"] == 0
    assert m["mean_e2e"] > 0 and np.isfinite(m["mean_e2e"])
    assert 0 < m["quality"] < 1
    assert m["cost_per_req"] > 0


def test_quality_beats_cost_preset(ctx):
    rq = run_cell(RouteBalance(RBConfig(weights=PRESETS["quality"]),
                               ctx["bundle"], ctx["tiers"]),
                  ctx["tiers"], ctx["names"], _reqs(ctx))
    rc = run_cell(RouteBalance(RBConfig(weights=PRESETS["cost"]),
                               ctx["bundle"], ctx["tiers"]),
                  ctx["tiers"], ctx["names"], _reqs(ctx))
    assert rq["quality"] > rc["quality"]
    assert rq["cost_per_req"] > rc["cost_per_req"]


def test_pipeline_baseline_runs(ctx):
    br = BestRouteRouter(threshold=0.5).fit(
        np.random.default_rng(0).normal(size=(200, 128)).astype(np.float32),
        np.random.default_rng(0).uniform(size=(200, 4)),
        np.random.default_rng(0).uniform(50, 500, (200, 4)),
        np.array([0.06, 0.07, 0.15, 0.40]))
    ps = PipelineScheduler(br, RoundRobin(), ctx["bundle"], ctx["tiers"],
                           PipelineConfig(deployment="concurrent"))
    m = run_cell(ps, ctx["tiers"], ctx["names"], _reqs(ctx, n=100))
    assert m["n"] == 100 and m["failed"] == 0


def test_bounded_queue_drops_under_overload(ctx):
    r = PassthroughRouter()
    r.serial_scoring_s = 0.5   # hopeless serial service at lam=20
    ps = PipelineScheduler(r, RoundRobin(), ctx["bundle"], ctx["tiers"],
                           PipelineConfig(deployment="serial",
                                          queue_capacity=10))
    m = run_cell(ps, ctx["tiers"], ctx["names"], _reqs(ctx, lam=20, n=120))
    assert m["failed"] > 0
    assert m["n"] + m["failed"] == 120


def test_tier_loss_graceful(ctx):
    iids = [f"{t.name}#{j}" for t in ctx["tiers"] if "72b" in t.name
            for j in range(t.n_instances)]
    rb = RouteBalance(RBConfig(weights=PRESETS["quality"]),
                      ctx["bundle"], ctx["tiers"])
    m = run_cell(rb, ctx["tiers"], ctx["names"], _reqs(ctx),
                 fail_at={"time": 0.0, "instances": iids})
    assert m["failed"] == 0                  # capacity event, not availability
    assert not any("72b" in k for k in m["mix"])


def test_budget_clamp_enforced(ctx):
    rng = np.random.default_rng(1)
    n = 120
    budgets = np.full(n, 1.2e-5)
    reqs = _reqs(ctx, n=n, budgets=budgets)
    rb = RouteBalance(RBConfig(), ctx["bundle"], ctx["tiers"])
    m = run_cell(rb, ctx["tiers"], ctx["names"], reqs)
    tier_by_model = {t.model: t for t in ctx["tiers"]}
    for r in reqs:
        t = tier_by_model[ctx["names"][r.model_idx]]
        # the clamp bounds OUTPUT spend by the remaining budget (input
        # cost can alone exceed an impossible budget — the system still
        # serves those on the cheapest tier, §6.2), with a 1-token floor
        out_cost = r.tokens_out * t.price_out / 1e6
        rem = max(r.budget - r.prompt.len_in * t.price_in / 1e6, 0.0)
        assert out_cost <= rem + t.price_out / 1e6 + 1e-12, \
            (out_cost, rem, r.budget)


def test_nonstationary_arrivals_complete(ctx):
    for kind in ("gamma", "square"):
        arr = make_arrivals(kind, 12.0, 100, seed=2)
        reqs = make_requests(ctx["ds"], "test", arr)
        rb = RouteBalance(RBConfig(), ctx["bundle"], ctx["tiers"])
        m = run_cell(rb, ctx["tiers"], ctx["names"], reqs)
        assert m["n"] == 100 and m["failed"] == 0


def test_isolation_arms_run(ctx):
    for mode in ("full", "off_reactive", "off_predictive", "static_prior"):
        rb = RouteBalance(RBConfig(latency_mode=mode), ctx["bundle"],
                          ctx["tiers"])
        m = run_cell(rb, ctx["tiers"], ctx["names"], _reqs(ctx, n=80))
        assert m["n"] == 80 and m["failed"] == 0
