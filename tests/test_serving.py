"""End-to-end serving integration: conservation, tier loss, baselines."""
import numpy as np
import pytest

from repro.core import (EstimatorBundle, PRESETS, PipelineConfig,
                        PipelineScheduler, RBConfig, RouteBalance,
                        make_requests, run_cell)
from repro.core.dispatchers import RoundRobin, ShortestQueue
from repro.core.routers import BestRouteRouter, PassthroughRouter
from repro.serving.tiers import paper_pool_tiers
from repro.serving.workload import make_arrivals, poisson_arrivals
from repro.serving.world import build_dataset, paper_world


@pytest.fixture(scope="module")
def ctx():
    world, names = paper_world(seed=0)
    ds = build_dataset(world, n=1200)
    tiers = paper_pool_tiers()
    bundle = EstimatorBundle.train(ds, tiers, names)
    return dict(world=world, names=names, ds=ds, tiers=tiers,
                bundle=bundle)


def _reqs(ctx, lam=10.0, n=150, seed=0, budgets=None):
    arr = poisson_arrivals(lam, n, seed=seed)
    return make_requests(ctx["ds"], "test", arr, budgets=budgets)


def test_routebalance_serves_all(ctx):
    reqs = _reqs(ctx)
    rb = RouteBalance(RBConfig(), ctx["bundle"], ctx["tiers"])
    m = run_cell(rb, ctx["tiers"], ctx["names"], reqs)
    assert m["n"] == len(reqs)
    assert m["failed"] == 0
    assert m["mean_e2e"] > 0 and np.isfinite(m["mean_e2e"])
    assert 0 < m["quality"] < 1
    assert m["cost_per_req"] > 0


def test_quality_beats_cost_preset(ctx):
    rq = run_cell(RouteBalance(RBConfig(weights=PRESETS["quality"]),
                               ctx["bundle"], ctx["tiers"]),
                  ctx["tiers"], ctx["names"], _reqs(ctx))
    rc = run_cell(RouteBalance(RBConfig(weights=PRESETS["cost"]),
                               ctx["bundle"], ctx["tiers"]),
                  ctx["tiers"], ctx["names"], _reqs(ctx))
    assert rq["quality"] > rc["quality"]
    assert rq["cost_per_req"] > rc["cost_per_req"]


def test_pipeline_baseline_runs(ctx):
    br = BestRouteRouter(threshold=0.5).fit(
        np.random.default_rng(0).normal(size=(200, 128)).astype(np.float32),
        np.random.default_rng(0).uniform(size=(200, 4)),
        np.random.default_rng(0).uniform(50, 500, (200, 4)),
        np.array([0.06, 0.07, 0.15, 0.40]))
    ps = PipelineScheduler(br, RoundRobin(), ctx["bundle"], ctx["tiers"],
                           PipelineConfig(deployment="concurrent"))
    m = run_cell(ps, ctx["tiers"], ctx["names"], _reqs(ctx, n=100))
    assert m["n"] == 100 and m["failed"] == 0


def test_bounded_queue_drops_under_overload(ctx):
    r = PassthroughRouter()
    r.serial_scoring_s = 0.5   # hopeless serial service at lam=20
    ps = PipelineScheduler(r, RoundRobin(), ctx["bundle"], ctx["tiers"],
                           PipelineConfig(deployment="serial",
                                          queue_capacity=10))
    m = run_cell(ps, ctx["tiers"], ctx["names"], _reqs(ctx, lam=20, n=120))
    assert m["failed"] > 0
    assert m["n"] + m["failed"] == 120


def test_tier_loss_graceful(ctx):
    iids = [f"{t.name}#{j}" for t in ctx["tiers"] if "72b" in t.name
            for j in range(t.n_instances)]
    rb = RouteBalance(RBConfig(weights=PRESETS["quality"]),
                      ctx["bundle"], ctx["tiers"])
    m = run_cell(rb, ctx["tiers"], ctx["names"], _reqs(ctx),
                 fail_at={"time": 0.0, "instances": iids})
    assert m["failed"] == 0                  # capacity event, not availability
    assert not any("72b" in k for k in m["mix"])


def test_budget_clamp_enforced(ctx):
    rng = np.random.default_rng(1)
    n = 120
    budgets = np.full(n, 1.2e-5)
    reqs = _reqs(ctx, n=n, budgets=budgets)
    rb = RouteBalance(RBConfig(), ctx["bundle"], ctx["tiers"])
    m = run_cell(rb, ctx["tiers"], ctx["names"], reqs)
    tier_by_model = {t.model: t for t in ctx["tiers"]}
    for r in reqs:
        t = tier_by_model[ctx["names"][r.model_idx]]
        # the clamp bounds OUTPUT spend by the remaining budget (input
        # cost can alone exceed an impossible budget — the system still
        # serves those on the cheapest tier, §6.2), with a 1-token floor
        out_cost = r.tokens_out * t.price_out / 1e6
        rem = max(r.budget - r.prompt.len_in * t.price_in / 1e6, 0.0)
        assert out_cost <= rem + t.price_out / 1e6 + 1e-12, \
            (out_cost, rem, r.budget)


def test_nonstationary_arrivals_complete(ctx):
    for kind in ("gamma", "square"):
        arr = make_arrivals(kind, 12.0, 100, seed=2)
        reqs = make_requests(ctx["ds"], "test", arr)
        rb = RouteBalance(RBConfig(), ctx["bundle"], ctx["tiers"])
        m = run_cell(rb, ctx["tiers"], ctx["names"], reqs)
        assert m["n"] == 100 and m["failed"] == 0


def test_isolation_arms_run(ctx):
    for mode in ("full", "off_reactive", "off_predictive", "static_prior"):
        rb = RouteBalance(RBConfig(latency_mode=mode), ctx["bundle"],
                          ctx["tiers"])
        m = run_cell(rb, ctx["tiers"], ctx["names"], _reqs(ctx, n=80))
        assert m["n"] == 80 and m["failed"] == 0


# -- hot-path edge cases pinned against the overload-control sweep ------------

def _lone_instance(seed=0):
    """One-tier, one-instance sim for driving Instance directly."""
    from repro.serving.cluster import ClusterSim
    from repro.serving.scenarios import synthetic_pool
    tiers, names, _ = synthetic_pool(1, 1, seed=seed)
    sim = ClusterSim(tiers, names, seed=0)
    return sim, sim.instances[0]


def test_zero_token_clamp_is_not_unlimited(ctx):
    """max_tokens=0 is a real (1-token, given the post-increment limit
    check) clamp, not 'unlimited': the falsy `max_tokens or 10**9`
    admission bug ran such requests to their full target length."""
    sim, inst = _lone_instance()
    r = _reqs(ctx, n=1)[0]
    r.true_length = np.full_like(r.true_length, 500.0)
    inst.submit(r, 0.0, pred_len=5.0, max_tokens=0)
    sim.run()
    assert r.tokens_out == 1
    assert r.exhausted and not r.failed
    assert r.finish_time is not None


def test_zero_pred_len_pending_decode_is_one(ctx):
    """pred_len=0.0 must count as ~1 pending decode token in the
    telemetry snapshot — the falsy `pred_len or max_tokens` fallback
    charged it as the full 10**9 dispatch clamp, blinding load_score."""
    sim, inst = _lone_instance()
    r = _reqs(ctx, n=1)[0]
    r.true_length = np.full_like(r.true_length, 500.0)
    inst.submit(r, 0.0, pred_len=0.0, max_tokens=None)
    sim.run(until=0.0)                # first _iterate: admit + 1 token
    assert len(inst.running) == 1     # still decoding
    assert inst.snapshot["pending_decode"] == 1.0


def test_fail_stamps_finish_time_on_queued_and_running(ctx):
    """Instance.fail() stamps the failure instant as finish_time —
    failed requests really leave the system then, and the metrics
    wall-clock fallback / tenant denominators read it."""
    from repro.serving.metrics import aggregate
    sim, inst = _lone_instance()
    reqs = _reqs(ctx, lam=50.0, n=3)
    inst.busy_until = 100.0           # pin admission: all three queue up
    for r in reqs:
        inst.submit(r, r.arrival, pred_len=20.0, max_tokens=None)
    sim.push(2.5, lambda t: inst.fail())
    sim.run(until=3.0)
    assert all(r.failed and r.finish_time == 2.5 for r in reqs)
    m = aggregate(reqs, sim.tiers, sim.model_names, wall=None)
    assert m["failed"] == 3           # wall fallback no longer crashes /
    assert np.isfinite(m["throughput"])  # skews on all-failed cells


def test_admission_queue_is_fifo(ctx):
    """The admission queue is a deque (O(1) pops) and stays strictly
    FIFO: with one decode slot, requests finish in submission order."""
    import collections
    import dataclasses as _dc
    sim, inst = _lone_instance()
    assert isinstance(inst.queue, collections.deque)
    inst.tier = _dc.replace(inst.tier, max_batch=1)
    reqs = _reqs(ctx, lam=100.0, n=4)
    for r in reqs:
        r.true_length = np.full_like(r.true_length, 4.0)
        inst.submit(r, r.arrival, pred_len=4.0, max_tokens=None)
    sim.run()
    finishes = [r.finish_time for r in reqs]
    assert all(f is not None for f in finishes)
    assert finishes == sorted(finishes)
    assert [r.tokens_out for r in reqs] == [4] * 4
