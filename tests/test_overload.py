"""Overload control + elastic roster: detector thresholds/hysteresis,
autoscaler scale-up lag + idle-only scale-down, SLO-aware priority
shedding, and the fused hot path's no-recompile contract under
autoscaler roster churn (with numpy==jax==fused trajectory parity)."""
import numpy as np
import pytest

from repro.core import PRESETS, RBConfig, RouteBalance
from repro.core.decision_jax import bucket_pow2
from repro.serving.cluster import ClusterSim
from repro.serving.overload import (ElasticController, OverloadConfig,
                                    arm_elastic, load_score,
                                    provision_reserve)
from repro.serving.request import Request
from repro.serving.scenarios import get_scenario, synthetic_pool
from repro.serving.world import Prompt


def _mini_sim(n_tiers=2, n_instances=4, seed=0):
    tiers, names, _ = synthetic_pool(n_tiers, n_instances, seed=seed)
    return ClusterSim(tiers, names, seed=0)


def _req(rid=0, priority=0, arrival=0.0):
    prompt = Prompt(pid=rid, topic=0, difficulty=0.5, verbosity=0.5,
                    tokens=np.zeros(4, np.int32), len_in=64)
    return Request(rid=rid, prompt=prompt, arrival=arrival,
                   true_quality=np.full(8, 0.5), true_length=np.full(8, 40.0),
                   priority=priority)


# -- detector -----------------------------------------------------------------

def test_load_score_normalizes_by_alive_capacity():
    sim = _mini_sim()
    tel = sim.tel
    assert load_score(tel) == 0.0
    cap = float(tel.max_batch.sum())
    tel.batch[:] = tel.max_batch            # fleet exactly full
    assert load_score(tel) == pytest.approx(1.0)
    tel.queue[:] = tel.max_batch            # one fleet of backlog behind
    assert load_score(tel) == pytest.approx(2.0)
    # killing a row removes its capacity AND its contribution: the
    # remaining fleet is still exactly full-plus-one-fleet-queued
    sim.instances[0].fail()
    assert load_score(tel) == pytest.approx(2.0)
    for inst in sim.instances:
        inst.alive = False
    tel.alive[:] = False
    assert load_score(tel) == float("inf")


def test_detector_hysteresis_and_cooldown():
    """Scale-up needs `up_patience` consecutive hot checks; a non-hot
    check resets the streak; cooldown gates back-to-back events."""
    sim = _mini_sim(n_tiers=2, n_instances=6)
    reserve = [i.iid for i in sim.instances[-2:]]
    cfg = OverloadConfig(up_threshold=1.25, up_patience=2, cooldown_s=5.0,
                         scale_up_lag_s=0.5, max_step=1,
                         shed_enabled=False)
    ctl = ElasticController(sim, cfg, reserve).arm()
    tel = sim.tel

    def pressure(on):
        tel.queue[:] = tel.max_batch * (3.0 if on else 0.0)

    pressure(True)
    ctl._check(0.25)
    assert ctl._hot == 1 and ctl.scale_ups == 0     # patience not met
    pressure(False)
    ctl._check(0.50)
    assert ctl._hot == 0                            # streak reset
    pressure(True)
    ctl._check(0.75)
    ctl._check(1.00)
    assert ctl.scale_ups == 1                       # 2 consecutive hots
    ctl._check(1.25)
    ctl._check(1.50)
    assert ctl.scale_ups == 1                       # cooldown gates
    ctl._check(7.00)
    ctl._check(7.25)
    assert ctl.scale_ups == 2                       # cooldown expired


# -- autoscaler ---------------------------------------------------------------

def test_scale_up_pays_provisioning_lag():
    """A scale-up decision at t revives the reserve at exactly
    t + scale_up_lag_s (through the ordinary kill/revive path)."""
    sim = _mini_sim(n_tiers=2, n_instances=6)
    reserve = [i.iid for i in sim.instances[-2:]]
    cfg = OverloadConfig(up_patience=1, cooldown_s=0.0,
                         scale_up_lag_s=2.0, max_step=1,
                         shed_enabled=False)
    ctl = ElasticController(sim, cfg, reserve).arm()
    for iid in reserve:
        assert not sim.by_id[iid].alive             # armed cold
    sim.tel.queue[:] = sim.tel.max_batch * 5.0      # sustained pressure
    sim.push(10.0, lambda t: None)                  # keep the loop alive
    sim.run(until=1.0)
    ups = [(t, iid) for t, kind, iid in ctl.events if kind == "scale_up"]
    assert ups and ups[0][0] <= 1.0
    t_up, iid = ups[0]
    assert not sim.by_id[iid].alive                 # still provisioning
    sim.run(until=t_up + 2.0 + 1e-9)
    assert sim.by_id[iid].alive                     # ready after the lag
    ready = [(t, i) for t, kind, i in ctl.events if kind == "ready"]
    assert ready[0] == (pytest.approx(t_up + 2.0), iid)


def test_scale_down_retires_idle_reserves_only():
    sim = _mini_sim(n_tiers=2, n_instances=6)
    r0, r1 = sim.instances[-2], sim.instances[-1]
    cfg = OverloadConfig(shed_enabled=False)
    ctl = ElasticController(sim, cfg, [r0.iid, r1.iid])
    # both reserves alive (not armed cold): r0 has queued work
    r0.queue.append((_req(), 10.0))
    ctl._scale_down(1.0)
    assert r0.alive and not r1.alive                # idle one retired
    assert ctl.scale_downs == 1
    ctl._last_scale = -10.0
    ctl._scale_down(2.0)
    assert r0.alive                                 # busy: never revoked
    assert ctl.scale_downs == 1


# -- shedding -----------------------------------------------------------------

def test_shed_thresholds_are_priority_ordered():
    sim = _mini_sim()
    cfg = OverloadConfig(shed_thresholds=(6.0, 3.0, 1.8))
    ctl = ElasticController(sim, cfg, [])
    ctl.load = 2.0
    assert [ctl.wants_shed(p) for p in (0, 1, 2)] == [False, False, True]
    ctl.load = 4.0
    assert [ctl.wants_shed(p) for p in (0, 1, 2)] == [False, True, True]
    ctl.load = 7.0
    assert [ctl.wants_shed(p) for p in (0, 1, 2, 9)] == [True] * 4
    ctl.load = 7.0
    assert not ElasticController(
        sim, OverloadConfig(shed_enabled=False), []).wants_shed(2)


def test_policy_can_veto_shedding():
    """Shedding is policy-visible: RBConfig(shed=False) admits
    everything even when the controller wants to shed."""
    from repro.core.policies import RouterDispatchPolicy
    from repro.core.routers import PassthroughRouter
    from repro.core.dispatchers import RoundRobin
    from repro.core.scheduler import RouteBalancePolicy
    sim = _mini_sim()
    ctl = ElasticController(sim, OverloadConfig(), [])
    ctl.load = 100.0
    req = _req(priority=2)
    assert RouteBalancePolicy(RBConfig()).shed_verdict(req, ctl)
    assert not RouteBalancePolicy(
        RBConfig(shed=False)).shed_verdict(req, ctl)
    assert RouterDispatchPolicy(
        PassthroughRouter(), RoundRobin()).shed_verdict(req, ctl)
    assert not RouterDispatchPolicy(
        PassthroughRouter(), RoundRobin(), shed=False).shed_verdict(
            req, ctl)


# -- fail/recover edge-case pins (the machinery the autoscaler rides) ---------

def test_kill_does_not_stamp_last_write():
    """TelemetryArrays.kill bumps version + roster_version but NOT the
    row's last_write stamp: incremental readers must reseed via
    roster_version, never via dirty_rows (the fused mirror relies on
    this; pinned so the autoscaler can't regress it)."""
    sim = _mini_sim()
    tel = sim.tel
    inst = sim.instances[1]
    v0, r0 = tel.version, tel.roster_version
    inst.fail()
    assert tel.version > v0                      # write DID happen...
    assert inst.slot not in tel.dirty_rows(v0)   # ...but row not stamped
    assert tel.roster_version == r0 + 1          # reseed signal instead
    inst.recover(1.0)
    assert tel.roster_version == r0 + 2
    assert inst.slot in tel.dirty_rows(v0)       # revive DOES write


def test_recover_keeps_pending_iterate_single_chained():
    """Revive while a pre-failure `_iterate` event is still heap-pending
    must not start a second concurrent decode chain. Iterate events now
    carry the instance's lifecycle epoch (`fail` bumps it, stale events
    no-op on entry), so a revived instance always runs exactly ONE live
    chain — pinned by counting this instance's current-epoch _iterate
    events in the heap after a fail -> recover -> resubmit sequence.
    The stale-event no-op itself is pinned in
    tests/test_recovery.py::test_stale_iterate_epoch."""
    sim = _mini_sim(n_tiers=1, n_instances=1)
    inst = sim.instances[0]
    inst.busy_until = 1.0                        # pin the next iteration
    inst.submit(_req(0), 0.0, 10.0, None)        # _iterate queued @ t=1.0
    assert inst.iter_scheduled

    def live_iterates():
        n = 0
        for _, _, fn in sim._events:             # functools.partial events
            f = getattr(fn, "func", None)
            if (getattr(f, "__self__", None) is inst
                    and getattr(f, "__func__", None)
                    is type(inst)._iterate
                    and fn.keywords.get("epoch") == inst.epoch):
                n += 1
        return n

    assert live_iterates() == 1
    sim.push(0.1, lambda t: inst.fail())
    sim.push(0.2, lambda t: inst.recover(t))
    sim.push(0.3, lambda t: inst.submit(_req(1), t, 10.0, None))
    sim.run(until=0.5)                           # stale event NOT yet fired
    assert inst.alive and inst.iter_scheduled
    assert live_iterates() == 1                  # no second live chain
    sim.run()
    assert live_iterates() == 0
    done = [r for r in sim.completed if not r.failed]
    assert [r.rid for r in done] == [1]          # resubmit served once


# -- roster provisioning ------------------------------------------------------

def test_provision_reserve_expands_in_bucket():
    tiers, names, _ = synthetic_pool(4, 6, seed=5)
    out, reserve = provision_reserve(tiers, 2)
    assert sum(t.n_instances for t in out) == 8
    assert len(reserve) == 2
    assert bucket_pow2(6) == bucket_pow2(8) == 8  # same fused I bucket
    sim = ClusterSim(out, names, seed=0)
    for iid in reserve:
        assert iid in sim.by_id                   # trailing replicas exist
    same, none = provision_reserve(tiers, 0)
    assert [t.n_instances for t in same] == [t.n_instances for t in tiers]
    assert none == ()


# -- end-to-end: elastic scenario on the serving engine ------------------------

@pytest.fixture(scope="module")
def elastic_run():
    run = get_scenario("flashcrowd_elastic").build(dataset_n=300)
    run.bundle()
    return run


def _cell(run, backend, weights=PRESETS["uniform"], n=420, scale=4.0,
          shed=True):
    reqs = run.requests(n, lam_scale=scale, seed=3)
    rb = RouteBalance(RBConfig(weights=weights, decision_backend=backend,
                               charge_compute=False, shed=shed),
                      run.bundle(), run.tiers)
    m = run.run_cell(rb, reqs, seed=0)
    return reqs, rb, m


def _trajectory(reqs):
    return [(r.rid, r.instance, r.model_idx, r.dispatch_time,
             r.finish_time, r.tokens_out, bool(r.failed), bool(r.shed))
            for r in reqs]


def test_elastic_scenario_end_to_end(elastic_run):
    reqs, rb, m = _cell(elastic_run, "fused")
    assert m["scale_ups"] > 0                     # autoscaler fired
    assert m["peak_alive"] > (elastic_run.n_instances
                              - len(elastic_run.reserve_iids))
    assert m["shed"] > 0 and m["shed_rate"] > 0   # overload shed load
    assert m["n"] + m["shed"] + m["failed"] == len(reqs)
    prio = m["priorities"]
    # SLO-aware ordering: premium never sheds before the batch class
    assert prio[0]["shed"] <= prio[2]["shed"]
    assert prio[0]["slo_attainment"] >= prio[2]["slo_attainment"]
    # shed requests never reached an instance
    for r in reqs:
        if r.shed:
            assert r.instance is None and r.finish_time is None


def test_shed_disabled_policy_admits_everything(elastic_run):
    reqs, _, m = _cell(elastic_run, "fused", shed=False)
    assert m["shed"] == 0 and m["n"] + m["failed"] == len(reqs)


def test_elastic_parity_across_backends(elastic_run):
    """numpy == jax == fused full-trajectory parity THROUGH autoscaler
    roster churn: controller decisions are deterministic functions of
    the telemetry trajectory, so identical assignments imply identical
    scale/shed timelines — the differential soak's contract extended to
    the elastic regime."""
    out = {}
    for be in ("numpy", "jax", "fused"):
        reqs, rb, m = _cell(elastic_run, be)
        assert m["scale_ups"] > 0 and m["shed"] > 0
        out[be] = (_trajectory(reqs),
                   (m["scale_ups"], m["scale_downs"], m["shed"]))
    assert out["numpy"] == out["jax"] == out["fused"]


def test_no_recompile_on_autoscale_events(elastic_run):
    """Scale events flip the alive mask and reseed the device mirror
    (roster_reseed > 0) but must add ZERO XLA compiles: one program per
    pow2 R bucket, exactly."""
    # a distinct weight preset gets its own FusedHotPath (the runner is
    # cached on the bundle per config), so the compile count is clean
    reqs, rb, m = _cell(elastic_run, "fused", weights=PRESETS["quality"])
    assert m["scale_ups"] > 0
    st = rb._fused.stats
    assert st["roster_reseed"] > 0                # mask churn resynced
    buckets = {bucket_pow2(s) for s, _ in rb.compute_log}
    assert rb._fused.compile_count() == len(buckets)
