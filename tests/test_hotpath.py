"""Differential harness for the single-dispatch fused hot path.

Decision-level: the fused device program (`repro.core.hotpath`) must
make exactly the staged numpy and staged jax backends' assignments at
fixed seeds across all four ``latency_mode`` arms x budget filter on/off
x LPT on/off. Estimator-level: packed GBM inference is bitwise the numpy
tree-ensemble prediction. Serving-level: the `ClusterSim` array-telemetry
view equals the dict snapshots, and a full cluster run under the fused
backend reproduces the staged trajectories request-for-request.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import PRESETS, RBConfig, RouteBalance, make_requests, \
    run_cell
from repro.core.decision_jax import bucket_pow2
from repro.serving.cluster import ClusterSim
from repro.serving.workload import poisson_arrivals

MODES = ("full", "off_reactive", "off_predictive", "static_prior")


def _loaded_sim(ctx, seed=9):
    """A sim whose telemetry arrays carry mid-run-looking load."""
    from repro.serving.scenarios import randomize_telemetry
    return randomize_telemetry(
        ClusterSim(ctx["tiers"], ctx["names"], seed=0), seed)


def _batch(ctx, R=24, seed=5, with_budgets=True):
    reqs = make_requests(ctx["ds"], "test", np.zeros(R))
    if with_budgets:
        rng = np.random.default_rng(seed)
        budgets = np.where(rng.uniform(size=R) < 0.5,
                           rng.uniform(1e-5, 3e-4, R), np.nan)
        for r, b in zip(reqs, budgets):
            r.budget = None if np.isnan(b) else float(b)
    return reqs


def _choices(ctx, backend, batch, **cfg_kw):
    rb = RouteBalance(RBConfig(decision_backend=backend, **cfg_kw),
                      ctx["bundle"], ctx["tiers"])
    rb.sim = _loaded_sim(ctx)
    instances, choice, l_chosen = rb._decide_core(batch)
    return [instances[int(i)].iid for i in choice], np.asarray(l_chosen)


@pytest.mark.parametrize("lpt", [True, False], ids=["lpt", "fifo"])
@pytest.mark.parametrize("budget_filter", [True, False],
                         ids=["budget", "nobudget"])
@pytest.mark.parametrize("mode", MODES)
def test_fused_exact_assignment_parity(small_ctx, mode, budget_filter,
                                       lpt):
    batch = _batch(small_ctx, with_budgets=budget_filter)
    kw = dict(latency_mode=mode, budget_filter=budget_filter, lpt=lpt)
    ids_np, l_np = _choices(small_ctx, "numpy", batch, **kw)
    ids_jx, l_jx = _choices(small_ctx, "jax", batch, **kw)
    ids_fu, l_fu = _choices(small_ctx, "fused", batch, **kw)
    assert ids_np == ids_jx == ids_fu
    np.testing.assert_allclose(l_fu, l_np, rtol=2e-4)
    np.testing.assert_array_equal(l_fu, l_jx)


def test_fused_batch_bucketing_parity(small_ctx):
    """R is bucketed to powers of two; pad rows must not leak into real
    assignments for any awkward batch size."""
    for R in (1, 3, 7, 13, 33):
        batch = _batch(small_ctx, R=R, seed=R)
        ids_np, _ = _choices(small_ctx, "numpy", batch)
        ids_fu, _ = _choices(small_ctx, "fused", batch)
        assert ids_np == ids_fu, f"R={R}"


def test_fused_carried_state_ignores_pad_rows(small_ctx):
    """R buckets to a power of two; the post-scan dead-reckoned device
    state must reflect only the real requests' dispatches, never the
    shape-padding rows'. The *carried* state (the telemetry mirror) must
    equal the host telemetry exactly — the delta path's reseed-per-batch
    contract."""
    R = 13                                    # buckets to 16 -> 3 pads
    batch = _batch(small_ctx, R=R, with_budgets=False)
    rb = RouteBalance(RBConfig(decision_backend="fused"),
                      small_ctx["bundle"], small_ctx["tiers"])
    rb.sim = _loaded_sim(small_ctx)
    tel = rb.sim.tel
    d0, free0 = tel.pending.sum(), tel.free.sum()
    _, choice, l_chosen = rb._decide_core(batch)
    d1, b1, f1 = (np.asarray(x, np.float64)
                  for x in rb._fused._post_state)
    # pending grew by exactly the real rows' predicted lengths
    np.testing.assert_allclose(d1.sum() - d0, l_chosen.sum(), rtol=1e-5)
    # at most R free slots were consumed
    assert free0 - f1.sum() <= R
    # the carried mirror is the (f32) telemetry, not the post-scan state
    I = len(rb.sim.instances)
    dm, bm, fm, cm = (np.asarray(x)[:I] for x in rb._fused._state)
    np.testing.assert_array_equal(dm, tel.pending.astype(np.float32))
    np.testing.assert_array_equal(fm, tel.free.astype(np.float32))


def test_fused_masks_dead_instances(small_ctx):
    """Failures flip the alive mask — the fused roster never assigns to
    a dead instance and stays in exact parity with the staged path."""
    batch = _batch(small_ctx, R=16)
    dead = None
    rbs = {}
    for be in ("numpy", "fused"):
        rb = RouteBalance(RBConfig(decision_backend=be),
                          small_ctx["bundle"], small_ctx["tiers"])
        rb.sim = _loaded_sim(small_ctx)
        if dead is None:
            dead = [i.iid for i in rb.sim.instances if "72b" in i.iid]
        for iid in dead:
            rb.sim.by_id[iid].fail()
        rbs[be] = rb
    out = {}
    for be, rb in rbs.items():
        instances, choice, _ = rb._decide_core(batch)
        out[be] = [instances[int(i)].iid for i in choice]
    assert out["numpy"] == out["fused"]
    assert not any(iid in dead for iid in out["fused"])


def test_fused_e2e_cluster_trajectory(small_ctx):
    """A full ClusterSim run lands on the identical request->instance
    trajectory (and therefore identical metrics) under all backends."""
    results = {}
    for be in ("numpy", "jax", "fused"):
        arr = poisson_arrivals(10.0, 60, seed=3)
        reqs = make_requests(small_ctx["ds"], "test", arr)
        rb = RouteBalance(RBConfig(decision_backend=be,
                                   charge_compute=False),
                          small_ctx["bundle"], small_ctx["tiers"])
        m = run_cell(rb, small_ctx["tiers"], small_ctx["names"], reqs)
        results[be] = ([r.instance for r in reqs], m)
    assert results["numpy"][0] == results["fused"][0]
    assert results["jax"][0] == results["fused"][0]
    for k in ("quality", "mean_e2e", "cost_per_req"):
        assert results["fused"][1][k] == pytest.approx(
            results["numpy"][1][k], rel=1e-9)


# -- estimator-level ---------------------------------------------------------

def _toy_gbm(seed=0, n_trees=20, depth=3):
    from repro.estimators.gbm import GradientBoostedRegressor
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(300, 4)).astype(np.float32)
    y = (2 * X[:, 0] + np.sin(X[:, 1]) + 0.1 * rng.normal(size=300)
         ).astype(np.float32)
    return GradientBoostedRegressor(n_trees=n_trees, depth=depth).fit(X, y)


def test_predict_packed_bitwise_matches_numpy():
    from repro.estimators.gbm import predict_packed
    g = _toy_gbm()
    Xq = np.random.default_rng(1).normal(size=(64, 4)).astype(np.float32)
    out, leaves = predict_packed(g.pack(), Xq, return_leaves=True)
    np.testing.assert_array_equal(np.asarray(out), g.predict(Xq))
    np.testing.assert_array_equal(np.asarray(leaves), g.leaf_indices(Xq))


def test_pack_ensemble_gathered_matches_members():
    from repro.estimators.gbm import pack_ensemble, predict_packed_gathered
    models = [_toy_gbm(seed=s) for s in range(3)]
    stacked = pack_ensemble(models)
    rng = np.random.default_rng(2)
    Xq = rng.normal(size=(40, 4)).astype(np.float32)
    member = rng.integers(0, 3, 40)
    got = np.asarray(predict_packed_gathered(stacked, member, Xq))
    ref = np.select([member == j for j in range(3)],
                    [m.predict(Xq) for m in models])
    np.testing.assert_array_equal(got, ref.astype(np.float32))


# -- serving-level -----------------------------------------------------------

def test_array_telemetry_matches_dict_snapshots(small_ctx):
    arr = poisson_arrivals(10.0, 50, seed=1)
    reqs = make_requests(small_ctx["ds"], "test", arr)
    rb = RouteBalance(RBConfig(charge_compute=False), small_ctx["bundle"],
                      small_ctx["tiers"])
    sim = ClusterSim(small_ctx["tiers"], small_ctx["names"], seed=0)
    snapshots = []

    def probe(t):
        for inst in sim.instances:
            s = inst.snapshot
            tel = sim.tel
            snapshots.append((
                s["pending_decode"] == tel.pending[inst.slot],
                s["batch_size"] == tel.batch[inst.slot],
                s["free_slots"] == tel.free[inst.slot],
                s["mean_ctx"] == tel.ctx[inst.slot],
                s["queue_depth"] == tel.queue[inst.slot]))
        if sim._events:
            sim.push(t + 0.25, probe)

    rb.expected = len(reqs)
    rb.attach(sim)
    for r in reqs:
        sim.push(r.arrival, lambda t, rr=r: rb.enqueue(rr, t))
    sim.push(0.1, probe)
    sim.run()
    assert snapshots and all(all(row) for row in snapshots)
    assert sim.tel.version > 0
    assert sim.tel.alive.all()


def test_telemetry_kill_marks_dead(small_ctx):
    sim = ClusterSim(small_ctx["tiers"], small_ctx["names"], seed=0)
    v0 = sim.tel.version
    sim.instances[0].fail()
    assert not sim.tel.alive[0] and sim.tel.alive[1:].all()
    assert sim.tel.version == v0 + 1


# -- plumbing ----------------------------------------------------------------

def test_fused_runner_cached_across_sims(small_ctx):
    """Repeated cells over the same bundle/roster/config reuse one
    compiled program (no per-sim recompile); carried state resets."""
    out = []
    for _ in range(2):
        arr = poisson_arrivals(10.0, 30, seed=4)
        reqs = make_requests(small_ctx["ds"], "test", arr)
        rb = RouteBalance(RBConfig(decision_backend="fused",
                                   charge_compute=False),
                          small_ctx["bundle"], small_ctx["tiers"])
        run_cell(rb, small_ctx["tiers"], small_ctx["names"], reqs)
        out.append((rb._fused, [r.instance for r in reqs]))
    assert out[0][0] is out[1][0]          # same compiled runner
    assert out[0][1] == out[1][1]          # identical trajectory


def test_fused_raises_on_dead_roster(small_ctx):
    rb = RouteBalance(RBConfig(decision_backend="fused"),
                      small_ctx["bundle"], small_ctx["tiers"])
    rb.sim = ClusterSim(small_ctx["tiers"], small_ctx["names"], seed=0)
    for inst in rb.sim.instances:
        inst.fail()
    with pytest.raises(RuntimeError, match="no alive instances"):
        rb._decide_core(_batch(small_ctx, R=4))


def test_default_backend_is_fused():
    """The fused single-dispatch program is the production default; the
    staged paths stay selectable under the parity harness."""
    assert RBConfig().decision_backend == "fused"


def test_bucket_pow2():
    assert [bucket_pow2(n) for n in (0, 1, 7, 8, 9, 63, 64, 65)] == \
        [8, 8, 8, 8, 16, 64, 64, 128]


def test_pad_tokens_vectorized_matches_loop():
    from repro.estimators.embedding import pad_tokens
    rng = np.random.default_rng(0)
    lists = [rng.integers(0, 4000, rng.integers(0, 40)).tolist()
             for _ in range(17)]
    lists[3] = []                                  # empty prompt
    lists[5] = rng.integers(0, 4000, 64).tolist()  # overlong
    for max_len in (1, 8, 32):
        ref = np.zeros((len(lists), max_len), np.int32)
        for i, t in enumerate(lists):
            n = min(len(t), max_len)
            ref[i, :n] = t[:n]
        np.testing.assert_array_equal(pad_tokens(lists, max_len), ref)
    assert pad_tokens([], 16).shape == (0, 16)
    assert pad_tokens([[], []], 16).shape == (2, 16)
