"""Hierarchical sharded scheduling (`repro.serving.hierarchy`): roster
partitioning, the cell telemetry mirror's incremental-refresh contract,
span-mode bitwise parity across cell counts, the balanced hierarchy's
1-cell == single-controller trajectory proof, per-cell recovery, and
the GlobalBalancer's digest-staleness routing discipline."""
import dataclasses

import numpy as np
import pytest

from repro.core import RBConfig, RouteBalance
from repro.serving.cluster import ClusterSim
from repro.serving.hierarchy import (GlobalBalancer, HierarchicalScheduler,
                                     HierarchyConfig, _CellTelemetry,
                                     build_scheduler, partition_roster)
from repro.serving.metrics import check_terminal_states
from repro.serving.recovery import RecoveryConfig
from repro.serving.scenarios import get_scenario, randomize_telemetry

_RUNS = {}


def _cluster(recovery=False):
    key = ("cluster", recovery)
    if key not in _RUNS:
        sc = get_scenario("cluster")
        if recovery:
            sc = dataclasses.replace(sc, recovery=RecoveryConfig())
        _RUNS[key] = sc.build(dataset_n=240)
        _RUNS[key].bundle()
    return _RUNS[key]


def _traj(reqs):
    return [(r.rid, r.instance, r.finish_time, r.tokens_out,
             bool(r.failed), bool(r.shed), r.attempt) for r in reqs]


# -- partitioning -------------------------------------------------------------

@pytest.mark.parametrize("n_cells", [1, 2, 3, 4, 7])
def test_partition_roster_properties(n_cells):
    run = _cluster()
    sim = ClusterSim(run.tiers, run.names, seed=0)
    cells = partition_roster(sim.instances, n_cells)
    assert len(cells) == n_cells
    assert all(cells), "every cell must be non-empty"
    seen = [i.iid for cell in cells for i in cell]
    assert sorted(seen) == sorted(i.iid for i in sim.instances)
    assert len(seen) == len(set(seen))           # disjoint
    for cell in cells:
        slots = [i.slot for i in cell]
        assert slots == sorted(slots)            # parent-slot order
    # round-robin within each tier: replica counts per tier differ by
    # at most one across cells
    for tier in {i.tier.name for i in sim.instances}:
        counts = [sum(1 for i in cell if i.tier.name == tier)
                  for cell in cells]
        assert max(counts) - min(counts) <= 1, (tier, counts)


def test_hierarchy_config_validation():
    with pytest.raises(AssertionError):
        HierarchyConfig(routing="nope")
    with pytest.raises(AssertionError):
        HierarchyConfig(n_cells=0)
    with pytest.raises(AssertionError):
        HierarchyConfig(digest_interval_s=0.0)
    with pytest.raises(AssertionError):
        HierarchyConfig(digest_interval_s=1.0, digest_stale_s=0.5)


# -- the cell telemetry mirror ------------------------------------------------

def test_cell_telemetry_mirror_refresh():
    """The mirror copies parent rows bitwise, refreshes only rows whose
    last_write stamp moved, and turns parent kill() (which deliberately
    does NOT stamp last_write) into a local roster_version bump."""
    run = _cluster()
    sim = ClusterSim(run.tiers, run.names, seed=0)
    slots = np.array([i.slot for i in sim.instances[::2]])
    ct = _CellTelemetry(sim.tel, slots)
    for name in ("pending", "batch", "free", "ctx", "queue", "t"):
        np.testing.assert_array_equal(getattr(ct, name),
                                      getattr(sim.tel, name)[slots])
    v0, r0 = ct.version, ct.roster_version
    assert ct.refresh() is ct            # no parent change: no-op
    assert (ct.version, ct.roster_version) == (v0, r0)
    # a write to a mirrored row propagates on refresh, bitwise
    sim.tel.write(int(slots[1]), pending=123.5, batch=3, free=2,
                  ctx=77.0, queue=4, t=1.25)
    ct.refresh()
    assert ct.version > v0
    assert ct.pending[1] == 123.5 and ct.queue[1] == 4
    assert len(ct.dirty_rows(v0)) == 1
    # a write to a row OUTSIDE the cell must not dirty the mirror
    outside = next(i.slot for i in sim.instances
                   if i.slot not in set(slots.tolist()))
    v1 = ct.version
    sim.tel.write(outside, pending=9.0, batch=1, free=1, ctx=1.0,
                  queue=0, t=1.5)
    ct.refresh()
    assert ct.version == v1
    # kill: alive-array comparison catches it, roster_version bumps so
    # the cell's fused runner full-reseeds its alive mask
    sim.tel.kill(int(slots[0]))
    ct.refresh()
    assert ct.roster_version > r0
    assert not ct.alive[0]


# -- span routing: one logical decision, sharded scan -------------------------

@pytest.mark.parametrize("n_cells", [2, 4])
def test_span_parity_across_cell_counts(n_cells):
    """The cell-sharded fused scan is bitwise the single controller on
    randomized mid-run telemetry, dead rows included."""
    run = _cluster()
    reqs = run.requests(64, seed=9)
    for r in reqs:
        r.arrival = 0.0
    plain = RouteBalance(RBConfig(charge_compute=False), run.bundle(),
                         run.tiers)
    span = RouteBalance(RBConfig(charge_compute=False,
                                 shard_cells=n_cells),
                        run.bundle(), run.tiers)
    for trial, kill in ((0, 0.0), (1, 0.25)):
        sim = randomize_telemetry(
            ClusterSim(run.tiers, run.names, seed=0), trial, kill)
        plain.sim = sim
        insts0, c0, l0 = plain._decide_core(reqs[:32])
        span.sim = sim
        insts1, c1, l1 = span._decide_core(reqs[:32])
        assert [insts0[int(i)].iid for i in c0] == \
            [insts1[int(i)].iid for i in c1]
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_make_cell_mesh_falls_back_without_devices():
    import jax

    from repro.launch.mesh import make_cell_mesh
    assert make_cell_mesh(1) is None
    if jax.device_count() < 4:
        assert make_cell_mesh(4) is None
    else:
        mesh = make_cell_mesh(4)
        assert mesh.axis_names == ("cell",)


def test_build_scheduler_span_returns_sharded_engine():
    run = _cluster()
    s = build_scheduler(RBConfig(), run.bundle(), run.tiers,
                        HierarchyConfig(n_cells=4, routing="span"))
    assert isinstance(s, RouteBalance)
    assert s.cfg.shard_cells == 4
    s1 = build_scheduler(RBConfig(), run.bundle(), run.tiers,
                         HierarchyConfig(n_cells=2, routing="balanced"))
    assert isinstance(s1, HierarchicalScheduler)


# -- balanced routing: per-cell engines + global balancer ---------------------

def test_balanced_1cell_trajectory_matches_single_controller():
    """The exact-assignment parity pin: at one cell the hierarchy (cell
    mirror, digest loop, global-expected parking) IS the single fused
    controller — identical per-request trajectories on the same trace,
    through the cluster scenario's failure schedule."""
    run = _cluster()
    cfg = RBConfig(charge_compute=False)
    reqs_a = run.requests(90, seed=0)
    run.run_cell(RouteBalance(cfg, run.bundle(), run.tiers), reqs_a,
                 seed=0)
    reqs_b = run.requests(90, seed=0)
    h1 = build_scheduler(cfg, run.bundle(), run.tiers,
                         HierarchyConfig(n_cells=1, routing="balanced"))
    run.run_cell(h1, reqs_b, seed=0)
    assert _traj(reqs_a) == _traj(reqs_b)


def test_balanced_two_cells_runs_clean():
    run = _cluster()
    sched = build_scheduler(
        RBConfig(charge_compute=False), run.bundle(), run.tiers,
        HierarchyConfig(n_cells=2, routing="balanced"))
    reqs = run.requests(80, seed=1)
    m = run.run_cell(sched, reqs, seed=1)
    check_terminal_states(reqs)
    assert m["failed"] == 0
    assert m["n"] + m["shed"] == len(reqs)
    # driver-contract surfaces
    assert m["policy"] == "routebalance"
    assert m["deployment"] == "windowed"
    assert sched.decisions + sched.shed_count == len(reqs)
    # the control plane actually ran: digests crossed the wire and both
    # cells took traffic
    bal = sched.balancer
    assert bal.digests_sent >= 2 and bal.bytes_sent > 0
    assert all(bal.assigned_total[ci] > 0 for ci in (0, 1))
    assert 0.0 <= bal.imbalance() < 1.0
    # every dispatch stayed inside the chosen cell's roster
    cell_iids = [{i.iid for i in cell} for cell in sched.cells]
    for r in reqs:
        if r.instance is not None:
            assert any(r.instance in iids for iids in cell_iids)


def test_balanced_per_cell_recovery():
    """Failures under balanced routing route to the victim's owning
    cell manager: retries re-enter through the cell's engine, nothing
    is lost, and the parent-facing router sums the counters."""
    run = _cluster(recovery=True)
    sched = build_scheduler(
        RBConfig(charge_compute=False), run.bundle(), run.tiers,
        HierarchyConfig(n_cells=2, routing="balanced"))
    reqs = run.requests(160, seed=1)
    m = run.run_cell(sched, reqs, seed=1)
    check_terminal_states(reqs)
    assert m["failed"] == 0
    assert m["retries"] > 0              # the schedule's kills landed
    mgrs = [cs.recovery for cs in sched.cell_sims]
    assert all(mgr is not None for mgr in mgrs)
    assert sum(mgr.retries for mgr in mgrs) == m["retries"]
    # a retried request re-entered through an engine bound to its cell
    for r in reqs:
        if r.attempt > 0 and r.instance is not None:
            owner = [any(i.iid == r.instance for i in cell)
                     for cell in sched.cells]
            assert sum(owner) == 1


# -- the balancer's staleness discipline --------------------------------------

def _fake_digest(bal, ci, t, depth, free, n_alive=4):
    from repro.distributed.compression import (TelemetryDigest,
                                               decode_digest,
                                               encode_digest)
    d = TelemetryDigest(
        cell=ci, seq=0, t=t, n_alive=n_alive, n_total=4,
        tier_occupancy=np.zeros(2, np.float32),
        tier_depth=np.array([depth, 0], np.float32),
        tier_free=np.array([free, 0], np.float32))
    bal.digests[ci] = decode_digest(encode_digest(d))


def test_balancer_staleness_and_dark_cells():
    """pick() prefers the least-loaded fresh cell, routes around a
    stale (dark) one, and falls back to round-robin only when every
    digest is past the bound."""
    bal = GlobalBalancer(HierarchyConfig(
        n_cells=3, digest_interval_s=0.25, digest_stale_s=1.0))
    for ci in range(3):
        bal.membership.register(f"cell{ci}", "cell", now=0.0)
        bal.assigned_since[ci] = 0
        bal.assigned_total[ci] = 0
        bal.membership.heartbeat(f"cell{ci}", 0.0)
    _fake_digest(bal, 0, t=0.0, depth=50.0, free=2.0)   # busy
    _fake_digest(bal, 1, t=0.0, depth=1.0, free=8.0)    # idle
    _fake_digest(bal, 2, t=0.0, depth=0.0, free=8.0, n_alive=0)
    # cell 1 wins (cell 2's digest says zero alive capacity)
    assert bal.pick(0.1, [0, 1, 2]) == 1
    # dead-reckoned placements pile onto cell 1 until it looks as
    # busy as cell 0 — (depth + assigned + 1)/(free + 1) crosses
    # cell 0's 51/3 once ~152 placements land on cell 1
    picks = [bal.pick(0.1, [0, 1]) for _ in range(200)]
    assert 0 in picks and 1 in picks
    assert picks[0] == 1                 # idle cell absorbed the front
    # past the staleness bound cell 1 goes dark: all traffic to cell 0
    _fake_digest(bal, 0, t=2.0, depth=50.0, free=2.0)
    bal.membership.heartbeat("cell0", 2.0)
    bal.assigned_since[0] = 0
    assert all(bal.pick(2.5, [0, 1]) == 0 for _ in range(5))
    # every cell dark: blind round-robin still serves
    picks = {bal.pick(9.0, [0, 1, 2]) for _ in range(6)}
    assert picks == {0, 1, 2}


def test_balanced_mode_rejects_span_config():
    run = _cluster()
    with pytest.raises(AssertionError):
        HierarchicalScheduler(RBConfig(shard_cells=2), run.bundle(),
                              run.tiers, HierarchyConfig(n_cells=2))
