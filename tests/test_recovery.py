"""Fault-tolerant request lifecycle (`repro.serving.recovery`):
retry/requeue with bounded attempts, hedged re-dispatch on deadline
expiry, the telemetry watchdog's quarantine/release/degraded cycle, the
fused hot path's zero-recompile contract through that churn, and
scheduler checkpoint/restore across a simulated controller crash."""
import dataclasses

import numpy as np
import pytest

from repro.core import (EngineConfig, RBConfig, RouteBalance,
                        ServingEngine, run_cell)
from repro.serving.cluster import ClusterSim
from repro.serving.faults import (CHAOS_SUITES, chaos_world, compose,
                                  correlated_failure, crash_storm,
                                  straggler_storm, telemetry_blackout)
from repro.serving.metrics import check_terminal_states
from repro.serving.recovery import (RecoveryConfig, arm_recovery,
                                    least_loaded_instance,
                                    simulate_controller_crash)
from repro.serving.request import Request
from repro.serving.scenarios import apply_schedule, synthetic_pool
from repro.serving.world import Prompt


def _mini_sim(n_tiers=2, n_instances=4, seed=0):
    tiers, names, _ = synthetic_pool(n_tiers, n_instances, seed=seed)
    return ClusterSim(tiers, names, seed=0)


def _req(rid=0, arrival=0.0):
    prompt = Prompt(pid=rid, topic=0, difficulty=0.5, verbosity=0.5,
                    tokens=np.zeros(4, np.int32), len_in=64)
    return Request(rid=rid, prompt=prompt, arrival=arrival,
                   true_quality=np.full(8, 0.5),
                   true_length=np.full(8, 40.0))


# -- satellite: stale-iterate epoch pin ---------------------------------------

def test_stale_iterate_epoch():
    """A pre-failure `_iterate` event firing after fail->recover is a
    no-op: `fail` bumps the instance's lifecycle epoch and the event
    carries the epoch it was scheduled under. The stale event must not
    touch `iter_scheduled`, generate tokens, or write telemetry — the
    behavioral pin that replaced the old comment in
    `Instance.recover`."""
    sim = _mini_sim(n_tiers=1, n_instances=1)
    inst = sim.instances[0]
    inst.busy_until = 1.0
    inst.submit(_req(0), 0.0, 10.0, None)        # _iterate queued @ t=1.0
    assert inst.epoch == 0 and inst.iter_scheduled
    sim.push(0.1, lambda t: inst.fail())
    sim.push(0.2, lambda t: inst.recover(t))
    sim.run(until=0.5)
    assert inst.epoch == 1
    assert not inst.iter_scheduled               # recover resets the flag
    v = sim.tel.version
    sim.run(until=1.5)                           # the stale event fires
    assert not inst.iter_scheduled               # ...and changed nothing
    assert sim.tel.version == v
    assert inst.running == [] and sim.completed[0].failed


# -- retry/requeue ------------------------------------------------------------

def test_requeue_resets_dispatch_state_keeps_first_arrival():
    r = _req(3, arrival=1.0)
    r.instance, r.model_idx, r.dispatch_time = "x#0", 2, 1.5
    r.pred_len, r.max_tokens, r.tokens_out = 80.0, 100, 17
    r.first_token_time = 1.8
    r.requeue(6.0)
    assert r.attempt == 1 and r.arrival == 6.0
    assert r.first_arrival == 1.0                # e2e keeps the true clock
    assert r.instance is None and r.model_idx is None
    assert r.dispatch_time is None and r.pred_len is None
    assert r.max_tokens is None and r.first_token_time is None
    assert r.tokens_out == 0 and not r.failed
    r.finish_time = 8.0
    assert r.e2e == pytest.approx(7.0)           # charged from t=1.0


def test_backoff_is_deterministic_and_bounded():
    """Backoff delays replay bitwise (counter-based jitter keyed on
    (seed, rid, attempt) — no RNG state) and stay within the configured
    jitter band around the exponential schedule."""
    cfg = RecoveryConfig(max_attempts=4)
    for attempt in (1, 2, 3):
        delays = set()
        for _ in range(3):
            sim = _mini_sim()
            mgr = arm_recovery(sim, cfg)
            r = _req(11)
            r.attempt = attempt - 1
            assert mgr.on_failure(r, sim.instances[0], 5, now=2.0)
            (_, due), = mgr._pending.values()
            delays.add(due)
            nominal = cfg.backoff_base_s * cfg.backoff_mult ** (attempt - 1)
            assert (2.0 + nominal * (1 - cfg.backoff_jitter)
                    <= due <= 2.0 + nominal * (1 + cfg.backoff_jitter))
        assert len(delays) == 1                  # bitwise replay


def test_attempt_bound_gives_up():
    cfg = RecoveryConfig(max_attempts=3)
    sim = _mini_sim()
    mgr = arm_recovery(sim, cfg)
    r = _req(5)
    assert mgr.on_failure(r, sim.instances[0], 0, 0.0)   # attempt 0 -> 1
    assert mgr.on_failure(r, sim.instances[0], 0, 1.0)   # attempt 1 -> 2
    assert not mgr.on_failure(r, sim.instances[0], 0, 2.0)  # exhausted
    assert r.attempt == 2 and mgr.gave_up == 1
    done = _req(6)
    done.finish_time = 1.0
    assert not mgr.on_failure(done, sim.instances[0], 0, 2.0)
    assert done.attempt == 0                     # terminal: untouched


def test_fail_routes_inflight_and_queued_through_recovery():
    """Instance.fail hands BOTH running and queued requests to the
    manager; requeued victims are not terminal, wasted tokens are
    charged for partial decodes."""
    from repro.serving.cluster import _Seq
    sim = _mini_sim(n_tiers=1, n_instances=2)
    mgr = arm_recovery(sim, RecoveryConfig())
    inst = sim.instances[0]
    a, b = _req(0), _req(1)
    a.instance = inst.iid                        # mid-decode in a batch
    inst.running.append(_Seq(req=a, target_tokens=40, max_tokens=10 ** 9,
                             budget_tokens=None, generated=7, ctx=71))
    b.instance = inst.iid
    inst.queue.append((b, 50.0))                 # still waiting to prefill
    inst.fail()
    assert mgr.retries == 2 and not a.failed and not b.failed
    assert a.finish_time is None and b.finish_time is None
    assert a.wasted_tokens == 7 and b.wasted_tokens == 0
    assert a.attempt == 1 and b.attempt == 1
    assert sim.completed == []                   # nothing terminal yet


# -- engine integration -------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_run():
    run = chaos_world().build(dataset_n=300)
    run.bundle()
    return run


def _cell(run, schedule, backend="fused", n=120,
          recovery=RecoveryConfig(), **rb_kw):
    run.recovery = recovery
    reqs = run.requests(n, seed=0)
    rb = RouteBalance(RBConfig(decision_backend=backend,
                               charge_compute=False, **rb_kw),
                      run.bundle(), run.tiers)
    m = run.run_cell(rb, _with_schedule(run, schedule, reqs), seed=0)
    return reqs, rb, m


def _with_schedule(run, schedule, reqs):
    # ScenarioRun.run_cell arms the SCENARIO's schedule; chaos cells
    # swap in a campaign by rebinding the (frozen) scenario
    run.scenario = dataclasses.replace(run.scenario, schedule=schedule)
    return reqs


def test_crash_storm_retries_everything(chaos_run):
    reqs, _, m = _cell(chaos_run, crash_storm(chaos_run.tiers))
    assert m["failed"] == 0 and m["n"] == len(reqs)
    assert m["retries"] > 0 and m["retried"] > 0
    assert m["wasted_tokens"] > 0                # partial decodes charged
    for r in reqs:
        if r.attempt > 0:
            assert r.arrival > r.first_arrival   # requeued later
            assert r.e2e == pytest.approx(r.finish_time - r.first_arrival)


def test_lost_work_without_recovery(chaos_run):
    reqs, _, m = _cell(chaos_run, crash_storm(chaos_run.tiers),
                       recovery=None)
    assert m["failed"] > 0                       # the arm retry beats
    assert "retries" not in m
    check_terminal_states(reqs)                  # failed, not lost


def test_correlated_failure_reroutes_heterogeneously(chaos_run):
    reqs, _, m = _cell(chaos_run,
                       correlated_failure(chaos_run.tiers))
    assert m["failed"] == 0 and m["n"] == len(reqs)
    assert m["retries"] > 0
    # victims moved to a DIFFERENT tier (the victim tier is fully dead)
    victim = max(chaos_run.tiers,
                 key=lambda t: (t.n_instances, t.name)).name
    moved = [r for r in reqs if r.attempt > 0
             and r.instance is not None]
    assert moved and any(not r.instance.startswith(victim)
                         for r in moved)


def test_straggler_storm_hedges(chaos_run):
    reqs, _, m = _cell(chaos_run,
                       straggler_storm(chaos_run.tiers, frac=0.7,
                                       factor=8.0, duration=10.0),
                       recovery=RecoveryConfig(hedge_factor=2.5,
                                               hedge_slack_s=1.0))
    assert m["failed"] == 0 and m["n"] == len(reqs)
    assert m["hedges"] > 0 and m["hedged"] > 0
    assert m["duplicate_tokens"] > 0             # loser's work charged
    hedged = [r for r in reqs if r.hedges > 0]
    assert all(r.finish_time is not None for r in hedged)


def test_watchdog_quarantine_release_zero_recompiles(chaos_run):
    """Partial telemetry blackout: stale rows are quarantined through
    the alive-mask path and released with a reseed when they publish
    again — with ZERO extra XLA recompiles (the same contract the
    autoscaler's roster churn pins). A distinct weight preset gets its
    own FusedHotPath (the runner is cached on the bundle per config),
    so the compile count is clean of the other cells in this module."""
    from repro.core import PRESETS
    reqs, rb, m = _cell(chaos_run,
                        telemetry_blackout(chaos_run.tiers, frac=0.5),
                        weights=PRESETS["quality"])
    assert m["failed"] == 0 and m["n"] == len(reqs)
    assert m["quarantines"] > 0
    from repro.core.decision_jax import bucket_pow2
    buckets = {bucket_pow2(s) for s, _ in rb.compute_log}
    assert rb._fused.compile_count() == len(buckets)


def test_full_blackout_degrades_to_least_loaded(chaos_run):
    reqs, _, m = _cell(chaos_run,
                       telemetry_blackout(chaos_run.tiers, frac=1.0))
    assert m["failed"] == 0 and m["n"] == len(reqs)
    assert m["degraded_decisions"] > 0           # mirror went dark


# -- prefix-affinity under failure / quarantine (serving.affinity) -----------

def _pick_one(run, req, w, mutate=None):
    """The unanimous (numpy == jax == fused) instance pick for a
    single-request decision at affinity weight `w`, on a fresh sim
    optionally perturbed by `mutate`."""
    picks = {}
    for be in ("numpy", "jax", "fused"):
        rb = RouteBalance(RBConfig(decision_backend=be,
                                   affinity_weight=w),
                          run.bundle(), run.tiers)
        sim = ClusterSim(run.tiers, run.names, seed=0)
        if mutate is not None:
            mutate(sim)
        rb.sim = sim
        instances, choice, _ = rb._decide_core([req])
        picks[be] = instances[int(choice[0])].iid
    assert len(set(picks.values())) == 1, picks
    return picks["fused"]


def test_revived_instance_returns_cold(chaos_run):
    """The KV cache dies with the node: after fail() -> recover() the
    instance's sketch AND its scheduler-side mirror row are empty, and
    a re-dispatch of the very prompt it was serving scores a zero hit
    (a retry must never be credited against the cache its failed victim
    lost)."""
    from repro.serving.affinity import prompt_signatures
    sim = ClusterSim(chaos_run.tiers, chaos_run.names, seed=0)
    inst = sim.instances[0]
    p = Prompt(pid=0, topic=0, difficulty=0.5, verbosity=0.5,
               tokens=np.arange(1, 65, dtype=np.int32), len_in=64)
    r1, r2 = (_req(i) for i in (0, 1))
    r1.prompt = r2.prompt = p
    inst.submit(r1, 0.0, 10.0, None)
    assert len(inst.sketch) > 0
    assert sim.tel.prefix_sig[inst.slot].any()
    inst.fail()
    inst.recover(1.0)
    assert len(inst.sketch) == 0                 # revived cold
    assert not sim.tel.prefix_sig[inst.slot].any()
    assert inst.sketch.hit_tokens(prompt_signatures(p), 64) == 0
    inst.submit(r2, 1.1, 10.0, None)
    assert r2.prefix_hit == 0.0                  # the retry pays full prefill


def test_quarantined_row_never_scores_affinity(chaos_run):
    """The watchdog's quarantine masks a row out of the candidate
    roster; its (still-populated) prefix mirror must contribute NOTHING:
    decisions with a quarantined warm row are identical to decisions
    with that row quarantined and cold, in every backend — and the warm
    instance is never picked while masked."""
    from repro.serving.affinity import prompt_signatures
    run = chaos_run
    req = run.requests(4, seed=5)[0]
    req.arrival = 0.0
    sig = prompt_signatures(req.prompt)
    base = _pick_one(run, req, 0.9)
    slot = next(i.slot for i in
                ClusterSim(run.tiers, run.names, seed=0).instances
                if i.iid == base)

    def warm(sim):
        inst = sim.instances[slot]
        inst.sketch.insert(sig)
        sim.tel.write_prefix(slot, inst.sketch)

    def quarantine(sim):
        sim.instances[slot].quarantined = True
        sim.tel.quarantine(slot)

    def warm_quar(sim):
        warm(sim)
        quarantine(sim)

    assert _pick_one(run, req, 0.9, warm) == base    # warm: still best
    q_warm = _pick_one(run, req, 0.9, warm_quar)
    assert q_warm != base                            # masked row unpickable
    # stale prefix credit on a masked row is invisible to the score
    assert q_warm == _pick_one(run, req, 0.9, quarantine)


def test_parity_through_recovery_churn(chaos_run):
    """numpy == jax == fused full-trajectory parity THROUGH retry,
    hedge and quarantine churn: every recovery decision is a
    deterministic function of the simulation trajectory, so the
    differential-soak contract extends to the fault-tolerant
    lifecycle."""
    campaign = compose(crash_storm(chaos_run.tiers, t0=2.0, waves=2),
                       straggler_storm(chaos_run.tiers, t0=6.0),
                       telemetry_blackout(chaos_run.tiers, t0=9.0,
                                          frac=0.5))
    out = {}
    for be in ("numpy", "jax", "fused"):
        reqs, _, m = _cell(chaos_run, campaign, backend=be)
        assert m["failed"] == 0
        out[be] = ([(r.rid, r.instance, r.model_idx, r.dispatch_time,
                     r.finish_time, r.tokens_out, r.attempt, r.hedges)
                    for r in reqs],
                   (m["retries"], m["hedges"], m["quarantines"]))
    assert out["numpy"] == out["jax"] == out["fused"]


def test_retries_are_never_shed():
    """Admission control gates NEW work only: a retry re-entering
    `enqueue` bypasses the shed verdict even under declared
    overload."""
    from repro.serving.overload import OverloadConfig, arm_elastic

    class _Policy:
        budget_clamp = False
        name = "stub"

        def engine_overrides(self):
            return {}

        def prepare(self, bundle, tiers):
            self.bundle = bundle

        def on_attach(self, sim):
            pass

        def shed_verdict(self, req, ctl):
            return True                           # shed EVERYTHING

    tiers, names, _ = synthetic_pool(2, 4, seed=0)
    sim = ClusterSim(tiers, names, seed=0)
    arm_elastic(sim, OverloadConfig())

    class _Bundle:
        encoder = None
    eng = ServingEngine(_Policy(), _Bundle(), tiers, EngineConfig())
    eng.attach(sim)
    fresh, retry = _req(0), _req(1)
    retry.attempt = 1
    eng.enqueue(fresh, 0.0)
    eng.enqueue(retry, 0.0)
    assert fresh.shed and not retry.shed
    assert eng.waiting == [retry]


# -- checkpoint/restore across a controller crash -----------------------------

def _controlled_run(run, reqs, sched, crash_at=None):
    """One windowed cell with recovery armed; optionally crash the
    controller at `crash_at` and resume a FRESH engine from the
    checkpoint taken at the crash instant."""
    cfg = EngineConfig(charge_compute=False)
    rb_cfg = dict(decision_backend="fused", charge_compute=False)
    sim = ClusterSim(run.tiers, run.names, seed=0)
    arm_recovery(sim, RecoveryConfig())
    eng1 = RouteBalance(RBConfig(**rb_cfg), run.bundle(), run.tiers)
    eng1.expected = len(reqs)
    eng1.attach(sim)
    holder = {"eng": eng1}
    for r in reqs:
        sim.push(r.arrival, lambda t, rr=r: holder["eng"].enqueue(rr, t))
    apply_schedule(sim, sched, seed=1)
    if crash_at is not None:
        def crash(t):
            tree = holder["eng"].checkpoint_tree()
            dropped = simulate_controller_crash(sim, holder["eng"])
            assert dropped > 0                   # something actually died
            arm_recovery(sim, RecoveryConfig())
            eng2 = RouteBalance(RBConfig(**rb_cfg), run.bundle(),
                                run.tiers)
            eng2.resume(sim, tree, reqs)
            holder["eng"] = eng2
        sim.push(crash_at, crash)
    sim.run()
    check_terminal_states(reqs)
    return [(r.rid, r.finish_time, r.tokens_out, r.model_idx,
             r.instance, r.failed, r.attempt, r.hedges) for r in reqs]


def test_controller_crash_restore_bitwise_identical(chaos_run):
    """A controller crash + checkpoint restore mid-trace resumes to the
    BITWISE-identical completion set of an uninterrupted run: no lost
    requests, no duplicates, same assignments, same finish times —
    through an active crash-storm campaign, at multiple crash points
    (before, during and after the fault window)."""
    sched = crash_storm(chaos_run.tiers)
    reqs = chaos_run.requests(120, seed=0)
    ref = _controlled_run(chaos_run, reqs, sched)
    for crash_at in (2.0, 5.3, 9.1):
        reqs2 = chaos_run.requests(120, seed=0)
        got = _controlled_run(chaos_run, reqs2, sched, crash_at=crash_at)
        assert got == ref, f"divergence after crash at t={crash_at}"


def test_checkpoint_roundtrip_through_manager(chaos_run, tmp_path):
    """The engine tree survives the on-disk CheckpointManager: save at
    a live instant, restore into a template, resume — the arrays (and
    the completion trajectory) come back exactly."""
    from repro.distributed.checkpoint import CheckpointManager
    sched = crash_storm(chaos_run.tiers)
    reqs = chaos_run.requests(120, seed=0)
    ref = _controlled_run(chaos_run, reqs, sched)

    cfg = dict(decision_backend="fused", charge_compute=False)
    reqs2 = chaos_run.requests(120, seed=0)
    sim = ClusterSim(chaos_run.tiers, chaos_run.names, seed=0)
    arm_recovery(sim, RecoveryConfig())
    eng1 = RouteBalance(RBConfig(**cfg), chaos_run.bundle(),
                        chaos_run.tiers)
    eng1.expected = len(reqs2)
    eng1.attach(sim)
    holder = {"eng": eng1}
    for r in reqs2:
        sim.push(r.arrival, lambda t, rr=r: holder["eng"].enqueue(rr, t))
    apply_schedule(sim, sched, seed=1)
    ckpt = CheckpointManager(tmp_path / "ckpt")

    def crash(t):
        holder["eng"].save_checkpoint(ckpt, step=1)
        simulate_controller_crash(sim, holder["eng"])
        tree, step = ckpt.restore(ServingEngine._checkpoint_template())
        assert step == 1
        arm_recovery(sim, RecoveryConfig())
        eng2 = RouteBalance(RBConfig(**cfg), chaos_run.bundle(),
                            chaos_run.tiers)
        eng2.resume(sim, tree, reqs2)
        holder["eng"] = eng2
    sim.push(5.3, crash)
    sim.run()
    got = [(r.rid, r.finish_time, r.tokens_out, r.model_idx,
            r.instance, r.failed, r.attempt, r.hedges) for r in reqs2]
    assert got == ref


# -- terminal-state invariant -------------------------------------------------

def test_terminal_invariant_catches_lifecycle_bugs():
    lost = _req(0)                               # ingested, then vanished
    with pytest.raises(AssertionError, match="lost"):
        check_terminal_states([lost])
    dual = _req(1)
    dual.failed = dual.shed = True
    with pytest.raises(AssertionError, match="both"):
        check_terminal_states([dual])
    zombie = _req(2)                             # shed but "finished"
    zombie.shed = True
    zombie.finish_time = 3.0
    with pytest.raises(AssertionError, match="shed"):
        check_terminal_states([zombie])
    ghost = _req(3)                              # failed, no timestamp
    ghost.failed = True
    with pytest.raises(AssertionError, match="terminal timestamp"):
        check_terminal_states([ghost])
    ok_served, ok_failed, ok_shed = _req(4), _req(5), _req(6)
    ok_served.finish_time = 1.0
    ok_failed.failed = True
    ok_failed.finish_time = 1.0
    ok_shed.shed = True
    check_terminal_states([ok_served, ok_failed, ok_shed])


# -- degraded fallback details ------------------------------------------------

def test_least_loaded_prefers_unquarantined():
    sim = _mini_sim(n_tiers=1, n_instances=3)
    a, b, c = sim.instances
    a.quarantined = True
    b.queue.append((_req(0), 10.0))              # b is loaded
    pick = least_loaded_instance(sim)
    assert pick is c                             # idle, not quarantined
    pick = least_loaded_instance(sim, exclude=(c.iid,))
    assert pick is b                             # quarantine = last resort
    b.alive = c.alive = False
    assert least_loaded_instance(sim, exclude=(a.iid,)) is None
