"""Distribution layer: sharding rules are always divisible, cache specs
cover every leaf, elastic membership + staleness, telemetry digest
codec (round-trip fidelity + the staleness contract), HLO cost
walker."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, input_specs, smoke_variant
from repro.distributed.compression import (TelemetryDigest,
                                           decode_digest, digest_fresh,
                                           digest_from_telemetry,
                                           encode_digest)
from repro.distributed.elastic import ElasticMembership
from repro.models import Model
from repro.models.config import SHAPES


class _FakeMesh:
    """Mesh stand-in with .shape/.axis_names (no devices needed)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma3-27b",
                                  "mixtral-8x7b", "mamba2-1.3b",
                                  "recurrentgemma-2b", "whisper-tiny"])
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    from repro.launch import sharding as shr
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16} if multi_pod
                     else {"data": 16, "model": 16})
    cfg = ARCHS[arch].replace(vocab_pad_to=256)
    model = Model(cfg)
    specs = shr.param_pspecs(model.param_specs(), mesh, fsdp=True)
    leaves = jax.tree_util.tree_flatten_with_path(
        model.param_specs())[0]
    spec_leaves = jax.tree.leaves(specs,
                                  is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for (path, sds), spec in zip(leaves, spec_leaves):
        for dim, names in enumerate(spec):
            if names is None:
                continue
            names = (names,) if isinstance(names, str) else names
            n = int(np.prod([mesh.shape[a] for a in names]))
            assert sds.shape[dim] % n == 0, \
                (jax.tree_util.keystr(path), sds.shape, spec)


@pytest.mark.parametrize("arch,shape", [
    ("granite-3-2b", "decode_32k"), ("mixtral-8x7b", "long_500k"),
    ("mamba2-1.3b", "long_500k"), ("whisper-tiny", "decode_32k")])
def test_cache_specs_divisible(arch, shape):
    from repro.launch import sharding as shr
    mesh = _FakeMesh({"data": 16, "model": 16})
    cfg = ARCHS[arch].replace(vocab_pad_to=256)
    model = Model(cfg)
    sp = SHAPES[shape]
    cache = model.cache_specs(sp.global_batch, sp.seq_len)
    specs = shr.cache_pspecs(cache, mesh, sp.global_batch)
    for (path, sds), spec in zip(
            jax.tree_util.tree_flatten_with_path(cache)[0],
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, names in enumerate(spec):
            if names is None:
                continue
            names = (names,) if isinstance(names, str) else names
            n = int(np.prod([mesh.shape[a] for a in names]))
            assert sds.shape[dim] % n == 0, \
                (jax.tree_util.keystr(path), sds.shape, spec)


def test_input_specs_all_cells():
    for arch, cfg in ARCHS.items():
        for sname, sp in SHAPES.items():
            specs = input_specs(cfg, sp)
            assert "tokens" in specs
            for v in specs.values():
                assert all(d > 0 for d in v.shape)


def test_elastic_membership_and_straggler():
    em = ElasticMembership(heartbeat_timeout=2.0)
    em.register("a", "t1", now=0.0)
    em.register("b", "t1", now=0.0)
    assert set(em.active(1.0)) == {"a", "b"}
    em.heartbeat("a", 3.0)
    assert set(em.active(3.5)) == {"a"}          # b quarantined
    em.heartbeat("b", 4.0)
    assert set(em.active(4.1)) == {"a", "b"}     # b re-admitted
    # staleness penalty grows with telemetry age
    p0 = em.staleness_penalty("a", 3.0)
    p1 = em.staleness_penalty("a", 4.5)
    assert p1 > p0 >= 1.0


def _digest(seed=0, T=5):
    rng = np.random.default_rng(seed)
    return TelemetryDigest(
        cell=3, seq=17, t=12.625, n_alive=40, n_total=48,
        tier_occupancy=rng.random(T).astype(np.float32),
        tier_depth=(rng.random(T) * 900).astype(np.float32),
        tier_free=np.floor(rng.random(T) * 30).astype(np.float32))


def test_digest_roundtrip_exact_bitwise():
    d = _digest()
    wire = encode_digest(d, mode="exact")
    d2 = decode_digest(wire)
    assert (d2.cell, d2.seq, d2.t) == (d.cell, d.seq, d.t)
    assert (d2.n_alive, d2.n_total) == (d.n_alive, d.n_total)
    for k in ("tier_occupancy", "tier_depth", "tier_free"):
        assert getattr(d, k).tobytes() == getattr(d2, k).tobytes(), k
    # header + 3 raw float32 planes, nothing else on the wire
    assert len(wire) == len(encode_digest(d, "exact"))
    # re-encoding the decoded digest is byte-identical (stable codec)
    assert encode_digest(d2, mode="exact") == wire


def test_digest_int8_lossy_bounded_and_idempotent():
    d = _digest(seed=1, T=8)
    wire = encode_digest(d, mode="int8")
    d2 = decode_digest(wire)
    for k in ("tier_occupancy", "tier_depth", "tier_free"):
        x, xq = getattr(d, k), getattr(d2, k)
        scale = max(float(np.abs(x).max()) / 127.0, 1e-12)
        assert np.max(np.abs(x - xq)) <= scale / 2 + 1e-7, k
    # quantization is a projection: a second trip changes nothing
    assert encode_digest(d2, mode="int8") == wire
    # and the int8 wire is materially smaller than exact
    assert len(wire) < len(encode_digest(d, mode="exact"))


def test_digest_from_telemetry_masks_dead_rows():
    from repro.serving.cluster import ClusterSim
    from repro.serving.scenarios import get_scenario
    run = get_scenario("paper").build(dataset_n=60)
    sim = ClusterSim(run.tiers, run.names, seed=0)
    tier_names = [t.name for t in run.tiers]
    tos = np.array([tier_names.index(i.tier.name)
                    for i in sim.instances])
    d0 = digest_from_telemetry(sim.tel, tos, len(tier_names),
                               cell=0, seq=0, t=0.0)
    assert d0.n_alive == len(sim.instances)
    assert d0.free_total > 0
    # kill a row: its capacity must vanish from the digest
    sim.tel.kill(0)
    d1 = digest_from_telemetry(sim.tel, tos, len(tier_names),
                               cell=0, seq=1, t=0.5)
    assert d1.n_alive == d0.n_alive - 1
    assert d1.free_total < d0.free_total


def test_digest_staleness_contract():
    d = _digest()                       # sent at t=12.625
    assert digest_fresh(d, now=12.625, stale_s=1.0)
    assert digest_fresh(d, now=13.625, stale_s=1.0)   # boundary: usable
    assert not digest_fresh(d, now=13.626, stale_s=1.0)
    # the GlobalBalancer's membership wiring: digest arrival heartbeats
    # the cell; a silent cell quarantines at the timeout and its
    # penalty multiplier grows with digest age meanwhile
    em = ElasticMembership(heartbeat_timeout=1.0)
    em.register("cell0", "cell", now=0.0)
    em.register("cell1", "cell", now=0.0)
    em.heartbeat("cell0", 2.0)          # cell1's digests stopped
    assert em.active(2.5) == ["cell0"]
    assert (em.staleness_penalty("cell1", 0.9)
            > em.staleness_penalty("cell0", 2.5))


def test_hlo_walker_trip_counts():
    import jax.numpy as jnp
    from benchmarks.hlo_cost import analyze

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)).compile().as_text()
    r = analyze(hlo)
    assert abs(r["flops"] - 12 * 2 * 64 ** 3) / (12 * 2 * 64 ** 3) < 0.05
