"""Distribution layer: sharding rules are always divisible, cache specs
cover every leaf, elastic membership + staleness, HLO cost walker."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, input_specs, smoke_variant
from repro.distributed.elastic import ElasticMembership
from repro.models import Model
from repro.models.config import SHAPES


class _FakeMesh:
    """Mesh stand-in with .shape/.axis_names (no devices needed)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma3-27b",
                                  "mixtral-8x7b", "mamba2-1.3b",
                                  "recurrentgemma-2b", "whisper-tiny"])
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    from repro.launch import sharding as shr
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16} if multi_pod
                     else {"data": 16, "model": 16})
    cfg = ARCHS[arch].replace(vocab_pad_to=256)
    model = Model(cfg)
    specs = shr.param_pspecs(model.param_specs(), mesh, fsdp=True)
    leaves = jax.tree_util.tree_flatten_with_path(
        model.param_specs())[0]
    spec_leaves = jax.tree.leaves(specs,
                                  is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for (path, sds), spec in zip(leaves, spec_leaves):
        for dim, names in enumerate(spec):
            if names is None:
                continue
            names = (names,) if isinstance(names, str) else names
            n = int(np.prod([mesh.shape[a] for a in names]))
            assert sds.shape[dim] % n == 0, \
                (jax.tree_util.keystr(path), sds.shape, spec)


@pytest.mark.parametrize("arch,shape", [
    ("granite-3-2b", "decode_32k"), ("mixtral-8x7b", "long_500k"),
    ("mamba2-1.3b", "long_500k"), ("whisper-tiny", "decode_32k")])
def test_cache_specs_divisible(arch, shape):
    from repro.launch import sharding as shr
    mesh = _FakeMesh({"data": 16, "model": 16})
    cfg = ARCHS[arch].replace(vocab_pad_to=256)
    model = Model(cfg)
    sp = SHAPES[shape]
    cache = model.cache_specs(sp.global_batch, sp.seq_len)
    specs = shr.cache_pspecs(cache, mesh, sp.global_batch)
    for (path, sds), spec in zip(
            jax.tree_util.tree_flatten_with_path(cache)[0],
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, names in enumerate(spec):
            if names is None:
                continue
            names = (names,) if isinstance(names, str) else names
            n = int(np.prod([mesh.shape[a] for a in names]))
            assert sds.shape[dim] % n == 0, \
                (jax.tree_util.keystr(path), sds.shape, spec)


def test_input_specs_all_cells():
    for arch, cfg in ARCHS.items():
        for sname, sp in SHAPES.items():
            specs = input_specs(cfg, sp)
            assert "tokens" in specs
            for v in specs.values():
                assert all(d > 0 for d in v.shape)


def test_elastic_membership_and_straggler():
    em = ElasticMembership(heartbeat_timeout=2.0)
    em.register("a", "t1", now=0.0)
    em.register("b", "t1", now=0.0)
    assert set(em.active(1.0)) == {"a", "b"}
    em.heartbeat("a", 3.0)
    assert set(em.active(3.5)) == {"a"}          # b quarantined
    em.heartbeat("b", 4.0)
    assert set(em.active(4.1)) == {"a", "b"}     # b re-admitted
    # staleness penalty grows with telemetry age
    p0 = em.staleness_penalty("a", 3.0)
    p1 = em.staleness_penalty("a", 4.5)
    assert p1 > p0 >= 1.0


def test_elastic_persistence(tmp_path):
    em = ElasticMembership()
    em.register("x", "tier", now=1.0)
    em.save(str(tmp_path / "members.json"))
    em2 = ElasticMembership.load(str(tmp_path / "members.json"))
    assert "x" in em2.members


def test_hlo_walker_trip_counts():
    import jax.numpy as jnp
    from benchmarks.hlo_cost import analyze

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)).compile().as_text()
    r = analyze(hlo)
    assert abs(r["flops"] - 12 * 2 * 64 ** 3) / (12 * 2 * 64 ** 3) < 0.05
