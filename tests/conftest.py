import os
import sys

# smoke tests & benches must see ONE device (the dry-run sets 512 itself,
# in a subprocess) — do not set device-count flags here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
