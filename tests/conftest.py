import os
import sys

# smoke tests & benches must see ONE device (the dry-run sets 512 itself,
# in a subprocess) — do not set device-count flags here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_ctx():
    """Trimmed serving workload: a small world + trained estimator
    bundle shared by the fast tier-1 tests. The paper-scale module
    fixtures (test_system's 1500-prompt world) stay where they are —
    this one exists so hot-path tests don't pay that setup."""
    from repro.core import EstimatorBundle
    from repro.serving.tiers import paper_pool_tiers
    from repro.serving.world import build_dataset, paper_world
    world, names = paper_world(seed=0)
    ds = build_dataset(world, n=400)
    tiers = paper_pool_tiers()
    bundle = EstimatorBundle.train(ds, tiers, names)
    return dict(world=world, names=names, ds=ds, tiers=tiers,
                bundle=bundle)
