"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property suite needs hypothesis (CI installs "
    "it; the suite must still collect without it)")
from hypothesis import given, settings, strategies as st

from repro.core import score_matrix
from repro.core.assignment import greedy_assign, lpt_order
from repro.core.budget import admission_mask
from repro.models.blocks import causal_conv, conv_step

import jax.numpy as jnp


@st.composite
def weights_st(draw):
    a = draw(st.floats(0, 1))
    b = draw(st.floats(0, 1))
    c = draw(st.floats(0, 1))
    s = a + b + c
    if s == 0:
        return (1 / 3, 1 / 3, 1 / 3)
    return (a / s, b / s, c / s)


@settings(max_examples=30, deadline=None)
@given(weights_st(), st.integers(1, 10), st.integers(1, 6),
       st.integers(0, 10_000))
def test_greedy_always_assigns(w, R, I, seed):
    rng = np.random.default_rng(seed)
    q = rng.uniform(0, 1, (R, I))
    c = rng.uniform(1e-7, 1e-4, (R, I))
    ln = rng.uniform(1, 600, (R, I))
    tpot = rng.uniform(1e-3, 0.1, I)
    choice, info = greedy_assign(
        lpt_order(ln.max(1)), q, c, ln, tpot, rng.uniform(0, 1e4, I),
        rng.integers(1, 16, I).astype(float),
        rng.integers(0, 8, I).astype(float), np.full(I, 32.0), w)
    assert choice.min() >= 0 and choice.max() < I
    assert np.all(info["est_latency"] >= 0)


@settings(max_examples=30, deadline=None)
@given(weights_st(), st.integers(2, 8), st.integers(2, 5),
       st.integers(0, 10_000))
def test_score_normalization_invariant(w, R, I, seed):
    """Scaling all costs (or latencies) by a constant must not change
    the score matrix (per-request normalization, §4.1)."""
    rng = np.random.default_rng(seed)
    q = rng.uniform(0, 1, (R, I))
    c = rng.uniform(1e-7, 1e-4, (R, I))
    T = rng.uniform(1e-3, 60.0, (R, I))
    s1 = score_matrix(q, c, T, w)
    s2 = score_matrix(q, c * 7.3, T * 0.11, w)
    np.testing.assert_allclose(s1, s2, rtol=1e-9, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 12), st.integers(2, 5), st.integers(0, 10_000))
def test_budget_admission_soundness(R, I, seed):
    rng = np.random.default_rng(seed)
    budgets = np.where(rng.uniform(size=R) < 0.5,
                       rng.uniform(1e-6, 1e-4, R), np.nan)
    len_in = rng.uniform(10, 500, R)
    pred = rng.uniform(10, 800, (R, I))
    p_in = rng.uniform(0.01, 0.5, I)
    p_out = rng.uniform(0.01, 0.5, I)
    allowed, c_hat = admission_mask(budgets, len_in, pred, p_in, p_out)
    # every request keeps at least one candidate
    assert allowed.any(axis=1).all()
    # allowed multi-candidate sets respect the budget (except the
    # cheapest-fallback singleton case)
    for r in range(R):
        if np.isnan(budgets[r]) or allowed[r].sum() == 1:
            continue
        assert np.all(c_hat[r][allowed[r]] <= budgets[r] + 1e-12)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(4, 24), st.integers(1, 8),
       st.integers(2, 4), st.integers(0, 1_000))
def test_conv_step_matches_causal_conv(B, S, C, width, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(B, S, C)), jnp.float32)
    p = {"w": jnp.asarray(rng.normal(size=(width, C)), jnp.float32)}
    full = causal_conv(x, p, width)
    state = jnp.zeros((B, width - 1, C), jnp.float32)
    outs = []
    for t in range(S):
        y, state = conv_step(x[:, t], state, p, width)
        outs.append(y)
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 64), st.integers(2, 6), st.integers(0, 10_000))
def test_gbm_reduces_training_error(n, f, seed):
    from repro.estimators.gbm import GradientBoostedRegressor
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(max(n, 16), f)).astype(np.float32)
    y = (X[:, 0] * 2 + np.sin(X[:, 1 % f])).astype(np.float32)
    base_mse = float(np.mean((y - y.mean()) ** 2))
    g = GradientBoostedRegressor(n_trees=20, depth=3).fit(X, y)
    mse = float(np.mean((g.predict(X) - y) ** 2))
    assert mse <= base_mse + 1e-6
