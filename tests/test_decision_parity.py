"""Differential harness for the scheduler hot path: the numpy greedy
loop vs the jitted JAX decision core must make the *same decisions*.

Decision-level: exact assignment parity at fixed seeds across all four
``latency_mode`` isolation arms x budget filter on/off x LPT on/off
(the scan carries float32 state while numpy runs float64, so parity is
exact away from argmax ties; the pinned seeds keep it deterministic).
System-level: a full ``ClusterSim`` run under each backend lands on
matching metrics, including with the Pallas ``knn_topk`` estimator feed.
"""
import numpy as np
import pytest

from repro.core import PRESETS, RBConfig, RouteBalance, make_requests, \
    run_cell
from repro.core import decision_jax
from repro.core.assignment import greedy_assign, lpt_order
from repro.core.budget import admission_mask
from repro.serving.workload import poisson_arrivals

MODES = ("full", "off_reactive", "off_predictive", "static_prior")
WEIGHTS = (PRESETS["uniform"], (0.55, 0.25, 0.2), PRESETS["cost"])


def _problem(seed, R=32, I=13):
    rng = np.random.default_rng(seed)
    return dict(
        q=rng.uniform(0, 1, (R, I)),
        ln=rng.uniform(20, 500, (R, I)),
        plm=rng.uniform(20, 600, R),          # LPT key (max over models)
        tpot=rng.uniform(0.005, 0.05, I),
        nominal=rng.uniform(0.005, 0.05, I),
        d=rng.uniform(0, 3000, I),
        b=rng.integers(1, 12, I).astype(float),
        free=rng.integers(0, 6, I).astype(float),
        maxb=np.full(I, 16.0),
        price_in=rng.uniform(0.05, 0.5, I),
        price_out=rng.uniform(0.05, 0.5, I),
        budgets=np.where(rng.uniform(size=R) < 0.5,
                         rng.uniform(1e-5, 3e-4, R), np.nan),
        len_in=rng.uniform(10, 500, R),
    )


def _numpy_decision(p, w, mode, budget_filter, lpt):
    R, I = p["q"].shape
    if budget_filter:
        allowed, c_hat = admission_mask(p["budgets"], p["len_in"],
                                        p["ln"], p["price_in"],
                                        p["price_out"])
    else:
        allowed = np.ones((R, I), bool)
        c_hat = (p["len_in"][:, None] * p["price_in"][None, :]
                 + p["ln"] * p["price_out"][None, :]) / 1e6
    order = lpt_order(p["plm"], enable=lpt)
    return greedy_assign(order, p["q"], c_hat, p["ln"], p["tpot"],
                         p["d"], p["b"], p["free"], p["maxb"], w,
                         allowed, latency_mode=mode,
                         nominal_tpot=p["nominal"])


@pytest.mark.parametrize("lpt", [True, False], ids=["lpt", "fifo"])
@pytest.mark.parametrize("budget_filter", [True, False],
                         ids=["budget", "nobudget"])
@pytest.mark.parametrize("mode", MODES)
def test_exact_assignment_parity(mode, budget_filter, lpt):
    for seed, w in enumerate(WEIGHTS):
        p = _problem(seed)
        ch_np, info = _numpy_decision(p, w, mode, budget_filter, lpt)
        ch_jx, est = decision_jax.decide(
            p["q"], p["ln"], p["plm"], p["tpot"], p["nominal"], p["d"],
            p["b"], p["free"], p["maxb"], p["budgets"], p["len_in"],
            p["price_in"], p["price_out"], w, latency_mode=mode,
            lpt=lpt, budget_filter=budget_filter)
        np.testing.assert_array_equal(ch_np, ch_jx)
        np.testing.assert_allclose(est, info["est_latency"],
                                   rtol=2e-4, atol=1e-7)


def test_parity_with_batch_padding():
    """decide() pads R to a power of two; pad rows must not leak into
    real assignments (they scan strictly after every real request)."""
    for R in (1, 5, 13, 33, 63):
        p = _problem(7 + R, R=R)
        w = PRESETS["uniform"]
        ch_np, _ = _numpy_decision(p, w, "full", True, True)
        ch_jx, _ = decision_jax.decide(
            p["q"], p["ln"], p["plm"], p["tpot"], p["nominal"], p["d"],
            p["b"], p["free"], p["maxb"], p["budgets"], p["len_in"],
            p["price_in"], p["price_out"], w)
        np.testing.assert_array_equal(ch_np, ch_jx)


def test_greedy_core_respects_allowed():
    p = _problem(42)
    R, I = p["q"].shape
    rng = np.random.default_rng(5)
    allowed = rng.uniform(size=(R, I)) < 0.3
    allowed[:, 2] = True
    order = lpt_order(p["plm"])
    c_hat = (p["len_in"][:, None] * p["price_in"][None, :]
             + p["ln"] * p["price_out"][None, :]) / 1e6
    choice, _ = decision_jax.greedy_core(
        order, p["q"], c_hat, p["ln"], p["tpot"], p["nominal"], p["d"],
        p["b"], p["free"], p["maxb"], PRESETS["uniform"], allowed)
    choice = np.asarray(choice)
    assert all(allowed[r, choice[r]] for r in range(R))


def test_admission_math_numpy_vs_jax():
    import jax.numpy as jnp
    from repro.core.budget import admission_math
    rng = np.random.default_rng(11)
    R, I = 24, 13
    budgets = np.where(rng.uniform(size=R) < 0.6,
                       rng.uniform(1e-6, 1e-4, R), np.nan)
    len_in = rng.uniform(10, 500, R)
    pred = rng.uniform(10, 800, (R, I))
    p_in = rng.uniform(0.01, 0.5, I)
    p_out = rng.uniform(0.01, 0.5, I)
    a_np, c_np = admission_math(budgets, len_in, pred, p_in, p_out, np)
    a_jx, c_jx = admission_math(
        jnp.asarray(budgets, jnp.float32), jnp.asarray(len_in, jnp.float32),
        jnp.asarray(pred, jnp.float32), jnp.asarray(p_in, jnp.float32),
        jnp.asarray(p_out, jnp.float32), jnp)
    np.testing.assert_array_equal(a_np, np.asarray(a_jx))
    np.testing.assert_allclose(c_np, np.asarray(c_jx), rtol=1e-5)


# -- system level -----------------------------------------------------------

def _run(ctx, cfg, n=80, lam=10.0, seed=3):
    arr = poisson_arrivals(lam, n, seed=seed)
    reqs = make_requests(ctx["ds"], "test", arr)
    rb = RouteBalance(cfg, ctx["bundle"], ctx["tiers"])
    return run_cell(rb, ctx["tiers"], ctx["names"], reqs)


@pytest.mark.parametrize("mode", ["full", "off_reactive"])
def test_e2e_cluster_metrics_parity(small_ctx, mode):
    base = dict(charge_compute=False, latency_mode=mode)
    m_np = _run(small_ctx, RBConfig(decision_backend="numpy", **base))
    m_jx = _run(small_ctx, RBConfig(decision_backend="jax", **base))
    assert abs(m_np["quality"] - m_jx["quality"]) < 0.01
    assert m_jx["mean_e2e"] == pytest.approx(m_np["mean_e2e"], rel=0.05)
    assert m_jx["cost_per_req"] == pytest.approx(m_np["cost_per_req"],
                                                 rel=0.05)


def test_e2e_pallas_knn_feed(small_ctx):
    """The jitted core fed by the Pallas knn_topk estimator lands on the
    same metrics as the jnp top_k feed."""
    base = dict(charge_compute=False)
    m_jnp = _run(small_ctx, RBConfig(decision_backend="jax", **base),
                 n=40)
    m_pal = _run(small_ctx, RBConfig(decision_backend="jax",
                                     knn_backend="pallas", **base), n=40)
    assert abs(m_jnp["quality"] - m_pal["quality"]) < 0.01
    assert m_pal["mean_e2e"] == pytest.approx(m_jnp["mean_e2e"], rel=0.05)


def test_knn_backend_override_does_not_mutate_bundle(small_ctx):
    before = small_ctx["bundle"].knn.backend
    RouteBalance(RBConfig(knn_backend="pallas"), small_ctx["bundle"],
                 small_ctx["tiers"])
    assert small_ctx["bundle"].knn.backend == before
