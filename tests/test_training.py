"""Training substrate: loss decreases, checkpoint/restart resume,
gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_variant
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.compression import compress_decompress
from repro.models import Model
from repro.training.data import TokenStream
from repro.training.train_loop import TrainConfig, train


@pytest.fixture(scope="module")
def tiny_model():
    cfg = smoke_variant(ARCHS["granite-3-2b"]).replace(vocab=256)
    return Model(cfg)


@pytest.mark.slow
def test_loss_decreases(tiny_model):
    data = TokenStream(256, 32, 8, seed=0)
    out = train(tiny_model, data, TrainConfig(n_steps=40, log_every=100),
                log=lambda s: None)
    assert out["final_loss"] < out["first_loss"] - 0.3, \
        (out["first_loss"], out["final_loss"])


@pytest.mark.slow
def test_checkpoint_resume_identical(tmp_path, tiny_model):
    data1 = TokenStream(256, 32, 8, seed=0)
    full = train(tiny_model, data1,
                 TrainConfig(n_steps=15, ckpt_every=10,
                             ckpt_dir=str(tmp_path / "a")),
                 log=lambda s: None)
    # crash-restart: a fresh run resumes from the step-10 checkpoint
    data2 = TokenStream(256, 32, 8, seed=0)
    for _ in range(10):         # skip the batches consumed before the ckpt
        next(data2.batches(1))
    resumed = train(tiny_model, data2,
                    TrainConfig(n_steps=25, ckpt_every=10,
                                ckpt_dir=str(tmp_path / "a")),
                    log=lambda s: None)
    assert np.isfinite(resumed["final_loss"])
    # params restored: the resumed run's first loss continues from the
    # checkpointed trajectory (matches the full run's step-10 loss, not
    # its step-0 loss)
    assert abs(resumed["first_loss"] - full["losses"][10]) < \
        abs(resumed["first_loss"] - full["losses"][0]) + 0.2
    assert resumed["first_loss"] <= full["first_loss"] + 0.05


def test_checkpoint_atomic_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    mgr.save(1, tree)
    mgr.save(2, jax.tree.map(lambda x: x * 2, tree))
    mgr.save(3, jax.tree.map(lambda x: x * 3, tree))
    assert mgr.all_steps() == [2, 3]          # keep=2 GC'd step 1
    restored, step = mgr.restore(tree)
    assert step == 3
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.arange(6).reshape(2, 3) * 3)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_gradient_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    ghat, e, mets = compress_decompress(g)
    # quantization error bounded by scale/2 per element
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(g["w"] - ghat["w"]))) <= scale * 0.51
    # error feedback: e = g - ghat
    np.testing.assert_allclose(np.asarray(e["w"]),
                               np.asarray(g["w"] - ghat["w"]), atol=1e-6)
    # second round: accumulated error is injected
    ghat2, e2, _ = compress_decompress(g, e)
    assert float(mets["compression_err_sq"]) >= 0


@pytest.mark.slow
def test_train_with_compression(tiny_model):
    data = TokenStream(256, 32, 8, seed=0)
    out = train(tiny_model, data,
                TrainConfig(n_steps=25, grad_compression=True,
                            log_every=100),
                log=lambda s: None)
    assert out["final_loss"] < out["first_loss"] - 0.2


@pytest.mark.slow
def test_microbatched_train_step_matches(tiny_model):
    """Gradient accumulation must match the single-batch step on the
    first step (same math, k=2)."""
    from repro.launch.steps import init_opt_state, make_train_step
    from repro.training.optimizer import AdamWConfig
    data = TokenStream(256, 32, 8, seed=0)
    batch = next(data.batches(1))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params = tiny_model.init(jax.random.key(0))
    oc = AdamWConfig(lr=1e-3)
    s1 = make_train_step(tiny_model, oc, microbatches=1)
    s2 = make_train_step(tiny_model, oc, microbatches=2)
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p2, _, m2 = s2(params, init_opt_state(params), batch)
    # losses computed over the same tokens; microbatch averages two halves
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.05
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 0.05
