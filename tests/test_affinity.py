"""Prefix-cache / session-affinity term (`repro.serving.affinity` +
``RBConfig.affinity_weight``): signature math, sketch lifecycle,
backend-exact hit scoring, decision steering across all three
backends, the zero-recompile pin through session churn, and the
SoA-ingest re-entrancy fixes that rode along (stale retry row stamps,
all-or-nothing embedding resume)."""
import dataclasses

import numpy as np
import pytest

from repro.core import RBConfig, RouteBalance
from repro.serving.affinity import (PREFIX_BLOCK, SIG_WIDTH, SKETCH_SLOTS,
                                    PrefixSketch, hit_fraction,
                                    prefix_signatures, prompt_signatures)
from repro.serving.cluster import ClusterSim
from repro.serving.request import Request, RequestColumns, batch_columns
from repro.serving.scenarios import (Scenario, TenantSpec, get_scenario,
                                     randomize_prefix_state,
                                     randomize_telemetry)
from repro.serving.world import Prompt

BACKENDS = ("numpy", "jax", "fused")


def _prompt(pid, toks):
    toks = np.asarray(toks, np.int32)
    return Prompt(pid=pid, topic=0, difficulty=0.5, verbosity=0.5,
                  tokens=toks, len_in=int(toks.size))


def _req(rid, prompt, arrival=0.0):
    return Request(rid=rid, prompt=prompt, arrival=arrival,
                   true_quality=np.full(8, 0.5),
                   true_length=np.full(8, 40.0))


# -- signatures ---------------------------------------------------------------

def test_signatures_are_int32_with_zero_sentinel():
    rng = np.random.default_rng(0)
    toks = rng.integers(1, 4096, (4, 128)).astype(np.int32)
    lens = np.array([128, 40, 16, 7])
    sig = prefix_signatures(toks, lens)
    assert sig.dtype == np.int32 and sig.shape == (4, SIG_WIDTH)
    # column d is 0 exactly where the prompt does not reach block d
    blocks = np.minimum(-(-lens // PREFIX_BLOCK), SIG_WIDTH)
    for p in range(4):
        assert (sig[p, :blocks[p]] != 0).all(), (p, sig[p])
        assert (sig[p, blocks[p]:] == 0).all(), (p, sig[p])


def test_signatures_shared_prefix_shares_leading_columns():
    rng = np.random.default_rng(1)
    a = rng.integers(1, 4096, 128).astype(np.int32)
    b = a.copy()
    b[48:] = rng.integers(1, 4096, 80)       # diverge inside block 3
    sig = prefix_signatures(np.stack([a, b]), np.array([128, 128]))
    assert (sig[0, :3] == sig[1, :3]).all()  # blocks 0..2 identical
    assert (sig[0, 3:] != sig[1, 3:]).all()  # divergence cascades


def test_signatures_padding_invariant():
    """The SoA scoring path hashes the zero-padded column matrix; the
    dispatch path hashes the raw per-prompt array. Identical results
    required — the masked update makes padding invisible."""
    rng = np.random.default_rng(2)
    raw = rng.integers(1, 4096, 37).astype(np.int32)
    padded = np.zeros((1, 128), np.int32)
    padded[0, :37] = raw
    s_raw = prefix_signatures(raw[None, :], np.array([37]))
    s_pad = prefix_signatures(padded, np.array([37]))
    np.testing.assert_array_equal(s_raw, s_pad)
    p = _prompt(0, raw)
    np.testing.assert_array_equal(prompt_signatures(p), s_raw[0])
    assert prompt_signatures(p) is prompt_signatures(p)   # memoized


def test_columns_prefix_sig_matches_prompt_signatures():
    rng = np.random.default_rng(3)
    reqs = [_req(i, _prompt(i, rng.integers(1, 4096, int(n))))
            for i, n in enumerate(rng.integers(5, 128, 12))]
    cols = RequestColumns.from_requests(reqs)
    for r in reqs:
        np.testing.assert_array_equal(
            cols.prefix_sig[cols.prompt_row[r.row]],
            prompt_signatures(r.prompt))


# -- sketch -------------------------------------------------------------------

def test_sketch_insert_hit_and_leading_run():
    sig = prefix_signatures(np.arange(1, 129)[None, :].astype(np.int32),
                            np.array([128]))[0]
    sk = PrefixSketch()
    sk.insert(sig[:3])                       # first 48 tokens cached
    assert sk.hit_tokens(sig, 128) == 3 * PREFIX_BLOCK
    assert sk.hit_tokens(sig, 40) == 40      # capped at the prompt len
    # a hole in the run stops the trie walk
    sk2 = PrefixSketch()
    sk2.insert([int(sig[0]), int(sig[2])])
    assert sk2.hit_tokens(sig, 128) == PREFIX_BLOCK


def test_sketch_lru_eviction_and_mirror():
    sk = PrefixSketch(capacity=4)
    sk.insert([1, 2, 3, 4])
    sk.insert([1])                           # touch 1: now 2 is LRU
    sk.insert([5])
    assert set(sk.slots) == {1, 3, 4, 5}
    row = sk.mirror()
    assert row.dtype == np.int32 and row.shape == (4,)
    assert set(row.tolist()) == {1, 3, 4, 5}
    sk.clear()
    assert len(sk) == 0 and (sk.mirror() == 0).all()


def test_hit_fraction_numpy_jax_bitwise():
    import jax.numpy as jnp
    rng = np.random.default_rng(4)
    toks = rng.integers(1, 4096, (6, 128)).astype(np.int32)
    lens = rng.integers(4, 129, 6)
    req_sig = prefix_signatures(toks, lens)
    plane = np.zeros((5, SKETCH_SLOTS), np.int32)
    for i in range(5):                       # partial-prefix caches
        sk = PrefixSketch()
        sk.insert(req_sig[i % 6, :rng.integers(1, SIG_WIDTH + 1)])
        sk.mirror(out=plane[i])
    lenf = lens.astype(np.float32)
    h_np = hit_fraction(req_sig, lenf, plane, np)
    h_j = np.asarray(hit_fraction(jnp.asarray(req_sig),
                                  jnp.asarray(lenf),
                                  jnp.asarray(plane), jnp))
    np.testing.assert_array_equal(h_np, h_j)          # bitwise
    assert h_np.dtype == np.float32
    assert (h_np >= 0).all() and (h_np <= 1).all()
    assert h_np.max() > 0                    # the caches really match
    # scalar sketch walk agrees with the vectorized form
    for i in range(5):
        sk = PrefixSketch()
        sk.insert(plane[i])
        for r in range(6):
            frac = sk.hit_tokens(req_sig[r], int(lens[r])) \
                / max(float(lens[r]), 1.0)
            assert h_np[r, i] == pytest.approx(frac), (r, i)


# -- dead reckoning on dispatch / finish / fail -------------------------------

@pytest.fixture(scope="module")
def chat_run():
    run = get_scenario("session_chat").build(dataset_n=300)
    run.bundle()
    return run


def _mini_sim(n_tiers=1, n_instances=3, seed=0):
    from repro.serving.scenarios import synthetic_pool
    tiers, names, _ = synthetic_pool(n_tiers, n_instances, seed=seed)
    return ClusterSim(tiers, names, seed=0)


def test_submit_stamps_hit_inserts_and_mirrors():
    sim = _mini_sim()
    inst = sim.instances[0]
    rng = np.random.default_rng(5)
    p = _prompt(0, rng.integers(1, 4096, 64))
    sig = prompt_signatures(p)
    inst.submit(_req(0, p), 0.0, 10.0, None)
    # cold cache: no hit, but the prompt is credited and mirrored
    assert sim.completed == []
    assert inst.sketch.hit_tokens(sig, 64) == 64
    assert set(sig[sig != 0].tolist()) <= set(
        sim.tel.prefix_sig[inst.slot].tolist())
    v = sim.tel.prefix_version
    r2 = _req(1, p)
    inst.submit(r2, 0.1, 10.0, None)
    assert r2.prefix_hit == pytest.approx(1.0)   # warm: full-prefix hit
    assert sim.tel.prefix_version > v
    # sketch writes must NOT look like telemetry heartbeats
    assert sim.tel.prefix_hit[inst.slot] > 0


def test_prefill_discount_shortens_admission():
    """`Instance._admit` discounts prefill by the matched fraction —
    the cache physics exists whether or not the router scored for it."""
    sim = _mini_sim()
    inst = sim.instances[0]
    rng = np.random.default_rng(6)
    p = _prompt(0, rng.integers(1, 4096, 128))
    cold = _req(0, p)
    inst.submit(cold, 0.0, 10.0, None)
    sim.run()
    assert cold.finish_time is not None and cold.prefix_hit == 0.0
    t1 = sim.now + 1.0
    warm = _req(1, p, arrival=t1)
    inst.submit(warm, t1, 10.0, None)
    sim.run()
    assert warm.prefix_hit == pytest.approx(1.0)
    # the warm admit skipped (1 - hit) of the prefill
    cold_prefill = cold.first_token_time - cold.dispatch_time
    warm_prefill = warm.first_token_time - warm.dispatch_time
    assert cold_prefill > 0.0
    assert warm_prefill < 0.5 * cold_prefill


def test_requeue_resets_prefix_hit():
    rng = np.random.default_rng(7)
    r = _req(0, _prompt(0, rng.integers(1, 4096, 64)))
    r.prefix_hit = 0.75
    r.requeue(2.0)
    assert r.prefix_hit == 0.0


def test_fail_clears_sketch_and_mirror_for_retries():
    """Dead-reckoned credit dies with the instance: a retry or hedge
    re-dispatch must never score affinity against a cache the victim
    lost. `recover()` re-enters cold."""
    sim = _mini_sim()
    inst = sim.instances[0]
    rng = np.random.default_rng(8)
    p = _prompt(0, rng.integers(1, 4096, 64))
    inst.submit(_req(0, p), 0.0, 10.0, None)
    assert len(inst.sketch) > 0
    inst.fail()
    assert len(inst.sketch) == 0
    assert (sim.tel.prefix_sig[inst.slot] == 0).all()
    inst.recover(1.0)
    assert len(inst.sketch) == 0             # cold re-entry
    assert (sim.tel.prefix_sig[inst.slot] == 0).all()
    assert inst.sketch.hit_tokens(prompt_signatures(p), 64) == 0


# -- decision steering: all three backends ------------------------------------

@pytest.fixture(scope="module")
def steer_run():
    sc = Scenario(name="steer", pool="synthetic", n_tiers=1,
                  n_instances=4, tenants=(TenantSpec("all", 8.0),),
                  seed=7)
    run = sc.build(dataset_n=220)
    run.bundle()
    return run


def test_affinity_steers_to_warm_instance_all_backends(steer_run):
    """Four identical idle replicas; one holds the request's full
    prefix. Affinity on must route the request to the warm cache —
    identically in every backend — while w=0 must ignore the sketch."""
    run = steer_run
    target = run.requests(4, seed=0)[0]
    target.arrival = 0.0
    sig = prompt_signatures(target.prompt)

    def pick(w, be, warm_slot=None):
        rb = RouteBalance(RBConfig(decision_backend=be,
                                   affinity_weight=w),
                          run.bundle(), run.tiers)
        sim = ClusterSim(run.tiers, run.names, seed=0)
        if warm_slot is not None:
            warm = sim.instances[warm_slot]
            warm.sketch.insert(sig)
            sim.tel.write_prefix(warm.slot, warm.sketch)
        rb.sim = sim
        instances, choice, _ = rb._decide_core([target])
        return instances[int(choice[0])].iid

    base = {be: pick(0.0, be) for be in BACKENDS}
    assert len(set(base.values())) == 1, base
    iids = [i.iid for i in ClusterSim(run.tiers, run.names,
                                      seed=0).instances]
    # warm a replica the cold tie-break does NOT pick
    warm_slot = next(s for s in range(len(iids))
                     if iids[s] != base["numpy"])
    for be in BACKENDS:
        assert pick(0.6, be, warm_slot) == iids[warm_slot], be
        assert pick(0.0, be, warm_slot) == base[be], \
            (be, "sketch must be inert at w=0")


def test_weight_zero_is_bitwise_inert(steer_run):
    """affinity_weight=0 must leave decisions AND est latencies exactly
    the legacy values even with warm sketches everywhere (the discount
    multiplies by an exact 1.0)."""
    run = steer_run
    reqs = run.requests(12, seed=1)[:12]
    for r in reqs:
        r.arrival = 0.0
    cols = reqs[0].cols
    out = {}
    for arm in ("legacy", "zero_w"):
        rb = RouteBalance(RBConfig(decision_backend="fused",
                                   affinity_weight=0.0),
                          run.bundle(), run.tiers)
        sim = randomize_telemetry(
            ClusterSim(run.tiers, run.names, seed=0), 3)
        if arm == "zero_w":
            randomize_prefix_state(sim, cols, seed=3, frac=1.0)
        rb.sim = sim
        instances, choice, l_chosen = rb._decide_core(reqs)
        out[arm] = ([instances[int(i)].iid for i in choice],
                    np.asarray(l_chosen))
    assert out["legacy"][0] == out["zero_w"][0]
    np.testing.assert_array_equal(out["legacy"][1], out["zero_w"][1])


def test_zero_recompiles_through_session_churn(chat_run):
    """Session traffic (multi-turn prefix churn, sketch writes every
    dispatch) must ride the compiled programs: one XLA compile per pow2
    R bucket, exactly as without the affinity term."""
    from repro.core.decision_jax import bucket_pow2
    run = chat_run
    reqs = run.requests(120, seed=0)
    rb = RouteBalance(RBConfig(decision_backend="fused",
                               affinity_weight=0.35,
                               charge_compute=False),
                      run.bundle(), run.tiers)
    m = run.run_cell(rb, reqs, seed=0)
    assert m["cache_hit_rate"] > 0
    buckets = {bucket_pow2(s) for s, _ in rb.compute_log}
    assert rb._fused.compile_count() == len(buckets)
    # a second cell over fresh sessions adds zero compiles
    reqs2 = run.requests(120, seed=1)
    rb2 = RouteBalance(RBConfig(decision_backend="fused",
                                affinity_weight=0.35,
                                charge_compute=False),
                       run.bundle(), run.tiers)
    run.run_cell(rb2, reqs2, seed=0)
    buckets |= {bucket_pow2(s) for s, _ in rb2.compute_log}
    assert rb2._fused.compile_count() == len(buckets)


def test_session_chat_turns_share_prefixes(chat_run):
    reqs = chat_run.requests(80, seed=0)
    cols = reqs[0].cols
    chat = [r for r in reqs if r.tenant == "chat"]
    assert len(chat) > 20
    sig = cols.prefix_sig[cols.prompt_row[[r.row for r in chat]]]
    first = sig[:, 0]
    # conversations: many turns share their first block hash
    _, counts = np.unique(first[first != 0], return_counts=True)
    assert (counts > 1).any()
    # follow-up turns really extend (longer len_in than the base turn)
    lens = np.array([r.prompt.len_in for r in chat])
    assert lens.max() > lens.min()


# -- SoA ingest re-entrancy fixes (the retry-path correctness sweep) ----------

class _StubEncoder:
    dim = 8
    max_len = 128

    def __init__(self, fail_at_call=None):
        self.calls = 0
        self.fail_at = fail_at_call

    def encode(self, toks, lens):
        self.calls += 1
        if self.calls == self.fail_at:
            self.fail_at = None
            raise RuntimeError("encoder died mid-chunk")
        out = np.zeros((len(toks), self.dim), np.float32)
        out[:, 0] = toks[:, 0]
        out[:, 1] = np.asarray(lens, np.float32)
        return out


def _many_prompt_reqs(n=300, seed=9):
    rng = np.random.default_rng(seed)
    return [_req(i, _prompt(i, rng.integers(1, 4096, 12)))
            for i in range(n)]


def test_ensure_embeddings_all_or_nothing_and_resume():
    """A mid-chunk encoder raise must leave `emb` unset (no garbage
    rows can ever be served) and a retry must resume from the first
    unencoded row — not recompute, not concatenate a fresh pad block."""
    reqs = _many_prompt_reqs()
    cols = RequestColumns.from_requests(reqs)
    flaky = _StubEncoder(fail_at_call=2)     # 300 prompts = 2 chunks
    with pytest.raises(RuntimeError):
        cols.ensure_embeddings(flaky)
    assert cols.emb is None                  # all-or-nothing
    assert cols._emb_partial is not None
    assert cols._emb_partial[1] == 256       # chunk 1 retained
    pad_cache = cols._toks_padded
    retry = _StubEncoder()
    cols.ensure_embeddings(retry)
    assert retry.calls == 1                  # resumed, not recomputed
    assert cols._toks_padded is pad_cache    # pad matrix built once
    assert cols.emb is not None and cols._emb_partial is None
    ref = RequestColumns.from_requests(reqs, stamp=False)
    ref.ensure_embeddings(_StubEncoder())
    np.testing.assert_array_equal(cols.emb, ref.emb)
    # idempotent re-entry after success
    emb = cols.emb
    cols.ensure_embeddings(_StubEncoder(fail_at_call=1))
    assert cols.emb is emb


def test_batch_columns_rejects_foreign_and_stale_rows():
    """The satellite-1 pin: a retry that crossed streams (or carries a
    stale row stamp) must degrade the batch to the AoS path — never
    gather another request's tokens/embedding row."""
    a = _many_prompt_reqs(6, seed=10)
    b = _many_prompt_reqs(6, seed=11)
    cols_a = RequestColumns.from_requests(a)
    RequestColumns.from_requests(b)
    got_cols, got_rows = batch_columns(a[:4])
    assert got_cols is cols_a
    np.testing.assert_array_equal(got_rows, [r.row for r in a[:4]])
    # mixed streams: retry from stream B lands in a stream-A batch
    b[0].requeue(5.0)
    assert batch_columns([a[0], b[0]]) == (None, None)
    # stale stamp pointing out of bounds: refuse the columnar path
    rogue = a[1]
    rogue.row = cols_a.n + 7
    assert batch_columns([a[0], rogue]) == (None, None)


def test_retry_across_two_streams_decides_safely(steer_run):
    """End-to-end satellite-1 regression: a requeued request from one
    `RequestColumns` stream joins a batch of another stream's requests;
    the decision core must fall back to per-request staging and assign
    every request to an alive instance of its own roster."""
    run = steer_run
    stream_a = run.requests(8, seed=2)
    stream_b = run.requests(8, seed=3)
    retry = stream_b[0]
    retry.requeue(0.0)
    batch = stream_a[:4] + [retry]
    for r in batch:
        r.arrival = 0.0
    out = {}
    for be in BACKENDS:
        rb = RouteBalance(RBConfig(decision_backend=be,
                                   affinity_weight=0.35),
                          run.bundle(), run.tiers)
        rb.sim = randomize_telemetry(
            ClusterSim(run.tiers, run.names, seed=0), 5)
        instances, choice, _ = rb._decide_core(batch)
        assert len(choice) == len(batch)
        out[be] = [instances[int(i)].iid for i in choice]
        alive = {i.iid for i in rb.sim.instances if i.alive}
        assert set(out[be]) <= alive
    assert out["numpy"] == out["jax"] == out["fused"]
