"""Estimator stack: encoder determinism, KNN generalization, GBM heads,
analytical latency combine."""
import numpy as np
import pytest

from repro.estimators.embedding import SentenceEncoder
from repro.estimators.gbm import GradientBoostedRegressor, predict_packed
from repro.estimators.knn import KNNEstimator
from repro.estimators.latency import LatencyHead, analytic_latency, \
    tpot_features


def test_encoder_deterministic_and_normalized():
    enc = SentenceEncoder(seed=7)
    toks = np.arange(64).reshape(2, 32)
    e1 = enc.encode(toks)
    e2 = enc.encode(toks)
    np.testing.assert_allclose(e1, e2)
    np.testing.assert_allclose(np.linalg.norm(e1, axis=1), 1.0, rtol=1e-4)


def test_encoder_similarity_structure():
    """Prompts sharing token statistics embed closer than disjoint ones."""
    enc = SentenceEncoder(seed=7)
    rng = np.random.default_rng(0)
    a = rng.integers(0, 200, (1, 64))
    a2 = a.copy()
    a2[0, :8] = rng.integers(0, 200, 8)         # small perturbation
    b = rng.integers(2000, 2200, (1, 64))       # different vocab region
    ea, ea2, eb = enc.encode(a), enc.encode(a2), enc.encode(b)
    assert float((ea @ ea2.T)[0, 0]) > float((ea @ eb.T)[0, 0])


def test_knn_recovers_latent_quality():
    from repro.serving.world import build_dataset, paper_world
    world, names = paper_world(seed=0)
    ds = build_dataset(world, n=1500)
    enc = SentenceEncoder(seed=7)

    def embed(prompts):
        toks = np.zeros((len(prompts), 128), np.int32)
        lens = []
        for i, p in enumerate(prompts):
            n = min(len(p.tokens), 128)
            toks[i, :n] = p.tokens[:n]
            lens.append(n)
        return enc.encode(toks, np.array(lens))

    ptr, Qtr, Ltr = ds.split("train")
    pte, Qte, Lte = ds.split("test")
    knn = KNNEstimator(k=10).fit(embed(ptr), Qtr, Ltr)
    acc = knn.best_model_accuracy(embed(pte), Qte)
    assert acc > 0.30, acc                     # well above random (0.25)
    qh, lh = knn.query(embed(pte))
    assert np.abs(qh - Qte).mean() < 0.18
    assert np.mean(np.abs(lh - Lte) / Lte) < 1.0


def test_gbm_packed_matches_numpy():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(400, 4)).astype(np.float32)
    y = (X[:, 0] - 0.5 * X[:, 2] ** 2).astype(np.float32)
    g = GradientBoostedRegressor(n_trees=15, depth=3).fit(X, y)
    import jax.numpy as jnp
    p1 = g.predict(X[:50])
    p2 = np.asarray(predict_packed(g.pack(), jnp.asarray(X[:50])))
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-4)


def test_latency_head_learns_tpot():
    from repro.serving.tiers import paper_pool_tiers
    rng = np.random.default_rng(2)
    t = paper_pool_tiers()[1]
    X, y = [], []
    for _ in range(800):
        b = rng.integers(1, 32)
        ctx = rng.uniform(64, 2048)
        X.append(tpot_features(b, b * 100, ctx))
        y.append(t.tpot(b, ctx))
    head = LatencyHead(t.name, nominal_tpot=t.tpot(8, 500)).fit(
        np.stack(X), np.asarray(y, np.float32))
    pred = head.tpot_batch(np.stack(X))
    mae = np.abs(pred - np.asarray(y)).mean()
    assert mae < 0.002, mae                     # < 2 ms/token


def test_analytic_latency_free_slot():
    T = analytic_latency(np.array([[0.01]]), np.array([[500.0]]),
                         np.array([[4.0]]), np.array([[100.0]]),
                         np.array([[True]]))
    np.testing.assert_allclose(T, 0.01 * 100.0)   # no wait term
    T2 = analytic_latency(np.array([[0.01]]), np.array([[500.0]]),
                          np.array([[4.0]]), np.array([[100.0]]),
                          np.array([[False]]))
    np.testing.assert_allclose(T2, 0.01 * (125 + 100))
