"""Per-kernel shape/dtype sweeps: pallas_call (interpret=True) vs the
pure-jnp oracles in repro/kernels/ref.py (brief requirement (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.knn_topk import knn_topk
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("B,N,E,k,tile", [
    (4, 700, 32, 5, 128), (16, 2048, 128, 10, 512), (2, 100, 16, 3, 64),
    (8, 1024, 64, 10, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_knn_topk(B, N, E, k, tile, dtype):
    ks = jax.random.split(jax.random.key(0), 2)
    q = jax.random.normal(ks[0], (B, E), dtype)
    x = jax.random.normal(ks[1], (N, E), dtype)
    dv, di = knn_topk(q, x, k=k, tile=tile)
    rv, ri = kref.knn_topk_ref(q, x, k=k)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(dv), np.asarray(rv),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,C,K,g,d,window,tile", [
    (2, 128, 2, 2, 32, 0, 64), (1, 513, 4, 1, 64, 0, 128),
    (3, 96, 1, 6, 16, 32, 32), (2, 64, 8, 1, 16, 0, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, C, K, g, d, window, tile, dtype):
    H = K * g
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, H, d), dtype)
    kc = jax.random.normal(ks[1], (B, C, K, d), dtype)
    vc = jax.random.normal(ks[2], (B, C, K, d), dtype)
    pos = C - 5
    cpos = jnp.where(jnp.arange(C) <= pos, jnp.arange(C),
                     -1).astype(jnp.int32)
    o = decode_attention(q, kc, vc, cpos, pos, window=window, tile=tile)
    r = kref.decode_attention_ref(q, kc, vc, cpos, pos, window=window)
    tol = 4e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,nh,P,N,chunk,hb", [
    (2, 64, 4, 16, 16, 16, 2), (1, 96, 8, 8, 32, 32, 8),
    (2, 32, 2, 16, 64, 16, 1)])
def test_ssd_scan(B, S, nh, P, N, chunk, hb):
    ks = jax.random.split(jax.random.key(2), 5)
    xh = jax.random.normal(ks[0], (B, S, nh, P))
    Bm = jax.random.normal(ks[1], (B, S, nh, N)) * 0.5
    Cm = jax.random.normal(ks[2], (B, S, nh, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[4], (nh,)) * 0.3)
    y, st = ssd_scan(xh, Bm, Cm, dt, A, chunk=chunk, head_tile=hb)
    yr, sr = kref.ssd_recurrent_ref(xh, Bm, Cm, dt, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               rtol=2e-3, atol=2e-3)


def test_ssd_kernel_matches_model_chunked_path():
    """Kernel vs the model's _ssd_chunked (grouped B/C) on equal inputs."""
    from repro.models.blocks import _ssd_chunked
    B, S, nh, P, N, G = 2, 64, 4, 8, 16, 1
    ks = jax.random.split(jax.random.key(3), 5)
    xh = jax.random.normal(ks[0], (B, S, nh, P))
    Bg = jax.random.normal(ks[1], (B, S, G, N)) * 0.5
    Cg = jax.random.normal(ks[2], (B, S, G, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[4], (nh,)) * 0.3)
    init = jnp.zeros((B, nh, P, N), jnp.float32)
    y_ref, st_ref = _ssd_chunked(xh, Bg, Cg, dt, A, 16, init)
    Bm = jnp.repeat(Bg, nh // G, axis=2)
    Cm = jnp.repeat(Cg, nh // G, axis=2)
    y, st = ssd_scan(xh, Bm, Cm, dt, A, chunk=16, head_tile=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-3, atol=2e-3)


def test_knn_estimator_backend_parity():
    rng = np.random.default_rng(0)
    from repro.estimators.knn import KNNEstimator
    x = rng.normal(size=(500, 32)).astype(np.float32)
    ql = rng.uniform(size=(500, 4)).astype(np.float32)
    ln = rng.uniform(50, 500, (500, 4)).astype(np.float32)
    q = rng.normal(size=(8, 32)).astype(np.float32)
    outs = {}
    for backend in ("numpy", "jax", "pallas"):
        est = KNNEstimator(k=7, backend=backend).fit(x, ql, ln)
        outs[backend] = est.query(q)
    for b in ("jax", "pallas"):
        np.testing.assert_allclose(outs["numpy"][0], outs[b][0],
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(outs["numpy"][1], outs[b][1],
                                   rtol=1e-3, atol=1e-2)
