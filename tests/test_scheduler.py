"""Scheduler-core invariants: Eq.1 scoring, LPT, greedy dead reckoning,
budget safety, numpy-vs-jax greedy parity, Hungarian validity."""
import numpy as np
import pytest

from repro.core import PRESETS, hungarian, score_matrix, validate
from repro.core.assignment import greedy_assign, greedy_assign_jax, \
    lpt_order
from repro.core.budget import admission_mask, max_tokens_clamp
from repro.core.weights import sweep


def _rand_problem(rng, R=12, I=7):
    q = rng.uniform(0, 1, (R, I))
    c = rng.uniform(1e-6, 1e-4, (R, I))
    ln = rng.uniform(20, 500, (R, I))
    tpot = rng.uniform(0.005, 0.05, I)
    d = rng.uniform(0, 3000, I)
    b = rng.integers(1, 12, I).astype(float)
    free = rng.integers(0, 6, I).astype(float)
    maxb = np.full(I, 32.0)
    return q, c, ln, tpot, d, b, free, maxb


def test_weights_simplex():
    for w in sweep(16):
        validate(w)
    with pytest.raises(AssertionError):
        validate((0.5, 0.5, 0.5))


def test_score_matrix_bounds(rng):
    q, c, ln, tpot, d, b, free, maxb = _rand_problem(rng)
    T = tpot[None] * (d / np.maximum(b, 1) + ln)
    s = score_matrix(q, c, T, PRESETS["uniform"])
    assert np.all(s <= 1.0 + 1e-9)
    # best-cost candidate gets the full cost credit
    wq, wl, wc = PRESETS["uniform"]
    am = c.argmin(1)
    assert np.all(s[np.arange(len(am)), am] > -np.inf)


def test_lpt_order():
    ln = np.array([5.0, 100.0, 50.0])
    assert list(lpt_order(ln)) == [1, 2, 0]
    assert list(lpt_order(ln, enable=False)) == [0, 1, 2]


def test_greedy_dead_reckoning_avoids_herding(rng):
    """Identical requests must spread across identical instances."""
    R, I = 8, 4
    q = np.ones((R, I)) * 0.5
    c = np.ones((R, I)) * 1e-5
    ln = np.full((R, I), 100.0)
    tpot = np.full(I, 0.01)
    d = np.zeros(I)
    b = np.ones(I)
    free = np.full(I, 8.0)
    maxb = np.full(I, 32.0)
    choice, _ = greedy_assign(np.arange(R), q, c, ln, tpot, d, b, free,
                              maxb, (0.0, 1.0, 0.0))
    counts = np.bincount(choice, minlength=I)
    assert counts.max() - counts.min() <= 1, counts


def test_greedy_respects_allowed(rng):
    q, c, ln, tpot, d, b, free, maxb = _rand_problem(rng)
    allowed = rng.uniform(size=q.shape) < 0.4
    allowed[:, 0] = True  # every request keeps one candidate
    order = lpt_order(ln.max(1))
    choice, _ = greedy_assign(order, q, c, ln, tpot, d, b, free, maxb,
                              PRESETS["uniform"], allowed)
    assert all(allowed[r, choice[r]] for r in range(len(choice)))


def test_greedy_numpy_vs_jax(rng):
    q, c, ln, tpot, d, b, free, maxb = _rand_problem(rng, R=10, I=5)
    order = lpt_order(ln.max(1))
    ch_np, _ = greedy_assign(order, q, c, ln, tpot, d, b, free, maxb,
                             PRESETS["uniform"])
    ch_jx = np.asarray(greedy_assign_jax(
        order, q.astype(np.float32), c.astype(np.float32),
        ln.astype(np.float32), tpot.astype(np.float32), d, b, free, maxb,
        PRESETS["uniform"]))
    np.testing.assert_array_equal(ch_np, ch_jx)


def test_budget_admission_and_clamp():
    budgets = np.array([1e-5, np.nan, 1e-9])
    len_in = np.array([100.0, 100.0, 100.0])
    pred = np.array([[100.0, 400.0], [100.0, 400.0], [100.0, 400.0]])
    p_in = np.array([0.06, 0.40])
    p_out = np.array([0.06, 0.40])
    allowed, c_hat = admission_mask(budgets, len_in, pred, p_in, p_out)
    assert allowed[0, 0] and not allowed[0, 1]    # 72b too pricey
    assert allowed[1].all()                        # no budget
    assert allowed[2].sum() == 1                   # impossible budget ->
    assert allowed[2, c_hat[2].argmin()]           # cheapest kept
    mt = max_tokens_clamp(1e-5, 100, 0.06, 0.06)
    # worst case: len_in cost + mt * out price <= budget
    assert 100 * 0.06 / 1e6 + mt * 0.06 / 1e6 <= 1e-5 + 0.06 / 1e6


@pytest.mark.parametrize("threshold", [0.0, 0.35, 0.5, 1.0])
def test_bestroute_vectorized_matches_loop(threshold):
    """Regression pin: the one-argmax route() must reproduce the
    original per-request double loop over the price order."""
    from repro.core.routers import BestRouteRouter
    rng = np.random.default_rng(17)
    train = rng.normal(size=(300, 64)).astype(np.float32)
    Q = rng.uniform(size=(300, 4))
    L = rng.uniform(50, 500, (300, 4))
    prices = np.array([0.06, 0.07, 0.15, 0.40])
    br = BestRouteRouter(threshold=threshold).fit(train, Q, L, prices)
    emb = rng.normal(size=(80, 64)).astype(np.float32)
    got = br.route(emb)
    # reference: the pre-vectorization implementation
    q, _ = br._knn.query(emb)
    best = q.max(1, keepdims=True)
    spread = best - q.min(1, keepdims=True)
    ok = q >= best - (1.0 - br.t) * spread - 1e-12
    want = np.zeros(emb.shape[0], np.int64)
    for pos, r in enumerate(ok):
        for m in br.price_order:
            if r[m]:
                want[pos] = m
                break
    np.testing.assert_array_equal(got, want)


def test_hungarian_optimality_small():
    rng = np.random.default_rng(3)
    for _ in range(5):
        C = rng.uniform(0, 1, (4, 5))
        a = hungarian(C)
        best = None
        import itertools
        for p in itertools.permutations(range(5), 4):
            v = sum(C[i, p[i]] for i in range(4))
            best = v if best is None else min(best, v)
        got = sum(C[i, a[i]] for i in range(4))
        assert abs(got - best) < 1e-9
        assert len(set(a.tolist())) == 4   # injective
