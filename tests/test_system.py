"""End-to-end behaviour tests for the paper's system: the fused
scheduler's headline properties on a small world."""
import numpy as np
import pytest

from repro.core import (EstimatorBundle, PRESETS, PipelineConfig,
                        PipelineScheduler, RBConfig, RouteBalance,
                        make_requests, run_cell)
from repro.core.dispatchers import ShortestQueue
from repro.core.routers import PassthroughRouter
from repro.serving.tiers import paper_pool_tiers
from repro.serving.workload import poisson_arrivals
from repro.serving.world import build_dataset, paper_world


@pytest.fixture(scope="module")
def ctx():
    world, names = paper_world(seed=0)
    ds = build_dataset(world, n=1500)
    tiers = paper_pool_tiers()
    bundle = EstimatorBundle.train(ds, tiers, names)
    return dict(names=names, ds=ds, tiers=tiers, bundle=bundle)


def _run(ctx, sched, lam=10.0, n=200, seed=0):
    arr = poisson_arrivals(lam, n, seed=seed)
    reqs = make_requests(ctx["ds"], "test", arr)
    return run_cell(sched, ctx["tiers"], ctx["names"], reqs)


def test_fused_pareto_dominates_load_only(ctx):
    """A load-only balancer is Pareto-dominated: some point of the
    RouteBalance weight family matches its quality at lower-or-equal
    latency and cost, or beats its quality outright (§1, Fig 5)."""
    lb = _run(ctx, PipelineScheduler(
        PassthroughRouter(), ShortestQueue(), ctx["bundle"], ctx["tiers"],
        PipelineConfig(deployment="concurrent")))
    dominated = False
    for w in (PRESETS["uniform"], (0.55, 0.25, 0.2), PRESETS["quality"]):
        rb = _run(ctx, RouteBalance(RBConfig(weights=w), ctx["bundle"],
                                    ctx["tiers"]))
        if (rb["quality"] >= lb["quality"] - 0.005
                and rb["mean_e2e"] <= lb["mean_e2e"] * 1.10):
            dominated = True
            break
    assert dominated, (lb["quality"], lb["mean_e2e"])


def test_weight_vector_traces_frontier(ctx):
    """Turning only the weight vector spans cost -> quality (§6.2)."""
    qs, costs = [], []
    for w in (PRESETS["cost"], PRESETS["uniform"], PRESETS["quality"]):
        m = _run(ctx, RouteBalance(RBConfig(weights=w), ctx["bundle"],
                                   ctx["tiers"]))
        qs.append(m["quality"])
        costs.append(m["cost_per_req"])
    assert qs[0] <= qs[1] <= qs[2] + 1e-9
    assert costs[0] <= costs[2]


def test_latency_term_shifts_mix_off_slow_tier(ctx):
    """Pricing latency at model-selection time steers traffic off the
    slowest tier (§6.3 arm1 vs arm2)."""
    full = _run(ctx, RouteBalance(RBConfig(latency_mode="full"),
                                  ctx["bundle"], ctx["tiers"]))
    off = _run(ctx, RouteBalance(RBConfig(latency_mode="off_reactive"),
                                 ctx["bundle"], ctx["tiers"]))
    share = lambda m, tag: sum(v for k, v in m["mix"].items() if tag in k)
    assert share(full, "72b") <= share(off, "72b") + 1e-9
    assert full["mean_e2e"] <= off["mean_e2e"] * 1.10


def test_static_prior_close_to_full(ctx):
    """Arm 4: a static per-tier prior nearly reproduces the full
    objective — the learned predictor is not load-bearing (§6.3)."""
    full = _run(ctx, RouteBalance(RBConfig(latency_mode="full"),
                                  ctx["bundle"], ctx["tiers"]))
    prior = _run(ctx, RouteBalance(RBConfig(latency_mode="static_prior"),
                                   ctx["bundle"], ctx["tiers"]))
    assert abs(prior["quality"] - full["quality"]) < 0.05
    assert prior["mean_e2e"] < full["mean_e2e"] * 1.6


def test_deterministic_given_seed(ctx):
    m1 = _run(ctx, RouteBalance(RBConfig(charge_compute=False),
                                ctx["bundle"], ctx["tiers"]), seed=3)
    m2 = _run(ctx, RouteBalance(RBConfig(charge_compute=False),
                                ctx["bundle"], ctx["tiers"]), seed=3)
    assert m1["quality"] == m2["quality"]
    assert m1["cost_per_req"] == m2["cost_per_req"]
