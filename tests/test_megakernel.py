"""Differential + structural harness for the Pallas decision
megakernel (`repro.kernels.decision_megakernel`).

Three layers, mirroring how the fused backend itself graduated:

  * kernel-level: `decision_call` against the pure-numpy full-pipeline
    oracle (`repro.kernels.ref.decision_ref`) on synthetic worlds —
    multi-window, pad rows, dead instances, budgets, GBM on/off;
  * backend-level: ``decision_backend="megakernel"`` through
    `RouteBalance` must make bitwise the fused-XLA program's
    assignments (and l_chosen, and the post-scan dead-reckoned state)
    across the full mode grid, awkward batch sizes, dead rosters and
    the prefix-affinity arm;
  * plumbing-level: multi-window coalescing equals K separate
    dispatches, compile variants stay pinned at the pow2 buckets
    through roster churn, and the `REPRO_PALLAS_INTERPRET` env toggle
    parses as documented.
"""
import numpy as np
import pytest

from repro.core import PRESETS, RBConfig, RouteBalance, make_requests, \
    run_cell
from repro.core.decision_jax import bucket_pow2
from repro.core.engine import BatchView
from repro.core.scheduler import RouteBalancePolicy
from repro.serving.cluster import ClusterSim

MODES = ("full", "off_reactive", "off_predictive", "static_prior")


def _loaded_sim(ctx, seed=9):
    from repro.serving.scenarios import randomize_telemetry
    return randomize_telemetry(
        ClusterSim(ctx["tiers"], ctx["names"], seed=0), seed)


def _batch(ctx, R=24, seed=5, with_budgets=True):
    reqs = make_requests(ctx["ds"], "test", np.zeros(R))
    if with_budgets:
        rng = np.random.default_rng(seed)
        budgets = np.where(rng.uniform(size=R) < 0.5,
                           rng.uniform(1e-5, 3e-4, R), np.nan)
        for r, b in zip(reqs, budgets):
            r.budget = None if np.isnan(b) else float(b)
    return reqs


def _choices(ctx, backend, batch, **cfg_kw):
    rb = RouteBalance(RBConfig(decision_backend=backend, **cfg_kw),
                      ctx["bundle"], ctx["tiers"])
    rb.sim = _loaded_sim(ctx)
    instances, choice, l_chosen = rb._decide_core(batch)
    return ([instances[int(i)].iid for i in choice],
            np.asarray(l_chosen), rb)


# -- backend-level: the 16-combo mode grid ------------------------------------

@pytest.mark.parametrize("lpt", [True, False], ids=["lpt", "fifo"])
@pytest.mark.parametrize("budget_filter", [True, False],
                         ids=["budget", "nobudget"])
@pytest.mark.parametrize("mode", MODES)
def test_megakernel_exact_assignment_parity(small_ctx, mode,
                                            budget_filter, lpt):
    """Every latency mode x budget filter x LPT combo: the megakernel
    makes bitwise the fused-XLA program's assignments AND l_chosen (both
    are float32 tracing the same shared stage math), and matches the
    float64 numpy reference loop's assignments exactly."""
    batch = _batch(small_ctx, with_budgets=budget_filter)
    kw = dict(latency_mode=mode, budget_filter=budget_filter, lpt=lpt)
    ids_np, _, _ = _choices(small_ctx, "numpy", batch, **kw)
    ids_fu, l_fu, _ = _choices(small_ctx, "fused", batch, **kw)
    ids_mk, l_mk, _ = _choices(small_ctx, "megakernel", batch, **kw)
    assert ids_mk == ids_fu == ids_np
    np.testing.assert_array_equal(l_mk, l_fu)


def test_megakernel_poststate_bitwise_matches_fused(small_ctx):
    """The in-kernel fori_loop's dead-reckoned carry (d1, b1, f1) must
    come back bitwise the fused lax.scan's — same greedy_step body,
    same float32 accumulation order — pow2 roster pads included."""
    batch = _batch(small_ctx, R=13)
    out = {}
    for be in ("fused", "megakernel"):
        _, _, rb = _choices(small_ctx, be, batch)
        out[be] = tuple(np.asarray(x) for x in rb._fused._post_state)
        # carried mirror too: both backends reseed from telemetry
        out[be + "_mirror"] = tuple(np.asarray(x)
                                    for x in rb._fused._state)
    for a, b in zip(out["fused"], out["megakernel"]):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(out["fused_mirror"], out["megakernel_mirror"]):
        np.testing.assert_array_equal(a, b)


def test_megakernel_batch_bucketing_parity(small_ctx):
    """Pad rows (R buckets to pow2) must not leak into real assignments
    for any awkward batch size."""
    for R in (1, 3, 7, 13, 33):
        batch = _batch(small_ctx, R=R, seed=R)
        ids_fu, l_fu, _ = _choices(small_ctx, "fused", batch)
        ids_mk, l_mk, _ = _choices(small_ctx, "megakernel", batch)
        assert ids_mk == ids_fu, f"R={R}"
        np.testing.assert_array_equal(l_mk, l_fu, err_msg=f"R={R}")


def test_megakernel_masks_dead_instances(small_ctx):
    batch = _batch(small_ctx, R=16)
    dead = None
    out = {}
    for be in ("fused", "megakernel"):
        rb = RouteBalance(RBConfig(decision_backend=be),
                          small_ctx["bundle"], small_ctx["tiers"])
        rb.sim = _loaded_sim(small_ctx)
        if dead is None:
            dead = [i.iid for i in rb.sim.instances if "72b" in i.iid]
        for iid in dead:
            rb.sim.by_id[iid].fail()
        instances, choice, _ = rb._decide_core(batch)
        out[be] = [instances[int(i)].iid for i in choice]
    assert out["megakernel"] == out["fused"]
    assert not any(iid in dead for iid in out["megakernel"])


def test_megakernel_affinity_parity(small_ctx):
    """Prefix-affinity live (w=0.35): warmed sketches, in-kernel
    hit_fraction must stay bitwise the fused program's."""
    from repro.serving.request import RequestColumns
    from repro.serving.scenarios import randomize_prefix_state
    batch = _batch(small_ctx, R=20, with_budgets=False)
    cols, _ = RequestColumns.for_batch(batch,
                                       small_ctx["bundle"].encoder)
    out = {}
    for be in ("fused", "megakernel"):
        rb = RouteBalance(RBConfig(decision_backend=be,
                                   affinity_weight=0.35),
                          small_ctx["bundle"], small_ctx["tiers"])
        sim = _loaded_sim(small_ctx)
        randomize_prefix_state(sim, cols, 3)
        rb.sim = sim
        instances, choice, l_chosen = rb._decide_core(batch)
        out[be] = ([instances[int(i)].iid for i in choice],
                   np.asarray(l_chosen))
    assert out["megakernel"][0] == out["fused"][0]
    np.testing.assert_array_equal(out["megakernel"][1], out["fused"][1])


def test_megakernel_e2e_cluster_trajectory(small_ctx):
    """A full ClusterSim run lands on the identical request->instance
    trajectory under fused and megakernel."""
    from repro.serving.workload import poisson_arrivals
    results = {}
    for be in ("fused", "megakernel"):
        arr = poisson_arrivals(10.0, 40, seed=3)
        reqs = make_requests(small_ctx["ds"], "test", arr)
        rb = RouteBalance(RBConfig(decision_backend=be,
                                   charge_compute=False),
                          small_ctx["bundle"], small_ctx["tiers"])
        m = run_cell(rb, small_ctx["tiers"], small_ctx["names"], reqs)
        results[be] = ([r.instance for r in reqs], m)
    assert results["megakernel"][0] == results["fused"][0]
    for k in ("quality", "mean_e2e", "cost_per_req"):
        assert results["megakernel"][1][k] == pytest.approx(
            results["fused"][1][k], rel=1e-12)


# -- plumbing: multi-window coalescing + compile pinning ----------------------

def _policy(ctx, sim, **cfg_kw):
    pol = RouteBalancePolicy(RBConfig(decision_backend="megakernel",
                                      **cfg_kw))
    pol.prepare(ctx["bundle"], ctx["tiers"])
    pol.on_attach(sim)
    return pol


def test_multi_window_matches_separate_dispatches(small_ctx):
    """K windows through ONE kernel dispatch (assign_windows ->
    decide_cols_multi, grid=(K,)) must be bitwise K separate assign
    calls against the same telemetry snapshot — including ragged window
    sizes that share a pow2 row bucket."""
    sim = _loaded_sim(small_ctx)
    reqs = _batch(small_ctx, R=42, seed=11)
    cuts = [reqs[0:12], reqs[12:24], reqs[24:35], reqs[35:42]]
    views = [BatchView(c) for c in cuts]
    pol = _policy(small_ctx, sim, window_coalesce=4)
    multi = [r.fetch() for r in pol.assign_windows(views, sim)]
    assert pol._fused.stats.get("multi_dispatch") == 1
    single = _policy(small_ctx, sim)
    sep = [single.assign(v, sim).fetch() for v in views]
    for (cm, lm), (cs, ls) in zip(multi, sep):
        np.testing.assert_array_equal(cm, cs)
        np.testing.assert_array_equal(lm, ls)


def test_assign_windows_falls_back_per_window(small_ctx):
    """Non-megakernel backends (and K == 1) route through plain
    per-window assign — coalescing is a megakernel capability, not a
    semantic fork."""
    sim = _loaded_sim(small_ctx)
    reqs = _batch(small_ctx, R=16, seed=2)
    views = [BatchView(reqs[:8]), BatchView(reqs[8:])]
    pol = RouteBalancePolicy(RBConfig(decision_backend="fused"))
    pol.prepare(small_ctx["bundle"], small_ctx["tiers"])
    pol.on_attach(sim)
    coal = [r.fetch() for r in pol.assign_windows(views, sim)]
    sep = [pol.assign(v, sim).fetch() for v in views]
    for (cm, lm), (cs, ls) in zip(coal, sep):
        np.testing.assert_array_equal(cm, cs)
        np.testing.assert_array_equal(lm, ls)


def test_window_coalesce_needs_megakernel():
    with pytest.raises(AssertionError):
        RouteBalancePolicy(RBConfig(decision_backend="fused",
                                    window_coalesce=4))


def test_megakernel_compile_variants_pinned(small_ctx):
    """Compile count stays O(log R) + O(log K x log R) through batch
    sizes, roster churn (fail/recover flips the alive mask, no
    recompile) and repeated dispatches. A non-default weights preset
    gives this test its own `for_bundle` cache slot — the session-scoped
    bundle shares compiled runners across tests, and jit caches survive
    `reset()` by design."""
    sim = _loaded_sim(small_ctx)
    pol = _policy(small_ctx, sim, weights=PRESETS["quality"])
    for R in (1, 3, 7, 13, 33, 13, 7):       # buckets: {8, 16, 64}
        pol.assign(BatchView(_batch(small_ctx, R=R, seed=R)),
                   sim).fetch()
    sim.instances[0].fail()                  # roster churn: alive mask
    pol.assign(BatchView(_batch(small_ctx, R=7)), sim).fetch()
    sim.instances[0].recover(t=1.0)
    pol.assign(BatchView(_batch(small_ctx, R=7)), sim).fetch()
    assert pol._fused.compile_count() == 3   # {8, 16, 64}, single-window
    reqs = _batch(small_ctx, R=24, seed=7)
    for cut in ([reqs[:8], reqs[8:16]],                    # K=2 -> Kb 2
                [reqs[:8], reqs[8:16], reqs[16:24]],       # K=3 -> Kb 4
                [reqs[:6], reqs[6:12], reqs[12:18], reqs[18:24]]):
        pol.assign_windows([BatchView(c) for c in cut], sim)
    # + two (Kb, Rb) multi variants: (2, 8) and (4, 8)
    assert pol._fused.compile_count() == 5


# -- kernel-level: decision_call vs the numpy oracle --------------------------

def _toy_world(seed=0, K=2, R=6, E=8, N=40, M=3, I=5, T=2, k=4):
    rng = np.random.default_rng(seed)
    f32 = np.float32
    emb = rng.normal(size=(K, R, E)).astype(f32)
    rv = np.ones((K, R), bool)
    rv[:, R - 1] = False                      # one pad row per window
    budgets = np.where(rng.uniform(size=(K, R)) < 0.5,
                       rng.uniform(1e-5, 3e-4, (K, R)), np.nan
                       ).astype(f32)
    len_in = rng.integers(8, 200, (K, R)).astype(f32)
    x = rng.normal(size=(N, E)).astype(f32)
    args = dict(
        emb=emb, row_valid=rv, budgets=budgets, len_in=len_in,
        psig=np.zeros((K, 1, 1), np.int32),
        d=rng.uniform(0, 300, I).astype(f32),
        b=rng.integers(1, 6, I).astype(f32),
        free=rng.integers(0, 4, I).astype(f32),
        ctx=rng.uniform(64, 900, I).astype(f32),
        alive=np.array([True] * (I - 1) + [False]),
        x=x, xsq=(x * x).sum(1).astype(f32),
        qual=rng.uniform(0, 1, (N, M)).astype(f32),
        leng=rng.uniform(20, 400, (N, M)).astype(f32),
        m_of_i=rng.integers(0, M, I).astype(np.int32),
        tier_of_i=(np.arange(I) % T).astype(np.int32),
        maxb=np.full(I, 8.0, f32),
        price_in=rng.uniform(1e-7, 1e-6, I).astype(f32),
        price_out=rng.uniform(1e-6, 1e-5, I).astype(f32),
        nominal=rng.uniform(0.01, 0.06, I).astype(f32),
        sig_plane=np.zeros((1, 1), np.int32))
    statics = dict(k=k, eps=1e-3, weights=PRESETS["uniform"],
                   latency_mode="full", lpt=True, budget_filter=True,
                   w_aff=0.0)
    return args, statics


@pytest.mark.parametrize("use_gbm", [False, True], ids=["nominal", "gbm"])
def test_decision_call_matches_numpy_oracle(use_gbm):
    """The kernel pipeline (interpret mode) against the pure-numpy
    full-pipeline oracle: exact assignments, latencies and dead-reckoned
    state to float tolerance — multi-window, pad rows, one dead
    instance, nan/finite budgets, GBM on and off."""
    from repro.kernels.ops import decision_megakernel as mk_op
    from repro.kernels.ref import decision_ref
    args, statics = _toy_world()
    if use_gbm:
        from repro.estimators.gbm import GradientBoostedRegressor, \
            pack_ensemble
        rng = np.random.default_rng(5)
        models = []
        for s in range(2):                    # T=2 tiers
            X = rng.uniform(0, 900, (200, 4)).astype(np.float32)
            y = (0.02 + 1e-5 * X[:, 1] + 1e-4 * X[:, 0]
                 ).astype(np.float32)
            models.append(GradientBoostedRegressor(
                n_trees=8, depth=2).fit(X, y))
        stacked = pack_ensemble(models)
        gbm_ref = stacked
        gfeat, gthr, gleaf, gbase = (stacked["feature"],
                                     stacked["threshold"],
                                     stacked["leaf"], stacked["base"])
        depth, lr = stacked["depth"], stacked["lr"]
    else:
        from repro.kernels.decision_megakernel import dummy_gbm
        gbm_ref = None
        gfeat, gthr, gleaf, gbase = dummy_gbm()
        depth, lr = 1, 0.1
    ref = decision_ref(*args.values(), gbm=gbm_ref, **statics)
    got = mk_op(*args.values(), gfeat, gthr, gleaf, gbase, **statics,
                use_gbm=use_gbm, depth=depth, lr=lr)
    np.testing.assert_array_equal(np.asarray(got[0]), ref[0])  # choice
    for g, r in zip(got[1:], ref[1:]):
        np.testing.assert_allclose(np.asarray(g), r, rtol=2e-5,
                                   atol=1e-7)
    # dead instance never chosen
    assert not np.any(np.asarray(got[0]) == len(args["d"]) - 1)


def test_decision_call_topk_modes_bitwise_equal():
    """topk_mode="running" (the Mosaic-lowerable TPU form) and
    topk_mode="topk" (the interpret-mode fast path) must produce
    bitwise-identical decisions end to end — survivor set, order, and
    every downstream float32 sum."""
    from repro.kernels.ops import decision_megakernel as mk_op
    from repro.kernels.decision_megakernel import dummy_gbm
    args, statics = _toy_world(seed=3)
    gfeat, gthr, gleaf, gbase = dummy_gbm()
    out = {}
    for mode in ("topk", "running"):
        out[mode] = mk_op(*args.values(), gfeat, gthr, gleaf, gbase,
                          **statics, use_gbm=False, depth=1, lr=0.1,
                          topk_mode=mode, knn_tile=16)
    for a, b in zip(out["topk"], out["running"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_topk_running_matches_lax_topk_order():
    """The in-kernel running-top-k must reproduce lax.top_k's exact
    neighbor ORDER (stable sort by (distance, index)) — the label-mix
    sums are order-sensitive in float32."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.decision_megakernel import _topk_running
    rng = np.random.default_rng(0)
    d2 = rng.uniform(0, 10, (32, 600)).astype(np.float32)
    d2[:, 100] = d2[:, 50]                   # force exact ties
    d2[:, 401] = d2[:, 400]
    vals, idx = _topk_running(jnp.asarray(d2), 10, tile=256)
    neg, ridx = jax.lax.top_k(-jnp.asarray(d2), 10)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(-neg))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


# -- env toggle ---------------------------------------------------------------

def test_env_interpret_toggle(monkeypatch):
    from repro.kernels.ops import env_interpret
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert env_interpret() is True            # container default
    assert env_interpret(default=False) is False
    for off in ("0", "false", "OFF", ""):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", off)
        assert env_interpret() is False, off
    for on in ("1", "true", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", on)
        assert env_interpret() is True, on
