"""Per-architecture smoke tests: a REDUCED same-family config runs one
forward/train step on CPU with correct shapes and no NaNs (brief
requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, list_archs, smoke_variant
from repro.models import Model
from repro.training.data import batch_for

# arch-zoo training smokes are the heaviest module in the suite (~2 min)
# and independent of the scheduler hot path — slow tier (`-m slow`)
pytestmark = pytest.mark.slow

ALL = list_archs()


@pytest.mark.parametrize("arch", ALL)
def test_train_step_smoke(arch):
    cfg = smoke_variant(ARCHS[arch])
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = batch_for(cfg, seq_len=32, global_batch=2, seed=1)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    loss, mets = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda x: jnp.sum(jnp.square(
            x.astype(jnp.float32))), grads))
    assert bool(jnp.isfinite(gn)), f"{arch} grads not finite"
    assert float(gn) > 0.0


@pytest.mark.parametrize("arch", ["granite-3-2b", "mixtral-8x7b",
                                  "mamba2-1.3b", "recurrentgemma-2b",
                                  "whisper-tiny"])
def test_prefill_decode_shapes(arch):
    cfg = smoke_variant(ARCHS[arch])
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, S0 = 2, 8
    toks = jnp.ones((B, S0), jnp.int32)
    if cfg.is_encdec:
        frames = jnp.zeros((B, 16, cfg.frontend_dim), jnp.float32)
        logits, cache = model.prefill(params, {"frames": frames,
                                               "tokens": toks})
    else:
        logits, cache = model.prefill(params, {"tokens": toks},
                                      pad_to=S0 + 4)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache = model.decode(params, cache, nxt)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_param_counts_match_pool_card():
    # total params should be within tolerance of the pool card's sizing
    expect = {"granite-3-2b": 2.5e9, "phi3-mini-3.8b": 3.8e9,
              "gemma3-27b": 27e9, "mixtral-8x7b": 46.7e9,
              "mamba2-1.3b": 1.3e9}
    for arch, n in expect.items():
        got = ARCHS[arch].param_counts()["total"]
        assert abs(got - n) / n < 0.12, (arch, got)


def test_moe_active_params():
    pc = ARCHS["granite-moe-3b-a800m"].param_counts()
    assert pc["total"] > 3.0e9
    assert 0.7e9 < pc["active"] < 1.1e9
